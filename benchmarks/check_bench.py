"""Perf-trajectory gate: compare a fresh ``--quick`` bench run against the
committed BENCH_*.json baselines at the repo root.

Fails (exit 1) when a tracked *speedup ratio* (machine-relative, robust
across runner hardware) collapsed below its floor. Absolute latencies
exceeding ``--factor`` x the committed baseline (default 2x) are reported as
warnings — they compare across machines (baselines come from a dev box, CI
runs on shared runners) — and gate only under ``--strict-latency``
(same-machine runs, e.g. refreshing the baselines locally):

* ``BENCH_device.json``   — per dataset×relation ``refine_scan_us`` vs the
  baseline, plus ``speedup_cluster`` (fused refinement vs the legacy argsort
  pipeline at cap=4096 / budget=256) staying >= ``--min-refine-speedup``.
* ``BENCH_maintenance.json`` — ``speedup_vs_republish`` (delta patching vs
  republish-per-epoch) staying >= ``--min-maint-speedup``.

Usage (CI bench-smoke job)::

    python -m benchmarks.run --quick --bench-dir /tmp/bench_fresh
    python -m benchmarks.check_bench /tmp/bench_fresh
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _load(path: pathlib.Path) -> dict:
    if not path.exists():
        sys.exit(f"check_bench: missing {path}")
    return json.loads(path.read_text())


def check(fresh_dir: pathlib.Path, committed_dir: pathlib.Path,
          factor: float, min_refine_speedup: float,
          min_maint_speedup: float, strict_latency: bool = False) -> list:
    errors = []

    dev_new = _load(fresh_dir / "BENCH_device.json")
    dev_old = _load(committed_dir / "BENCH_device.json")
    for ds, rels in dev_old.get("datasets", {}).items():
        for rel, row in rels.items():
            new_row = dev_new.get("datasets", {}).get(ds, {}).get(rel)
            if new_row is None:
                errors.append(f"device: {ds}/{rel} missing from fresh run")
                continue
            old_us, new_us = row["refine_scan_us"], new_row["refine_scan_us"]
            if new_us > factor * old_us:
                # absolute wall-clock comparisons cross machines (baselines
                # are committed from a dev box, CI runs on shared runners):
                # advisory by default, a hard gate only under
                # --strict-latency. The machine-relative speedup floors
                # below are always hard.
                msg = (f"device: {ds}/{rel} refine {new_us:.0f}us > "
                       f"{factor:g}x baseline {old_us:.0f}us")
                if strict_latency:
                    errors.append(msg)
                else:
                    print(f"WARNING {msg} (cross-machine; not gating — "
                          "pass --strict-latency to enforce)")
    sc = dev_new.get("speedup_cluster", 0.0)
    if sc < min_refine_speedup:
        errors.append(
            f"device: fused-refine speedup on cluster x{sc:.2f} < floor "
            f"x{min_refine_speedup:g} (committed x"
            f"{dev_old.get('speedup_cluster', 0):.2f})")

    mnt_new = _load(fresh_dir / "BENCH_maintenance.json")
    sv = mnt_new.get("speedup_vs_republish", 0.0)
    if sv < min_maint_speedup:
        errors.append(
            f"maintenance: delta-patch speedup x{sv:.2f} < floor "
            f"x{min_maint_speedup:g}")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh_dir", type=pathlib.Path,
                    help="directory holding the fresh --quick BENCH_*.json")
    ap.add_argument("--committed", type=pathlib.Path, default=REPO_ROOT,
                    help="directory holding the committed baselines")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max tolerated latency regression factor")
    ap.add_argument("--min-refine-speedup", type=float, default=1.2)
    ap.add_argument("--min-maint-speedup", type=float, default=1.5)
    ap.add_argument("--strict-latency", action="store_true",
                    help="gate on absolute latency too (same-machine runs)")
    args = ap.parse_args()
    errors = check(args.fresh_dir, args.committed, args.factor,
                   args.min_refine_speedup, args.min_maint_speedup,
                   strict_latency=args.strict_latency)
    for e in errors:
        print(f"REGRESSION {e}")
    if errors:
        sys.exit(1)
    print("check_bench: perf trajectory OK")


if __name__ == "__main__":
    main()
