"""Perf-trajectory gate: compare a fresh ``--quick`` bench run against the
committed BENCH_*.json baselines at the repo root.

Fails (exit 1) when a tracked *speedup ratio* (machine-relative, robust
across runner hardware) collapsed below its floor. Absolute latencies
exceeding ``--factor`` x the committed baseline (default 2x) are reported as
warnings — they compare across machines (baselines come from a dev box, CI
runs on shared runners) — and gate only under ``--strict-latency``
(same-machine runs, e.g. refreshing the baselines locally):

* ``BENCH_device.json``   — per dataset×relation ``refine_scan_us`` vs the
  baseline, ``speedup_cluster`` (two-stage refinement vs the legacy argsort
  pipeline at cap=4096 / budget=256) staying >= ``--min-refine-speedup``,
  ``speedup_fused_cluster`` (the one-dispatch fused path vs the staged
  scan pipeline) staying >= ``--min-fused-speedup``, and the ``knn`` row:
  the device-complete knn batch staying >= ``--min-knn-speedup`` x faster
  than the host-ranked rung ladder it replaced (both measured fresh in the
  same run), exact vs the fp64 brute-force oracle, with the CDF-seeded
  median rung depth <= 2 (the radius model still lands within one
  doubling). Columns a row lists in
  its ``"unmeasured"`` marker (e.g. the Pallas kernel timings off-TPU) are
  warned about, never gated — the backend they need is absent, not slow.
* ``BENCH_maintenance.json`` — ``speedup_vs_republish`` (delta patching vs
  republish-per-epoch) staying >= ``--min-maint-speedup``, and the async
  double-buffering gate: query p50 WHILE a snapshot republish is in flight
  must stay within ``--max-republish-p50-ratio`` of steady-state p50
  (``republish.p50_ratio`` — the stream used to block for the full rebuild).
* ``BENCH_sharded.json``  — fused-vs-dense per-shard refinement speedup on
  the host-device CPU mesh staying >= ``--min-sharded-speedup`` on EVERY
  tracked dataset x relation x mesh cell (``min_speedup``), plus the knn
  tier on every fresh mesh: present, exact vs the fp64 host loop, and
  actually moving cross-shard merge bytes (a zero would mean the k-merge
  silently fell back to a host merge).
* ``BENCH_serving.json``  — the serving tier's max sustainable QPS under
  the p99 SLO staying >= ``--min-serving-qps-ratio`` x the serial-flush
  baseline's (``qps_ratio``, both measured fresh on the same host against
  the same machine-relative SLO), the exactness flag from the in-run oracle
  checks, and a percentile sanity check (p999 present and
  p999 >= p99 >= p50 on every tier of every config — a harness that stops
  reporting the tail would otherwise pass the ratio gate vacuously). The
  Zipf hot-query tier is gated too: it must stay exact and its repeats must
  surface as cache hits and/or coalesced duplicates.
* ``BENCH_storage.json``  — the CSR vertex-pool store's bytes on the
  heavy-tailed ``mixed`` dataset staying >= ``--min-storage-ratio`` x
  smaller than the dense ``(N, maxV, 2)`` padding would cost (size-based,
  so this one is machine-independent and always hard).

Usage (CI bench-smoke job)::

    python -m benchmarks.run --quick --bench-dir /tmp/bench_fresh
    python -m benchmarks.check_bench /tmp/bench_fresh
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _load(path: pathlib.Path) -> dict:
    if not path.exists():
        sys.exit(f"check_bench: missing {path}")
    return json.loads(path.read_text())


def check(fresh_dir: pathlib.Path, committed_dir: pathlib.Path,
          factor: float, min_refine_speedup: float,
          min_maint_speedup: float, strict_latency: bool = False,
          min_sharded_speedup: float = 1.2,
          min_fused_speedup: float = 1.2,
          min_knn_speedup: float = 1.2,
          max_republish_p50_ratio: float = 4.0,
          min_serving_qps_ratio: float = 1.05,
          min_storage_ratio: float = 2.0) -> list:
    errors = []

    dev_new = _load(fresh_dir / "BENCH_device.json")
    dev_old = _load(committed_dir / "BENCH_device.json")
    for ds, rels in dev_old.get("datasets", {}).items():
        for rel, row in rels.items():
            new_row = dev_new.get("datasets", {}).get(ds, {}).get(rel)
            if new_row is None:
                errors.append(f"device: {ds}/{rel} missing from fresh run")
                continue
            # columns declared unmeasured on the fresh run's backend (e.g.
            # the Pallas kernel timings off-TPU): warn, never gate
            for col in new_row.get("unmeasured", []):
                print(f"WARNING device: {ds}/{rel} column {col!r} unmeasured "
                      f"on backend {dev_new.get('backend', '?')!r} (null in "
                      "the fresh run; not gating)")
            old_us, new_us = row["refine_scan_us"], new_row["refine_scan_us"]
            if new_us > factor * old_us:
                # absolute wall-clock comparisons cross machines (baselines
                # are committed from a dev box, CI runs on shared runners):
                # advisory by default, a hard gate only under
                # --strict-latency. The machine-relative speedup floors
                # below are always hard.
                msg = (f"device: {ds}/{rel} refine {new_us:.0f}us > "
                       f"{factor:g}x baseline {old_us:.0f}us")
                if strict_latency:
                    errors.append(msg)
                else:
                    print(f"WARNING {msg} (cross-machine; not gating — "
                          "pass --strict-latency to enforce)")
    sc = dev_new.get("speedup_cluster", 0.0)
    if sc < min_refine_speedup:
        errors.append(
            f"device: two-stage refine speedup on cluster x{sc:.2f} < floor "
            f"x{min_refine_speedup:g} (committed x"
            f"{dev_old.get('speedup_cluster', 0):.2f})")
    sf = dev_new.get("speedup_fused_cluster", 0.0)
    if sf < min_fused_speedup:
        errors.append(
            f"device: one-dispatch fused speedup on cluster x{sf:.2f} < "
            f"floor x{min_fused_speedup:g} (committed x"
            f"{dev_old.get('speedup_fused_cluster', 0):.2f})")
    knn = dev_new.get("knn")
    if not knn:
        errors.append("device: knn row missing from fresh run")
    else:
        sk = knn.get("speedup_knn", 0.0)
        if sk < min_knn_speedup:
            errors.append(
                f"device: device-complete knn x{sk:.2f} < floor "
                f"x{min_knn_speedup:g} vs the host-ranked rung ladder "
                f"(committed x"
                f"{dev_old.get('knn', {}).get('speedup_knn', 0):.2f})")
        if not knn.get("exact", False):
            errors.append("device: knn exactness flag missing/false")
        rm = knn.get("rungs_median_seeded")
        if rm is None or rm > 2.0:
            errors.append(
                f"device: CDF-seeded knn median rung depth {rm} > 2 — "
                "the radius model no longer lands within one doubling "
                f"(blind baseline: {knn.get('rungs_median_blind')})")

    mnt_new = _load(fresh_dir / "BENCH_maintenance.json")
    sv = mnt_new.get("speedup_vs_republish", 0.0)
    if sv < min_maint_speedup:
        errors.append(
            f"maintenance: delta-patch speedup x{sv:.2f} < floor "
            f"x{min_maint_speedup:g}")
    rep = mnt_new.get("republish")
    if rep is None:
        errors.append("maintenance: republish section missing from fresh run")
    else:
        ratio = rep.get("p50_ratio", float("inf"))
        if ratio > max_republish_p50_ratio:
            errors.append(
                f"maintenance: query p50 during async republish x"
                f"{ratio:.2f} of steady-state > ceiling x"
                f"{max_republish_p50_ratio:g} (double-buffering regressed; "
                f"sync rebuild blocks {rep.get('sync_blocked_ms', 0):.0f}ms)")

    sh_new = _load(fresh_dir / "BENCH_sharded.json")
    sh_old = _load(committed_dir / "BENCH_sharded.json")
    for mesh, old_payload in sh_old.get("meshes", {}).items():
        new_payload = sh_new.get("meshes", {}).get(mesh)
        if new_payload is None:
            errors.append(f"sharded: {mesh}-way mesh missing from fresh run")
            continue
        for ds, rels in old_payload.get("datasets", {}).items():
            for rel, row in rels.items():
                new_row = new_payload.get("datasets", {}).get(ds, {}).get(rel)
                if new_row is None:
                    errors.append(
                        f"sharded: {mesh}-way {ds}/{rel} missing from "
                        "fresh run")
                    continue
                sp = new_row.get("speedup", 0.0)
                if sp < min_sharded_speedup:
                    errors.append(
                        f"sharded: {mesh}-way {ds}/{rel} fused-vs-dense "
                        f"x{sp:.2f} < floor x{min_sharded_speedup:g} "
                        f"(committed x{row.get('speedup', 0):.2f})")
                old_us, new_us = row.get("fused_us"), new_row.get("fused_us")
                if old_us and new_us and new_us > factor * old_us:
                    msg = (f"sharded: {mesh}-way {ds}/{rel} fused "
                           f"{new_us:.0f}us > {factor:g}x baseline "
                           f"{old_us:.0f}us")
                    if strict_latency:
                        errors.append(msg)
                    else:
                        print(f"WARNING {msg} (cross-machine; not gating — "
                              "pass --strict-latency to enforce)")
        sknn = new_payload.get("knn")
        if not sknn:
            errors.append(f"sharded: {mesh}-way knn tier missing from "
                          "fresh run")
        else:
            if not sknn.get("exact", False):
                errors.append(f"sharded: {mesh}-way knn exactness flag "
                              "missing/false")
            if sknn.get("merge_bytes", 0) <= 0:
                errors.append(
                    f"sharded: {mesh}-way knn moved no cross-shard merge "
                    "bytes — the k-merge fell back off the device")

    srv_new = _load(fresh_dir / "BENCH_serving.json")
    qr = srv_new.get("qps_ratio", 0.0)
    if qr < min_serving_qps_ratio:
        errors.append(
            f"serving: sustainable-QPS ratio x{qr:.2f} < floor "
            f"x{min_serving_qps_ratio:g} (serving tier no longer beats the "
            "serial-flush baseline under the p99 SLO)")
    if not srv_new.get("exact", False):
        errors.append("serving: in-run oracle exactness flag missing/false")
    for cname, cres in srv_new.get("configs", {}).items():
        tiers = cres.get("tiers", [])
        if not tiers:
            errors.append(f"serving: {cname} reported no tiers")
        for row in tiers:
            p50 = row.get("p50_ms")
            p99 = row.get("p99_ms")
            p999 = row.get("p999_ms")
            if p999 is None or p99 is None or p50 is None:
                errors.append(
                    f"serving: {cname}@{row.get('offered_qps', '?')}qps "
                    "missing a latency percentile (p50/p99/p999)")
            elif not (p999 >= p99 >= p50):
                errors.append(
                    f"serving: {cname}@{row.get('offered_qps', 0):.0f}qps "
                    f"percentiles not monotone (p50={p50:.1f} p99={p99:.1f} "
                    f"p999={p999:.1f}ms)")

    # the Zipf hot-query tier must exist, stay exact, and actually exercise
    # the fast paths it was built to cover: byte-identical repeats have to
    # show up as cache hits or coalesced duplicates (both zero would mean
    # the tier degenerated into a plain uniform load)
    zipf = srv_new.get("zipf")
    if not zipf:
        errors.append("serving: zipf hot-query tier missing")
    else:
        if not zipf.get("exact", False):
            errors.append("serving: zipf tier exactness flag missing/false")
        if zipf.get("completed", 0) < 0.98 * zipf.get("submitted", 1):
            errors.append("serving: zipf tier dropped arrivals "
                          f"({zipf.get('completed')}/{zipf.get('submitted')})")
        hits = zipf.get("cache_hits", 0)
        coal = zipf.get("coalesced", 0)
        if hits + coal <= 0:
            errors.append("serving: zipf tier produced no cache hits and no "
                          "coalesced duplicates — the skewed stream missed "
                          "the cache + coalescing path entirely")
        zp = [zipf.get("p50_ms"), zipf.get("p99_ms"), zipf.get("p999_ms")]
        if any(p is None for p in zp):
            errors.append("serving: zipf tier missing a latency percentile")
        elif not (zp[2] >= zp[1] >= zp[0]):
            errors.append(f"serving: zipf tier percentiles not monotone "
                          f"(p50={zp[0]:.1f} p99={zp[1]:.1f} "
                          f"p999={zp[2]:.1f}ms)")

    # storage overhead is size-based, hence machine-independent: the pooled
    # CSR layout must keep beating dense (N, maxV, 2) padding on the
    # heavy-tailed mixed family by at least the floor, on every tracked
    # dataset present in the committed baseline
    st_new = _load(fresh_dir / "BENCH_storage.json")
    st_old = _load(committed_dir / "BENCH_storage.json")
    sr = st_new.get("storage_ratio", 0.0)
    if sr < min_storage_ratio:
        errors.append(
            f"storage: dense/pooled ratio on mixed x{sr:.2f} < floor "
            f"x{min_storage_ratio:g} (committed "
            f"x{st_old.get('storage_ratio', 0):.2f}; the vertex pool no "
            "longer pays for itself)")
    for ds in st_old.get("datasets", {}):
        if ds not in st_new.get("datasets", {}):
            errors.append(f"storage: {ds} missing from fresh run")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh_dir", type=pathlib.Path,
                    help="directory holding the fresh --quick BENCH_*.json")
    ap.add_argument("--committed", type=pathlib.Path, default=REPO_ROOT,
                    help="directory holding the committed baselines")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max tolerated latency regression factor")
    ap.add_argument("--min-refine-speedup", type=float, default=1.2)
    ap.add_argument("--min-fused-speedup", type=float, default=1.2,
                    help="floor for the one-dispatch fused path vs the "
                         "staged scan pipeline on cluster/intersects "
                         "(machine-relative: both sides measured in the "
                         "same fresh run)")
    ap.add_argument("--min-knn-speedup", type=float, default=1.2,
                    help="floor for the device-complete knn batch vs the "
                         "host-ranked rung ladder on cluster "
                         "(machine-relative: both sides measured in the "
                         "same fresh run)")
    ap.add_argument("--min-maint-speedup", type=float, default=1.5)
    ap.add_argument("--min-sharded-speedup", type=float, default=1.2,
                    help="floor for fused-vs-dense sharded refinement on "
                         "every dataset x relation x mesh cell")
    ap.add_argument("--max-republish-p50-ratio", type=float, default=4.0,
                    help="ceiling for query p50 during an async republish "
                         "relative to steady-state p50. The design target "
                         "is 2x — measured ~1.2-1.7x on idle multi-core "
                         "hardware, but ~2-3x on a saturated 2-core host "
                         "(one core is all that is left for serving while "
                         "the niced builder crunches). The regression this "
                         "ceiling guards — the rebuild blocking the stream "
                         "again — shows up as a 10-30x spike, far above it.")
    ap.add_argument("--min-serving-qps-ratio", type=float, default=1.05,
                    help="floor for the serving tier's max sustainable QPS "
                         "under the p99 SLO relative to the serial-flush "
                         "baseline, both measured fresh on the same host "
                         "(machine-relative; ~1.25x on a single-core "
                         "runner from micro-batch amortisation alone, more "
                         "with real overlap parallelism)")
    ap.add_argument("--min-storage-ratio", type=float, default=2.0,
                    help="floor for the dense/pooled store-bytes ratio on "
                         "the heavy-tailed mixed dataset (size-based, "
                         "machine-independent)")
    ap.add_argument("--strict-latency", action="store_true",
                    help="gate on absolute latency too (same-machine runs)")
    args = ap.parse_args()
    errors = check(args.fresh_dir, args.committed, args.factor,
                   args.min_refine_speedup, args.min_maint_speedup,
                   strict_latency=args.strict_latency,
                   min_sharded_speedup=args.min_sharded_speedup,
                   min_fused_speedup=args.min_fused_speedup,
                   min_knn_speedup=args.min_knn_speedup,
                   max_republish_p50_ratio=args.max_republish_p50_ratio,
                   min_serving_qps_ratio=args.min_serving_qps_ratio,
                   min_storage_ratio=args.min_storage_ratio)
    for e in errors:
        print(f"REGRESSION {e}")
    if errors:
        sys.exit(1)
    print("check_bench: perf trajectory OK")


if __name__ == "__main__":
    main()
