"""Closed-/open-loop serving-tier load harness (the SLO gate for PR 6).

Drives ``repro.serve.SpatialQueryServer`` with an **open-loop Poisson
arrival process** (latency is measured from the *scheduled* arrival time, so
a falling-behind server cannot hide queueing delay — no coordinated
omission) over a mixed relation + write workload at fixed selectivity
tiers, and reports p50/p99/p999 versus offered QPS plus the **max
sustainable QPS under a p99 SLO** for two configurations:

* ``serial_flush`` — the pre-serving-tier usage: no dispatcher, every
  arrival is served by its own submit + ``flush()`` cycle with relation
  groups executing serially (what a production caller got before the pump
  loop existed);
* ``serving``      — the full tier: pump dispatcher with adaptive
  micro-batching, overlapped relation groups on the worker pool, replica
  fan-out, admission control.

``--large`` adds a third ``batched_serial`` ablation (pump dispatcher with
overlap + adaptive gather off) separating the micro-batching win from the
overlap/replica win.

After the serving ladder, a **Zipf hot-query tier** replays byte-identical
popular windows (bounded Zipf popularity, no per-arrival jitter) through the
live pump config: repeats land in the result cache or get coalesced inside a
micro-batch, and the tier reports the cache-hit / coalesced telemetry deltas
alongside its percentiles (``zipf`` in the BENCH record, gated by
``check_bench.py``).

Both configurations serve EVERY scheduled arrival (overload tiers pay the
backlog in latency, which is what busts the SLO), and exactness is asserted
against the host oracle through the serving path after every tier, with
writes quiesced (coordinates are fp32-clamped so host fp64 and device fp32
agree bit-for-bit).

The p99 SLO and the offered-QPS ladder are machine-relative: both derive
from a closed-loop calibration of the serial per-query service time, so the
gated ``qps_ratio`` (serving max QPS / serial-flush max QPS) is robust
across runner hardware.

    PYTHONPATH=src python -m benchmarks.bench_serving [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.datasets import generate, make_query_windows
from repro.core.engine import EngineConfig, SpatialIndex
from repro.core.geometry import mbrs_of_verts
from repro.core.index import GLINConfig
from repro.serve import Rejected, ServerConfig, SpatialQueryServer

from .common import Csv

SELECTIVITIES = (1e-5, 1e-4)
# Fractions of the calibrated serial peak. The ladder is fine-grained around
# 1x because the honest single-core win (micro-batch amortisation of the
# fixed per-flush cost) lands in the 1.2-2x band; the ladder must be able to
# resolve it. A config that fails two consecutive tiers exits the ladder
# early -- overload tiers serve every arrival, so they are the expensive ones.
TIER_FRACS = (0.5, 0.8, 1.0, 1.25, 1.4, 1.6, 2.1, 2.8)
CHECK_WINDOWS = 8


def _fp32_dataset(n: int, seed: int = 0):
    gs = generate("cluster", n, seed=seed)
    gs.verts = gs.verts.astype(np.float32).astype(np.float64)
    gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
    return gs


def _polygon(rng, nv: int = 8, r: float = 2e-4) -> np.ndarray:
    c = rng.uniform(0.15, 0.85, 2)
    ang = np.sort(rng.uniform(0, 2 * np.pi, nv))
    v = np.stack([c[0] + r * np.cos(ang), c[1] + r * np.sin(ang)], -1)
    return v.astype(np.float32).astype(np.float64)


def _build_index(n: int) -> SpatialIndex:
    gs = _fp32_dataset(n)
    return SpatialIndex.build(
        gs, GLINConfig(piece_limitation=10_000),
        EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1,
                     exact_budget=1024, async_republish=True))


def _window_pool(idx: SpatialIndex, per_sel: int) -> np.ndarray:
    pools = [make_query_windows(idx.gs, sel, per_sel, seed=2 + i)
             for i, sel in enumerate(SELECTIVITIES)]
    return np.concatenate(pools).astype(np.float32).astype(np.float64)


def _warm(idx: SpatialIndex, pool: np.ndarray, relations, max_batch: int):
    """Settle the shared adaptive candidate cap over the full window pool
    FIRST (cap is a static jit arg — compiling a bucket before the cap
    stops growing would leave a stale compile that recompiles mid-ladder),
    then compile every (relation, pow2 batch bucket) the run can hit at
    that settled cap — the ladder measures serving, not XLA compiles."""
    idx.snapshot()
    jittered = _tier_windows(pool, np.arange(len(pool)),
                             np.random.default_rng(99))
    for rel in relations:
        for i in range(0, len(pool), 32):
            idx.query(pool[i:i + 32], rel)
        for i in range(0, len(jittered), 32):
            idx.query(jittered[i:i + 32], rel)
    for rel in relations:
        q = 1
        while q <= max_batch:
            idx.query(pool[:q], rel)
            q *= 2


def _calibrate(server: SpatialQueryServer, pool: np.ndarray, relations,
               reps: int = 48) -> float:
    """Closed-loop per-query service time of the serial-flush cycle
    (seconds): one submit + flush per query, relations round-robin, a write
    every 8th rep so the result cache cannot flatter the number."""
    rng = np.random.default_rng(3)
    times = []
    for i in range(reps):
        if i % 8 == 0:
            server.insert(_polygon(rng), 8, 0)
        t0 = time.perf_counter()
        server.submit(pool[i % len(pool)], relations[i % len(relations)])
        server.flush()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _percentiles(lat: List[float]) -> Dict[str, float]:
    a = np.asarray(lat)
    p50, p99, p999 = np.percentile(a, [50, 99, 99.9])
    return {"p50_ms": 1e3 * float(p50), "p99_ms": 1e3 * float(p99),
            "p999_ms": 1e3 * float(p999)}


def _schedule(qps: float, seconds: float, rng) -> np.ndarray:
    """Poisson arrival offsets in [0, seconds)."""
    gaps = rng.exponential(1.0 / qps, size=int(qps * seconds * 1.5) + 8)
    arr = np.cumsum(gaps)
    return arr[arr < seconds]


def _tier_windows(pool: np.ndarray, picks: np.ndarray, rng) -> np.ndarray:
    """One UNIQUE window per scheduled arrival: the picked pool window
    shifted by a tiny per-arrival offset (same delta on both corners, so
    the box stays valid; fp32-clamped for the exactness protocol). Without
    this, repeated pool windows hit the server result cache between writes
    and each tier's capacity depends on where the Poisson stream happened
    to place the cache-invalidating writes — the ladder then measures
    cache-hit luck, not the serve path."""
    d = rng.uniform(-1e-5, 1e-5, size=(len(picks), 2))
    shift = np.concatenate([d, d], axis=1)
    return (pool[picks] + shift).astype(np.float32).astype(np.float64)


def _exactness_check(server: SpatialQueryServer, idx: SpatialIndex,
                     wins: np.ndarray, relations, pump: bool) -> None:
    """Serving-path results vs the host oracle, writes quiesced. Raises on
    any mismatch (the bench fails loudly rather than reporting a number
    computed from wrong answers)."""
    for rel in relations:
        if pump:
            tickets = [server.submit(w, rel, tenant="check") for w in wins]
            outs = [server.result(t, timeout=120.0) for t in tickets]
        else:
            tickets = [server.submit(w, rel, tenant="check") for w in wins]
            flushed = server.flush()
            outs = [flushed[t] for t in tickets]
        host = idx.query(wins, rel, backend="host")
        for q, o in enumerate(outs):
            if isinstance(o, Rejected):
                raise AssertionError(f"exactness check shed: {o}")
            np.testing.assert_array_equal(o, host[q])


def _run_tier_serial(server, pool, relations, qps, seconds, write_frac,
                     rng) -> dict:
    """Open-loop tier against the no-dispatcher baseline: every arrival is
    served by its own flush cycle, on schedule when the server keeps up and
    late (with the lateness measured) when it does not."""
    sched = _schedule(qps, seconds, rng)
    picks = rng.integers(len(pool), size=len(sched))
    wins = _tier_windows(pool, picks, rng)
    rels = [relations[i % len(relations)] for i in range(len(sched))]
    writes = rng.random(len(sched)) < write_frac
    lat: List[float] = []
    shed = 0
    t0 = time.perf_counter()
    for k, dt_arr in enumerate(sched):
        t_arr = t0 + dt_arr
        now = time.perf_counter()
        if t_arr > now:
            time.sleep(t_arr - now)
        if writes[k]:
            server.insert(_polygon(rng), 8, 0)
        t = server.submit(wins[k], rels[k])
        out = server.flush()[t]
        if isinstance(out, Rejected):
            shed += 1
        else:
            lat.append(time.perf_counter() - t_arr)
    return {"offered_qps": qps, "submitted": len(sched), "shed": shed,
            "completed": len(lat), "lat": lat,
            "wall_s": time.perf_counter() - t0}


def _run_tier_pump(server, pool, relations, qps, seconds, write_frac,
                   rng, tenants: int = 2) -> dict:
    """Open-loop tier against the pump dispatcher: arrivals are submitted on
    the Poisson schedule, results collected afterwards from the resolution
    timestamps ``result_at`` records — collector lag cannot inflate (or
    hide) latency."""
    sched = _schedule(qps, seconds, rng)
    picks = rng.integers(len(pool), size=len(sched))
    wins = _tier_windows(pool, picks, rng)
    rels = [relations[i % len(relations)] for i in range(len(sched))]
    writes = rng.random(len(sched)) < write_frac
    tens = [f"t{i % tenants}" for i in range(len(sched))]
    t_submit: Dict[int, float] = {}
    t0 = time.perf_counter()
    for k, dt_arr in enumerate(sched):
        t_arr = t0 + dt_arr
        now = time.perf_counter()
        if t_arr > now:
            time.sleep(t_arr - now)
        if writes[k]:
            server.insert(_polygon(rng), 8, 0)
        t_submit[server.submit(wins[k], rels[k], tenant=tens[k])] = t_arr
    lat: List[float] = []
    shed = 0
    for t, t_arr in t_submit.items():
        val, t_res = server.result_at(t, timeout=120.0)
        if isinstance(val, Rejected):
            shed += 1
        else:
            lat.append(t_res - t_arr)
    return {"offered_qps": qps, "submitted": len(sched), "shed": shed,
            "completed": len(lat), "lat": lat,
            "wall_s": time.perf_counter() - t0}


def _run_tier_zipf(server, pool, relations, qps, seconds, write_frac,
                   rng, skew: float = 1.3, tenants: int = 2) -> dict:
    """Hot-query-skew tier: arrivals draw their window from a bounded Zipf
    popularity law over the RAW pool — repeats are byte-identical on
    purpose (no per-arrival jitter), so the stream exercises the result
    cache and, whenever a write drops a generation or the hot set collides
    inside one gather, the micro-batch coalescing path. Submission is
    open-loop pump-mode like :func:`_run_tier_pump`."""
    probs = 1.0 / np.arange(1.0, len(pool) + 1) ** skew
    probs /= probs.sum()
    sched = _schedule(qps, seconds, rng)
    picks = rng.choice(len(pool), size=len(sched), p=probs)
    wins = pool[picks]
    rels = [relations[i % len(relations)] for i in range(len(sched))]
    writes = rng.random(len(sched)) < write_frac
    tens = [f"t{i % tenants}" for i in range(len(sched))]
    t_submit: Dict[int, float] = {}
    t0 = time.perf_counter()
    for k, dt_arr in enumerate(sched):
        t_arr = t0 + dt_arr
        now = time.perf_counter()
        if t_arr > now:
            time.sleep(t_arr - now)
        if writes[k]:
            server.insert(_polygon(rng), 8, 0)
        t_submit[server.submit(wins[k], rels[k], tenant=tens[k])] = t_arr
    lat: List[float] = []
    shed = 0
    for t, t_arr in t_submit.items():
        val, t_res = server.result_at(t, timeout=120.0)
        if isinstance(val, Rejected):
            shed += 1
        else:
            lat.append(t_res - t_arr)
    row = {"offered_qps": qps, "skew": skew, "submitted": len(sched),
           "shed": shed, "completed": len(lat),
           "wall_s": time.perf_counter() - t0}
    row.update(_percentiles(lat))
    return row


def _zipf_tier(server, idx, pool, relations, qps, seconds, write_frac,
               csv: Csv) -> dict:
    """Run the Zipf tier on the live serving config and report the cache /
    coalescing telemetry it generated (deltas across the tier)."""
    before = server.stats()
    row = _run_tier_zipf(server, pool, relations, qps, seconds, write_frac,
                         np.random.default_rng(41))
    after = server.stats()
    for key in ("cache_hits", "cache_misses", "coalesced"):
        row[key] = after[key] - before[key]
    served = row["cache_hits"] + row["cache_misses"]
    row["cache_hit_rate"] = row["cache_hits"] / served if served else 0.0
    _exactness_check(server, idx, pool[:CHECK_WINDOWS], relations, pump=True)
    row["exact"] = True
    csv.emit(f"serving/zipf/qps={qps:.0f}", 1e3 * row["p99_ms"],
             f"p50={row['p50_ms']:.1f}ms;p99={row['p99_ms']:.1f}ms;"
             f"hits={row['cache_hits']};coalesced={row['coalesced']};"
             f"hit_rate={row['cache_hit_rate']:.2f}")
    return row


def _ladder(name: str, server, idx, pool, relations, tiers, seconds,
            write_frac, slo_s, csv: Csv, pump: bool) -> dict:
    rng = np.random.default_rng(17)
    check_wins = pool[:CHECK_WINDOWS]
    def one_tier(qps: float) -> dict:
        run = (_run_tier_pump if pump else _run_tier_serial)(
            server, pool, relations, qps, seconds, write_frac, rng)
        lat = run.pop("lat")
        row = dict(run)
        row.update(_percentiles(lat))
        ok_p99 = row["p99_ms"] <= 1e3 * slo_s
        ok_done = row["completed"] >= 0.98 * row["submitted"]
        row["sustainable"] = bool(ok_p99 and ok_done)
        return row

    rows = []
    max_sustainable = 0.0
    fails = 0
    for qps in tiers:
        if fails >= 2:   # two consecutive busted tiers: the knee is found
            break
        row = one_tier(qps)
        if not row["sustainable"]:
            # one retry on a fresh Poisson draw: a transient host-noise
            # burst busts a 2s tier's p99 once, genuine overload twice
            retry = one_tier(qps)
            if retry["sustainable"] or retry["p99_ms"] < row["p99_ms"]:
                retry["retried"] = True
                row = retry
        if row["sustainable"]:
            max_sustainable = max(max_sustainable, qps)
            fails = 0
        else:
            fails += 1
        rows.append(row)
        csv.emit(f"serving/{name}/qps={qps:.0f}",
                 1e3 * row["p99_ms"],
                 f"p50={row['p50_ms']:.1f}ms;p99={row['p99_ms']:.1f}ms;"
                 f"p999={row['p999_ms']:.1f}ms;shed={row['shed']};"
                 f"sustainable={row['sustainable']}")
        # exactness through the serving path, writes quiesced (the queue is
        # already drained: both tier runners resolve every ticket)
        _exactness_check(server, idx, check_wins, relations, pump)
    return {"tiers": rows, "max_sustainable_qps": max_sustainable,
            "slo_ms": 1e3 * slo_s, "stats": server.stats(), "exact": True}


def run(csv: Csv, large: bool = False, quick: bool = False,
        n: Optional[int] = None, seconds: Optional[float] = None) -> dict:
    n = n or (100_000 if large else (20_000 if quick else 50_000))
    seconds = seconds or (2.0 if quick else 4.0)
    relations = (("intersects", "contains") if quick
                 else ("intersects", "contains", "dwithin:0.003"))
    write_frac = 0.01
    # per-query service time is U-shaped in batch size on CPU (fixed
    # dispatch amortises out by q=8, then large batches thrash the cache
    # hierarchy: q=128 costs MORE per query than q=1) — cap micro-batches
    # at the measured sweet spot instead of letting overload depth pick a
    # pessimal size
    max_batch = 32

    # ---- serial-flush baseline: fresh index, no dispatcher -------------
    idx_a = _build_index(n)
    pool = _window_pool(idx_a, 128)
    _warm(idx_a, pool, relations, max_batch)
    serial = SpatialQueryServer(idx_a, config=ServerConfig(
        overlap_groups=False, max_workers=1, adaptive_batch=False))
    unit_s = _calibrate(serial, pool, relations)
    peak_qps = 1.0 / max(unit_s, 1e-6)
    # The SLO has to sit in the gap between "keeping up" (p99 bounded by
    # batching latency plus host-noise bursts — shared runners stall a
    # busy-spinning process for 100-300ms at a time) and "fallen behind"
    # (open-loop lateness diverges, p99 shoots past 2x the SLO within a
    # tier). 20x the calibrated unit cost lands in that gap; a tighter SLO
    # measures the runner's throttle jitter, not the server.
    slo_s = max(0.5, 20.0 * unit_s)
    tiers = [f * peak_qps for f in TIER_FRACS]
    csv.emit("serving/calibration", 1e6 * unit_s,
             f"unit={1e3 * unit_s:.2f}ms;peak={peak_qps:.0f}qps;"
             f"slo={1e3 * slo_s:.0f}ms")
    res_serial = _ladder("serial_flush", serial, idx_a, pool, relations,
                         tiers, seconds, write_frac, slo_s, csv, pump=False)

    # ---- the serving tier: pump + micro-batching + overlap + replicas --
    idx_b = _build_index(n)
    _warm(idx_b, pool, relations, max_batch)
    # gather window sized to actually amortise the fixed per-batch cost:
    # well under the SLO, wide enough that mid-ladder tiers collect
    # double-digit micro-batches per relation group.
    serving = SpatialQueryServer(idx_b, config=ServerConfig(
        replicas=2, overlap_groups=True, adaptive_batch=True,
        min_batch=16, max_batch=max_batch, gather_window_s=0.04,
        max_queue=4096, fair_watermark=0.9))
    serving.start()
    try:
        res_serving = _ladder("serving", serving, idx_b, pool, relations,
                              tiers, seconds, write_frac, slo_s, csv,
                              pump=True)
        # hot-query skew: byte-identical repeats through cache + coalescing
        res_zipf = _zipf_tier(serving, idx_b, pool, relations,
                              1.25 * peak_qps, seconds, write_frac, csv)
    finally:
        serving.stop()

    out = {
        "bench": "serving",
        "n": n,
        "relations": list(relations),
        "selectivities": list(SELECTIVITIES),
        "write_frac": write_frac,
        "seconds_per_tier": seconds,
        "calib_unit_ms": 1e3 * unit_s,
        "slo_ms": 1e3 * slo_s,
        "configs": {"serial_flush": res_serial, "serving": res_serving},
        "zipf": res_zipf,
        "exact": True,
    }

    if large:   # ablation: micro-batching alone, overlap/replicas off
        idx_c = _build_index(n)
        _warm(idx_c, pool, relations, max_batch)
        batched = SpatialQueryServer(idx_c, config=ServerConfig(
            overlap_groups=False, max_workers=1, adaptive_batch=True,
            min_batch=8, max_batch=max_batch, gather_window_s=0.01))
        batched.start()
        try:
            out["configs"]["batched_serial"] = _ladder(
                "batched_serial", batched, idx_c, pool, relations, tiers,
                seconds, write_frac, slo_s, csv, pump=True)
        finally:
            batched.stop()

    smax = res_serving["max_sustainable_qps"]
    bmax = res_serial["max_sustainable_qps"]
    out["qps_ratio"] = smax / bmax if bmax > 0 else 0.0
    csv.emit("serving/qps_ratio", 0.0,
             f"serving={smax:.0f}qps;serial={bmax:.0f}qps;"
             f"x{out['qps_ratio']:.2f};exact=True")
    print("BENCH " + json.dumps(out))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--seconds", type=float, default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--large", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(Csv(), large=args.large, quick=args.quick, n=args.n,
        seconds=args.seconds)


if __name__ == "__main__":
    main()
