"""GLIN benchmarks mapped 1:1 onto the paper's tables/figures.

run(csv, large):
  tab5_fig6_fig7   piece_limitation sweep: PW size / probing / query time
  tab6_fig8        index sizes + node counts vs R-Tree / Quad-Tree
  fig9             initialization time (GLIN vs GLIN-piecewise vs trees)
  fig10            index probing time per selectivity
  fig11_12_14      query response time, Contains + Intersects
  tab3_fig13       refinement checks with vs without leaf MBRs
  fig15_16         insertion / deletion throughput
  fig17            hybrid read-/write-intensive workloads
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import QuadTree, RTree, SortedArray
from repro.core.index import GLIN, GLINConfig, QueryStats

from .common import (DATASETS, SELECTIVITIES, Csv, build_glin, build_index,
                     dataset, scale_n, timeit, windows)


def _probe_only(g: GLIN, w, relation):
    from repro.core.model import probe
    from repro.core.relations import get_relation
    from repro.core.zorder import mbr_to_zinterval_np
    rel = get_relation(relation)
    probe_win = rel.probe_window(np.asarray(w, np.float64))
    zmin_q, zmax_q = (int(v[0]) for v in
                      mbr_to_zinterval_np(probe_win[None], g.gs.grid))
    if rel.augment:
        zmin_q = g.pw.augment(zmin_q)
    return probe(g.root, zmin_q)


def tab5_fig6_fig7(csv: Csv, n: int) -> None:
    name = "cluster"
    for pl in (100, 1000, 10000, 100000):
        idx = build_index(name, n, pl=pl)
        g = idx.glin   # model internals (probe / piecewise timings)
        # use the paper-faithful Alg-2 scan for probing time (Fig 6) and the
        # suffix-min fast path as the beyond-paper comparison
        wins = windows(name, n, 0.001)
        w0 = wins[0]
        t_scan = timeit(lambda: g.pw.augment_scan(10**15), repeats=3, number=200)
        t_fast = timeit(lambda: g.pw.augment(10**15), repeats=3, number=200)
        t_probe = timeit(lambda: _probe_only(g, w0, "intersects"),
                         repeats=3, number=50)
        t_query = timeit(lambda: idx.query(w0, "intersects"), repeats=3, number=5)
        csv.emit(f"tab5/pw_size_bytes/PL={pl}", g.pw.nbytes(),
                 f"pieces={g.pw.num_pieces}")
        csv.emit(f"fig6/probe_us/PL={pl}", t_probe,
                 f"aug_scan_us={t_scan:.2f};aug_sufmin_us={t_fast:.2f}")
        csv.emit(f"fig7/query_us/PL={pl}", t_query, "intersects sel=0.1%")


def tab6_fig8(csv: Csv, n: int) -> None:
    for name in DATASETS:
        idx = build_index(name, n)
        rt = RTree.build(dataset(name, n))
        qt = QuadTree.build(dataset(name, n))
        gs_ = idx.stats()
        csv.emit(f"fig8/glin_bytes/{name}", gs_["total_index_bytes"],
                 f"nodes={gs_['nodes']}")
        csv.emit(f"fig8/rtree_bytes/{name}", rt.stats()["index_bytes"],
                 f"nodes={rt.stats()['nodes']};"
                 f"x{rt.stats()['index_bytes']/gs_['total_index_bytes']:.1f}")
        csv.emit(f"fig8/quadtree_bytes/{name}", qt.stats()["index_bytes"],
                 f"nodes={qt.stats()['nodes']};"
                 f"x{qt.stats()['index_bytes']/gs_['total_index_bytes']:.1f}")


def fig9(csv: Csv, n: int) -> None:
    name = "cluster"
    gs = dataset(name, n)
    from repro.core.engine import SpatialIndex
    t_glin = timeit(lambda: SpatialIndex.build(gs, GLINConfig(enable_piecewise=False)),
                    repeats=2)
    t_glin_pw = timeit(lambda: SpatialIndex.build(gs, GLINConfig()), repeats=2)
    t_rt = timeit(lambda: RTree.build(gs), repeats=2)
    t_qt = timeit(lambda: QuadTree.build(gs), repeats=1)
    csv.emit("fig9/init_us/glin", t_glin, "")
    csv.emit("fig9/init_us/glin_piecewise", t_glin_pw,
             f"overhead={100*(t_glin_pw/t_glin-1):.0f}%")
    csv.emit("fig9/init_us/rtree", t_rt, "")
    csv.emit("fig9/init_us/quadtree", t_qt, "")


def fig10(csv: Csv, n: int) -> None:
    name = "cluster"
    g = build_glin(name, n)
    rt = RTree.build(dataset(name, n))
    qt = QuadTree.build(dataset(name, n))
    for sel in SELECTIVITIES:
        wins = windows(name, n, sel, k=8)
        t_g = timeit(lambda: [_probe_only(g, w, "contains") for w in wins]) / len(wins)
        st = QueryStats()
        t_rt = timeit(lambda: [rt.probe(w, st) for w in wins]) / len(wins)
        t_qt = timeit(lambda: [qt.probe(w, st) for w in wins]) / len(wins)
        csv.emit(f"fig10/probing_us/glin/sel={sel}", t_g, "")
        csv.emit(f"fig10/probing_us/rtree/sel={sel}", t_rt,
                 f"x{t_rt/max(t_g,1e-9):.1f} vs glin")
        csv.emit(f"fig10/probing_us/quadtree/sel={sel}", t_qt,
                 f"x{t_qt/max(t_g,1e-9):.1f} vs glin")


def fig11_12_14(csv: Csv, n: int) -> None:
    for name in ("cluster", "uniform", "concave"):
        fac = build_index(name, n)
        rt = RTree.build(dataset(name, n))
        qt = QuadTree.build(dataset(name, n))
        for relation, fig in (("contains", "fig11"), ("intersects", "fig12")):
            for sel in SELECTIVITIES:
                wins = windows(name, n, sel, k=8)
                for label, idx in (("glin", fac), ("rtree", rt), ("quadtree", qt)):
                    t = timeit(lambda: [idx.query(w, relation) for w in wins],
                               repeats=2) / len(wins)
                    csv.emit(f"{fig}/query_us/{label}/{name}/sel={sel}", t,
                             relation)


def tab3_fig13(csv: Csv, n: int) -> None:
    for name in ("cluster", "roads"):
        idx = build_index(name, n)
        for sel in SELECTIVITIES:
            wins = windows(name, n, sel, k=8)
            res = idx.query(wins, "contains", collect_stats=True)
            cand = sum(st.candidates for st in res.stats)
            checked = sum(st.checked for st in res.stats)
            csv.emit(f"tab3/refine_checked/{name}/sel={sel}",
                     checked / len(wins),
                     f"wo_leaf_mbr={cand/len(wins):.0f};"
                     f"reduction=x{cand/max(checked,1):.1f}")


def fig15_16(csv: Csv, n: int) -> None:
    name = "cluster"
    gs = dataset(name, n)
    half = n // 2
    import copy

    from repro.core.engine import SpatialIndex

    # GLIN and GLIN-piecewise (through the facade: epoch bump, no rebuild)
    for label, pw in (("glin", False), ("glin_piecewise", True)):
        sub = copy.deepcopy(gs.take(np.arange(half)))
        idx = SpatialIndex.build(sub, GLINConfig(enable_piecewise=pw))
        t0 = time.perf_counter()
        count = min(20000, half)
        for rec in range(half, half + count):
            idx.insert(gs.verts[rec], int(gs.nverts[rec]), int(gs.kinds[rec]))
        dt = time.perf_counter() - t0
        csv.emit(f"fig15/insert_per_s/{label}", 1e6 * dt / count, f"{count/dt:.0f}/s")

    rt = RTree.build(gs.take(np.arange(half)))
    t0 = time.perf_counter()
    count = min(20000, half)
    for rec in range(count):
        rt.insert(rec)  # ids are local to the subset store
    dt = time.perf_counter() - t0
    csv.emit("fig15/insert_per_s/rtree", 1e6 * dt / count, f"{count/dt:.0f}/s")

    qt = QuadTree.build(gs.take(np.arange(half)))
    t0 = time.perf_counter()
    for rec in range(count):
        qt.insert(rec)
    dt = time.perf_counter() - t0
    csv.emit("fig15/insert_per_s/quadtree", 1e6 * dt / count, f"{count/dt:.0f}/s")

    # deletion (Fig 16)
    rng = np.random.default_rng(0)
    dels = rng.choice(half, min(20000, half // 2), replace=False)
    idx = SpatialIndex.build(copy.deepcopy(gs.take(np.arange(half))), GLINConfig())
    t0 = time.perf_counter()
    for d in dels:
        idx.delete(int(d))
    dt = time.perf_counter() - t0
    csv.emit("fig16/delete_per_s/glin_piecewise", 1e6 * dt / len(dels),
             f"{len(dels)/dt:.0f}/s")
    rt = RTree.build(gs.take(np.arange(half)))
    t0 = time.perf_counter()
    for d in dels:
        rt.delete(int(d))
    dt = time.perf_counter() - t0
    csv.emit("fig16/delete_per_s/rtree", 1e6 * dt / len(dels),
             f"{len(dels)/dt:.0f}/s")
    qt = QuadTree.build(gs.take(np.arange(half)))
    t0 = time.perf_counter()
    for d in dels:
        qt.delete(int(d))
    dt = time.perf_counter() - t0
    csv.emit("fig16/delete_per_s/quadtree", 1e6 * dt / len(dels),
             f"{len(dels)/dt:.0f}/s")


def fig17(csv: Csv, n: int) -> None:
    import copy
    name = "cluster"
    gs = dataset(name, n)
    half = n // 2
    wins = windows(name, n, 0.01, k=8)
    for label, write_frac in (("read_intensive", 0.1), ("write_intensive", 0.5)):
        for idx_label in ("glin_piecewise", "rtree"):
            sub = copy.deepcopy(gs.take(np.arange(half)))
            if idx_label == "glin_piecewise":
                from repro.core.engine import SpatialIndex
                idx = SpatialIndex.build(sub, GLINConfig())
                def ins(rec, idx=idx):
                    return idx.insert(gs.verts[rec], int(gs.nverts[rec]),
                                      int(gs.kinds[rec]))
            else:
                idx = RTree.build(gs.take(np.arange(half)))
                def ins(rec, idx=idx):
                    return idx.insert(rec % half)
            rng = np.random.default_rng(1)
            nxt = half
            t0 = time.perf_counter()
            tx = 0
            while tx < 400 and nxt < n:
                if rng.random() < write_frac:
                    # one "insertion transaction" = 0.1% of n new records
                    for _ in range(max(1, n // 1000)):
                        if nxt >= n:
                            break
                        ins(nxt)
                        nxt += 1
                else:
                    idx.query(wins[tx % len(wins)], "intersects")
                tx += 1
            dt = time.perf_counter() - t0
            csv.emit(f"fig17/{label}/tx_per_s/{idx_label}", 1e6 * dt / tx,
                     f"{tx/dt:.1f} tx/s")


def concave_refine(csv: Csv, n: int) -> dict:
    """Beyond-paper: refinement cost on a CONCAVE workload, per relation.

    Real corpora are mostly concave; the exact (ray-cast / edge-clip)
    predicates are priced here so regressions in the refine step show up in
    the tracked ``BENCH {json}`` line. Exactness is asserted against the
    brute-force oracle on every window before anything is timed.
    """
    import json

    name = "concave"
    idx = build_index(name, n)
    out: dict = {"bench": "concave_refine", "n": n, "relations": {}}
    for relation in ("intersects", "within", "touches", "crosses",
                     "dwithin:0.002"):
        wins = windows(name, n, 0.001, k=8)
        for w in wins:   # exactness gate (untimed)
            got = idx.glin.query(w, relation)
            want = idx.glin.query_bruteforce(w, relation)
            np.testing.assert_array_equal(np.sort(got), np.sort(want))
        res = idx.query(wins, relation, backend="host", collect_stats=True)
        checked = sum(st.checked for st in res.stats)
        t = timeit(lambda: idx.query(wins, relation, backend="host"),
                   repeats=2) / len(wins)
        out["relations"][relation] = {
            "query_us": t,
            "checked_per_window": checked / len(wins),
            "hits_per_window": res.total_hits / len(wins),
            "exact": True,
        }
        csv.emit(f"concave/query_us/{relation}/sel=0.001", t,
                 f"checked={checked / len(wins):.0f};exact=True")
    print("BENCH " + json.dumps(out))
    return out


def storage(csv: Csv, n: int,
            names: tuple = ("mixed", "cluster", "roads")) -> dict:
    """Storage-overhead experiment for the CSR vertex-pool store.

    Per dataset: live store bytes in the pooled layout (``gs.nbytes()``) vs
    what the pre-pool dense ``(N, maxV, 2)`` padding would cost
    (``gs.dense_nbytes()``), next to the R-Tree / Quad-Tree index structures
    over the same records. The headline ``storage_ratio`` (dense/pooled on
    the heavy-tailed ``mixed`` family — where every point used to pay for
    the widest ring) is gated by ``check_bench --min-storage-ratio``.
    """
    import json

    out: dict = {"bench": "storage", "n": n, "datasets": {}}
    for name in names:
        gs = dataset(name, n)
        pooled = gs.nbytes()
        dense = gs.dense_nbytes()
        rt = RTree.build(gs)
        qt = QuadTree.build(gs)
        row = {
            "pooled_bytes": pooled,
            "dense_bytes": dense,
            "dense_over_pooled": dense / pooled,
            "rtree_bytes": rt.stats()["index_bytes"],
            "quadtree_bytes": qt.stats()["index_bytes"],
            "max_nverts": gs.max_nverts,
            "mean_nverts": float(gs.nverts.mean()),
        }
        out["datasets"][name] = row
        csv.emit(f"storage/pooled_bytes/{name}", pooled,
                 f"dense={dense};x{row['dense_over_pooled']:.2f};"
                 f"maxV={row['max_nverts']};meanV={row['mean_nverts']:.1f}")
    out["storage_ratio"] = out["datasets"]["mixed"]["dense_over_pooled"]
    csv.emit("storage/dense_over_pooled/mixed", 0.0,
             f"x{out['storage_ratio']:.2f}")
    print("BENCH " + json.dumps(out))
    return out


def run(csv: Csv, large: bool = False) -> None:
    n = scale_n(large)
    tab5_fig6_fig7(csv, n)
    tab6_fig8(csv, n)
    fig9(csv, n)
    fig10(csv, n)
    fig11_12_14(csv, n)
    tab3_fig13(csv, n)
    fig15_16(csv, min(n, 200_000))
    fig17(csv, min(n, 120_000))
    ablation_learned_vs_binary(csv, n)
    concave_refine(csv, min(n, 120_000))


def ablation_learned_vs_binary(csv: Csv, n: int) -> None:
    """Ablation (beyond paper): the learned model's probing benefit vs plain
    binary search over the same Zmin-sorted array (SortedArray baseline)."""
    name = "cluster"
    g = build_glin(name, n)
    sa = SortedArray.build(dataset(name, n))
    wins = windows(name, n, 0.001, k=16)
    t_model = timeit(lambda: [_probe_only(g, w, "contains") for w in wins]) / len(wins)
    import numpy as _np
    from repro.core.zorder import mbr_to_zinterval_np as _z

    def _sa_probe():
        for w in wins:
            zmin_q, zmax_q = (int(v[0]) for v in _z(_np.asarray(w)[None],
                                                    sa.gs.grid))
            _np.searchsorted(sa.keys, zmin_q)
            _np.searchsorted(sa.keys, zmax_q, side="right")

    t_binary = timeit(_sa_probe) / len(wins)
    csv.emit("ablation/probe_us/learned_model", t_model, "")
    csv.emit("ablation/probe_us/binary_search", t_binary,
             f"model_speedup=x{t_binary/max(t_model,1e-9):.2f}")
