"""Sharded refinement benchmark: fused vs dense per-shard pipeline.

The ``sharded`` planner backend (``core.distributed``) runs the PR-4 fused
probe -> mask+compact -> exact-refine pipeline PER RECORD SHARD; before this
it materialized the dense ``(Q, cap)`` candidate window on every shard and
exact-checked all of it. This bench times both through the public facade —
``EngineConfig(exact_budget=256)`` (fused) vs ``exact_budget=0`` (the legacy
dense path, kept in ``build_glin_query_step`` as the baseline) — on a
host-device CPU mesh (``--xla_force_host_platform_device_count``), per
dataset x relation, asserting exactness against ``query_bruteforce`` every
run, and emits the ``BENCH {json}`` line committed as ``BENCH_sharded.json``.
Each mesh also times the device-complete knn tier (shard-local top-k + the
one-collective k-merge) on the cluster dataset, exact vs the fp64 host loop.

Device count is fixed per process, so the orchestrating ``run()`` spawns one
``--inner`` subprocess per mesh size (the full matrix on the 4-way mesh, a
cluster/intersects confirmation on the 2-way mesh) and merges their BENCH
payloads.

    PYTHONPATH=src python -m benchmarks.bench_sharded [--n 30000]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

import numpy as np

from .common import Csv

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
SHARDED_BUDGET = 256
SHARDED_CAP = 4096
SHARDED_DATASETS = ("uniform", "cluster", "concave")
SHARDED_RELATIONS = ("intersects", "contains")


def _inner(csv: Csv, devices: int, n: int, q: int, full: bool) -> dict:
    """Runs inside the fake-device subprocess: one mesh, dense vs fused."""
    import jax

    from repro.core.datasets import generate, make_query_windows
    from repro.core.engine import EngineConfig, QueryBatch, SpatialIndex
    from repro.core.geometry import mbrs_of_verts
    from repro.core.index import GLIN, GLINConfig
    from repro.utils.compat import make_auto_mesh

    from .common import timeit

    assert jax.device_count() >= devices, (
        f"need {devices} devices, have {jax.device_count()} — the inner "
        "bench must run with --xla_force_host_platform_device_count")
    mesh = make_auto_mesh((devices, 1), ("data", "model"))

    def engine(budget: int) -> EngineConfig:
        return EngineConfig(mesh=mesh, shard_min_records=1,
                            initial_cap=SHARDED_CAP, exact_budget=budget)

    datasets = SHARDED_DATASETS if full else ("cluster",)
    relations = SHARDED_RELATIONS if full else ("intersects",)
    out: dict = {"devices": devices, "n": n, "q": q, "cap": SHARDED_CAP,
                 "budget": SHARDED_BUDGET, "backend": jax.default_backend(),
                 "datasets": {}}
    for name in datasets:
        # fp32-representable coordinates: fp64 query_bruteforce and fp32
        # sharded refinement then decide identically (exactness assertable)
        gs = generate(name, n, seed=0)
        gs.verts = gs.verts.astype(np.float32).astype(np.float64)
        gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
        glin = GLIN.build(gs, GLINConfig(piece_limitation=10_000))
        fused = SpatialIndex(glin, engine(SHARDED_BUDGET))
        dense = SpatialIndex(glin, engine(0))
        wins = make_query_windows(gs, 0.0001, q, seed=2)
        wins = wins.astype(np.float32).astype(np.float64)
        out["datasets"][name] = {}
        for rel in relations:
            row: dict = {}
            ref_ids = None
            for impl, idx in (("dense", dense), ("fused", fused)):
                batch = QueryBatch.window(wins, rel, backend="sharded")

                def run(idx=idx, batch=batch):
                    return idx.query(batch)

                res = run()   # compile + settle the shared adaptive cap
                assert res.plan.backend == "sharded"
                row[f"{impl}_us"] = timeit(run, repeats=3)
                ids = list(res)
                if ref_ids is None:
                    ref_ids = ids
                    for qi in range(q):   # exactness vs the oracle, every run
                        bf = glin.query_bruteforce(wins[qi], rel)
                        np.testing.assert_array_equal(ids[qi], bf)
                    row["hits"] = int(sum(r.shape[0] for r in ids))
                else:
                    for a, b in zip(ids, ref_ids):   # impls agree exactly
                        np.testing.assert_array_equal(a, b)
                row[f"{impl}_cap"] = idx.device_cap
            row["speedup"] = row["dense_us"] / max(row["fused_us"], 1e-9)
            out["datasets"][name][rel] = row
            csv.emit(f"sharded/{devices}way/{name}/{rel}_us",
                     row["fused_us"],
                     f"dense={row['dense_us']:.0f}us;"
                     f"speedup=x{row['speedup']:.2f};exact=True")
        if name == "cluster":
            # device-complete knn over the mesh: shard-local top-k + the
            # one-collective k-merge, exact vs the fp64 host loop every run
            from repro.core.index import knn as host_knn
            kq = 10
            pts = (wins[:, :2] + wins[:, 2:]) / 2.0
            pts = pts.astype(np.float32).astype(np.float64)
            kb = QueryBatch.knn(pts, kq)

            def runk(idx=fused, kb=kb):
                return idx.query(kb)

            resk = runk()   # compile + settle
            assert resk.plan.backend == "sharded"
            knn_us = timeit(runk, repeats=3)
            for qi, p in enumerate(pts):
                hi, _ = host_knn(fused.glin, p, kq)
                np.testing.assert_array_equal(resk.ids[qi],
                                              np.asarray(hi, np.int64))
            stage = resk.stages[-1]
            out["knn"] = {"k": kq, "q": int(len(pts)), "knn_us": knn_us,
                          "merge_bytes": int(stage.merge_bytes),
                          "rungs": int(stage.rungs),
                          "seed_hits": int(stage.seed_hits), "exact": True}
            csv.emit(f"sharded/{devices}way/knn_us", knn_us,
                     f"k={kq};merge_bytes={stage.merge_bytes};"
                     f"rungs={stage.rungs};exact=True")
    return out


def _spawn_inner(csv: Csv, devices: int, n: int, q: int, full: bool) -> dict:
    """Run ``--inner`` in a subprocess with ``devices`` fake CPU devices and
    parse its CSV rows + BENCH payload off stdout."""
    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={devices}"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO_ROOT / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, "-m", "benchmarks.bench_sharded", "--inner",
           "--devices", str(devices), "--n", str(n), "--q", str(q)]
    if full:
        cmd.append("--full")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=REPO_ROOT, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(
            f"bench_sharded inner ({devices} devices) failed:\n"
            f"STDOUT:\n{r.stdout[-4000:]}\nSTDERR:\n{r.stderr[-4000:]}")
    payload = None
    for line in r.stdout.splitlines():
        if line.startswith("BENCH "):
            payload = json.loads(line[len("BENCH "):])
        elif line.startswith("sharded/"):
            csv.rows.append(line)
            print(line, flush=True)
    if payload is None:
        raise RuntimeError("bench_sharded inner emitted no BENCH line")
    return payload


def run(csv: Csv, large: bool = False, n: int = 30_000, q: int = 64) -> dict:
    if large:
        n = max(n, 200_000)
    meshes = {"4": _spawn_inner(csv, 4, n, q, full=True),
              "2": _spawn_inner(csv, 2, n, q, full=False)}
    speedups = [row["speedup"]
                for payload in meshes.values()
                for rels in payload["datasets"].values()
                for row in rels.values()]
    out = {
        "bench": "sharded_refine",
        "n": n,
        "q": q,
        "meshes": meshes,
        "speedup_cluster":
            meshes["4"]["datasets"]["cluster"]["intersects"]["speedup"],
        "min_speedup": min(speedups),
    }
    csv.emit("sharded/min_fused_vs_dense_speedup", 0.0,
             f"x{out['min_speedup']:.2f}")
    print("BENCH " + json.dumps(out))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true",
                    help="run one mesh in-process (spawned by run())")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--q", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="inner: full dataset x relation matrix")
    ap.add_argument("--large", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.inner:
        payload = _inner(Csv(), args.devices, args.n, args.q, args.full)
        print("BENCH " + json.dumps(payload))
    else:
        run(Csv(), large=args.large, n=args.n, q=args.q)


if __name__ == "__main__":
    main()
