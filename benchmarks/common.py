"""Shared benchmark infrastructure.

Real TIGER/OSM corpora are unavailable offline; datasets are the synthetic
stand-ins from core.datasets (documented in DESIGN.md §6). Default scale is
CPU-friendly (--large raises it). Output format: ``name,us_per_call,derived``
CSV rows, one per measured quantity, mirroring a paper table/figure each.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, List

import numpy as np

from repro.core.datasets import GeometrySet, generate, make_query_windows
from repro.core.engine import EngineConfig, SpatialIndex
from repro.core.index import GLIN, GLINConfig

SELECTIVITIES = [0.01, 0.001, 0.0001, 0.00001]  # 1% .. 0.001% of N
DATASETS = ["cluster", "uniform", "roads", "concave", "mixed"]


@functools.lru_cache(maxsize=16)
def dataset(name: str, n: int, seed: int = 0) -> GeometrySet:
    return generate(name, n, seed=seed)


@functools.lru_cache(maxsize=32)
def windows(name: str, n: int, sel: float, k: int = 20, seed: int = 0):
    return make_query_windows(dataset(name, n), sel, k, seed=seed)


def build_index(name: str, n: int, pl: int = 10000,
                engine: "EngineConfig | None" = None, **kw) -> SpatialIndex:
    """The one public way to build an index (facade over the host GLIN)."""
    return SpatialIndex.build(dataset(name, n),
                              GLINConfig(piece_limitation=pl, **kw),
                              config=engine)


def build_glin(name: str, n: int, pl: int = 10000, **kw) -> GLIN:
    """Host-structure handle for model-internal measurements (probe timing,
    piecewise internals); querying goes through ``build_index``."""
    return build_index(name, n, pl, **kw).glin


def timeit(fn: Callable, repeats: int = 3, number: int = 1) -> float:
    """Median wall time per call, in microseconds."""
    best = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best.append((time.perf_counter() - t0) / number)
    return float(np.median(best) * 1e6)


class Csv:
    def __init__(self):
        self.rows: List[str] = []

    def emit(self, name: str, us_per_call: float, derived: str = "") -> None:
        line = f"{name},{us_per_call:.2f},{derived}"
        self.rows.append(line)
        print(line, flush=True)


def scale_n(large: bool) -> int:
    return 1_000_000 if large else 120_000
