"""Render the roofline table from dry-run artifacts (EXPERIMENTS.md source),
plus the analytic roofline of the GLIN refinement kernels (``--kernels``).

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
       PYTHONPATH=src python -m benchmarks.roofline_report --kernels
"""
from __future__ import annotations

import argparse
import json
import pathlib

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"

# Representative refinement shapes: (queries, slots, exact budget, gather
# width). The exact stage gathers per pow2 width-bucket from the vertex-pool
# pods, so the width term is the WIDEST SURVIVING bucket (pow2ceil of the
# ring width), not a store-wide dense padding.
KERNEL_SHAPES = [
    (512, 1 << 17, 256, 16),
    (4096, 1 << 20, 256, 16),
    (4096, 1 << 24, 512, 64),
]
# Mesh sizes for the sharded compact+refine variant (record shards)
KERNEL_SHARDS = (4, 16)


def kernel_rows(shapes=None, shards=KERNEL_SHARDS):
    """Roofline terms of the refinement pipeline from the analytic bytes/flops
    model in ``repro.kernels.refine.refine_cost`` — covering the compact
    kernel, the downstream exact-shape stage over the compacted survivors,
    the staged compact+refine pipeline sum, the ONE-dispatch ``fused``
    probe+compact+exact kernel (same work minus the staged pipeline's
    inter-dispatch HBM round trips), AND the sharded variant
    (``sharded_refine_cost``: per-shard compact+refine plus the cross-shard
    survivor all-gather bytes), matching what
    ``core.distributed.build_glin_query_step`` actually executes."""
    from repro.kernels.refine import refine_cost, sharded_refine_cost
    from repro.utils import roofline

    out = []
    for q, n, budget, verts in (shapes or KERNEL_SHAPES):
        shape = f"Q={q}/N={n}/budget={budget}"
        stages = {
            "count": refine_cost("count", q, n),
            "compact": refine_cost("compact", q, n, budget),
            "exact": refine_cost("exact", q, n, budget, verts=verts),
        }
        pipeline = {
            "flops": (stages["compact"]["flops"] + stages["exact"]["flops"]),
            "bytes_accessed": (stages["compact"]["bytes_accessed"]
                               + stages["exact"]["bytes_accessed"]),
        }
        stages["compact+refine"] = pipeline
        stages["fused"] = refine_cost("fused", q, n, budget, verts=verts)
        for s in shards:
            stages[f"sharded[{s}]"] = sharded_refine_cost(
                q, n, budget, shards=s, verts=verts)
        for stage, cost in stages.items():
            coll = cost.get("collective_bytes", 0.0)
            terms = roofline.roofline_terms(
                cost["flops"], cost["bytes_accessed"], coll, chips=1)
            detail = (
                f"flops={cost['flops']:.3g} bytes={cost['bytes_accessed']:.3g} "
                f"compute={terms['compute_s']*1e6:.3g}us "
                f"memory={terms['memory_s']*1e6:.3g}us ")
            if coll:
                detail += (f"allgather={coll:.3g}B "
                           f"coll={terms['collective_s']*1e6:.3g}us ")
            out.append((f"refine/{stage}/{shape}",
                        detail + f"dom={terms['dominant']}"))
    return out


def fmt(v, digits=3):
    return f"{v:.{digits}g}" if isinstance(v, (int, float)) else str(v)


def rows(mesh="single"):
    out = []
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        name = f"{r['arch']}/{r['shape']}"
        if r["status"] == "skip":
            out.append((name, "SKIP", r.get("reason", "")))
            continue
        if r["status"] != "ok":
            out.append((name, "FAIL", r.get("error", "")[:80]))
            continue
        ro = r["roofline"]
        mem = r["memory"].get("total_bytes_per_device", 0) / 2**30
        out.append((
            name, "ok",
            f"compute={ro['compute_s']:.4g}s memory={ro['memory_s']:.4g}s "
            f"coll={ro['collective_s']:.4g}s dom={ro['dominant']} "
            f"frac={ro['compute_fraction']:.3f} "
            f"useful={fmt(r.get('useful_flops_ratio'))} mem/dev={mem:.2f}GiB"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--kernels", action="store_true",
                    help="analytic roofline of the GLIN refinement kernels "
                         "(count / compact / exact / compact+refine / fused)")
    args = ap.parse_args()
    if args.kernels:
        for name, detail in kernel_rows():
            print(f"{name:44s} {detail}")
        return
    if args.markdown:
        print(markdown(args.mesh))
        return
    for name, status, detail in rows(args.mesh):
        print(f"{name:42s} {status:5s} {detail}")


def markdown(mesh="single"):
    """Markdown table for EXPERIMENTS.md §Roofline."""
    lines = [
        f"| arch/shape ({mesh}-pod) | compute_s | memory_s | coll_s | dominant "
        "| MODEL/HLO flops | mem/dev GiB | note |",
        "|---|---|---|---|---|---|---|",
    ]
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        name = f"{r['arch']}/{r['shape']}"
        if r["status"] == "skip":
            lines.append(f"| {name} | — | — | — | SKIP | — | — | "
                         f"{r.get('reason','')[:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {name} | — | — | — | FAIL | — | — | "
                         f"{r.get('error','')[:60]} |")
            continue
        ro = r["roofline"]
        mem = r["memory"].get("total_bytes_per_device", 0) / 2**30
        ur = r.get("useful_flops_ratio")
        lines.append(
            f"| {name} | {ro['compute_s']:.3g} | {ro['memory_s']:.3g} | "
            f"{ro['collective_s']:.3g} | {ro['dominant']} | "
            f"{ur:.3f} | {mem:.2f} | |" if ur is not None else
            f"| {name} | {ro['compute_s']:.3g} | {ro['memory_s']:.3g} | "
            f"{ro['collective_s']:.3g} | {ro['dominant']} | — | {mem:.2f} | |")
    return "\n".join(lines)


if __name__ == "__main__":
    main()
