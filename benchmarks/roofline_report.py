"""Render the roofline table from dry-run artifacts (EXPERIMENTS.md source).

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def fmt(v, digits=3):
    return f"{v:.{digits}g}" if isinstance(v, (int, float)) else str(v)


def rows(mesh="single"):
    out = []
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        name = f"{r['arch']}/{r['shape']}"
        if r["status"] == "skip":
            out.append((name, "SKIP", r.get("reason", "")))
            continue
        if r["status"] != "ok":
            out.append((name, "FAIL", r.get("error", "")[:80]))
            continue
        ro = r["roofline"]
        mem = r["memory"].get("total_bytes_per_device", 0) / 2**30
        out.append((
            name, "ok",
            f"compute={ro['compute_s']:.4g}s memory={ro['memory_s']:.4g}s "
            f"coll={ro['collective_s']:.4g}s dom={ro['dominant']} "
            f"frac={ro['compute_fraction']:.3f} "
            f"useful={fmt(r.get('useful_flops_ratio'))} mem/dev={mem:.2f}GiB"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    if args.markdown:
        print(markdown(args.mesh))
        return
    for name, status, detail in rows(args.mesh):
        print(f"{name:42s} {status:5s} {detail}")


def markdown(mesh="single"):
    """Markdown table for EXPERIMENTS.md §Roofline."""
    lines = [
        f"| arch/shape ({mesh}-pod) | compute_s | memory_s | coll_s | dominant "
        "| MODEL/HLO flops | mem/dev GiB | note |",
        "|---|---|---|---|---|---|---|",
    ]
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        name = f"{r['arch']}/{r['shape']}"
        if r["status"] == "skip":
            lines.append(f"| {name} | — | — | — | SKIP | — | — | "
                         f"{r.get('reason','')[:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {name} | — | — | — | FAIL | — | — | "
                         f"{r.get('error','')[:60]} |")
            continue
        ro = r["roofline"]
        mem = r["memory"].get("total_bytes_per_device", 0) / 2**30
        ur = r.get("useful_flops_ratio")
        lines.append(
            f"| {name} | {ro['compute_s']:.3g} | {ro['memory_s']:.3g} | "
            f"{ro['collective_s']:.3g} | {ro['dominant']} | "
            f"{ur:.3f} | {mem:.2f} | |" if ur is not None else
            f"| {name} | {ro['compute_s']:.3g} | {ro['memory_s']:.3g} | "
            f"{ro['collective_s']:.3g} | {ro['dominant']} | — | {mem:.2f} | |")
    return "\n".join(lines)


if __name__ == "__main__":
    main()
