"""Update-throughput benchmark: the paper's maintenance experiment (§IX-E).

GLIN's headline maintenance result is that patch-not-rebuild keeps
insert/delete throughput high while staying exact. This bench reproduces the
device-serving version of that experiment through the public facade: an
interleaved insert/delete/query stream runs at several
``EngineConfig.refresh_threshold`` settings against a cluster dataset —

* ``refresh_threshold=0``  — delta patching off: every query batch after a
  write republishes the device snapshot (the PR-1 behavior);
* ``refresh_threshold>0``  — the planner serves ``device+delta`` (published
  snapshot + tombstone mask + vectorized added-set check) and republishes
  only when the delta crosses the threshold.

Exactness is asserted every round: device(+delta) results must equal host
results for the full query batch (coordinates are clamped to
fp32-representable values so fp64 host and fp32 device refinement agree).

Queries use ``contains`` windows: its probe runs keep the candidate cap small
on CPU, so the timed difference between configurations is the maintenance
machinery itself (with augmented ``intersects`` runs the shared adaptive cap
grows until refinement cost masks the republish cost on every config alike).

Emits the usual ``name,us_per_call,derived`` CSV rows plus one machine
readable ``BENCH {json}`` line.

    PYTHONPATH=src python -m benchmarks.bench_maintenance [--n 100000]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np

from repro.core.datasets import generate, make_query_windows
from repro.core.engine import EngineConfig, SpatialIndex
from repro.core.geometry import mbrs_of_verts
from repro.core.index import GLINConfig

from .common import Csv

RELATION = "contains"
THRESHOLDS = (0, 512, 4096)   # 0 = republish-every-epoch baseline


def _fp32_dataset(n: int, seed: int = 0):
    """Cluster dataset with fp32-representable coordinates (exact host/device
    parity). Generated fresh — never from the shared lru_cache — because the
    cast mutates the GeometrySet in place."""
    gs = generate("cluster", n, seed=seed)
    gs.verts = gs.verts.astype(np.float32).astype(np.float64)
    gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
    return gs


def _polygon(rng, nv: int = 8, r: float = 2e-4) -> np.ndarray:
    c = rng.uniform(0.15, 0.85, 2)
    ang = np.sort(rng.uniform(0, 2 * np.pi, nv))
    v = np.stack([c[0] + r * np.cos(ang), c[1] + r * np.sin(ang)], -1)
    return v.astype(np.float32).astype(np.float64)


def _run_stream(n: int, refresh_threshold: int, rounds: int,
                inserts_per_round: int, deletes_per_round: int,
                batch_windows: int) -> dict:
    """One configuration: fresh index, identical op stream, timed rounds.

    Each round interleaves two write bursts with two query-batch flushes —
    the serving cadence under write-heavy load, where EVERY flush finds the
    snapshot stale (that is exactly what the republish-per-epoch baseline
    pays for and delta patching avoids).
    """
    gs = _fp32_dataset(n)
    patching = refresh_threshold > 0
    idx = SpatialIndex.build(
        gs, GLINConfig(piece_limitation=10_000),
        EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1,
                     exact_budget=1024,
                     delta_patch_max=refresh_threshold if patching else 0,
                     refresh_threshold=max(refresh_threshold, 1)))
    wins = make_query_windows(gs, 1e-5, 2 * batch_windows, seed=2)
    wins = wins.astype(np.float32).astype(np.float64)
    halves = (wins[:batch_windows], wins[batch_windows:])
    rng = np.random.default_rng(7)

    idx.snapshot()
    for half in halves:                    # compile + settle the adaptive cap
        idx.query(half, RELATION)

    backends: dict = {}
    t_ops = 0.0
    ops = 0
    for _ in range(rounds):
        live = np.nonzero(idx.glin._live_mask())[0]
        victims = rng.choice(live, 2 * deletes_per_round, replace=False)
        for flush, half in enumerate(halves):
            t0 = time.perf_counter()
            for _ in range(inserts_per_round):
                idx.insert(_polygon(rng), 8, 0)
            for v in victims[flush::2][:deletes_per_round]:
                idx.delete(int(v))
            res = idx.query(half, RELATION)
            t_ops += time.perf_counter() - t0
            ops += inserts_per_round + deletes_per_round + batch_windows
            b = res.plan.backend
            backends[b] = backends.get(b, 0) + 1
            # exactness gate (untimed): device results == host results
            host = idx.query(half, RELATION, backend="host")
            for a, b2 in zip(res, host):
                np.testing.assert_array_equal(a, b2)
    return {
        "refresh_threshold": refresh_threshold,
        "delta_patching": patching,
        "ops_per_s": ops / t_ops,
        "round_ms": 1e3 * t_ops / rounds,
        "publishes": idx._publishes,
        "final_delta": idx.delta_size(),
        "backends": backends,
        "device_cap": idx.device_cap,
        "exact": True,                      # assert above would have raised
    }


def _run_republish_probe(n: int, async_on: bool, batch_windows: int = 8,
                         refresh: int = 48) -> dict:
    """Query latency THROUGH a snapshot republish (the double-buffering
    experiment): steady-state p50 of patched query batches vs the p50 of
    batches issued while the republish runs.

    * ``async_on=False`` — the PR-2 behavior: once the delta crosses
      ``refresh_threshold`` the next query batch blocks on the full rebuild
      (its latency IS the rebuild).
    * ``async_on=True``  — the build runs on a background thread; queries
      keep serving the published snapshot + delta until the epoch-tagged
      swap, so per-batch latency stays near steady-state.

    Exactness is asserted (untimed) for every measured batch.
    """
    gs = _fp32_dataset(n)
    idx = SpatialIndex.build(
        gs, GLINConfig(piece_limitation=10_000),
        EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1,
                     exact_budget=1024, delta_patch_max=refresh,
                     refresh_threshold=refresh, async_republish=async_on))
    wins = make_query_windows(gs, 1e-5, batch_windows, seed=2)
    wins = wins.astype(np.float32).astype(np.float64)
    rng = np.random.default_rng(7)
    idx.snapshot()
    idx.query(wins, RELATION)              # compile + settle the cap

    def timed_batch():
        t0 = time.perf_counter()
        res = idx.query(wins, RELATION)
        dt = time.perf_counter() - t0
        host = idx.query(wins, RELATION, backend="host")
        for a, b in zip(res, host):
            np.testing.assert_array_equal(a, b)
        return dt, res.plan.backend

    # steady state under the SAME write cadence as the republish window
    # (one insert per batch, delta stays under the threshold): each batch is
    # a device+delta patched query, exactly what the during-phase serves
    steady = []
    for _ in range(12):
        idx.insert(_polygon(rng), 8, 0)
        steady.append(timed_batch()[0])

    # drive the delta across the refresh threshold, then measure batches
    # until the republish lands (async: the background swap; sync: the first
    # query batch performs — and is blocked by — the rebuild). The trigger
    # batch (which pays the synchronous host capture, or the whole rebuild
    # in sync mode) is reported separately: a production stream pays it once
    # per refresh_threshold writes, while this compressed probe would
    # otherwise over-sample it once per 3-4 batches. Several
    # trigger->publish cycles pool enough during-samples for a stable p50.
    during: List[float] = []
    triggers: List[float] = []
    backends: dict = {}
    for _cycle in range(4):
        while idx.delta_size() < refresh:
            idx.insert(_polygon(rng), 8, 0)
        pubs0 = idx._publishes
        triggers.append(timed_batch()[0])      # starts (or IS) the republish
        # the during-phase lasts until the swap lands, so bound it by WALL
        # time, not batch count — on a slow or single-core host the niced
        # builder shares the core with serving and needs real seconds, while
        # a fixed iteration budget couples the window to batch latency
        deadline = time.perf_counter() + 120.0
        while time.perf_counter() < deadline:
            if idx._publishes > pubs0:
                break
            idx.insert(_polygon(rng), 8, 0)    # writes keep flowing
            dt, backend = timed_batch()
            during.append(dt)
            backends[backend] = backends.get(backend, 0) + 1
        assert idx._publishes > pubs0, "republish never landed"
    return {
        "async": async_on,
        "steady_p50_ms": 1e3 * float(np.median(steady)),
        "during_p50_ms": 1e3 * float(np.median(during or triggers)),
        "during_max_ms": 1e3 * float(np.max(during or triggers)),
        "trigger_p50_ms": 1e3 * float(np.median(triggers)),
        "batches_during": len(during),
        "backends_during": backends,
        "exact": True,
    }


def mixed_ingest(csv: Csv, n: int) -> dict:
    """Mixed-width ingestion: append heavy-tailed records (1-vertex points
    through 64-vertex rings) through the facade and read the store's own
    ``bytes_moved`` counter. Under the dense-era layout one wide insert
    re-padded the whole ``(N, V, 2)`` block (O(N*V) bytes); under the CSR
    pool each insert moves O(record width) bytes amortized. Reported:
    settled insert throughput and bytes moved per insert next to the raw
    payload bytes actually appended."""
    src = generate("mixed", n, seed=11)
    half = n // 2
    idx = SpatialIndex.build(
        src.take(np.arange(half)), GLINConfig(piece_limitation=10_000),
        EngineConfig(refresh_threshold=1 << 30))
    gs = idx.gs

    def burst(lo, hi):
        payload = 0
        t0 = time.perf_counter()
        for rec in range(lo, hi):
            w = int(src.nverts[rec])
            idx.insert(src.ring(rec), w, int(src.kinds[rec]))
            payload += w * 16 + 45          # ring + per-record metadata
        return time.perf_counter() - t0, payload

    count = min(10_000, half // 2)
    burst(half, half + count)               # settle buffer doublings
    moved0 = gs.bytes_moved
    dt, payload = burst(half + count, half + 2 * count)
    moved = gs.bytes_moved - moved0
    out = {
        "inserts": count,
        "inserts_per_s": count / dt,
        "bytes_moved_per_insert": moved / count,
        "payload_bytes_per_insert": payload / count,
        "amplification": moved / payload,
        "max_width": int(src.nverts[half + count:half + 2 * count].max()),
        "dense_repad_bytes_per_insert": len(gs) * gs.max_nverts * 16,
    }
    csv.emit("maintenance/mixed_ingest_us_per_insert", 1e6 * dt / count,
             f"{out['inserts_per_s']:.0f}/s;"
             f"moved={out['bytes_moved_per_insert']:.0f}B/insert;"
             f"payload={out['payload_bytes_per_insert']:.0f}B;"
             f"x{out['amplification']:.2f} vs "
             f"dense_repad={out['dense_repad_bytes_per_insert']}B")
    return out


def republish_latency(csv: Csv, n: int) -> dict:
    """Async vs blocking republish; emits the ``republish`` BENCH section.
    The store is scaled up so the rebuild window is long enough to collect a
    meaningful p50 of query batches issued while it runs (at small n the
    build finishes within 2-3 batches and the p50 is sampling noise)."""
    n = max(n, 150_000)
    sync = _run_republish_probe(n, async_on=False)
    asy = _run_republish_probe(n, async_on=True)
    out = {
        "sync": sync,
        "async": asy,
        # the headline the CI gates on: query p50 while a republish is in
        # flight, relative to steady-state p50 (async double-buffering)
        "p50_ratio": asy["during_p50_ms"] / max(asy["steady_p50_ms"], 1e-9),
        "sync_blocked_ms": sync["during_max_ms"],
    }
    csv.emit("maintenance/republish_p50_during_ms", 1e3 * 0.0,
             f"async_p50={asy['during_p50_ms']:.1f}ms;"
             f"steady_p50={asy['steady_p50_ms']:.1f}ms;"
             f"ratio=x{out['p50_ratio']:.2f};"
             f"sync_blocked={sync['during_max_ms']:.0f}ms;exact=True")
    return out


def run(csv: Csv, large: bool = False, n: int = 100_000,
        rounds: int = 24) -> dict:
    if large:
        n = max(n, 1_000_000)
    configs: List[dict] = []
    for thr in THRESHOLDS:
        r = _run_stream(n, thr, rounds=rounds, inserts_per_round=4,
                        deletes_per_round=2, batch_windows=8)
        configs.append(r)
        csv.emit(f"maintenance/ops_per_s/refresh={thr}",
                 1e6 / r["ops_per_s"],
                 f"ops_per_s={r['ops_per_s']:.0f};publishes={r['publishes']};"
                 "backends=" + "+".join(
                     f"{k}:{v}" for k, v in sorted(r["backends"].items()))
                 + f";exact={r['exact']}")
    base = configs[0]["ops_per_s"]
    best = max(c["ops_per_s"] for c in configs if c["delta_patching"])
    out = {
        "bench": "maintenance",
        "n": n,
        "rounds": rounds,
        "relation": RELATION,
        "configs": configs,
        "speedup_vs_republish": best / base,
        "republish": republish_latency(csv, n),
        "mixed_ingest": mixed_ingest(csv, min(n, 60_000)),
    }
    csv.emit("maintenance/speedup_vs_republish", 0.0,
             f"x{best / base:.2f}")
    print("BENCH " + json.dumps(out))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--large", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(Csv(), large=args.large, n=args.n, rounds=args.rounds)


if __name__ == "__main__":
    main()
