"""Beyond-paper benchmarks: the TPU-native batched query path and the
Pallas kernels (timed via their XLA reference semantics on CPU; interpret
mode executes kernel bodies in Python and is not a timing proxy).

``refine_pipeline`` is the perf-trajectory anchor: it times the OLD
refinement (legacy stable-argsort compaction over chained per-query MBR
gathers, ``compaction="sort"``), the staged pipeline (slot-aligned MBR
tables + cumsum/scatter compaction, ``compaction="scan"``) and the
ONE-dispatch fused path (``batch_query_fused`` — its "reference" XLA
composition on CPU, the Pallas kernel itself on TPU) per dataset and
relation, asserts exactness against ``query_bruteforce`` every time, and
emits the ``BENCH {json}`` line committed as ``BENCH_device.json``.
``knn_pipeline`` adds the ``"knn"`` row: the device-complete knn batch
(CDF-seeded radii + device top-k) against the host-ranked rung ladder it
replaced, both asserted exact against the fp64 brute-force oracle. The
Pallas kernel columns are only *measured* on TPU; elsewhere they are
emitted as ``null`` and listed in each row's ``"unmeasured"`` marker so the
committed trajectory never silently conflates backends.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datasets import generate, make_query_windows
from repro.core.device import (batch_query, batch_query_bounds,
                               batch_query_fused)
from repro.core.engine import EngineConfig, SpatialIndex
from repro.core.geometry import mbrs_of_verts
from repro.core.index import GLINConfig
from repro.core.relations import get_relation
from repro.kernels import ops

from .common import Csv, build_index, scale_n, timeit, windows

REFINE_CAP = 4096
REFINE_BUDGET = 256
REFINE_DATASETS = ("uniform", "cluster", "concave")
REFINE_RELATIONS = ("intersects", "contains")


def _fp32_dataset(name: str, n: int, seed: int = 0):
    """fp32-representable coordinates: fp64 ``query_bruteforce`` and fp32
    device refinement then decide identically, so exactness is assertable
    bit-for-bit. Generated fresh (the cast mutates the GeometrySet)."""
    gs = generate(name, n, seed=seed)
    gs.verts = gs.verts.astype(np.float32).astype(np.float64)
    gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
    return gs


def refine_pipeline(csv: Csv, n: int, q: int = 128) -> dict:
    """Old-vs-new refinement per dataset × relation at the tracked config
    (cap=4096, budget=256). ``refine_us`` isolates the refinement stage:
    total batched query time minus the (shared) probe time. ``fused`` is the
    one-dispatch ``batch_query_fused`` path — the Pallas kernel on TPU, its
    bit-identical "reference" XLA composition elsewhere (interpret mode is a
    correctness tool, not a timing proxy)."""
    on_tpu = jax.default_backend() == "tpu"
    impls = ["sort", "scan", "fused"]
    if on_tpu:
        impls.append("pallas")
    out: dict = {"bench": "device_refine", "n": n, "q": q, "cap": REFINE_CAP,
                 "budget": REFINE_BUDGET, "backend": jax.default_backend(),
                 "datasets": {}}
    for name in REFINE_DATASETS:
        gs = _fp32_dataset(name, n)
        idx = SpatialIndex.build(
            gs, GLINConfig(piece_limitation=10_000),
            EngineConfig(initial_cap=REFINE_CAP, exact_budget=REFINE_BUDGET))
        snap = idx.snapshot()
        pods, mb = idx._device_payload(idx._snapshot_recs)
        wins = make_query_windows(gs, 0.0001, q, seed=2)
        wins = wins.astype(np.float32).astype(np.float64)
        wj = jnp.asarray(wins.astype(np.float32))
        out["datasets"][name] = {}
        bounds_fn = jax.jit(batch_query_bounds, static_argnames=("relation",))
        for rel_name in REFINE_RELATIONS:
            base = get_relation(rel_name).base_name()

            def probe(wj=wj, base=base):
                s, e = bounds_fn(snap, wj, base)
                return e.block_until_ready()

            probe()
            probe_us = timeit(probe, repeats=5)
            # settle the candidate cap the way the facade's overflow ladder
            # does: the dense legacy path must cover the longest augmented
            # run (its core weakness — the (Q, cap) intermediate scales with
            # the run; the fused kernel path has no such intermediate)
            s0, e0 = bounds_fn(snap, wj, base)
            need = int(np.max(np.asarray(e0) - np.asarray(s0)))
            cap = max(REFINE_CAP, 1 << max(need - 1, 1).bit_length())
            row: dict = {"probe_us": probe_us, "settled_cap": cap,
                         "max_run": need}
            ref_hits = None
            for impl in impls:
                if impl == "fused":
                    def run(wj=wj):
                        # one dispatch end-to-end (probe included), so the
                        # probe_us subtraction below still isolates the
                        # refinement delta fairly vs the staged columns
                        h, c = batch_query_fused(
                            snap, wj, pods, relation=base,
                            exact_budget=REFINE_BUDGET,
                            mode="pallas" if on_tpu else "reference")
                        return h.block_until_ready(), c.block_until_ready()
                else:
                    def run(impl=impl, wj=wj, cap=cap):
                        h, c = batch_query(
                            snap, wj, pods, mb, relation=base,
                            cap=cap, exact_budget=REFINE_BUDGET,
                            compaction=impl)
                        return h.block_until_ready(), c.block_until_ready()
                hits, counts = run()   # compile outside the timed region
                counts = np.asarray(counts)
                assert (counts >= 0).all(), \
                    f"{name}/{rel_name}/{impl}: overflow at settled cap"
                total_us = timeit(run, repeats=5)
                row[f"{impl}_us"] = total_us
                row[f"refine_{impl}_us"] = max(total_us - probe_us, 0.0)
                ids = [np.sort(r[r >= 0]) for r in np.asarray(hits)]
                if ref_hits is None:
                    ref_hits = ids
                    # exactness vs the brute-force oracle (fp32 grid: exact)
                    for qi in range(q):
                        bf = idx.glin.query_bruteforce(wins[qi], rel_name)
                        np.testing.assert_array_equal(ids[qi], bf)
                    row["hits"] = int(sum(r.shape[0] for r in ids))
                else:
                    for a, b in zip(ids, ref_hits):   # impls agree exactly
                        np.testing.assert_array_equal(a, b)
            if not on_tpu:
                # the Pallas kernel columns exist on every row of the
                # committed trajectory but are only measurable on TPU:
                # null + an explicit marker beats silent omission
                row["pallas_us"] = None
                row["refine_pallas_us"] = None
                row["unmeasured"] = ["pallas"]
            row["speedup_refine"] = (row["refine_sort_us"]
                                     / max(row["refine_scan_us"], 1e-9))
            row["speedup_fused"] = (row["refine_scan_us"]
                                    / max(row["refine_fused_us"], 1e-9))
            out["datasets"][name][rel_name] = row
            csv.emit(
                f"device/refine/{name}/{rel_name}_us", row["refine_fused_us"],
                f"scan={row['refine_scan_us']:.0f}us;"
                f"old_sort={row['refine_sort_us']:.0f}us;"
                f"probe={probe_us:.0f}us;"
                f"speedup=x{row['speedup_refine']:.2f};"
                f"fused=x{row['speedup_fused']:.2f};exact=True")
    out["speedup_cluster"] = (
        out["datasets"]["cluster"]["intersects"]["speedup_refine"])
    out["speedup_fused_cluster"] = (
        out["datasets"]["cluster"]["intersects"]["speedup_fused"])
    return out


def knn_pipeline(csv: Csv, n: int, q: int = 64, k: int = 10) -> dict:
    """Device-complete knn vs the host-ranked rung ladder it replaced.

    Baseline = the old shape: batched device ``dwithin`` probes at blindly
    doubling radii, then PER-POINT host ranking — gather every candidate's
    vertices to the host (``gs.padded``), exact fp64 distances, lexsort
    top-k. New = ``QueryBatch.knn`` on the device backend: CDF-seeded
    per-point radii, exact squared distances on the pooled VertexPods
    survivors and a device top-k; only the final ``(Q, k)`` comes home.
    Both run fresh on the same index; BOTH are asserted exact against the
    fp64 brute-force oracle every run, and the payload carries the median
    rung depth seeded vs blind (``check_bench`` gates seeded <= 2)."""
    import dataclasses

    from repro.core import geometry as geom
    from repro.core.engine import QueryBatch
    from repro.core.index import initial_knn_radius

    gs = _fp32_dataset("cluster", n)
    idx = SpatialIndex.build(
        gs, GLINConfig(piece_limitation=10_000),
        EngineConfig(initial_cap=REFINE_CAP, exact_budget=REFINE_BUDGET))
    idx.snapshot()
    rng = np.random.default_rng(7)
    lo, hi = gs.mbrs[:, :2].min(0), gs.mbrs[:, 2:].max(0)
    pts = lo + (hi - lo) * rng.uniform(0.1, 0.9, (q, 2))
    pts = pts.astype(np.float32).astype(np.float64)

    # fp64 brute-force oracle, ranked by the shared (distance, id) contract
    all_ids = np.arange(n, dtype=np.int64)
    pad, nv, kd = gs.padded(all_ids), gs.nverts, gs.kinds

    def exact_rank(p, ids, vv, nvv, kdd):
        rect = np.array([p[0], p[1], p[0], p[1]])
        d2 = geom.rect_geom_sqdist(rect, vv, nvv, kdd, xp=np)
        return geom.rank_knn(ids, np.sqrt(np.maximum(d2, 0.0)), k)[0]

    want = [exact_rank(p, all_ids, pad, nv, kd) for p in pts]

    # ---- baseline: blind doubling ladder, candidates ranked on the host
    r0 = initial_knn_radius(idx.glin, k)
    r0 = float(np.power(2.0, np.ceil(np.log2(max(r0, 1e-9)))))

    def host_ladder():
        done = np.zeros(q, bool)
        out = [None] * q
        r = r0
        while not done.all():
            sel = np.nonzero(~done)[0]
            w = np.concatenate([pts[sel], pts[sel]], axis=1)
            res = idx.query(QueryBatch.window(
                w, f"dwithin:{r:.17g}", backend="device"))
            for j, i in enumerate(sel):
                hits = np.asarray(res.ids[j])
                if len(hits) >= min(k, n):
                    out[i] = exact_rank(pts[i], hits, gs.padded(hits),
                                        nv[hits], kd[hits])
                    done[i] = True
            r *= 2.0
        return out

    base_ids = host_ladder()   # compile every rung's query bucket
    host_us = timeit(host_ladder, repeats=3)

    # ---- new: one device-complete knn batch
    batch = QueryBatch.knn(pts, k, backend="device")
    res = idx.query(batch)     # compile + walk the adaptive cap up
    assert res.plan.backend == "device"
    idx.query(batch)           # second warm: the first call grew the cap
    #                            mid-flight, so rung shapes recompile once
    #                            at the settled cap before the timed region
    dev_us = timeit(lambda: idx.query(batch), repeats=3)

    for qi in range(q):        # exactness of BOTH paths, every run
        np.testing.assert_array_equal(np.asarray(res.ids[qi]), want[qi])
        np.testing.assert_array_equal(np.asarray(base_ids[qi]), want[qi])

    def med_rungs(stage):
        probes = np.repeat(np.arange(1, stage.rungs + 1),
                           np.asarray(stage.rung_hist, np.int64))
        return float(np.median(probes)) if probes.size else 0.0

    seeded = res.stages[-1]
    cfg0 = idx.config
    try:                       # same batch, blind global seed radius
        idx.config = dataclasses.replace(cfg0, knn_seed="global")
        blind = idx.query(QueryBatch.knn(pts, k, backend="device")).stages[-1]
    finally:
        idx.config = cfg0
    row = {"n": n, "q": q, "k": k,
           "host_ladder_us": host_us, "device_us": dev_us,
           "speedup_knn": host_us / max(dev_us, 1e-9),
           "rungs_median_seeded": med_rungs(seeded),
           "rungs_median_blind": med_rungs(blind),
           "seed_hits": int(seeded.seed_hits), "exact": True}
    csv.emit("device/knn_us", dev_us,
             f"host_ladder={host_us:.0f}us;"
             f"speedup=x{row['speedup_knn']:.2f};"
             f"rungs_med={row['rungs_median_seeded']:.1f}"
             f"(blind={row['rungs_median_blind']:.1f});exact=True")
    return row


def device_batch_query(csv: Csv, n: int) -> None:
    name = "cluster"
    # Augmented Intersects runs are long: two-stage refinement (MBR masks over
    # the full run, exact checks on <=1024 survivors). The facade's adaptive
    # cap walks the overflow ladder once, so the timed region is exact AND
    # steady-state (the seed bench silently timed truncated results).
    idx = build_index(name, n, pl=10000,
                      engine=EngineConfig(initial_cap=4096, exact_budget=1024))
    idx.snapshot()  # materialize outside the timed region
    for q in (64, 512):
        wins = np.concatenate([windows(name, n, 0.0001, k=20)] * (q // 20 + 1))[:q]
        def fn(wins=wins):
            return idx.query(wins, "intersects", backend="device")
        fn()  # compile + settle the adaptive cap
        t = timeit(fn, repeats=3)
        # host loop comparison (same facade, forced host backend)
        t_host = timeit(lambda: idx.query(wins[:32], "intersects",
                                          backend="host"),
                        repeats=2) / 32 * q
        csv.emit(f"device/batch_query_us/Q={q}", t,
                 f"per_query={t/q:.1f}us;host_loop={t_host:.0f}us;"
                 f"speedup=x{t_host/t:.1f};cap={idx.device_cap}")
    # planner-chosen path + refine-kernel selectivity estimation
    wins = windows(name, n, 0.0001, k=20)
    plan = idx.plan(wins, "intersects")
    counts = idx.count_candidates(wins, "intersects")
    csv.emit("device/count_candidates_us",
             timeit(lambda: idx.count_candidates(wins, "intersects"), repeats=3),
             f"plan={plan.backend};mean_cand={float(counts.mean()):.0f}")


def kernels(csv: Csv) -> None:
    rng = np.random.default_rng(0)
    # morton (XLA path)
    qx = jnp.asarray(rng.integers(0, 2**30, 1 << 20), jnp.int32)
    qy = jnp.asarray(rng.integers(0, 2**30, 1 << 20), jnp.int32)
    def f():
        return ops.morton_encode(qx, qy,
                                 use_pallas=False)[0].block_until_ready()
    f()
    csv.emit("kernels/morton_1M_us", timeit(f), "XLA path; pallas=TPU target")
    # refine count
    wins = jnp.asarray(rng.uniform(0, 1, (64, 4)).astype(np.float32))
    mbrs = jnp.asarray(rng.uniform(0, 1, (1 << 17, 4)).astype(np.float32))
    bounds = jnp.zeros((64, 2), jnp.int32).at[:, 1].set(1 << 17)
    def f():
        return ops.refine_count(wins, bounds, mbrs,
                                use_pallas=False).block_until_ready()
    f()
    csv.emit("kernels/refine_64x131k_us", timeit(f), "XLA path")
    # fused compact (jnp reference semantics)
    def f():
        return ops.refine_compact(wins, bounds, mbrs, mbrs, budget=256,
                                  use_pallas=False)[0].block_until_ready()
    f()
    csv.emit("kernels/compact_64x131k_us", timeit(f),
             "XLA path; budget=256; pallas=TPU target")
    # flash attention vs reference (XLA timing)
    q = jnp.asarray(rng.normal(0, 1, (1, 8, 1024, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 1024, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 1024, 64)), jnp.float32)
    def f():
        return ops.flash_attention(q, k, v,
                                   use_pallas=False).block_until_ready()
    f()
    csv.emit("kernels/attention_1k_us", timeit(f), "XLA ref; pallas=TPU target")
    # ssd chunked
    x = jnp.asarray(rng.normal(0, 1, (1, 1024, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, 1024, 8)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 1, 8), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (1, 1024, 64)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (1, 1024, 64)), jnp.float32)
    from repro.models.ssm import ssd_chunked
    def f():
        return ssd_chunked(x, dt, a, bm, cm, 128)[0].block_until_ready()
    f()
    csv.emit("kernels/ssd_1k_us", timeit(f), "XLA chunked path")


def run(csv: Csv, large: bool = False, quick: bool = False) -> dict:
    if quick:
        bench = refine_pipeline(csv, n=30_000, q=64)
        bench["knn"] = knn_pipeline(csv, n=30_000, q=64)
        print("BENCH " + json.dumps(bench))
        return bench
    n = min(scale_n(large), 200_000)
    bench = refine_pipeline(csv, n=min(n, 120_000))
    bench["knn"] = knn_pipeline(csv, n=min(n, 60_000), q=64)
    print("BENCH " + json.dumps(bench))
    device_batch_query(csv, n)
    kernels(csv)
    return bench
