"""Beyond-paper benchmarks: the TPU-native batched query path and the
Pallas kernels (timed via their XLA reference semantics on CPU; interpret
mode executes kernel bodies in Python and is not a timing proxy)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.device import batch_query, snapshot_from_host
from repro.kernels import ops

from .common import Csv, build_glin, dataset, scale_n, timeit, windows


def device_batch_query(csv: Csv, n: int) -> None:
    name = "cluster"
    g = build_glin(name, n, pl=10000)
    s = snapshot_from_host(g)
    gs = g.gs
    verts = jnp.asarray(gs.verts.astype(np.float32))
    nv = jnp.asarray(gs.nverts)
    kd = jnp.asarray(gs.kinds.astype(np.int32))
    mb = jnp.asarray(gs.mbrs.astype(np.float32))
    for q in (64, 512):
        wins = np.concatenate([windows(name, n, 0.0001, k=20)] * (q // 20 + 1))[:q]
        wj = jnp.asarray(wins.astype(np.float32))
        fn = lambda: batch_query(s, wj, verts, nv, kd, mb,
                                 relation="intersects", cap=2048)[1].block_until_ready()
        fn()  # compile
        t = timeit(fn, repeats=3)
        # host loop comparison
        t_host = timeit(lambda: [g.query(w, "intersects") for w in wins[:32]],
                        repeats=2) / 32 * q
        csv.emit(f"device/batch_query_us/Q={q}", t,
                 f"per_query={t/q:.1f}us;host_loop={t_host:.0f}us;speedup=x{t_host/t:.1f}")


def kernels(csv: Csv) -> None:
    rng = np.random.default_rng(0)
    # morton (XLA path)
    qx = jnp.asarray(rng.integers(0, 2**30, 1 << 20), jnp.int32)
    qy = jnp.asarray(rng.integers(0, 2**30, 1 << 20), jnp.int32)
    f = lambda: ops.morton_encode(qx, qy, use_pallas=False)[0].block_until_ready()
    f()
    csv.emit("kernels/morton_1M_us", timeit(f), "XLA path; pallas=TPU target")
    # refine count
    wins = jnp.asarray(rng.uniform(0, 1, (64, 4)).astype(np.float32))
    mbrs = jnp.asarray(rng.uniform(0, 1, (1 << 17, 4)).astype(np.float32))
    bounds = jnp.zeros((64, 2), jnp.int32).at[:, 1].set(1 << 17)
    f = lambda: ops.refine_count(wins, bounds, mbrs,
                                 use_pallas=False).block_until_ready()
    f()
    csv.emit("kernels/refine_64x131k_us", timeit(f), "XLA path")
    # flash attention vs reference (XLA timing)
    q = jnp.asarray(rng.normal(0, 1, (1, 8, 1024, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 1024, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 1024, 64)), jnp.float32)
    f = lambda: ops.flash_attention(q, k, v, use_pallas=False).block_until_ready()
    f()
    csv.emit("kernels/attention_1k_us", timeit(f), "XLA ref; pallas=TPU target")
    # ssd chunked
    x = jnp.asarray(rng.normal(0, 1, (1, 1024, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, 1024, 8)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 1, 8), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (1, 1024, 64)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (1, 1024, 64)), jnp.float32)
    from repro.models.ssm import ssd_chunked
    f = lambda: ssd_chunked(x, dt, a, bm, cm, 128)[0].block_until_ready()
    f()
    csv.emit("kernels/ssd_1k_us", timeit(f), "XLA chunked path")


def run(csv: Csv, large: bool = False) -> None:
    device_batch_query(csv, min(scale_n(large), 200_000))
    kernels(csv)
