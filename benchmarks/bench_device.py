"""Beyond-paper benchmarks: the TPU-native batched query path and the
Pallas kernels (timed via their XLA reference semantics on CPU; interpret
mode executes kernel bodies in Python and is not a timing proxy)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.engine import EngineConfig
from repro.kernels import ops

from .common import Csv, build_index, scale_n, timeit, windows


def device_batch_query(csv: Csv, n: int) -> None:
    name = "cluster"
    # Augmented Intersects runs are long: two-stage refinement (MBR masks over
    # the full run, exact checks on <=1024 survivors). The facade's adaptive
    # cap walks the overflow ladder once, so the timed region is exact AND
    # steady-state (the seed bench silently timed truncated results).
    idx = build_index(name, n, pl=10000,
                      engine=EngineConfig(initial_cap=4096, exact_budget=1024))
    idx.snapshot()  # materialize outside the timed region
    for q in (64, 512):
        wins = np.concatenate([windows(name, n, 0.0001, k=20)] * (q // 20 + 1))[:q]
        def fn(wins=wins):
            return idx.query(wins, "intersects", backend="device")
        fn()  # compile + settle the adaptive cap
        t = timeit(fn, repeats=3)
        # host loop comparison (same facade, forced host backend)
        t_host = timeit(lambda: idx.query(wins[:32], "intersects",
                                          backend="host"),
                        repeats=2) / 32 * q
        csv.emit(f"device/batch_query_us/Q={q}", t,
                 f"per_query={t/q:.1f}us;host_loop={t_host:.0f}us;"
                 f"speedup=x{t_host/t:.1f};cap={idx.device_cap}")
    # planner-chosen path + refine-kernel selectivity estimation
    wins = windows(name, n, 0.0001, k=20)
    plan = idx.plan(wins, "intersects")
    counts = idx.count_candidates(wins, "intersects")
    csv.emit("device/count_candidates_us",
             timeit(lambda: idx.count_candidates(wins, "intersects"), repeats=3),
             f"plan={plan.backend};mean_cand={float(counts.mean()):.0f}")


def kernels(csv: Csv) -> None:
    rng = np.random.default_rng(0)
    # morton (XLA path)
    qx = jnp.asarray(rng.integers(0, 2**30, 1 << 20), jnp.int32)
    qy = jnp.asarray(rng.integers(0, 2**30, 1 << 20), jnp.int32)
    def f():
        return ops.morton_encode(qx, qy,
                                 use_pallas=False)[0].block_until_ready()
    f()
    csv.emit("kernels/morton_1M_us", timeit(f), "XLA path; pallas=TPU target")
    # refine count
    wins = jnp.asarray(rng.uniform(0, 1, (64, 4)).astype(np.float32))
    mbrs = jnp.asarray(rng.uniform(0, 1, (1 << 17, 4)).astype(np.float32))
    bounds = jnp.zeros((64, 2), jnp.int32).at[:, 1].set(1 << 17)
    def f():
        return ops.refine_count(wins, bounds, mbrs,
                                use_pallas=False).block_until_ready()
    f()
    csv.emit("kernels/refine_64x131k_us", timeit(f), "XLA path")
    # flash attention vs reference (XLA timing)
    q = jnp.asarray(rng.normal(0, 1, (1, 8, 1024, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 1024, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 1024, 64)), jnp.float32)
    def f():
        return ops.flash_attention(q, k, v,
                                   use_pallas=False).block_until_ready()
    f()
    csv.emit("kernels/attention_1k_us", timeit(f), "XLA ref; pallas=TPU target")
    # ssd chunked
    x = jnp.asarray(rng.normal(0, 1, (1, 1024, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, 1024, 8)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 1, 8), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 1, (1, 1024, 64)), jnp.float32)
    cm = jnp.asarray(rng.normal(0, 1, (1, 1024, 64)), jnp.float32)
    from repro.models.ssm import ssd_chunked
    def f():
        return ssd_chunked(x, dt, a, bm, cm, 128)[0].block_until_ready()
    f()
    csv.emit("kernels/ssd_1k_us", timeit(f), "XLA chunked path")


def run(csv: Csv, large: bool = False) -> None:
    device_batch_query(csv, min(scale_n(large), 200_000))
    kernels(csv)
