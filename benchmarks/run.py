"""Benchmark orchestrator. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see common.Csv). GLIN benchmarks
mirror the paper's experiment suite (§IX); device/kernel benchmarks cover the
beyond-paper TPU-native path. Roofline artifacts are produced separately by
launch/dryrun.py and rendered by benchmarks/roofline_report.py.

``--quick`` is the CI bench-smoke mode: reduced scale, device + maintenance
+ sharded + serving + storage only, and the machine-readable ``BENCH`` dicts
are written to ``BENCH_device.json`` / ``BENCH_maintenance.json`` /
``BENCH_sharded.json`` / ``BENCH_serving.json`` / ``BENCH_storage.json``
in ``--bench-dir``
(default: the repo root — the committed perf trajectory;
``benchmarks.check_bench`` compares a fresh run against it).
"""
from __future__ import annotations

import argparse
import json
import pathlib

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true",
                    help="paper-scale datasets (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma list: glin,device,maintenance,sharded,"
                         "serving,storage")
    ap.add_argument("--quick", action="store_true",
                    help="CI bench-smoke: reduced scale, write BENCH_*.json")
    ap.add_argument("--bench-dir", default=str(REPO_ROOT),
                    help="where --quick writes BENCH_*.json")
    args = ap.parse_args()

    from .common import Csv
    csv = Csv()
    default = ("device,maintenance,sharded,serving,storage" if args.quick
               else "glin,device,maintenance,sharded,serving,storage")
    which = set((args.only or default).split(","))
    bench_jsons = {}
    print("name,us_per_call,derived")
    if "glin" in which:
        from . import bench_glin
        bench_glin.run(csv, large=args.large)
    if "device" in which:
        from . import bench_device
        bench_jsons["device"] = bench_device.run(csv, large=args.large,
                                                 quick=args.quick)
    if "maintenance" in which:
        from . import bench_maintenance
        if args.quick:
            bench_jsons["maintenance"] = bench_maintenance.run(
                csv, n=20_000, rounds=8)
        else:
            bench_jsons["maintenance"] = bench_maintenance.run(
                csv, large=args.large)
    if "sharded" in which:
        from . import bench_sharded
        if args.quick:
            bench_jsons["sharded"] = bench_sharded.run(csv, n=20_000, q=48)
        else:
            bench_jsons["sharded"] = bench_sharded.run(csv, large=args.large)
    if "serving" in which:
        from . import bench_serving
        bench_jsons["serving"] = bench_serving.run(csv, large=args.large,
                                                   quick=args.quick)
    if "storage" in which:
        from . import bench_glin
        n_store = 20_000 if args.quick else (1_000_000 if args.large
                                             else 120_000)
        bench_jsons["storage"] = bench_glin.storage(csv, n_store)
    if args.quick:
        out_dir = pathlib.Path(args.bench_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        for name, payload in bench_jsons.items():
            path = out_dir / f"BENCH_{name}.json"
            path.write_text(json.dumps(payload, indent=1) + "\n")
            print(f"# wrote {path}")
    print(f"# {len(csv.rows)} measurements")


if __name__ == "__main__":
    main()
