"""Benchmark orchestrator. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see common.Csv). GLIN benchmarks
mirror the paper's experiment suite (§IX); device/kernel benchmarks cover the
beyond-paper TPU-native path. Roofline artifacts are produced separately by
launch/dryrun.py and rendered by benchmarks/roofline_report.py.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true",
                    help="paper-scale datasets (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma list: glin,device,maintenance")
    args = ap.parse_args()

    from .common import Csv
    csv = Csv()
    which = set((args.only or "glin,device,maintenance").split(","))
    print("name,us_per_call,derived")
    if "glin" in which:
        from . import bench_glin
        bench_glin.run(csv, large=args.large)
    if "device" in which:
        from . import bench_device
        bench_device.run(csv, large=args.large)
    if "maintenance" in which:
        from . import bench_maintenance
        bench_maintenance.run(csv, large=args.large)
    print(f"# {len(csv.rows)} measurements")


if __name__ == "__main__":
    main()
