"""AdamW with fully-sharded (ZeRO-3 style) optimizer state.

Moments are fp32 and inherit the parameter PartitionSpecs, so optimizer
memory shards over pod×data exactly like FSDP parameters. Implemented from
scratch (no optax dependency) with a cosine-with-warmup schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    grad_clip: float = 1.0


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def adamw_init(params) -> Dict[str, Any]:
    def zeros32(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "mu": jax.tree_util.tree_map(zeros32, params),
        "nu": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        mhat = mu / c1
        nhat = nu / c2
        delta = (mhat / (jnp.sqrt(nhat) + cfg.eps)
                 + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_nu = jax.tree_util.tree_leaves(opt_state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    params = jax.tree_util.tree_unflatten(tdef, new_p)
    new_state = {"mu": jax.tree_util.tree_unflatten(tdef, new_mu),
                 "nu": jax.tree_util.tree_unflatten(tdef, new_nu),
                 "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params, new_state, metrics
