"""Step builders: train (grad-accum microbatching), prefill, decode.

Each builder returns ``(fn, in_shardings, out_shardings, specs)`` ready for
``jax.jit(fn, in_shardings=…, out_shardings=…).lower(*specs).compile()`` —
the exact path the multi-pod dry-run exercises. Input ShapeDtypeStructs are
produced by :func:`input_specs` (nothing is allocated).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as tf
from repro.sharding import MeshRules, constrain, logical_to_spec, use_rules
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["input_specs", "param_shardings", "build_train_step",
           "build_prefill_step", "build_decode_step"]


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; the dry-run's only "data")
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        if cfg.frontend == "embed_stub":
            batch = {"embeds": jax.ShapeDtypeStruct((b, cfg.d_model), dt)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b,), i32)}
        return batch
    if cfg.frontend == "embed_stub":
        batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
        if cfg.mrope:
            batch["positions"] = jax.ShapeDtypeStruct((b, 3, s), i32)
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return batch


def _batch_spec(rules: MeshRules, batch) -> Dict[str, NamedSharding]:
    out = {}
    for k, v in batch.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(rules.mesh, logical_to_spec(rules, logical, v.shape))
    return out


def param_shardings(cfg: ArchConfig, rules: MeshRules):
    """(param ShapeDtypeStructs, param NamedShardings) without allocation."""
    shapes = jax.eval_shape(functools.partial(tf.init_params, cfg),
                            jax.random.PRNGKey(0))
    logical = tf.logical_axes(cfg)

    def to_sharding(lg, shp):
        return NamedSharding(rules.mesh, logical_to_spec(rules, lg, tuple(shp.shape)))

    def is_lg(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)

    shardings = jax.tree_util.tree_map(to_sharding, logical, shapes,
                                       is_leaf=is_lg)
    return shapes, shardings


def _opt_shardings(rules: MeshRules, p_shapes, p_shardings):
    opt_shapes = jax.eval_shape(adamw_init, p_shapes)
    rep = NamedSharding(rules.mesh, P())
    return opt_shapes, {"mu": p_shardings, "nu": p_shardings, "step": rep}


# ---------------------------------------------------------------------------
# Train step (grad accumulation over microbatches)
# ---------------------------------------------------------------------------
def build_train_step(cfg: ArchConfig, shape: ShapeConfig, rules: MeshRules,
                     opt_cfg: AdamWConfig = AdamWConfig(),
                     microbatches: int = 8, remat: bool = True,
                     accum_dtype: Optional[str] = None):
    assert shape.global_batch % microbatches == 0
    mb = shape.global_batch // microbatches
    acc_dt = jnp.dtype(accum_dtype or cfg.dtype)

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            def split(v):
                return v.reshape(microbatches, mb, *v.shape[1:])

            mbatches = jax.tree_util.tree_map(split, batch)

            def mb_grad(carry, mb_batch):
                loss, grads = jax.value_and_grad(tf.loss_fn)(
                    params, cfg, mb_batch, constrain, remat=remat)
                acc_loss, acc_g = carry
                acc_g = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(acc_dt) / microbatches, acc_g, grads)
                return (acc_loss + loss / microbatches, acc_g), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(mb_grad, (jnp.zeros((), jnp.float32),
                                                      zero_g), mbatches)
            new_params, new_opt, metrics = adamw_update(grads, opt_state,
                                                        params, opt_cfg)
            metrics["loss"] = loss
            return new_params, new_opt, metrics

    p_shapes, p_sh = param_shardings(cfg, rules)
    o_shapes, o_sh = _opt_shardings(rules, p_shapes, p_sh)
    batch = input_specs(cfg, shape)
    b_sh = _batch_spec(rules, batch)
    rep = NamedSharding(rules.mesh, P())
    in_sh = (p_sh, o_sh, b_sh)
    out_sh = (p_sh, o_sh, {"loss": rep, "grad_norm": rep, "lr": rep})
    specs = (p_shapes, o_shapes, batch)
    return train_step, in_sh, out_sh, specs


# ---------------------------------------------------------------------------
# Prefill / decode steps
# ---------------------------------------------------------------------------
def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig, rules: MeshRules):
    def prefill_step(params, batch):
        with use_rules(rules):
            return tf.prefill(params, cfg, batch, constrain,
                              seq_len_cache=shape.seq_len)

    p_shapes, p_sh = param_shardings(cfg, rules)
    batch = input_specs(cfg, shape)
    b_sh = _batch_spec(rules, batch)
    cache_sh = _cache_shardings(cfg, shape, rules)
    logits_sh = NamedSharding(
        rules.mesh, logical_to_spec(rules, ("batch", "vocab"),
                                    (shape.global_batch, cfg.vocab)))
    in_sh = (p_sh, b_sh)
    out_sh = (logits_sh, cache_sh)
    specs = (p_shapes, batch)
    return prefill_step, in_sh, out_sh, specs


def _cache_shardings(cfg, shape, rules: MeshRules):
    cache_specs = tf.init_cache(cfg, shape.global_batch, shape.seq_len,
                                as_specs=True)
    logical = tf.cache_logical(cfg)

    def to_sh(lg, shp):
        return NamedSharding(rules.mesh, logical_to_spec(rules, lg, tuple(shp.shape)))

    def is_lg(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)

    return jax.tree_util.tree_map(to_sh, logical, cache_specs, is_leaf=is_lg)


def build_decode_step(cfg: ArchConfig, shape: ShapeConfig, rules: MeshRules):
    """One-token decode against a seq_len-deep cache (the decode_* cells)."""

    def decode_fn(params, cache, batch):
        with use_rules(rules):
            return tf.decode_step(params, cfg, batch, cache, constrain)

    p_shapes, p_sh = param_shardings(cfg, rules)
    cache_specs = tf.init_cache(cfg, shape.global_batch, shape.seq_len,
                                as_specs=True)
    cache_sh = _cache_shardings(cfg, shape, rules)
    batch = input_specs(cfg, shape)
    b_sh = _batch_spec(rules, batch)
    logits_sh = NamedSharding(
        rules.mesh, logical_to_spec(rules, ("batch", "vocab"),
                                    (shape.global_batch, cfg.vocab)))
    in_sh = (p_sh, cache_sh, b_sh)
    out_sh = (logits_sh, cache_sh)
    specs = (p_shapes, cache_specs, batch)
    return decode_fn, in_sh, out_sh, specs
