"""Int8 gradient compression for the DP all-reduce (error feedback).

Distributed-optimization trick (DESIGN.md §4): each DP rank quantizes its
local gradient to int8 with a per-tensor scale, psums the int8 payload in
int32 (no overflow: 127 · dp_size < 2^31 for any realistic mesh), and
dequantizes the mean. An error-feedback accumulator carries the quantization
residual into the next step, preserving convergence (Karimireddy et al.).

Payload on the wire: 1 byte/grad element instead of 2 (bf16) or 4 (f32) —
a 2–4× cut of the gradient all-reduce term. Exposed as a shard_map-wrapped
step builder; validated in tests (bounded error, toy-model convergence).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize", "dequantize", "compressed_psum_mean",
           "apply_error_feedback"]


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _shared_scale(g32: jax.Array, axis_name: str) -> jax.Array:
    """One scale for all ranks (pmax — a scalar collective) so the int8 sum
    dequantizes exactly: |error| <= shared_scale/2 per element."""
    local = jnp.max(jnp.abs(g32)) / 127.0
    return jnp.maximum(jax.lax.pmax(local, axis_name), 1e-30)


def compressed_psum_mean(g: jax.Array, axis_name: str) -> jax.Array:
    """Mean-all-reduce of ``g`` over ``axis_name`` with int8 payload.
    Must be called inside shard_map/pmap."""
    n = jax.lax.psum(1, axis_name)
    g32 = g.astype(jnp.float32)
    scale = _shared_scale(g32, axis_name)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)      # int32 wire sum
    return (acc.astype(jnp.float32) * scale / n).astype(g.dtype)


def apply_error_feedback(g: jax.Array, err: jax.Array, axis_name: str
                         ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback wrapper: compress (g + carried error), return the
    averaged gradient and the new local residual."""
    corrected = g.astype(jnp.float32) + err
    scale = _shared_scale(corrected, axis_name)
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - dequantize(q, scale)
    n = jax.lax.psum(1, axis_name)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    avg = acc.astype(jnp.float32) * scale / n
    return avg.astype(g.dtype), new_err
