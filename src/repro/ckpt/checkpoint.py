"""Fault-tolerant checkpointing: atomic manifests, async writes, elastic
restore onto a different mesh.

Layout::

    <dir>/step_000123/
        manifest.json      # step, tree paths, shapes, dtypes, mesh snapshot
        arrays.npz         # one entry per pytree leaf (path-encoded)
    <dir>/LATEST           # atomic pointer (rename-committed)

Restore never assumes the saving mesh: arrays are loaded host-side and
``device_put`` with the *target* shardings, so a run checkpointed on 512
chips resumes on 256 (elastic scale-down) or on 1 CPU device (tests). On a
multi-host deployment each host would write its addressable slice; the
manifest already records per-leaf global shapes so that layout is a pure
extension (per-host .npz fan-in on load).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_all"]

_EXECUTOR = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")
_LOCK = threading.Lock()


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic checkpoint. Returns the committed path."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:09d}"
    tmp = d / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": int(step),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "format": 1,
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                    # atomic commit
    with _LOCK:
        # Monotonic pointer: a slow async save finishing after a newer save
        # (e.g. the trainer's final sync save racing an in-flight background
        # one) must never swing LATEST back to an older step.
        cur = latest_step(str(d))
        if cur is None or step >= cur:
            ptr = d / ".LATEST_tmp"
            ptr.write_text(final.name)
            os.replace(ptr, d / "LATEST")     # atomic pointer swap
    return str(final)


def save_async(directory: str, step: int, tree: Any) -> Future:
    """Non-blocking checkpoint: snapshot to host memory now, write in a
    background thread (training continues immediately)."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    return _EXECUTOR.submit(save, directory, step, host_tree)


def wait_all() -> None:
    _EXECUTOR.submit(lambda: None).result()


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    ptr = d / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (d / name / "manifest.json").exists():
        return None
    return int(name.split("_")[-1])


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[int, Any]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings for elastic placement on the current mesh."""
    d = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = d / f"step_{step:09d}"
    data = np.load(path / "arrays.npz")

    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pp)
        for pp, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves_like))
    out = []
    for key, ref, sh in zip(flat_paths, leaves_like, sh_leaves):
        arr = data[key]
        expect = tuple(ref.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"leaf {key}: checkpoint shape {arr.shape} != "
                             f"expected {expect}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return step, jax.tree_util.tree_unflatten(treedef, out)
