"""Serving tier: the GLIN spatial-query server (replica router, admission
control, adaptive micro-batching). The LM slot-serving demo lives in
``repro.launch.serve``."""
from .server import Rejected, ServerConfig, SpatialQueryServer

__all__ = ["Rejected", "ServerConfig", "SpatialQueryServer"]
