"""Serving substrate: LM slot server + GLIN spatial-query server."""
from .server import SlotServer, SpatialQueryServer

__all__ = ["SlotServer", "SpatialQueryServer"]
