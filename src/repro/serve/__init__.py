"""Serving substrate: continuous-batching slot server (see server.py)."""
from .server import SlotServer

__all__ = ["SlotServer"]
