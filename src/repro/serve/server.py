"""Continuous-batching slot server (moved from launch/serve.py for reuse)."""
from repro.launch.serve import SlotServer  # single source of truth

__all__ = ["SlotServer"]
