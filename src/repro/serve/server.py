"""Serving layer: the continuous-batching LM slot server and the GLIN
spatial-query front-end.

This module is the single source of truth for server classes;
``launch/serve.py`` is a thin CLI launcher that re-exports from here.

* :class:`SlotServer`        — fixed-slot continuous batching around the
  transformer ``prefill`` / ``decode_step`` (used by the serving launcher and
  the serving integration test).
* :class:`SpatialQueryServer` — micro-batching front-end over
  :class:`repro.core.SpatialIndex.query`: requests are queued per relation and
  flushed as one batched facade query each, writes go through the facade so
  the device snapshot's mutation epoch stays correct.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import QueryBatch, SpatialIndex
from repro.core.relations import get_relation
from repro.sharding import constrain

__all__ = ["SlotServer", "SpatialQueryServer"]


class SlotServer:
    """Fixed-slot continuous batching around prefill/decode_step."""

    def __init__(self, cfg, params, slots: int, max_ctx: int):
        from repro.models import transformer as tf

        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_ctx = max_ctx
        self.cache = tf.init_cache(cfg, slots, max_ctx)
        self.active = [False] * slots
        self.remaining = [0] * slots
        self.generated: List[List[int]] = [[] for _ in range(slots)]
        self._decode = jax.jit(
            lambda p, c, b: tf.decode_step(p, cfg, b, c, constrain))
        self._prefill = jax.jit(
            lambda p, b: tf.prefill(p, cfg, b, constrain,
                                    seq_len_cache=max_ctx))

    def admit(self, slot: int, prompt: np.ndarray, gen_len: int) -> None:
        """Prefill a request and splice its state into `slot`."""
        batch = {"tokens": jnp.asarray(prompt[None, :])}
        _, cache1 = self._prefill(self.params, batch)

        def splice(dst, src):
            return dst.at[:, slot].set(src[:, 0])

        self.cache = jax.tree_util.tree_map(splice, self.cache, cache1)
        self.active[slot] = True
        self.remaining[slot] = gen_len
        self.generated[slot] = []

    def step(self, tokens: np.ndarray) -> np.ndarray:
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": jnp.asarray(tokens)})
        return np.asarray(jnp.argmax(logits, axis=-1))


class SpatialQueryServer:
    """Micro-batching spatial-query server over a :class:`SpatialIndex`.

    ``submit`` enqueues a window and returns a ticket; ``flush`` groups the
    queue by relation, issues ONE facade query per relation group (so the
    planner sees the full batch and can take the device path), and returns
    ``{ticket: hit ids}``. ``query`` is the submit-all + flush convenience.
    Writes are delegated to the facade, which records them as a delta against
    the published device snapshot — a flush after a write can never serve
    stale results, and under a write-heavy stream the planner serves the
    ``device+delta`` backend (snapshot + tombstone/added patch) instead of
    republishing the snapshot per write (``backend_counts`` records the mix).

    **Result cache.** Flushed results are cached per window, keyed on the
    facade's **serving generation** — ``(index epoch, snapshot publish
    count)`` — plus window bytes and relation: repeated windows (hot map
    tiles, dashboard refreshes) are served from the cache without touching
    the facade. The epoch component makes every write an implicit
    invalidation, and the publish component makes every snapshot swap one
    too — an async double-buffered republish (``EngineConfig.
    async_republish``) replaces the served snapshot WITHOUT bumping the
    epoch, so keying on the epoch alone could serve a hit computed against
    the previous snapshot. Entries from dead generations are dropped
    eagerly. ``backend_counts["cache"]`` counts cache-served queries next to
    the facade backends; ``cache_hits`` / ``cache_misses`` give the raw
    telemetry.

    ``async_republish=True`` flips the facade's double-buffering on at
    construction: under a write-heavy stream, snapshot republishes build on
    a background thread while ``flush``/``query`` keep serving the current
    snapshot + delta — the query stream never blocks on a rebuild.
    """

    CACHE_MAX_ENTRIES = 4096

    def __init__(self, index: SpatialIndex,
                 async_republish: Optional[bool] = None):
        self.index = index
        if async_republish is not None:
            index.config = dataclasses.replace(
                index.config, async_republish=async_republish)
        self._queue: List[Tuple[int, str, np.ndarray]] = []
        self._next_ticket = 0
        self.served_queries = 0
        self.served_batches = 0
        self.write_ops = 0
        self.backend_counts: Dict[str, int] = {}  # plan.backend -> batches
        self._cache: Dict[Tuple[Tuple[int, int], bytes, str], np.ndarray] = {}
        self._cache_gen: Tuple[int, int] = (-1, -1)
        self.cache_hits = 0
        self.cache_misses = 0

    def _record_plan(self, res) -> None:
        b = res.plan.backend
        self.backend_counts[b] = self.backend_counts.get(b, 0) + 1

    def _cache_lookup(self, gen: Tuple[int, int], w: np.ndarray,
                      relation: str):
        """Return a writable copy of the cached hit ids for a window, or
        None. A write bumps the epoch and a snapshot swap bumps the publish
        count, so stale entries never match; the whole cache is dropped when
        the serving generation moves (dead keys can never hit again). Hits
        are copies so callers get the same mutable-array contract on hits
        and misses alike."""
        if self._cache_gen != gen:
            self._cache.clear()
            self._cache_gen = gen
        hit = self._cache.get((gen, w.tobytes(), relation))
        return None if hit is None else hit.copy()

    def _cache_store(self, gen: Tuple[int, int], w: np.ndarray, relation: str,
                     ids: np.ndarray) -> None:
        if gen != self._cache_gen or gen != self.index.serving_generation:
            return         # a write or a snapshot swap landed mid-flush
        if len(self._cache) >= self.CACHE_MAX_ENTRIES:
            self._cache.pop(next(iter(self._cache)))   # FIFO eviction
        # cache a frozen copy, not the array handed to the caller: an
        # in-place mutation by one caller must not poison later hits
        frozen = ids.copy()
        frozen.setflags(write=False)
        self._cache[(gen, w.tobytes(), relation)] = frozen

    # ------------------------------------------------------------------ reads
    def submit(self, window: np.ndarray, relation: str = "intersects") -> int:
        get_relation(relation)  # fail fast, not at flush time
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, relation,
                            np.asarray(window, np.float64).reshape(4)))
        return ticket

    def flush(self) -> Dict[int, np.ndarray]:
        if not self._queue:
            return {}
        gen = self.index.serving_generation
        out: Dict[int, np.ndarray] = {}
        by_rel: Dict[str, List[Tuple[int, np.ndarray]]] = {}
        cached = 0
        for ticket, rel, w in self._queue:
            hit = self._cache_lookup(gen, w, rel)
            if hit is not None:
                out[ticket] = hit
                cached += 1
            else:
                by_rel.setdefault(rel, []).append((ticket, w))
        plans = []
        for rel, items in by_rel.items():
            windows = np.stack([w for _, w in items])
            res = self.index.query(windows, rel)
            plans.append(res)
            for (ticket, w), ids in zip(items, res):
                out[ticket] = ids
                self._cache_store(gen, w, rel, ids)
        # commit counters and drop the queue only once every group succeeded
        # — an exception above (e.g. device OverflowError) leaves all tickets
        # retryable WITHOUT having skewed the telemetry
        for res in plans:
            self._record_plan(res)
        self.cache_hits += cached
        self.cache_misses += sum(len(v) for v in by_rel.values())
        if cached:
            self.backend_counts["cache"] = (
                self.backend_counts.get("cache", 0) + cached)
        self._queue.clear()
        self.served_queries += len(out)
        self.served_batches += len(by_rel)
        return out

    def query(self, windows: np.ndarray, relation: str = "intersects",
              backend: Optional[str] = None):
        """Batched one-shot: queue nothing, serve ``windows`` directly."""
        res = self.index.query(
            QueryBatch.window(windows, relation, backend=backend))
        self._record_plan(res)
        self.served_queries += len(res)
        self.served_batches += 1
        return res

    # ----------------------------------------------------------------- writes
    def insert(self, verts: np.ndarray, nverts: int, kind: int = 0) -> int:
        self.write_ops += 1
        return self.index.insert(verts, nverts, kind)

    def delete(self, rec: int) -> bool:
        self.write_ops += 1
        return self.index.delete(rec)
