"""Serving tier: the GLIN spatial-query front-end.

:class:`SpatialQueryServer` is a load-tested micro-batching server over
:class:`repro.core.SpatialIndex`:

* **replica router** — query batches are dispatched to the least-loaded of
  ``ServerConfig.replicas`` device placements (``EngineConfig.replicas``:
  independent ``device_put`` fan-outs of the published snapshot + payload,
  refreshed from the same ``HostCapture`` at every publish, so the
  write/delta stream republishes to all replicas at once);
* **bounded queues, backpressure, admission control** — per-tenant FIFO
  queues drained in weighted-fair round-robin order; past
  ``ServerConfig.max_queue`` (and, above the ``fair_watermark``, past a
  tenant's weighted share) submissions are shed with an explicit
  :class:`Rejected` result, never silently dropped;
* **adaptive micro-batching** — the serving loop sizes each batch from the
  observed queue depth (clamped to ``min_batch``/``max_batch``) and, under
  light load, waits a gather window derived from the EWMA per-query service
  time so batches fill instead of fragmenting;
* **overlapped group flushes** — distinct relation groups execute
  concurrently on a worker pool (each picking its own replica) instead of
  serially, with the PR-4 telemetry-atomicity contract intact: ``flush()``
  commits counters, cache entries and the drained queue slice only once
  EVERY group succeeded — a failed group restores all sibling tickets
  untouched and unreported.

The old ``SlotServer`` (continuous-batching LM demo) lives in
``repro.launch.serve``, its only consumer.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import QueryBatch, SpatialIndex
from repro.core.relations import get_relation

__all__ = ["Rejected", "ServerConfig", "SpatialQueryServer"]


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Explicit load-shed marker: a submission the admission controller (or a
    failed serving batch) turned away. Delivered through the same channels as
    hit ids — ``flush()`` values and ``result()`` — so shed requests surface
    to the caller instead of vanishing."""

    reason: str
    tenant: str = "default"
    relation: str = ""


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Serving-tier knobs (all backpressure off by default: ``max_queue=0``
    admits everything, ``replicas=1``/``overlap_groups=True`` still overlap
    relation groups on one placement)."""

    replicas: int = 1            # device placements to route over (also
                                 # raises EngineConfig.replicas on the index)
    max_queue: int = 0           # total queued requests before shedding
                                 # (0 = unbounded, admission control off)
    fair_watermark: float = 0.5  # fraction of max_queue above which a tenant
                                 # is capped at its weighted share
    tenant_weights: Optional[Dict[str, float]] = None  # default weight 1.0
    min_batch: int = 8           # adaptive micro-batch floor (pump mode)
    max_batch: int = 4096        # micro-batch ceiling (depth is clamped here)
    adaptive_batch: bool = True  # gather-window batching in the pump loop
    gather_window_s: float = 0.002  # max extra wait for a batch to fill
    overlap_groups: bool = True  # relation groups run concurrently
    max_workers: Optional[int] = None  # pool size; default max(replicas, 2)
                                       # when overlapping, capped at the
                                       # host's core count, else 1

    def workers(self) -> int:
        if self.max_workers is not None:
            return max(1, self.max_workers)
        if not self.overlap_groups:
            return 1
        # overlap degree is capped at the core count: concurrent XLA host
        # computations on an oversubscribed machine thrash instead of
        # overlapping (measured ~25% throughput LOSS from 2 workers on one
        # core), and a single-core host serves groups fastest back-to-back.
        # An explicit max_workers overrides the cap verbatim.
        return max(1, min(max(self.replicas, 2), os.cpu_count() or 1))


# one queued request: (ticket, tenant, relation, window)
_Pending = Tuple[int, str, str, np.ndarray]


class SpatialQueryServer:
    """Micro-batching spatial-query server over a :class:`SpatialIndex`.

    ``submit`` enqueues a window and returns a ticket; ``flush`` drains the
    queues in weighted-fair order, groups by relation, issues ONE facade
    query per relation group (so the planner sees the full batch and can
    take the device path) — groups overlapping on the worker pool, each
    routed to the least-loaded replica — and returns ``{ticket: hit ids}``
    (shed tickets map to :class:`Rejected`). ``query`` is the submit-all +
    flush convenience. Writes are delegated to the facade, which records
    them as a delta against the published device snapshot — a flush after a
    write can never serve stale results, and under a write-heavy stream the
    planner serves the ``device+delta`` backend (snapshot + tombstone/added
    patch) instead of republishing per write (``backend_counts`` records the
    mix).

    **Serving loop.** ``start()`` spawns a dispatcher thread that drains the
    queues continuously with adaptive micro-batching (batch size from queue
    depth, gather window from the per-batch service-time EWMA) and resolves
    tickets asynchronously; ``result(ticket)`` blocks for one. ``submit`` /
    ``insert`` / ``delete`` are thread-safe in both modes — the facade
    serializes writes against query prologues internally.

    **Result cache.** Flushed results are cached per window, keyed on the
    facade's **serving generation** — ``(index epoch, snapshot publish
    count)`` — plus window bytes and relation: repeated windows (hot map
    tiles, dashboard refreshes) are served from the cache without touching
    the facade. The epoch component makes every write an implicit
    invalidation, and the publish component makes every snapshot swap one
    too — an async double-buffered republish (``EngineConfig.
    async_republish``) replaces the served snapshot WITHOUT bumping the
    epoch, so keying on the epoch alone could serve a hit computed against
    the previous snapshot. Entries from dead generations are dropped
    eagerly. ``backend_counts["cache"]`` counts cache-served queries next to
    the facade backends; ``cache_hits`` / ``cache_misses`` give the raw
    telemetry.

    **kNN.** ``submit_knn(point, k)`` rides the same machinery: the point is
    encoded as its degenerate window under the pseudo-relation ``knn:<k>``,
    so one flush issues ONE device-complete knn batch per distinct k,
    duplicate points coalesce, and kNN batches become cacheable single-plan
    flushes — a repeated point is served its ``(ids, distances)`` pair
    straight from the result cache under the same generation keying.

    **Request coalescing.** Within one relation group of a micro-batch,
    duplicate windows (byte-identical) are folded into a single engine row
    before the facade call — under hot-query skew the engine sees the
    distinct working set, not the arrival stream. Each caller still gets an
    independent writable result array, and the ``coalesced`` counter tracks
    how many duplicates were folded.

    ``async_republish=True`` flips the facade's double-buffering on at
    construction: under a write-heavy stream, snapshot republishes build on
    a background thread while ``flush``/``query`` keep serving the current
    snapshot + delta — the query stream never blocks on a rebuild.
    """

    CACHE_MAX_ENTRIES = 4096

    def __init__(self, index: SpatialIndex,
                 async_republish: Optional[bool] = None,
                 config: Optional[ServerConfig] = None):
        self.index = index
        self.config = config or ServerConfig()
        eng_updates = {}
        if async_republish is not None:
            eng_updates["async_republish"] = async_republish
        if self.config.replicas > index.config.replicas:
            eng_updates["replicas"] = self.config.replicas
        if eng_updates:
            index.config = dataclasses.replace(index.config, **eng_updates)
        # one lock (the Condition's) guards every mutable server field;
        # facade queries run OUTSIDE it (the engine has its own lock)
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._queues: Dict[str, Deque[_Pending]] = {}
        self._tenant_order: List[str] = []
        self._rr = 0                       # weighted round-robin cursor
        self._depth = 0                    # total queued requests
        self._next_ticket = 0
        self._rejected: Dict[int, Rejected] = {}   # shed, awaiting flush()
        self._done: Dict[int, Any] = {}            # pump-mode results
        self._pool: Optional[ThreadPoolExecutor] = None
        self._slots: Optional[threading.Semaphore] = None
        self._pump: Optional[threading.Thread] = None
        self._running = False
        # telemetry (commit rules: flush() counters move only after every
        # group of a flush succeeded; pump-mode batches commit per group)
        self.served_queries = 0
        self.served_batches = 0
        self.write_ops = 0
        self.shed_count = 0
        self.failed_batches = 0
        self.backend_counts: Dict[str, int] = {}  # plan.backend -> batches
        self.batch_hist: Dict[int, int] = {}      # pow2 bucket -> batches
        self.replica_queries = [0] * max(1, self.config.replicas)
        self._replica_inflight = [0] * max(1, self.config.replicas)
        self._tenant_stats: Dict[str, Dict[str, int]] = {}
        self._service_ewma: Optional[float] = None  # s per served batch
        self._query_ewma: Optional[float] = None    # s per served query
        # window rows cache an ids array; knn rows an (ids, distances) pair
        self._cache: Dict[Tuple[Tuple[int, int], bytes, str], Any] = {}
        self._cache_gen: Tuple[int, int] = (-1, -1)
        self.cache_hits = 0
        self.cache_misses = 0
        self.coalesced = 0      # duplicate windows folded within a group

    # ------------------------------------------------------------------ cache
    def _record_plan(self, res) -> None:
        b = res.plan.backend
        self.backend_counts[b] = self.backend_counts.get(b, 0) + 1

    def _cache_lookup(self, gen: Tuple[int, int], w: np.ndarray,
                      relation: str):
        """Return a writable copy of the cached hit ids for a window, or
        None. A write bumps the epoch and a snapshot swap bumps the publish
        count, so stale entries never match; the whole cache is dropped when
        the serving generation moves (dead keys can never hit again). Hits
        are copies so callers get the same mutable-array contract on hits
        and misses alike. Call under the server lock."""
        if self._cache_gen != gen:
            self._cache.clear()
            self._cache_gen = gen
        hit = self._cache.get((gen, w.tobytes(), relation))
        if hit is None:
            return None
        if isinstance(hit, tuple):          # knn: (ids, distances)
            return tuple(a.copy() for a in hit)
        return hit.copy()

    def _cache_store(self, gen: Tuple[int, int], w: np.ndarray, relation: str,
                     ids: np.ndarray) -> None:
        if gen != self._cache_gen or gen != self.index.serving_generation:
            return         # a write or a snapshot swap landed mid-flush
        if len(self._cache) >= self.CACHE_MAX_ENTRIES:
            self._cache.pop(next(iter(self._cache)))   # FIFO eviction
        # cache a frozen copy, not the array handed to the caller: an
        # in-place mutation by one caller must not poison later hits
        def freeze(a):
            f = a.copy()
            f.setflags(write=False)
            return f
        frozen = (tuple(freeze(a) for a in ids) if isinstance(ids, tuple)
                  else freeze(ids))          # knn rows are (ids, distances)
        self._cache[(gen, w.tobytes(), relation)] = frozen

    # ------------------------------------------------------------- admission
    def _weight(self, tenant: str) -> float:
        w = (self.config.tenant_weights or {}).get(tenant, 1.0)
        return max(w, 1e-9)

    def _tenant(self, tenant: str) -> Dict[str, int]:
        ts = self._tenant_stats.get(tenant)
        if ts is None:
            ts = self._tenant_stats[tenant] = {
                "admitted": 0, "rejected": 0, "served": 0}
        return ts

    def _admit_locked(self, tenant: str) -> Tuple[bool, str]:
        """Admission control: global queue bound, then (above the fairness
        watermark) a per-tenant weighted share of the bound — a flooding
        tenant saturates only its share while others keep being admitted.
        Shares divide over every tenant SEEN so far (not just the currently
        queued ones), so a trickle tenant's slice is reserved even while its
        queue happens to be empty."""
        cfg = self.config
        if cfg.max_queue <= 0:
            return True, ""
        if self._depth >= cfg.max_queue:
            return False, f"queue full ({self._depth}/{cfg.max_queue})"
        if self._depth >= cfg.fair_watermark * cfg.max_queue:
            known = set(self._tenant_stats)
            known.add(tenant)
            total = sum(self._weight(t) for t in known)
            share = max(1, int(cfg.max_queue * self._weight(tenant) / total))
            mine = len(self._queues.get(tenant, ()))
            if mine >= share:
                return False, (f"tenant {tenant!r} over fair share "
                               f"({mine}/{share} above watermark)")
        return True, ""

    # ------------------------------------------------------------------ reads
    def submit(self, window: np.ndarray, relation: str = "intersects",
               tenant: str = "default") -> int:
        """Enqueue one window; returns a ticket. A shed submission still
        returns a ticket — it resolves to a :class:`Rejected` (via
        ``flush()`` or ``result()``), never a silent drop."""
        get_relation(relation)  # fail fast, not at flush time
        w = np.asarray(window, np.float64).reshape(4)
        return self._enqueue(w, relation, tenant)

    def submit_knn(self, point: np.ndarray, k: int,
                   tenant: str = "default") -> int:
        """Enqueue one kNN point; the ticket resolves to ``(ids,
        distances)``. The point is encoded as its degenerate window and
        grouped under the pseudo-relation ``knn:<k>`` — one flush issues ONE
        device-complete knn batch per distinct k, duplicate points coalesce,
        repeated points hit the result cache."""
        if int(k) < 1:
            raise ValueError(f"knn needs k >= 1, got {k}")
        p = np.asarray(point, np.float64).reshape(2)
        w = np.array([p[0], p[1], p[0], p[1]], np.float64)
        return self._enqueue(w, f"knn:{int(k)}", tenant)

    def _enqueue(self, w: np.ndarray, relation: str, tenant: str) -> int:
        with self._cond:
            ticket = self._next_ticket
            self._next_ticket += 1
            ts = self._tenant(tenant)
            ok, reason = self._admit_locked(tenant)
            if not ok:
                rej = Rejected(reason=reason, tenant=tenant, relation=relation)
                self.shed_count += 1
                ts["rejected"] += 1
                if self._running:
                    self._done[ticket] = (rej, time.perf_counter())
                else:
                    self._rejected[ticket] = rej
                self._cond.notify_all()
                return ticket
            ts["admitted"] += 1
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = deque()
                self._tenant_order.append(tenant)
            q.append((ticket, tenant, relation, w))
            self._depth += 1
            self._cond.notify_all()
        return ticket

    def _drain_locked(self, limit: Optional[int]) -> List[_Pending]:
        """Pop up to ``limit`` requests (all when None) in weighted
        round-robin order over tenants, FIFO within a tenant: each pass
        hands tenant *t* up to ``remaining * w_t / W`` slots (min 1),
        rotating the starting tenant so no tenant is structurally first."""
        take = self._depth if limit is None else min(limit, self._depth)
        out: List[_Pending] = []
        while len(out) < take:
            active = [t for t in self._tenant_order if self._queues.get(t)]
            if not active:
                break
            total = sum(self._weight(t) for t in active)
            start, n = self._rr, len(active)
            self._rr = (self._rr + 1) % n
            rem = take - len(out)
            for i in range(n):
                t = active[(start + i) % n]
                quota = max(1, int(rem * self._weight(t) / total))
                q = self._queues[t]
                for _ in range(min(quota, len(q))):
                    if len(out) >= take:
                        break
                    out.append(q.popleft())
        self._depth -= len(out)
        return out

    def _restore_locked(self, items: List[_Pending]) -> None:
        """Push a drained slice back to the FRONT of the queues, preserving
        per-tenant FIFO order (a failed flush leaves every ticket
        retryable)."""
        for item in reversed(items):
            t = item[1]
            q = self._queues.get(t)
            if q is None:
                q = self._queues[t] = deque()
                self._tenant_order.append(t)
            q.appendleft(item)
        self._depth += len(items)

    # --------------------------------------------------------- group dispatch
    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            w = self.config.workers()
            self._pool = ThreadPoolExecutor(
                max_workers=w, thread_name_prefix="glin-serve")
            self._slots = threading.Semaphore(w)
        return self._pool

    def _pick_replica_locked(self) -> int:
        """Least-loaded dispatch over the configured replica placements."""
        inflight = self._replica_inflight
        rep = min(range(len(inflight)), key=inflight.__getitem__)
        inflight[rep] += 1
        return rep

    def _run_group(self, rel: str, items: List[_Pending]):
        """One facade query for one relation group, routed to the
        least-loaded replica. Duplicate windows within the group are
        coalesced into one engine row; every caller still receives its own
        writable ids array (the first claim gets the engine's array, each
        duplicate a copy). Returns ``(res, per_item, ncoal, replica,
        seconds)`` with ``per_item`` aligned to ``items``."""
        uniq: Dict[bytes, int] = {}
        slot: List[int] = []
        rows: List[np.ndarray] = []
        for _, _, _, w in items:
            k = w.tobytes()
            mi = uniq.get(k)
            if mi is None:
                mi = uniq[k] = len(rows)
                rows.append(w)
            slot.append(mi)
        ncoal = len(items) - len(rows)
        windows = np.stack(rows)
        knn_k = int(rel[4:]) if rel.startswith("knn:") else None
        batch = (QueryBatch.knn(windows[:, :2], knn_k)
                 if knn_k is not None else QueryBatch.window(windows, rel))
        with self._lock:
            rep = self._pick_replica_locked()
        t0 = time.perf_counter()
        try:
            res = self.index.query(batch, replica=rep)
        finally:
            dt = time.perf_counter() - t0
            dtq = dt / max(1, len(items))
            with self._lock:
                self._replica_inflight[rep] -= 1
                a = 0.3       # EWMAs of service time (pump gather sizing)
                self._service_ewma = (dt if self._service_ewma is None
                                      else a * dt + (1 - a) * self._service_ewma)
                self._query_ewma = (dtq if self._query_ewma is None
                                    else a * dtq + (1 - a) * self._query_ewma)
        claimed = [False] * len(rows)
        per_item: List[Any] = []
        for mi in slot:
            if knn_k is not None:           # knn rows: (ids, distances)
                v = (res.ids[mi], res.distances[mi])
                per_item.append(tuple(a.copy() for a in v)
                                if claimed[mi] else v)
            else:
                per_item.append(res[mi].copy() if claimed[mi] else res[mi])
            claimed[mi] = True
        return res, per_item, ncoal, rep, dt

    @staticmethod
    def _hist_bucket(n: int) -> int:
        return 1 << max(n - 1, 0).bit_length()

    def flush(self) -> Dict[int, Any]:
        """Serve everything queued; returns ``{ticket: hit ids | Rejected}``.

        Relation groups run concurrently on the worker pool
        (``ServerConfig.overlap_groups``), each on its least-loaded replica.
        Telemetry atomicity (PR-4 contract, extended to the overlapped
        path): counters, cache entries and the queue drain commit only once
        EVERY group succeeded — one failed group restores all drained
        tickets (including its siblings' completed work, which is discarded)
        and re-raises without double-counting or dropping anything."""
        with self._cond:
            items = self._drain_locked(None)
            if not items and not self._rejected:
                return {}
            gen = self.index.serving_generation
            out: Dict[int, Any] = {}
            cached: List[_Pending] = []
            by_rel: Dict[str, List[_Pending]] = {}
            for item in items:
                ticket, tenant, rel, w = item
                hit = self._cache_lookup(gen, w, rel)
                if hit is not None:
                    out[ticket] = hit
                    cached.append(item)
                else:
                    by_rel.setdefault(rel, []).append(item)
        groups = list(by_rel.items())
        results: List[Tuple[str, List[_Pending], Any]] = []
        try:
            if len(groups) > 1 and self.config.overlap_groups:
                pool = self._ensure_pool()
                futs = [(rel, g, pool.submit(self._run_group, rel, g))
                        for rel, g in groups]
                first_err = None
                for rel, g, f in futs:
                    try:
                        results.append((rel, g, f.result()))
                    except BaseException as e:   # noqa: BLE001 — re-raised
                        if first_err is None:
                            first_err = e
                if first_err is not None:
                    raise first_err
            else:
                for rel, g in groups:
                    results.append((rel, g, self._run_group(rel, g)))
        except BaseException:
            with self._cond:
                self._restore_locked(items)
            raise
        # ---- commit: every group succeeded ----
        with self._cond:
            for rel, g, (res, per_item, ncoal, rep, _dt) in results:
                for (ticket, tenant, r, w), ids in zip(g, per_item):
                    out[ticket] = ids
                    self._cache_store(gen, w, r, ids)
                    self._tenant(tenant)["served"] += 1
                self._record_plan(res)
                self.coalesced += ncoal
                self.replica_queries[rep] += len(g)
                b = self._hist_bucket(len(g))
                self.batch_hist[b] = self.batch_hist.get(b, 0) + 1
            for item in cached:
                self._tenant(item[1])["served"] += 1
            shed = self._rejected
            self._rejected = {}
            out.update(shed)
            self.cache_hits += len(cached)
            self.cache_misses += sum(len(g) for _, g in groups)
            if cached:
                self.backend_counts["cache"] = (
                    self.backend_counts.get("cache", 0) + len(cached))
            self.served_queries += len(out) - len(shed)
            self.served_batches += len(groups)
        return out

    def query(self, windows: np.ndarray, relation: str = "intersects",
              backend: Optional[str] = None):
        """Batched one-shot: queue nothing, serve ``windows`` directly."""
        res = self.index.query(
            QueryBatch.window(windows, relation, backend=backend))
        with self._lock:
            self._record_plan(res)
            self.served_queries += len(res)
            self.served_batches += 1
        return res

    # ----------------------------------------------------------- serving loop
    def start(self) -> "SpatialQueryServer":
        """Spawn the dispatcher thread: queues drain continuously with
        adaptive micro-batching; results resolve via :meth:`result`."""
        with self._cond:
            if self._running:
                return self
            self._running = True
            self._ensure_pool()
            self._pump = threading.Thread(
                target=self._pump_loop, daemon=True, name="glin-serve-pump")
            self._pump.start()
        return self

    def stop(self) -> None:
        """Stop the dispatcher, drain what is left (no waiter hangs), and
        wait for in-flight groups."""
        with self._cond:
            if not self._running:
                return
            self._running = False
            self._cond.notify_all()
        if self._pump is not None:
            self._pump.join()
            self._pump = None
        while True:
            with self._cond:
                items = self._drain_locked(None)
            if not items:
                break
            self._dispatch(items, wait=True)
        # barrier: drain every worker slot so in-flight groups finish
        w = self.config.workers()
        for _ in range(w):
            self._slots.acquire()
        for _ in range(w):
            self._slots.release()

    def result(self, ticket: int, timeout: Optional[float] = None):
        """Block until ``ticket`` resolves (pump mode); returns hit ids or a
        :class:`Rejected`."""
        val, _ts = self.result_at(ticket, timeout)
        return val

    def result_at(self, ticket: int, timeout: Optional[float] = None):
        """Like :meth:`result` but returns ``(value, perf_counter at
        resolution)`` — load harnesses measure latency from the resolution
        stamp, not from when the collector got around to asking."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            while ticket not in self._done:
                rem = (None if deadline is None
                       else deadline - time.perf_counter())
                if rem is not None and rem <= 0:
                    raise TimeoutError(f"ticket {ticket} not served")
                self._cond.wait(0.1 if rem is None else min(rem, 0.1))
            return self._done.pop(ticket)

    def _batch_target_locked(self) -> int:
        cfg = self.config
        return max(min(self._depth, cfg.max_batch), min(cfg.min_batch,
                                                        cfg.max_batch))

    def _gather_window(self) -> float:
        """How long the pump may wait for a batch to fill: half the EWMA
        service time of the batch it is trying to BUILD (``min_batch``
        queries at the per-query EWMA), capped by ``gather_window_s``.
        Scaling by the target batch rather than the last-served batch
        matters: under light load the last batch is size 1 and its service
        time is a few ms — a window derived from it would never open and
        the pump would be trapped serving singletons forever."""
        ewma_q = self._query_ewma or 0.0
        floor = min(self.config.min_batch, self.config.max_batch)
        return min(self.config.gather_window_s, 0.5 * floor * ewma_q)

    def _pump_loop(self) -> None:
        cfg = self.config
        while True:
            with self._cond:
                ahead = self._depth == 0   # idle => the server is ahead of
                while self._running and self._depth == 0:   # the load
                    self._cond.wait(0.05)
                if not self._running:
                    return
                depth = self._depth
                target = self._batch_target_locked()
            # Gather (wait for the batch to fill) ONLY when the pump went
            # idle before this cycle: anything queued then is fresh, so the
            # wait costs bounded latency and buys a fuller batch. When work
            # was already waiting as the previous batch finished, the server
            # is at or past saturation — every gather tick would be idle
            # time repaid later with interest (draining the whole queue,
            # idling a window, and repeating caps throughput at roughly
            # min_batch per window, well below the batched service rate).
            if (cfg.adaptive_batch and ahead
                    and depth < min(cfg.min_batch, cfg.max_batch)):
                deadline = time.perf_counter() + self._gather_window()
                with self._cond:
                    while (self._running and self._depth < cfg.min_batch):
                        rem = deadline - time.perf_counter()
                        if rem <= 0:
                            break
                        self._cond.wait(rem)
                    target = self._batch_target_locked()
            with self._cond:
                items = self._drain_locked(target)
            if items:
                self._dispatch(items, wait=False)

    def _dispatch(self, items: List[_Pending], wait: bool) -> None:
        """Group a drained batch by relation and hand each group to the
        worker pool, bounded by the slot semaphore — when every worker is
        busy the pump blocks here, queue depth grows, and admission control
        sheds: backpressure end to end."""
        by_rel: Dict[str, List[_Pending]] = {}
        for item in items:
            by_rel.setdefault(item[2], []).append(item)
        pool = self._ensure_pool()
        futs = []
        for rel, g in by_rel.items():
            self._slots.acquire()
            futs.append(pool.submit(self._serve_group_task, rel, g))
        if wait:
            for f in futs:
                f.result()

    def _serve_group_task(self, rel: str, items: List[_Pending]) -> None:
        """Pump-mode worker: serve one relation group, resolve its tickets.
        A failed group resolves every ticket as :class:`Rejected` (counted
        in ``failed_batches``) — waiters never hang on an exception."""
        try:
            with self._cond:
                gen = self.index.serving_generation
                todo: List[_Pending] = []
                for item in items:
                    ticket, tenant, r, w = item
                    hit = self._cache_lookup(gen, w, r)
                    if hit is not None:
                        self._done[ticket] = (hit, time.perf_counter())
                        self._tenant(tenant)["served"] += 1
                        self.cache_hits += 1
                        self.served_queries += 1
                        self.backend_counts["cache"] = (
                            self.backend_counts.get("cache", 0) + 1)
                    else:
                        todo.append(item)
                self._cond.notify_all()
            if not todo:
                return
            res, per_item, ncoal, rep, _dt = self._run_group(rel, todo)
            now = time.perf_counter()
            with self._cond:
                for (ticket, tenant, r, w), ids in zip(todo, per_item):
                    self._cache_store(gen, w, r, ids)
                    self._done[ticket] = (ids, now)
                    self._tenant(tenant)["served"] += 1
                self._record_plan(res)
                self.coalesced += ncoal
                self.cache_misses += len(todo)
                self.served_queries += len(todo)
                self.served_batches += 1
                self.replica_queries[rep] += len(todo)
                b = self._hist_bucket(len(todo))
                self.batch_hist[b] = self.batch_hist.get(b, 0) + 1
                self._cond.notify_all()
        except BaseException as e:   # noqa: BLE001 — resolved as Rejected
            now = time.perf_counter()
            with self._cond:
                self.failed_batches += 1
                for ticket, tenant, r, w in items:
                    if ticket not in self._done:
                        self._done[ticket] = (
                            Rejected(f"serve error: {e!r}", tenant, r), now)
                self._cond.notify_all()
        finally:
            self._slots.release()

    # ------------------------------------------------------------- telemetry
    def stats(self) -> dict:
        """One JSON-serializable snapshot of the serving tier. Includes the
        facade's per-stage execution telemetry (``engine_stages``) so one
        stats call covers the whole pipeline: queue → stage → replica."""
        # grab engine telemetry before taking the server lock (the facade
        # has its own lock; never hold both)
        eng_stages = self.index.stats().get("stages", {})
        with self._lock:
            return {
                "queue_depth": self._depth,
                "queued_by_tenant": {t: len(q)
                                     for t, q in self._queues.items() if q},
                "shed": self.shed_count,
                "failed_batches": self.failed_batches,
                "tenants": {t: dict(v)
                            for t, v in sorted(self._tenant_stats.items())},
                "batch_size_hist": {str(k): v for k, v in
                                    sorted(self.batch_hist.items())},
                "replica_queries": list(self.replica_queries),
                "replica_inflight": list(self._replica_inflight),
                "replicas": max(1, self.config.replicas),
                "workers": self.config.workers(),
                "backend_counts": dict(self.backend_counts),
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "coalesced": self.coalesced,
                "engine_stages": eng_stages,
                "served_queries": self.served_queries,
                "served_batches": self.served_batches,
                "write_ops": self.write_ops,
                "service_time_ms": (None if self._service_ewma is None
                                    else 1e3 * self._service_ewma),
            }

    # ----------------------------------------------------------------- writes
    def insert(self, verts: np.ndarray, nverts: int, kind: int = 0) -> int:
        rec = self.index.insert(verts, nverts, kind)
        with self._lock:
            self.write_ops += 1
        return rec

    def delete(self, rec: int) -> bool:
        ok = self.index.delete(rec)
        with self._lock:
            self.write_ops += 1
        return ok
