import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the appropriate step (train_4k -> train_step with grad-accum;
     prefill_32k -> prefill_step; decode_32k / long_500k -> decode_step;
     the GLIN cell -> the shard_map glin_query_step),
  3. ``jax.jit(step, in_shardings, out_shardings).lower(*specs).compile()``,
  4. records memory_analysis / cost_analysis / HLO collective bytes + the
     derived roofline terms to benchmarks/artifacts/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi4_mini_3p8b \
      --shape train_4k --mesh multi                               # one cell
  ... --resume     # skip cells whose artifact already exists
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import (ARCH_IDS, SHAPES, cell_supported, get_arch,
                                get_shape)
from repro.launch.mesh import make_production_mesh
from repro.sharding import MeshRules
from repro.utils import roofline

ART_DIR = (pathlib.Path(__file__).resolve().parents[3]
           / "benchmarks" / "artifacts" / "dryrun")


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not support it
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0) + out.get("temp_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0) - out.get("alias_size_in_bytes", 0))
    return out


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": str(e)}


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             microbatches: int = 16, seq_shard: bool = False,
             ssd_chunk: int = 0) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    rules = MeshRules(mesh=mesh, seq_sharding=seq_shard)
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "status": "ok"}
    t0 = time.time()

    if arch_id == "glin":
        from repro.core.distributed import build_glin_query_step, glin_input_specs
        # records shard over the data(×pod) axes only (query×record 2D
        # decomposition): size the index to ~2.3 GiB/device of record table.
        num_records = (1 << 29) if mesh_kind == "multi" else (1 << 28)
        num_queries = 4096
        step, in_sh, out_sh = build_glin_query_step(mesh, relation="intersects",
                                                    cap=512)
        specs = glin_input_specs(num_records, num_queries, mesh)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*specs)
            compiled = lowered.compile()
        rec["tokens"] = num_queries
        cfg = None
        shape = None
    else:
        from repro.train.step import (build_decode_step, build_prefill_step,
                                      build_train_step)
        cfg = get_arch(arch_id)
        if ssd_chunk:
            import dataclasses as _dc
            cfg = _dc.replace(cfg, ssd_chunk=ssd_chunk)
        shape = get_shape(shape_name)
        ok, why = cell_supported(cfg, shape)
        if not ok:
            rec.update(status="skip", reason=why)
            return rec
        if shape.kind == "train":
            # each microbatch must still divide the DP extent or activations
            # silently replicate (batch sharding dropped by the rule table)
            dp = chips // mesh.shape["model"]
            mbs = min(microbatches, max(1, shape.global_batch // dp))
            step, in_sh, out_sh, specs = build_train_step(
                cfg, shape, rules, microbatches=mbs)
        elif shape.kind == "prefill":
            step, in_sh, out_sh, specs = build_prefill_step(cfg, shape, rules)
        else:
            step, in_sh, out_sh, specs = build_decode_step(cfg, shape, rules)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*specs)
            compiled = lowered.compile()

    rec["lower_compile_s"] = round(time.time() - t0, 1)
    rec["memory"] = _mem_analysis(compiled)
    # per-computation; see utils/hlo.py
    rec["cost_analysis_raw"] = _cost_analysis(compiled)

    # Per-chip costs from the partitioned module, with while-loop trip-count
    # scaling (XLA's cost_analysis counts loop bodies once — utils/hlo.py).
    from repro.utils.hlo import analyze_hlo
    hc = analyze_hlo(compiled.as_text())
    rec["hlo_cost"] = {
        "flops_per_chip": hc.flops,
        "bytes_per_chip": hc.bytes,
        "collectives_per_chip": hc.collectives,
        "collective_total_per_chip": hc.collective_total,
        "unknown_trip_whiles": hc.unknown_trip_whiles,
    }
    rec["roofline"] = roofline.roofline_terms(
        hc.flops, hc.bytes, hc.collective_total, chips=1)
    if cfg is not None:
        mf = roofline.model_flops(cfg, shape)
        rec["model_flops"] = mf
        rec["useful_flops_ratio"] = (mf / (hc.flops * chips)
                                     if hc.flops else None)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=16)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--ssd-chunk", type=int, default=0)
    args = ap.parse_args()

    ART_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    failures = 0
    for arch_id in archs:
        shapes = ([args.shape] if args.shape
                  else (["query"] if arch_id == "glin" else list(SHAPES)))
        for shape_name in shapes:
            for mesh_kind in meshes:
                name = f"{arch_id}__{shape_name}__{mesh_kind}"
                path = ART_DIR / f"{name}.json"
                if args.resume and path.exists():
                    print(f"[skip existing] {name}")
                    continue
                print(f"[dryrun] {name} ...", flush=True)
                try:
                    rec = run_cell(arch_id, shape_name, mesh_kind,
                                   microbatches=args.microbatches,
                                   seq_shard=args.seq_shard,
                                   ssd_chunk=args.ssd_chunk)
                except Exception as e:
                    rec = {"arch": arch_id, "shape": shape_name,
                           "mesh": mesh_kind, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                path.write_text(json.dumps(rec, indent=1))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    gib = rec["memory"].get("total_bytes_per_device", 0) / 2**30
                    extra = (f" compile={rec['lower_compile_s']}s"
                             f" dominant={r['dominant']}"
                             f" mem/dev={gib:.2f}GiB")
                print(f"[{status}] {name}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
