"""Serving launchers.

``python -m repro.launch.serve [spatial] ...`` — the default: drive the GLIN
spatial serving tier (``repro.serve.SpatialQueryServer``) with a short
open-loop demo load (Poisson arrivals, mixed relations, a write fraction)
and dump ``server.stats()`` as JSON: queue depth, shed count, per-tenant
admitted/rejected/served, batch-size histogram, per-replica query counts,
coalesced duplicates, and the facade's per-stage execution telemetry
(``engine_stages``: wall time, survivors, ladder escalations and delta sizes
per pipeline stage). ``--explain`` additionally pretty-prints the compiled
execution plan (``SpatialIndex.explain``) for each demo relation before the
load starts.

``python -m repro.launch.serve lm ...`` — the continuous-batching LM demo:
``--slots`` concurrent sequences in a fixed decode batch, each arriving
request prefilled individually and its KV/SSM state spliced into a free slot
(per-sequence positions make slot states independent — the same mechanism a
production continuous-batching scheduler relies on); finished sequences free
their slot; reports prefill/decode latency and tokens/s.

:class:`SlotServer` lives here (this launcher is its only consumer; the
spatial serving tier in ``repro.serve`` is the production-path server).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np

__all__ = ["SlotServer", "main"]


class SlotServer:
    """Fixed-slot continuous batching around prefill/decode_step."""

    def __init__(self, cfg, params, slots: int, max_ctx: int):
        import jax

        from repro.models import transformer as tf
        from repro.sharding import constrain

        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_ctx = max_ctx
        self.cache = tf.init_cache(cfg, slots, max_ctx)
        self.active = [False] * slots
        self.remaining = [0] * slots
        self.generated: List[List[int]] = [[] for _ in range(slots)]
        self._decode = jax.jit(
            lambda p, c, b: tf.decode_step(p, cfg, b, c, constrain))
        self._prefill = jax.jit(
            lambda p, b: tf.prefill(p, cfg, b, constrain,
                                    seq_len_cache=max_ctx))

    def admit(self, slot: int, prompt: np.ndarray, gen_len: int) -> None:
        """Prefill a request and splice its state into `slot`."""
        import jax
        import jax.numpy as jnp

        batch = {"tokens": jnp.asarray(prompt[None, :])}
        _, cache1 = self._prefill(self.params, batch)

        def splice(dst, src):
            return dst.at[:, slot].set(src[:, 0])

        self.cache = jax.tree_util.tree_map(splice, self.cache, cache1)
        self.active[slot] = True
        self.remaining[slot] = gen_len
        self.generated[slot] = []

    def step(self, tokens: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": jnp.asarray(tokens)})
        return np.asarray(jnp.argmax(logits, axis=-1))


# --------------------------------------------------------------- spatial mode
def main_spatial(args) -> int:
    from repro.core.datasets import generate, make_query_windows
    from repro.core.engine import EngineConfig, SpatialIndex
    from repro.core.index import GLINConfig
    from repro.serve import Rejected, ServerConfig, SpatialQueryServer

    rng = np.random.default_rng(args.seed)
    gs = generate(args.dataset, args.n, seed=args.seed)
    index = SpatialIndex.build(
        gs, GLINConfig(piece_limitation=10_000),
        EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1))
    cfg = ServerConfig(replicas=args.replicas, max_queue=args.max_queue,
                       min_batch=args.min_batch, max_batch=args.max_batch,
                       overlap_groups=not args.no_overlap,
                       max_workers=args.workers)
    server = SpatialQueryServer(index, async_republish=True, config=cfg)

    relations = ["intersects", "contains", "dwithin:0.003"]
    pool = make_query_windows(gs, 1e-4, 256, seed=args.seed + 1)
    if args.explain:
        for rel in relations:
            print(index.explain(pool[:cfg.min_batch], rel), flush=True)
    tenants = [f"tenant{i}" for i in range(max(args.tenants, 1))]
    print(f"[serve] {args.dataset} n={args.n}: {args.qps:.0f} qps offered "
          f"for {args.seconds:.0f}s over {len(tenants)} tenant(s), "
          f"replicas={cfg.replicas} workers={cfg.workers()}", flush=True)
    server.start()
    tickets: List[int] = []
    t_end = time.perf_counter() + args.seconds
    next_arrival = time.perf_counter()
    served = 0
    try:
        while time.perf_counter() < t_end:
            now = time.perf_counter()
            while next_arrival <= now:
                w = pool[rng.integers(len(pool))]
                rel = relations[rng.integers(len(relations))]
                tickets.append(server.submit(
                    w, rel, tenant=tenants[rng.integers(len(tenants))]))
                if rng.random() < args.write_frac:
                    c = rng.uniform(0.15, 0.85, 2)
                    ang = np.sort(rng.uniform(0, 2 * np.pi, 8))
                    v = np.stack([c[0] + 2e-4 * np.cos(ang),
                                  c[1] + 2e-4 * np.sin(ang)], -1)
                    server.insert(v, 8, 0)
                next_arrival += rng.exponential(1.0 / args.qps)
            # collect what has resolved so far (non-blocking cadence)
            while tickets:
                try:
                    out = server.result(tickets[0], timeout=0.0)
                except TimeoutError:
                    break
                served += 0 if isinstance(out, Rejected) else 1
                tickets.pop(0)
            time.sleep(min(0.001, max(0.0, next_arrival - time.perf_counter())))
        for t in tickets:
            out = server.result(t, timeout=30.0)
            served += 0 if isinstance(out, Rejected) else 1
    finally:
        server.stop()
    st = server.stats()
    st["collected"] = served
    print(json.dumps(st, indent=2), flush=True)
    return 0


# -------------------------------------------------------------------- lm mode
def main_lm(args) -> int:
    import jax

    from repro.configs.base import get_arch
    from repro.models import transformer as tf

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    server = SlotServer(cfg, params, args.slots, args.max_ctx)

    queue = [(rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
              int(rng.integers(8, args.max_ctx - args.prompt_len)))
             for _ in range(args.requests)]
    done = 0
    cur_tokens = np.zeros(args.slots, np.int32)
    t0 = time.time()
    decoded = 0
    prefills = 0
    while done < args.requests:
        # admit queued requests into free slots
        for s in range(args.slots):
            if not server.active[s] and queue:
                prompt, gen = queue.pop(0)
                ta = time.time()
                server.admit(s, prompt, gen)
                prefills += 1
                cur_tokens[s] = prompt[-1]
                if prefills == 1:
                    print(f"[serve] first prefill {time.time()-ta:.2f}s",
                          flush=True)
        if not any(server.active):
            break
        nxt = server.step(cur_tokens)
        for s in range(args.slots):
            if server.active[s]:
                server.generated[s].append(int(nxt[s]))
                cur_tokens[s] = nxt[s]
                server.remaining[s] -= 1
                decoded += 1
                if server.remaining[s] <= 0:
                    server.active[s] = False
                    done += 1
    dt = time.time() - t0
    print(f"[serve] {done} requests, {decoded} tokens in {dt:.1f}s "
          f"({decoded/max(dt,1e-9):.1f} tok/s, {prefills} prefills)",
          flush=True)
    return 0


def main(argv=None) -> int:
    import sys

    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] not in ("spatial", "lm"):
        argv = ["spatial"] + argv          # spatial serving is the default
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    sp = sub.add_parser("spatial", help="GLIN spatial serving tier demo")
    sp.add_argument("--dataset", default="cluster")
    sp.add_argument("--n", type=int, default=50_000)
    sp.add_argument("--qps", type=float, default=200.0)
    sp.add_argument("--seconds", type=float, default=5.0)
    sp.add_argument("--write-frac", type=float, default=0.02)
    sp.add_argument("--tenants", type=int, default=2)
    sp.add_argument("--replicas", type=int, default=2)
    sp.add_argument("--max-queue", type=int, default=2048)
    sp.add_argument("--min-batch", type=int, default=8)
    sp.add_argument("--max-batch", type=int, default=4096)
    sp.add_argument("--workers", type=int, default=None)
    sp.add_argument("--no-overlap", action="store_true")
    sp.add_argument("--explain", action="store_true",
                    help="print the compiled execution plan per relation")
    sp.add_argument("--seed", type=int, default=0)

    lm = sub.add_parser("lm", help="continuous-batching LM demo")
    lm.add_argument("--arch", default="granite_3_2b")
    lm.add_argument("--reduced", action="store_true", default=True)
    lm.add_argument("--slots", type=int, default=4)
    lm.add_argument("--requests", type=int, default=12)
    lm.add_argument("--prompt-len", type=int, default=32)
    lm.add_argument("--max-ctx", type=int, default=128)
    lm.add_argument("--seed", type=int, default=0)

    args = ap.parse_args(argv)
    return main_spatial(args) if args.mode == "spatial" else main_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
