"""Batched serving launcher: continuous-batching decode over fixed slots.

A small-scale but structurally real serving loop:

  * ``--slots`` concurrent sequences in a fixed decode batch;
  * each arriving request is prefLilled individually and its KV/SSM state is
    spliced into a free slot (per-sequence positions make slot states
    independent — the same mechanism a production continuous-batching
    scheduler relies on);
  * finished sequences (random target lengths) free their slot for the next
    queued request;
  * reports prefill/decode latency and tokens/s.

The server class itself lives in ``repro.serve.server`` (the serving layer);
this module is the thin CLI launcher and re-exports :class:`SlotServer` for
backward compatibility.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import transformer as tf
from repro.serve.server import SlotServer

__all__ = ["SlotServer", "main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-ctx", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    server = SlotServer(cfg, params, args.slots, args.max_ctx)

    queue = [(rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
              int(rng.integers(8, args.max_ctx - args.prompt_len)))
             for _ in range(args.requests)]
    done = 0
    cur_tokens = np.zeros(args.slots, np.int32)
    t0 = time.time()
    decoded = 0
    prefills = 0
    while done < args.requests:
        # admit queued requests into free slots
        for s in range(args.slots):
            if not server.active[s] and queue:
                prompt, gen = queue.pop(0)
                ta = time.time()
                server.admit(s, prompt, gen)
                prefills += 1
                cur_tokens[s] = prompt[-1]
                if prefills == 1:
                    print(f"[serve] first prefill {time.time()-ta:.2f}s", flush=True)
        if not any(server.active):
            break
        nxt = server.step(cur_tokens)
        for s in range(args.slots):
            if server.active[s]:
                server.generated[s].append(int(nxt[s]))
                cur_tokens[s] = nxt[s]
                server.remaining[s] -= 1
                decoded += 1
                if server.remaining[s] <= 0:
                    server.active[s] = False
                    done += 1
    dt = time.time() - t0
    print(f"[serve] {done} requests, {decoded} tokens in {dt:.1f}s "
          f"({decoded/max(dt,1e-9):.1f} tok/s, {prefills} prefills)", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
