"""End-to-end training launcher (fault-tolerant).

Runs a real training loop on the current backend: reduced configs train on
CPU in tests/examples; the same code path drives a TPU slice (the mesh and
shardings come from launch/mesh.py + sharding/rules.py).

Fault tolerance (DESIGN.md §4):
  * checkpoints are written asynchronously every ``--ckpt-every`` steps with
    atomic commit; ``--resume`` restarts from LATEST;
  * the data pipeline is stateless-deterministic (step -> batch), so a
    restart replays no data and skips none;
  * ``--simulate-failure-at`` kills the process mid-run (used by the
    crash-recovery integration test);
  * on restart with a different device count, parameters are resharded by
    ckpt.restore (elastic).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run0
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from repro.ckpt import checkpoint as ckpt
from repro.configs.base import get_arch
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models import transformer as tf
from repro.sharding import constrain
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def build_fns(cfg, opt_cfg, remat):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(tf.loss_fn)(params, cfg, batch,
                                                     constrain, remat=remat)
        params, opt_state, metrics = adamw_update(grads, opt_state, params,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return jax.jit(train_step, donate_argnums=(0, 1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                          total_steps=args.steps)

    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    start_step = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        start_step, state = ckpt.restore(args.ckpt_dir,
                                         {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed from step {start_step}", flush=True)

    step_fn = build_fns(cfg, opt_cfg, args.remat)
    source = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed)
    prefetch = Prefetcher(source, start_step=start_step)

    t0 = time.time()
    losses = []
    try:
        for step, batch in prefetch:
            if step >= args.steps:
                break
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (args.simulate_failure_at is not None
                    and step == args.simulate_failure_at):
                # Drain in-flight async saves so the crash point is
                # deterministic: resume then restores the last boundary
                # checkpoint regardless of IO load. Torn-write recovery is
                # exercised separately (test_atomic_commit_ignores_partial).
                ckpt.wait_all()
                print(f"[train] simulating crash at step {step}", flush=True)
                os._exit(42)
            if args.ckpt_dir and step > 0 and step % args.ckpt_every == 0:
                ckpt.save_async(args.ckpt_dir, step,
                                {"params": params, "opt": opt_state})
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.time() - t0
                print(f"[train] step={step} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)", flush=True)
    finally:
        prefetch.close()
    if args.ckpt_dir:
        ckpt.wait_all()   # drain in-flight async saves before the final one
        ckpt.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt_state})
    if len(losses) >= 2:
        print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})",
              flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
