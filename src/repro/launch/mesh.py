"""Production mesh construction (single-pod 16×16; multi-pod 2×16×16).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. The dry-run (launch/dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing jax
so these meshes can be built on the CPU container.
"""
from __future__ import annotations

from repro.utils.compat import make_auto_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for multi-device unit tests (8 fake devices)."""
    return make_auto_mesh(shape, axes)
