"""Pallas TPU kernel: single-token decode attention over a ring KV cache.

The serving inner loop (decode_32k / long_500k cells): one query token per
sequence attends to a (possibly ring-buffered) cache of W slots with
per-slot absolute positions (-1 = empty; sliding-window masking applied from
positions, matching models/attention.attention_decode exactly).

Layout: q (B, Hq, D); k/v (B, Hkv, W, D); abs_pos (B, W) int32; pos (B,).
Grid = (B, Hq, W/BK): the KV axis is the minor sequential dimension so the
(D,) accumulator + running max/denominator live in SMEM-sized VMEM scratch;
GQA is expressed in the K/V index_map (head h reads kv-head h // group).
Decode is memory-bound — the kernel's job is to stream K/V exactly once at
full HBM bandwidth with masking fused.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 256
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, ap_ref, pos_ref, o_ref,
                   acc, m_i, l_i, *, bk: int, window: int, scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (D,)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    ap = ap_ref[0]                                       # (BK,) int32
    pos = pos_ref[0]                                     # scalar int32

    s = jnp.einsum("d,kd->k", q, k)                      # (BK,)
    valid = (ap >= 0) & (ap <= pos)
    if window > 0:
        valid &= (pos - ap) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_i[0]
    m_new = jnp.maximum(m_prev, s.max())
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_i[0] = l_i[0] * alpha + p.sum()
    acc[...] = acc[...] * alpha + jnp.einsum("k,kd->d", p, v)[None]
    m_i[0] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _final():
        o_ref[0, 0] = (acc[0] / jnp.maximum(l_i[0], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            abs_pos: jax.Array, pos: jax.Array, *,
                            window: int = 0, bk: int = DEFAULT_BK,
                            interpret: bool = False) -> jax.Array:
    """q (B,Hq,D); k/v (B,Hkv,W,D); abs_pos (B,W) i32; pos (B,) i32
    -> (B,Hq,D)."""
    b, hq, d = q.shape
    _, hkv, w, _ = k.shape
    assert w % bk == 0, (w, bk)
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    grid = (b, hq, w // bk)

    kernel = functools.partial(_decode_kernel, bk=bk, window=window,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b_, h, ik: (b_, h, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, bk), lambda b_, h, ik: (b_, ik)),
            pl.BlockSpec((1,), lambda b_, h, ik: (b_,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h, ik: (b_, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, abs_pos, pos)
