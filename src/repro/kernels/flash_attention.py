"""Pallas TPU kernel: blocked causal attention (flash-style, GQA + SWA).

The serving/training hot-spot for the assigned LM architectures. Classic
online-softmax tiling adapted to the TPU memory hierarchy:

* grid = (batch, q_heads, Sq/BQ, Skv/BK); the KV axis is the minor grid
  dimension, so the (BQ, D) accumulator + running max/denominator live in
  VMEM scratch across KV steps of one query block;
* BlockSpecs keep a (BQ, D) Q tile and (BK, D) K/V tiles resident — MXU
  matmuls are (BQ×D)·(D×BK) and (BQ×BK)·(BK×D) with D, BQ, BK multiples of
  128 (8 for sublanes) by construction;
* GQA is expressed in the K/V index_map (q-head h reads kv-head h // group) —
  no HBM duplication of KV;
* causal + sliding-window masking is applied per-tile; fully-masked tiles are
  skipped with ``pl.when`` (block-level early-out).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *,
                  bq: int, bk: int, window: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    q_lo = iq * bq                  # first query index in this tile
    k_lo = ik * bk
    # Block-level skip: entirely in the future, or entirely beyond the window.
    live = q_lo + bq - 1 >= k_lo
    if window > 0:
        live = live & (k_lo + bk - 1 >= q_lo + bq - 1 - (window - 1) - (bq - 1))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)              # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (BQ,BK)
        qi = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = qi >= kj
        if window > 0:
            mask &= (qi - kj) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_i[...] = l_i[...] * alpha + p.sum(axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_i[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _final():
        denom = jnp.maximum(l_i[...], 1e-30)
        o_ref[0, 0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, window: int = 0,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jax.Array:
    """q (B,Hq,S,D); k,v (B,Hkv,S,D); window=0 -> pure causal, else SWA.

    Returns (B,Hq,S,D) in q.dtype. S must divide by bq and bk.
    """
    b, hq, s, d = q.shape
    _, hkv, sk, _ = k.shape
    assert s % bq == 0 and sk % bk == 0, (s, sk, bq, bk)
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    grid = (b, hq, s // bq, sk // bk)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
