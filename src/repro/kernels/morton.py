"""Pallas TPU kernel: batched Morton (Z-address) encoding.

Encodes 30-bit quantized (x, y) coordinate pairs into (hi, lo) int32
Z-address limbs (DESIGN.md §2 — the TPU-native 64-bit-free representation).
Pure VPU bit arithmetic: each grid step loads a (BLOCK_M, 128) tile of
coordinates into VMEM, spreads bits with the magic-mask ladder, and writes
both limbs. Arithmetic intensity is low, so the kernel exists to (a) fuse the
quantize+interleave chain into one HBM round-trip and (b) feed downstream
Pallas stages without leaving VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_M = 8


def _part1by1(v):
    v = v.astype(jnp.uint32)
    v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
    v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & jnp.uint32(0x33333333)
    v = (v | (v << 1)) & jnp.uint32(0x55555555)
    return v


def _morton_kernel(qx_ref, qy_ref, hi_ref, lo_ref):
    qx = qx_ref[...]
    qy = qy_ref[...]
    mask15 = jnp.int32((1 << 15) - 1)
    x_lo, x_hi = qx & mask15, qx >> 15
    y_lo, y_hi = qy & mask15, qy >> 15
    lo_ref[...] = (_part1by1(x_lo) | (_part1by1(y_lo) << 1)).astype(jnp.int32)
    hi_ref[...] = (_part1by1(x_hi) | (_part1by1(y_hi) << 1)).astype(jnp.int32)


def morton_encode_pallas(qx: jax.Array, qy: jax.Array,
                         block_m: int = DEFAULT_BLOCK_M,
                         interpret: bool = False):
    """(M, 128) int32 coordinate tiles -> ((M,128) hi, (M,128) lo)."""
    m, lanes = qx.shape
    assert lanes == LANES and m % block_m == 0, (qx.shape, block_m)
    grid = (m // block_m,)
    spec = pl.BlockSpec((block_m, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _morton_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((m, LANES), jnp.int32)] * 2,
        interpret=interpret,
    )(qx, qy)
