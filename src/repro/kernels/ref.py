"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.zorder import morton_encode_hilo


# ---------------------------------------------------------------- morton ----
def morton_ref(qx: jax.Array, qy: jax.Array):
    """(..., ) int32 coords -> (hi, lo) int32 limbs (shared device codec)."""
    return morton_encode_hilo(qx, qy)


# ---------------------------------------------------------------- refine ----
def refine_mask_ref(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array):
    """(Q,4), (Q,2) i32, (N,4) -> (Q,N) int8."""
    w = windows[:, None, :]
    r = mbrs[None, :, :]
    inter = (
        (w[..., 0] <= r[..., 2]) & (r[..., 0] <= w[..., 2])
        & (w[..., 1] <= r[..., 3]) & (r[..., 1] <= w[..., 3])
    )
    slot = jnp.arange(mbrs.shape[0], dtype=jnp.int32)[None, :]
    in_run = (slot >= bounds[:, 0:1]) & (slot < bounds[:, 1:2])
    return (inter & in_run).astype(jnp.int8)


def refine_count_ref(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array):
    return refine_mask_ref(windows, bounds, mbrs).astype(jnp.int32).sum(axis=1)


def compact_mask_ref(slot_mask: jax.Array, budget: int):
    """(Q, N) bool -> (slots (Q, budget) int32 [-1 padded, ascending slot
    order], counts (Q,) int32 total survivors). Pure-jnp oracle of the fused
    kernel's compaction step: a stable cumsum + scatter, no sort."""
    q, n = slot_mask.shape
    m32 = slot_mask.astype(jnp.int32)
    excl = jnp.cumsum(m32, axis=1) - m32
    pos = jnp.where(slot_mask & (excl < budget), excl, budget)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, n), 1)
    slots = jnp.full((q, budget), -1, jnp.int32).at[
        jnp.arange(q, dtype=jnp.int32)[:, None], pos
    ].set(cols, mode="drop")
    return slots, m32.sum(axis=1)


def refine_compact_ref(windows: jax.Array, bounds: jax.Array,
                       leaf_mbrs: jax.Array, rec_mbrs: jax.Array,
                       budget: int, prefilter: str = "intersects"):
    """Oracle of ``refine_compact_pallas``: fused interval + leaf-MBR +
    record-MBR mask, then stable compaction to (Q, budget) slots."""
    w = windows[:, None, :]
    lm = leaf_mbrs[None, :, :]
    rm = rec_mbrs[None, :, :]
    leaf_ok = (
        (w[..., 0] <= lm[..., 2]) & (lm[..., 0] <= w[..., 2])
        & (w[..., 1] <= lm[..., 3]) & (lm[..., 1] <= w[..., 3])
    )
    if prefilter == "contains":
        rec_ok = (
            (rm[..., 0] <= w[..., 0]) & (rm[..., 1] <= w[..., 1])
            & (w[..., 2] <= rm[..., 2]) & (w[..., 3] <= rm[..., 3])
        )
    else:
        rec_ok = (
            (w[..., 0] <= rm[..., 2]) & (rm[..., 0] <= w[..., 2])
            & (w[..., 1] <= rm[..., 3]) & (rm[..., 1] <= w[..., 3])
        )
    slot = jnp.arange(rec_mbrs.shape[0], dtype=jnp.int32)[None, :]
    in_run = (slot >= bounds[:, 0:1]) & (slot < bounds[:, 1:2])
    return compact_mask_ref(leaf_ok & rec_ok & in_run, budget)


# ------------------------------------------------------------- attention ----
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0) -> jax.Array:
    """Dense causal (+ sliding window) GQA attention, fp32 softmax."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / math.sqrt(d)
    s_mat = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(k.shape[2])[None, :]
    mask = qi >= kj
    if window > 0:
        mask &= (qi - kj) < window
    s_mat = jnp.where(mask[None, None], s_mat, -1e30)
    p = jax.nn.softmax(s_mat, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ------------------------------------------------------------------- ssd ----
def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array) -> jax.Array:
    """Exact SSM recurrence: h_t = e^{dt_t A} h_{t-1} + dt_t B_t x_t^T;
    y_t = C_t h_t. x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp          # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dt_t * a[None, :])  # (B,H)
        upd = jnp.einsum("bn,bhp,bh->bhnp", b_t, x_t.astype(jnp.float32),
                         dt_t.astype(jnp.float32))
        state = state * decay[:, :, None, None] + upd
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, state)
        return state, y_t

    state0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (jnp.swapaxes(x, 0, 1), jnp.swapaxes(dt, 0, 1),
          jnp.swapaxes(b, 0, 1), jnp.swapaxes(c, 0, 1))
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.swapaxes(ys, 0, 1).astype(x.dtype)


def decode_attention_ref(q, k, v, abs_pos, pos, *, window: int = 0):
    """Dense decode attention oracle. q (B,Hq,D); k/v (B,Hkv,W,D);
    abs_pos (B,W); pos (B,) -> (B,Hq,D)."""
    b, hq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = (abs_pos >= 0) & (abs_pos <= pos[:, None])
    if window > 0:
        valid &= (pos[:, None] - abs_pos) < window
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
