"""Pallas TPU kernel: tiled GLIN refinement (candidate masking + counting).

The paper's profile (§IX-D) shows refinement dominates query time. This
kernel evaluates the (query-window × record) MBR-intersection matrix in VMEM
tiles, fused with the Z-interval slot test (``start <= slot < end``) and the
leaf-MBR skip, so a (BQ × BN) tile of candidates is disposed of per grid step
without materializing gathers in HBM.

Two entry points:

* ``refine_mask_pallas``  — full (Q, N) int8 mask (drives compaction).
* ``refine_count_pallas`` — (Q,) match counts via grid-axis accumulation
  (selectivity estimation / Table III instrumentation at device speed).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 8
DEFAULT_BN = 512


def _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn):
    """(BQ,4) windows x (BN,4) record MBRs -> (BQ,BN) bool."""
    w = win_ref[...]          # (BQ, 4)
    r = mbr_ref[...]          # (BN, 4)
    b = bounds_ref[...]       # (BQ, 2) int32 [start, end)
    inter = (
        (w[:, None, 0] <= r[None, :, 2])
        & (r[None, :, 0] <= w[:, None, 2])
        & (w[:, None, 1] <= r[None, :, 3])
        & (r[None, :, 1] <= w[:, None, 3])
    )
    slot = nb * bn + jax.lax.broadcasted_iota(jnp.int32, inter.shape, 1)
    in_run = (slot >= b[:, 0:1]) & (slot < b[:, 1:2])
    return inter & in_run


def _mask_kernel(win_ref, bounds_ref, mbr_ref, out_ref, *, bn):
    nb = pl.program_id(1)
    out_ref[...] = _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn).astype(jnp.int8)


def _count_kernel(win_ref, bounds_ref, mbr_ref, out_ref, *, bn):
    nb = pl.program_id(1)
    mask = _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn)
    partial_counts = mask.sum(axis=1).astype(jnp.int32)

    @pl.when(nb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial_counts


def _grids(q, n, bq, bn):
    assert q % bq == 0 and n % bn == 0, (q, n, bq, bn)
    return (q // bq, n // bn)


def refine_mask_pallas(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array,
                       bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                       interpret: bool = False) -> jax.Array:
    """windows (Q,4) f32, bounds (Q,2) i32, mbrs (N,4) f32 -> (Q,N) int8."""
    q, n = windows.shape[0], mbrs.shape[0]
    grid = _grids(q, n, bq, bn)
    return pl.pallas_call(
        partial(_mask_kernel, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int8),
        interpret=interpret,
    )(windows, bounds, mbrs)


def refine_count_pallas(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array,
                        bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                        interpret: bool = False) -> jax.Array:
    """Same inputs -> (Q,) int32 match counts (reduction over the N grid axis,
    accumulated in the revisited output block)."""
    q, n = windows.shape[0], mbrs.shape[0]
    grid = _grids(q, n, bq, bn)
    return pl.pallas_call(
        partial(_count_kernel, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(windows, bounds, mbrs)
