"""Pallas TPU kernels: tiled GLIN refinement (mask / count / fused compact).

The paper's profile (§IX-D) shows refinement dominates query time. These
kernels evaluate the (query-window × record) MBR tests in VMEM tiles, fused
with the Z-interval slot test (``start <= slot < end``), so a (BQ × BN) tile
of candidates is disposed of per grid step without materializing gathers in
HBM.

Four entry points (all pad internally — arbitrary Q and N just work):

* ``refine_mask_pallas``    — full (Q, N) int8 mask.
* ``refine_count_pallas``   — (Q,) int32 match counts via grid-axis
  accumulation (selectivity estimation at device speed).
* ``refine_compact_pallas`` — the two-dispatch refinement front-end: fused
  interval + leaf-MBR + record-MBR mask with in-VMEM prefix-sum compaction.
  Emits the per-query compacted candidate slots ``(Q, budget)`` plus
  survivor counts, replacing both the dense ``(Q, cap)`` mask
  materialization and the ``O(Q·cap·log cap)`` argsort compaction in
  ``core.device.batch_query``: only ``Q·budget`` slot ids ever reach HBM,
  and the expensive exact-shape vertex gathers downstream shrink from
  ``(Q·cap·V)`` to ``(Q·budget·V)``.
* ``refine_fused_pallas``   — the ONE-dispatch query (ROADMAP one-kernel
  queries): the learned-index probe (piecewise suffix-min augmentation,
  model traversal, bounded binary search — all model tables VMEM-resident),
  the compact stage above, AND the exact rect-vs-geometry tests over the
  ``VertexPods`` pool, in a single kernel. The ``(Q, 2)`` probe bounds and
  ``(Q, budget)`` survivor slots never round-trip HBM; only the final
  record-id hits and counts leave the chip. Bit-identical to composing
  ``batch_query_bounds`` + the compact stage + the exact stage
  (``core.device.batch_query_fused(mode="reference")`` is that composition
  in one jit).

``refine_cost`` is the analytic bytes/flops model of each kernel (used both
as the ``pl.CostEstimate`` handed to the compiler and by
``benchmarks/roofline_report.py --kernels``).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 8
DEFAULT_BN = 512
COMPACT_BN = 256      # smaller record tiles: the one-hot scatter tensor is
                      # (BQ, BN, budget) in VMEM
MAX_COMPACT_BUDGET = 1024   # (bq=8, bn=256, budget=1024) int32 = 8 MB — the
                            # scatter tensor must fit ~16 MB TPU VMEM next to
                            # the streamed tiles; larger budgets must take
                            # the jnp "scan" path (no VMEM constraint)
_NEVER = 2e30         # padding MBR coordinate: intersects nothing
_LO_LIMB_F = float(1 << 30)   # fp32 weight of the hi limb (zorder.LO_LIMB_SIZE)
_LIMB_MAX = (1 << 30) - 1     # largest valid limb value: key padding that
                              # preserves sorted order past the true table
_INF_HI = 2 ** 30             # hi-limb +inf sentinel (zorder._INF_HI)
FUSED_VMEM_LIMIT = 12 << 20   # budget for the fused kernel's VMEM residency
                              # (model tables + pods + scatter block); past it
                              # the engine falls back to the staged pipeline


def _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn):
    """(BQ,4) windows x (BN,4) record MBRs -> (BQ,BN) bool."""
    w = win_ref[...]          # (BQ, 4)
    r = mbr_ref[...]          # (BN, 4)
    b = bounds_ref[...]       # (BQ, 2) int32 [start, end)
    inter = (
        (w[:, None, 0] <= r[None, :, 2])
        & (r[None, :, 0] <= w[:, None, 2])
        & (w[:, None, 1] <= r[None, :, 3])
        & (r[None, :, 1] <= w[:, None, 3])
    )
    slot = nb * bn + jax.lax.broadcasted_iota(jnp.int32, inter.shape, 1)
    in_run = (slot >= b[:, 0:1]) & (slot < b[:, 1:2])
    return inter & in_run


def _mask_kernel(win_ref, bounds_ref, mbr_ref, out_ref, *, bn):
    nb = pl.program_id(1)
    out_ref[...] = _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn).astype(jnp.int8)


def _count_kernel(win_ref, bounds_ref, mbr_ref, out_ref, *, bn):
    nb = pl.program_id(1)
    mask = _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn)
    partial_counts = mask.sum(axis=1).astype(jnp.int32)

    @pl.when(nb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial_counts


def _compact_tile_mask(win_ref, lmbr_ref, rmbr_ref, bounds_ref, nb, bn,
                       prefilter):
    """Fused interval + leaf-MBR + record-MBR tests -> (BQ, BN) bool.

    ``win_ref`` holds the PROBE window (already padded for dwithin-style
    relations); ``prefilter`` selects the record-MBR test shape:
    "intersects" (record MBR meets the probe window) or "contains" (record
    MBR covers the window — the ``within`` prefilter)."""
    w = win_ref[...]          # (BQ, 4) probe windows
    lm = lmbr_ref[...]        # (BN, 4) per-slot leaf MBRs
    rm = rmbr_ref[...]        # (BN, 4) per-slot record MBRs
    b = bounds_ref[...]       # (BQ, 2) int32 [start, end)
    leaf_ok = (
        (w[:, None, 0] <= lm[None, :, 2])
        & (lm[None, :, 0] <= w[:, None, 2])
        & (w[:, None, 1] <= lm[None, :, 3])
        & (lm[None, :, 1] <= w[:, None, 3])
    )
    if prefilter == "contains":
        rec_ok = (
            (rm[None, :, 0] <= w[:, None, 0])
            & (rm[None, :, 1] <= w[:, None, 1])
            & (w[:, None, 2] <= rm[None, :, 2])
            & (w[:, None, 3] <= rm[None, :, 3])
        )
    else:
        rec_ok = (
            (w[:, None, 0] <= rm[None, :, 2])
            & (rm[None, :, 0] <= w[:, None, 2])
            & (w[:, None, 1] <= rm[None, :, 3])
            & (rm[None, :, 1] <= w[:, None, 3])
        )
    slot = nb * bn + jax.lax.broadcasted_iota(jnp.int32, leaf_ok.shape, 1)
    in_run = (slot >= b[:, 0:1]) & (slot < b[:, 1:2])
    return leaf_ok & rec_ok & in_run


def _compact_kernel(win_ref, bounds_ref, lmbr_ref, rmbr_ref,
                    slots_ref, count_ref, *, bn, budget, prefilter):
    """Grid step (i, j): mask the (BQ, BN) tile, then prefix-sum compact the
    survivors into the revisited (BQ, budget) output block.

    ``count_ref`` carries the running per-query survivor count across the
    record axis; a survivor's output column is that running count plus its
    exclusive within-tile prefix sum. The scatter itself is a one-hot
    reduction over the tile (TPU vector units have no scatter): survivors
    past ``budget`` only advance the count — overflow is ``count > budget``,
    signalled to the caller, never silent truncation."""
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        slots_ref[...] = jnp.full_like(slots_ref, -1)
        count_ref[...] = jnp.zeros_like(count_ref)

    mask = _compact_tile_mask(win_ref, lmbr_ref, rmbr_ref, bounds_ref, nb, bn,
                              prefilter)
    m32 = mask.astype(jnp.int32)
    base = count_ref[...]                            # (BQ,)
    excl = jnp.cumsum(m32, axis=1) - m32             # exclusive prefix
    pos = base[:, None] + excl                       # output column per slot
    sel = mask & (pos < budget)
    slot = nb * bn + jax.lax.broadcasted_iota(jnp.int32, mask.shape, 1)
    # one-hot scatter: out[q, k] = slot of the survivor whose pos == k
    cols = jax.lax.broadcasted_iota(jnp.int32, (mask.shape[0], bn, budget), 2)
    hot = (pos[:, :, None] == cols) & sel[:, :, None]
    written = (hot * (slot + 1)[:, :, None]).sum(axis=1)   # 0 where no write
    slots_ref[...] = jnp.where(written > 0, written - 1, slots_ref[...])
    count_ref[...] = base + m32.sum(axis=1)


def _z_less(a_hi, a_lo, b_hi, b_lo):
    """a < b on (hi, lo) Z-address limb pairs (zorder.z_less_hilo)."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def _fused_probe(qk, keys_hi, keys_lo, li, lf, ni, nf, codes, pw, *,
                 augment, search_steps, depth):
    """In-kernel port of ``core.device`` ``_augment`` + ``batch_probe`` over
    the VMEM-resident packed model tables — same fp32 op order, so the probe
    bounds are bit-identical to ``batch_query_bounds``.

    ``qk`` is (BQ, 4) int32 ``[zmin_hi, zmin_lo, ub_hi, ub_lo]`` PRE-
    augmentation query keys (the O(Q) window quantization stays outside the
    kernel); table layouts are documented on ``refine_fused_pallas``.
    Returns the ``[start, end)`` slot run per query row."""
    zmin_hi, zmin_lo = qk[:, 0], qk[:, 1]
    ub_hi, ub_lo = qk[:, 2], qk[:, 3]

    if augment:
        # suffix-min piecewise augmentation: first piece with zmax >= zmin,
        # then take its suffix-min Zmin when it precedes the query key
        p = pw.shape[0]
        steps = max(1, math.ceil(math.log2(p + 1)))
        alo = jnp.zeros_like(zmin_hi)
        ahi = jnp.full_like(zmin_hi, p)

        def astep(_, st):
            lo_i, hi_i = st
            mid = (lo_i + hi_i) >> 1
            less = _z_less(pw[mid, 0], pw[mid, 1], zmin_hi, zmin_lo)
            return jnp.where(less, mid + 1, lo_i), jnp.where(less, hi_i, mid)

        alo, _ = jax.lax.fori_loop(0, steps, astep, (alo, ahi))
        in_range = alo < p
        idx = jnp.minimum(alo, p - 1)
        m_hi = jnp.where(in_range, pw[idx, 2], _INF_HI)
        m_lo = jnp.where(in_range, pw[idx, 3], 0)
        take = _z_less(m_hi, m_lo, zmin_hi, zmin_lo)
        zmin_hi = jnp.where(take, m_hi, zmin_hi)
        zmin_lo = jnp.where(take, m_lo, zmin_lo)

    num_leaves = li.shape[0] - 1

    def find_leaf(q_hi, q_lo):
        def body(_, state):
            node, leaf, done = state
            dh = (q_hi - ni[node, 0]).astype(jnp.float32)
            dl = (q_lo - ni[node, 1]).astype(jnp.float32)
            key_f = dh * jnp.float32(_LO_LIMB_F) + dl
            cell_f = jnp.clip(jnp.floor(key_f * nf[node, 0]), 0.0,
                              (ni[node, 2] - 1).astype(jnp.float32))
            cell = cell_f.astype(jnp.int32)
            code = codes[ni[node, 3] + cell, 0]
            is_leaf = code < 0
            new_leaf = jnp.where(is_leaf & ~done, -code - 1, leaf)
            new_node = jnp.where(is_leaf | done, node, code)
            return new_node, new_leaf, done | is_leaf

        node0 = jnp.zeros_like(q_hi)
        leaf0 = jnp.zeros_like(q_hi)
        done0 = jnp.zeros(q_hi.shape, bool)
        _, leaf, _ = jax.lax.fori_loop(0, depth, body, (node0, leaf0, done0))
        # fp32 routing fix-up against exact integer leaf-domain boundaries
        for _ in range(2):
            too_low = _z_less(q_hi, q_lo, li[leaf, 1], li[leaf, 2])
            leaf = jnp.maximum(leaf - too_low.astype(jnp.int32), 0)
            too_high = ~_z_less(q_hi, q_lo, li[leaf + 1, 1], li[leaf + 1, 2])
            leaf = jnp.minimum(leaf + too_high.astype(jnp.int32),
                               num_leaves - 1)
        return leaf

    def probe(q_hi, q_lo):
        leaf = find_leaf(q_hi, q_lo)
        start = li[leaf, 0]
        end = li[leaf + 1, 0]
        size = end - start
        key_f = ((q_hi - li[leaf, 3]).astype(jnp.float32)
                 * jnp.float32(_LO_LIMB_F)
                 + (q_lo - li[leaf, 4]).astype(jnp.float32))
        pred = jnp.rint(lf[leaf, 0] * key_f + lf[leaf, 1]).astype(jnp.int32)
        pred = jnp.clip(pred, 0, jnp.maximum(size - 1, 0))
        err = (1 << search_steps) // 2 + 2
        lo = jnp.maximum(pred - err, 0) + start
        hi = jnp.minimum(pred + err, size) + start

        def bstep(_, st):
            lo_i, hi_i = st
            live = lo_i < hi_i  # converged lanes must not move
            mid = (lo_i + hi_i) >> 1
            less = _z_less(keys_hi[mid], keys_lo[mid], q_hi, q_lo) & live
            return (jnp.where(less, mid + 1, lo_i),
                    jnp.where(less | ~live, hi_i, mid))

        lo, _ = jax.lax.fori_loop(0, search_steps + 2, bstep, (lo, hi))
        return lo

    return probe(zmin_hi, zmin_lo), probe(ub_hi, ub_lo)


def _fused_kernel(win_ref, pwin_ref, qk_ref, keys_ref, recs_ref, leaf_i_ref,
                  leaf_f_ref, node_i_ref, node_f_ref, codes_ref, pw_ref,
                  pod_ref, pool_ref, lmbr_ref, rmbr_ref,
                  slots_ref, count_ref, bounds_ref, *,
                  bn, budget, lanes, prefilter, predicate, augment,
                  search_steps, depth, num_buckets):
    """Grid step (i, j) of the one-dispatch query.

    j == 0: probe the learned index for the (BQ,) query tile and park the
    slot runs in the revisited ``bounds_ref`` output block (outputs double
    as cross-step state, like ``_compact_kernel``'s count). Every j: mask +
    prefix-sum compact the (BQ, BN) slot tile exactly as ``_compact_kernel``
    — except survivors index with the TRUE budget, not the lane-aligned
    block width, so the in-kernel exact stage sees exactly the (Q, budget)
    survivor prefix the two-dispatch reference sees. j == last: gather the
    survivors' records and vertex pods (at the widest pow2 bucket among the
    tile's survivors) and overwrite the slot block with final record-id hits
    and the count block with exact-hit counts — or ``-(survivors) - 1`` on
    budget overflow (the fused path is capless, so overflow is ALWAYS the
    budget)."""
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _probe():
        start, end = _fused_probe(
            qk_ref[...], keys_ref[:, 0], keys_ref[:, 1], leaf_i_ref[...],
            leaf_f_ref[...], node_i_ref[...], node_f_ref[...],
            codes_ref[...], pw_ref[...], augment=augment,
            search_steps=search_steps, depth=depth)
        bounds_ref[...] = jnp.stack([start, end], axis=1)
        slots_ref[...] = jnp.full_like(slots_ref, -1)
        count_ref[...] = jnp.zeros_like(count_ref)

    mask = _compact_tile_mask(pwin_ref, lmbr_ref, rmbr_ref, bounds_ref, nb,
                              bn, prefilter)
    m32 = mask.astype(jnp.int32)
    base = count_ref[...]
    excl = jnp.cumsum(m32, axis=1) - m32
    pos = base[:, None] + excl
    sel = mask & (pos < budget)
    slot = nb * bn + jax.lax.broadcasted_iota(jnp.int32, mask.shape, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (mask.shape[0], bn, lanes), 2)
    hot = (pos[:, :, None] == cols) & sel[:, :, None]
    written = (hot * (slot + 1)[:, :, None]).sum(axis=1)
    slots_ref[...] = jnp.where(written > 0, written - 1, slots_ref[...])
    count_ref[...] = base + m32.sum(axis=1)

    @pl.when(nb == pl.num_programs(1) - 1)
    def _exact():
        slots = slots_ref[...]
        total = count_ref[...]
        taken = slots >= 0
        slotc = jnp.maximum(slots, 0)
        rec = jnp.where(taken, recs_ref[:, 0][slotc], 0)
        pod = pod_ref[...]
        off = pod[:, 0][rec]
        nv = pod[:, 1][rec]
        kd = pod[:, 2][rec]
        b = jnp.max(jnp.where(taken, pod[:, 3][rec], 0))
        pool = pool_ref[...]
        w = win_ref[...]

        def branch(width):
            def run(off, nv, kd):
                lane = jnp.minimum(
                    jax.lax.broadcasted_iota(jnp.int32, off.shape + (width,),
                                             off.ndim),
                    nv[..., None] - 1)
                idx = jnp.clip(off[..., None] + lane, 0, pool.shape[0] - 1)
                return jax.vmap(predicate)(w, pool[idx], nv, kd)
            return run

        fmask = taken & jax.lax.switch(
            b, [branch(1 << i) for i in range(num_buckets)], off, nv, kd)
        slots_ref[...] = jnp.where(fmask, rec, -1)
        count_ref[...] = jnp.where(total > budget, -total - 1,
                                   fmask.sum(axis=1).astype(jnp.int32))


def _grids(q, n, bq, bn):
    """Grid over internally padded operand shapes (no divisibility demands)."""
    return (pl.cdiv(q, bq), pl.cdiv(n, bn))


def _pad_inputs(windows, bounds, bq, bn, *mbr_tables):
    """Pad Q to a multiple of bq and N to a multiple of bn. Padded MBR rows
    sit at ``_NEVER`` (intersect nothing, contain nothing); padded query rows
    get empty [0, 0) runs. Callers slice outputs back to (q, n)."""
    q, n = windows.shape[0], mbr_tables[0].shape[0]
    qp, np_ = (-q) % bq, (-n) % bn
    if qp:
        windows = jnp.pad(windows, ((0, qp), (0, 0)))
        bounds = jnp.pad(bounds, ((0, qp), (0, 0)))
    padded = []
    for m in mbr_tables:
        if np_:
            m = jnp.pad(m, ((0, np_), (0, 0)), constant_values=_NEVER)
        padded.append(m)
    return windows, bounds, padded


def refine_mask_pallas(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array,
                       bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                       interpret: bool = False) -> jax.Array:
    """windows (Q,4) f32, bounds (Q,2) i32, mbrs (N,4) f32 -> (Q,N) int8."""
    q, n = windows.shape[0], mbrs.shape[0]
    windows, bounds, (mbrs,) = _pad_inputs(windows, bounds, bq, bn, mbrs)
    qp, np_ = windows.shape[0], mbrs.shape[0]
    out = pl.pallas_call(
        partial(_mask_kernel, bn=bn),
        grid=_grids(qp, np_, bq, bn),
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.int8),
        cost_estimate=_cost_estimate("mask", qp, np_),
        interpret=interpret,
    )(windows, bounds, mbrs)
    return out[:q, :n]


def refine_count_pallas(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array,
                        bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                        interpret: bool = False) -> jax.Array:
    """Same inputs -> (Q,) int32 match counts (reduction over the N grid axis,
    accumulated in the revisited output block)."""
    q = windows.shape[0]
    windows, bounds, (mbrs,) = _pad_inputs(windows, bounds, bq, bn, mbrs)
    qp, np_ = windows.shape[0], mbrs.shape[0]
    out = pl.pallas_call(
        partial(_count_kernel, bn=bn),
        grid=_grids(qp, np_, bq, bn),
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((qp,), jnp.int32),
        cost_estimate=_cost_estimate("count", qp, np_),
        interpret=interpret,
    )(windows, bounds, mbrs)
    return out[:q]


def refine_compact_pallas(windows: jax.Array, bounds: jax.Array,
                          leaf_mbrs: jax.Array, rec_mbrs: jax.Array,
                          budget: int, prefilter: str = "intersects",
                          bq: int = DEFAULT_BQ, bn: int = COMPACT_BN,
                          interpret: bool = False):
    """Fused mask + in-VMEM compaction.

    windows (Q,4) f32 PROBE windows, bounds (Q,2) i32 slot runs,
    leaf_mbrs/rec_mbrs (N,4) f32 slot-aligned MBR tables ->
    (slots (Q, budget) int32 [-1 padded, ascending slot order],
     counts (Q,) int32 TOTAL mask survivors — ``counts > budget`` means the
     compacted list is truncated and the caller must re-issue).
    """
    if prefilter not in ("intersects", "contains"):
        raise ValueError(f"unsupported prefilter {prefilter!r}")
    if budget > MAX_COMPACT_BUDGET:
        raise ValueError(
            f"budget {budget} exceeds MAX_COMPACT_BUDGET="
            f"{MAX_COMPACT_BUDGET}: the (bq, bn, budget) one-hot scatter "
            "block would not fit VMEM — use the jnp reference "
            "(use_pallas=False / compaction='scan') for larger budgets")
    q = windows.shape[0]
    # the one-hot scatter block is (bq, bn, budget) int32 in VMEM: keep the
    # budget axis lane-aligned
    bud = max(128, -(-budget // 128) * 128)
    windows, bounds, (leaf_mbrs, rec_mbrs) = _pad_inputs(
        windows, bounds, bq, bn, leaf_mbrs, rec_mbrs)
    qp, np_ = windows.shape[0], leaf_mbrs.shape[0]
    slots, counts = pl.pallas_call(
        partial(_compact_kernel, bn=bn, budget=bud, prefilter=prefilter),
        grid=_grids(qp, np_, bq, bn),
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bq, bud), lambda i, j: (i, 0)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((qp, bud), jnp.int32),
            jax.ShapeDtypeStruct((qp,), jnp.int32),
        ),
        cost_estimate=_cost_estimate("compact", qp, np_, bud),
        interpret=interpret,
    )(windows, bounds, leaf_mbrs, rec_mbrs)
    return slots[:q, :budget], counts[:q]


def refine_fused_pallas(windows: jax.Array, probe_w: jax.Array,
                        qkeys: jax.Array, keys: jax.Array, recs: jax.Array,
                        leaf_i: jax.Array, leaf_f: jax.Array,
                        node_i: jax.Array, node_f: jax.Array,
                        codes: jax.Array, pw: jax.Array, pod_i: jax.Array,
                        pool: jax.Array, leaf_mbrs: jax.Array,
                        rec_mbrs: jax.Array, *, budget: int, prefilter: str,
                        predicate, augment: bool, search_steps: int,
                        depth: int, num_buckets: int, bq: int = DEFAULT_BQ,
                        bn: int = COMPACT_BN, interpret: bool = False):
    """One-dispatch probe + compact + exact refine.

    Per-query inputs (Q rows): ``windows``/``probe_w`` (Q, 4) f32 raw and
    relation-padded windows, ``qkeys`` (Q, 4) i32 pre-augmentation
    ``[zmin_hi, zmin_lo, ub_hi, ub_lo]`` query keys. VMEM-resident tables
    (packed by ``core.device._fused_operands``): ``keys`` (N, 2) i32 limb
    pairs, ``recs`` (N, 1) i32 record ids, ``leaf_i`` (L+1, 5) i32
    ``[start, dlo_hi, dlo_lo, k0_hi, k0_lo]``, ``leaf_f`` (L+1, 2) f32
    ``[slope, icpt]``, ``node_i`` (M, 4) i32 ``[dlo_hi, dlo_lo, fanout,
    child_base]``, ``node_f`` (M, 1) f32 scale, ``codes`` (C, 1) i32,
    ``pw`` (P, 4) i32 ``[zmax_hi, zmax_lo, sufmin_hi, sufmin_lo]``,
    ``pod_i`` (R, 4) i32 ``[off, nv, kind, bucket]`` pod headers and
    ``pool`` (V, 2) f32 vertex pods. ``leaf_mbrs``/``rec_mbrs`` are the
    (N, 4) slot-aligned MBR tables, streamed in (BN, 4) tiles.

    ``predicate`` is the relation's exact test ``(window, verts, nv, kind)
    -> bool`` already bound to ``xp=jnp``; ``augment`` statically enables
    the in-kernel suffix-min search (pass False when the relation does not
    augment OR the piecewise table is empty).

    Returns ``(hits (Q, budget) i32 record ids [-1 padded], counts (Q,)
    i32)`` — identical to ``batch_query``'s two-stage paths, except the
    fused path is capless so a negative count is ALWAYS budget overflow
    encoding the total MBR-survivor count (``-(survivors) - 1``).
    """
    if prefilter not in ("intersects", "contains"):
        raise ValueError(f"unsupported prefilter {prefilter!r}")
    if not 0 < budget <= MAX_COMPACT_BUDGET:
        raise ValueError(
            f"budget {budget} outside (0, MAX_COMPACT_BUDGET="
            f"{MAX_COMPACT_BUDGET}]: the fused kernel is two-stage only and "
            "its one-hot scatter block must fit VMEM — use the staged "
            "batch_query for budget 0 or larger budgets")
    q, n = windows.shape[0], keys.shape[0]
    lanes = max(128, -(-budget // 128) * 128)   # lane-aligned survivor block
    qp, np_ = (-q) % bq, (-n) % bn
    if qp:
        # padded query rows carry zero windows and zero keys: an empty
        # [lower_bound(0), lower_bound(0)) run, no survivors, sliced off
        windows = jnp.pad(windows, ((0, qp), (0, 0)))
        probe_w = jnp.pad(probe_w, ((0, qp), (0, 0)))
        qkeys = jnp.pad(qkeys, ((0, qp), (0, 0)))
    if np_:
        # padded slots: sorted-order-preserving max keys, record 0, MBRs
        # that intersect nothing (and can never contain a window)
        keys = jnp.pad(keys, ((0, np_), (0, 0)), constant_values=_LIMB_MAX)
        recs = jnp.pad(recs, ((0, np_), (0, 0)))
        leaf_mbrs = jnp.pad(leaf_mbrs, ((0, np_), (0, 0)),
                            constant_values=_NEVER)
        rec_mbrs = jnp.pad(rec_mbrs, ((0, np_), (0, 0)),
                           constant_values=_NEVER)
    qpad, npad = windows.shape[0], keys.shape[0]

    def full(a):
        return pl.BlockSpec(a.shape, lambda i, j, nd=a.ndim: (0,) * nd)

    hits, counts, _bounds = pl.pallas_call(
        partial(_fused_kernel, bn=bn, budget=budget, lanes=lanes,
                prefilter=prefilter, predicate=predicate, augment=augment,
                search_steps=search_steps, depth=depth,
                num_buckets=num_buckets),
        grid=_grids(qpad, npad, bq, bn),
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),   # raw windows
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),   # probe windows
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),   # query z-keys
            full(keys), full(recs), full(leaf_i), full(leaf_f),
            full(node_i), full(node_f), full(codes), full(pw),
            full(pod_i), full(pool),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),   # leaf MBR tile
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),   # record MBR tile
        ],
        out_specs=(
            pl.BlockSpec((bq, lanes), lambda i, j: (i, 0)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((qpad, lanes), jnp.int32),
            jax.ShapeDtypeStruct((qpad,), jnp.int32),
            # probe bounds live in a revisited output block (cross-grid-step
            # state, like the running count); callers discard them
            jax.ShapeDtypeStruct((qpad, 2), jnp.int32),
        ),
        cost_estimate=_cost_estimate("fused", qpad, npad, budget),
        interpret=interpret,
    )(windows, probe_w, qkeys, keys, recs, leaf_i, leaf_f, node_i, node_f,
      codes, pw, pod_i, pool, leaf_mbrs, rec_mbrs)
    return hits[:q, :budget], counts[:q]


def _knn_topk_kernel(d_ref, id_ref, outd_ref, outi_ref, *, k):
    """Deterministic k-round partial selection sort of one (BQ, B) tile.

    Round j extracts the minimum (distance, id) pair — the minimum distance,
    then the minimum id among its ties, matching the ``geometry.rank_knn``
    ordering contract — stores it at output column j and masks the selected
    lane to +inf. O(k·B) work per query row: cheaper than a full O(B log B)
    sort whenever k << B (the large-budget regime this kernel targets)."""
    d = d_ref[...]            # (BQ, B) f32 squared distances, +inf padded
    ids = id_ref[...]         # (BQ, B) i32 record ids, INT32_MAX padded
    outd_ref[...] = jnp.full_like(outd_ref[...], jnp.inf)
    outi_ref[...] = jnp.full_like(outi_ref[...], jnp.int32(2**31 - 1))

    def round_(j, dw):
        m = jnp.min(dw, axis=1, keepdims=True)               # (BQ, 1)
        tie = dw == m
        mid = jnp.min(jnp.where(tie, ids, jnp.int32(2**31 - 1)),
                      axis=1, keepdims=True)
        pl.store(outd_ref, (slice(None), pl.dslice(j, 1)), m)
        pl.store(outi_ref, (slice(None), pl.dslice(j, 1)), mid)
        return jnp.where(tie & (ids == mid), jnp.float32(jnp.inf), dw)

    jax.lax.fori_loop(0, k, round_, d)


def knn_topk_pallas(d: jax.Array, ids: jax.Array, k: int,
                    bq: int = DEFAULT_BQ, interpret: bool = False):
    """Partial-sort top-k for the kNN ranking stage.

    d (Q, B) f32 squared distances (+inf = dead lane), ids (Q, B) i32 ->
    ((Q, k) f32 ascending distances, (Q, k) i32 ids), ordered by the shared
    (distance, id) contract — identical to ``lax.sort([d, ids],
    num_keys=2)`` truncated to k columns (``core.device.batch_knn_rank``'s
    reference impl). Pads internally: any Q, B and k work."""
    q, b = d.shape
    qp = -(-q // bq) * bq
    bp = max(128, -(-b // 128) * 128)
    kp = max(128, -(-k // 128) * 128)   # lane-aligned output block
    dp = jnp.full((qp, bp), jnp.inf, jnp.float32).at[:q, :b].set(
        d.astype(jnp.float32))
    ip = jnp.full((qp, bp), 2**31 - 1, jnp.int32).at[:q, :b].set(ids)
    outd, outi = pl.pallas_call(
        partial(_knn_topk_kernel, k=k),
        grid=(qp // bq,),
        in_specs=[
            pl.BlockSpec((bq, bp), lambda i: (i, 0)),
            pl.BlockSpec((bq, bp), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bq, kp), lambda i: (i, 0)),
            pl.BlockSpec((bq, kp), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((qp, kp), jnp.float32),
            jax.ShapeDtypeStruct((qp, kp), jnp.int32),
        ),
        cost_estimate=_cost_estimate("knn", qp, bp, k),
        interpret=interpret,
    )(dp, ip)
    return outd[:q, :k], outi[:q, :k]


def fused_vmem_bytes(n_slots: int, n_leaves: int, n_nodes: int, n_codes: int,
                     n_pieces: int, n_records: int, pool_rows: int,
                     budget: int, max_width: int, bq: int = DEFAULT_BQ,
                     bn: int = COMPACT_BN) -> int:
    """Worst-case VMEM residency of one fused-kernel grid step: the
    replicated model tables + pods (resident for the whole dispatch), the
    streamed MBR tiles, the one-hot scatter block and the widest-bucket
    vertex gather. The engine compares this against ``FUSED_VMEM_LIMIT``
    and falls back to the staged pipeline when the store outgrows it."""
    lanes = max(128, -(-budget // 128) * 128)
    resident = (n_slots * 12                 # keys (2) + recs (1) int32
                + (n_leaves + 1) * 28        # leaf_i (5) + leaf_f (2)
                + n_nodes * 20               # node_i (4) + node_f (1)
                + n_codes * 4
                + n_pieces * 16              # pw (4) int32
                + n_records * 16             # pod headers (4) int32
                + pool_rows * 8)             # (V, 2) f32 vertex pods
    streamed = 2 * bn * 16 + bq * 56         # MBR tiles + query rows/bounds
    scatter = bq * bn * lanes * 4            # one-hot compaction block
    gather = bq * lanes * (max_width * 8 + 16)   # widest-bucket pod gather
    return resident + streamed + scatter + gather


# ---------------------------------------------------------------------------
# Analytic cost model (compiler CostEstimate + roofline_report --kernels)
# ---------------------------------------------------------------------------
def refine_cost(kind: str, q: int, n: int, budget: int = 0,
                verts: int = 0, bq: int = DEFAULT_BQ,
                bn: int = DEFAULT_BN) -> dict:
    """Bytes/flops model of one kernel invocation.

    ``kind``: "mask" | "count" | "compact" | "exact" | "fused" | "knn" —
    "exact" models the downstream exact-shape refinement stage over the
    compacted (Q, budget) survivors, so the roofline report covers the full
    compact+refine pipeline, not just candidate counting; "knn" models the
    device top-k ranking stage (``knn_topk_pallas`` /
    ``core.device.batch_knn_rank``): exact-distance evaluation over ``n``
    candidate columns at gather width ``verts`` plus the k-round partial
    selection, where ``budget`` is k; "fused" models
    the one-dispatch probe+compact+exact kernel: the compact and exact
    terms plus one key-limb stream per query tile for the in-kernel binary
    searches, MINUS the (Q, budget) survivor-slot and (Q, 2) bounds HBM
    round trips the staged pipeline pays between dispatches. ``verts`` is
    the gather width of the batch's widest surviving pow2 width-bucket (the
    vertex-pool pods gather per-bucket, see ``core.device.VertexPods``),
    NOT the store-wide dense padding — callers should pass ``pow2ceil`` of
    the surviving ring width they expect.
    """
    tiles_q = -(-q // bq)
    if kind == "fused":
        c = refine_cost("compact", q, n, budget, bq=bq, bn=bn)
        e = refine_cost("exact", q, n, budget, verts=verts, bq=bq, bn=bn)
        # staged-pipeline intermediates that never touch HBM in one
        # dispatch: compact writes + exact reads of the (Q, budget) slots,
        # plus the (Q, 2) probe bounds each stage re-reads
        saved = q * (2.0 * max(budget, 1) + 5.0) * 4.0
        # in-kernel probe: the keys limb pairs are VMEM-resident for the
        # whole dispatch (constant-index BlockSpec — fetched from HBM once,
        # not per query tile); ~2 searches x (steps ~ 18) x ~12 flops of
        # limb compares + model arithmetic per query
        probe_bytes = n * 8.0 + q * 32.0
        probe_flops = q * 2.0 * 18.0 * 12.0
        return {
            "flops": c["flops"] + e["flops"] + probe_flops,
            "bytes_accessed": max(
                c["bytes_accessed"] + e["bytes_accessed"]
                + probe_bytes - saved, 0.0),
            "transcendentals": 0,
        }
    if kind == "exact":
        # per-bucket pod gather + predicate over compacted survivors:
        # verts = widest surviving bucket width, (verts, 2) f32 rings plus
        # the (off, nverts, kind, bucket) record header; ~40 flops per
        # vertex (edge clip + ray cast)
        bytes_accessed = q * budget * (verts * 8 + 16) + q * budget * 4
        flops = q * budget * verts * 40
        return {"flops": float(flops), "bytes_accessed": float(bytes_accessed),
                "transcendentals": 0}
    if kind == "knn":
        # exact-distance gather over n candidate columns (same per-pair cost
        # as the "exact" predicate) + the k-round partial selection (budget
        # here is k): each round scans the n-wide tile twice (min + tie mask)
        k = max(budget, 1)
        bytes_accessed = (q * n * (verts * 8 + 16)   # pod gather
                          + q * n * 8                # (d2, ids) tile
                          + q * k * 8)               # (Q, k) result
        flops = q * n * verts * 40 + q * k * n * 3.0
        return {"flops": float(flops), "bytes_accessed": float(bytes_accessed),
                "transcendentals": float(q * k)}     # sqrt on the k winners
    # streaming kernels: each query row-tile streams the full MBR table(s)
    streams = 2 if kind == "compact" else 1
    bytes_accessed = tiles_q * n * 16 * streams + q * 24
    flops = q * n * 10.0          # interval + MBR comparisons per pair
    if kind == "mask":
        bytes_accessed += q * n   # int8 mask writeback
    elif kind == "count":
        bytes_accessed += tiles_q * bq * 4
    elif kind == "compact":
        flops += q * n * 6.0      # prefix sums
        flops += q * n * float(max(budget, 1)) * 2.0   # one-hot scatter
        bytes_accessed += q * (max(budget, 1) + 1) * 4
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")
    return {"flops": float(flops), "bytes_accessed": float(bytes_accessed),
            "transcendentals": 0}


def sharded_refine_cost(q: int, n: int, budget: int, shards: int,
                        verts: int = 0, bq: int = DEFAULT_BQ,
                        bn: int = DEFAULT_BN) -> dict:
    """Per-device cost of the SHARDED compact+refine pipeline
    (``core.distributed.build_glin_query_step`` with ``exact_budget``).

    Each of ``shards`` devices streams its N/shards slice of the slot-aligned
    MBR tables through the compact stage, exact-refines its own ``(Q,
    budget)`` survivor block, and contributes the block + its survivor count
    to the cross-shard result gather — ``collective_bytes`` models that
    all-gather of ``(Q, shards, budget+1)`` int32 (the only cross-shard
    traffic; the dense path moved ``(Q, shards, cap)``)."""
    n_local = -(-n // max(shards, 1))
    c = refine_cost("compact", q, n_local, budget, bq=bq, bn=bn)
    e = refine_cost("exact", q, n_local, budget, verts=verts)
    return {
        "flops": c["flops"] + e["flops"],
        "bytes_accessed": c["bytes_accessed"] + e["bytes_accessed"],
        "transcendentals": 0,
        # every device receives the other shards' survivor blocks + counts
        "collective_bytes": float(q * shards * (budget + 1) * 4),
    }


def sharded_knn_cost(q: int, n: int, budget: int, k: int, shards: int,
                     verts: int = 0, bq: int = DEFAULT_BQ,
                     bn: int = DEFAULT_BN) -> dict:
    """Per-device cost of the SHARDED device-complete kNN rung
    (``core.distributed.build_glin_knn_step``): the local compact+refine
    over the shard's N/shards slice, the local exact-distance top-k over its
    ``(Q, budget)`` survivors, and the cross-shard k-merge.
    ``collective_bytes`` models the all-gather of the per-shard ``(Q, k)``
    (distance, id) blocks plus the (Q,) within-radius counts — the ONLY
    cross-shard traffic; the host merge it replaces moved the full
    ``(Q, shards, budget)`` candidate lists through the host."""
    n_local = -(-n // max(shards, 1))
    c = refine_cost("compact", q, n_local, budget, bq=bq, bn=bn)
    r = refine_cost("knn", q, budget, k, verts=verts, bq=bq, bn=bn)
    # merge: every device re-sorts the gathered (Q, shards*k) block
    merge_flops = q * shards * k * math.log2(max(shards * k, 2)) * 4.0
    return {
        "flops": c["flops"] + r["flops"] + merge_flops,
        "bytes_accessed": (c["bytes_accessed"] + r["bytes_accessed"]
                           + q * shards * k * 8.0),
        "transcendentals": r["transcendentals"],
        "collective_bytes": float(q * shards * (k * 8 + 4)),
    }


def _cost_estimate(kind: str, q: int, n: int, budget: int = 0):
    c = refine_cost(kind, q, n, budget)
    return pl.CostEstimate(flops=int(c["flops"]),
                           bytes_accessed=int(c["bytes_accessed"]),
                           transcendentals=0)
