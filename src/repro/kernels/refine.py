"""Pallas TPU kernels: tiled GLIN refinement (mask / count / fused compact).

The paper's profile (§IX-D) shows refinement dominates query time. These
kernels evaluate the (query-window × record) MBR tests in VMEM tiles, fused
with the Z-interval slot test (``start <= slot < end``), so a (BQ × BN) tile
of candidates is disposed of per grid step without materializing gathers in
HBM.

Three entry points (all pad internally — arbitrary Q and N just work):

* ``refine_mask_pallas``    — full (Q, N) int8 mask.
* ``refine_count_pallas``   — (Q,) int32 match counts via grid-axis
  accumulation (selectivity estimation at device speed).
* ``refine_compact_pallas`` — THE refinement front-end: fused interval +
  leaf-MBR + record-MBR mask with in-VMEM prefix-sum compaction. Emits the
  per-query compacted candidate slots ``(Q, budget)`` plus survivor counts,
  replacing both the dense ``(Q, cap)`` mask materialization and the
  ``O(Q·cap·log cap)`` argsort compaction in ``core.device.batch_query``:
  only ``Q·budget`` slot ids ever reach HBM, and the expensive exact-shape
  vertex gathers downstream shrink from ``(Q·cap·V)`` to ``(Q·budget·V)``.

``refine_cost`` is the analytic bytes/flops model of each kernel (used both
as the ``pl.CostEstimate`` handed to the compiler and by
``benchmarks/roofline_report.py --kernels``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 8
DEFAULT_BN = 512
COMPACT_BN = 256      # smaller record tiles: the one-hot scatter tensor is
                      # (BQ, BN, budget) in VMEM
MAX_COMPACT_BUDGET = 1024   # (bq=8, bn=256, budget=1024) int32 = 8 MB — the
                            # scatter tensor must fit ~16 MB TPU VMEM next to
                            # the streamed tiles; larger budgets must take
                            # the jnp "scan" path (no VMEM constraint)
_NEVER = 2e30         # padding MBR coordinate: intersects nothing


def _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn):
    """(BQ,4) windows x (BN,4) record MBRs -> (BQ,BN) bool."""
    w = win_ref[...]          # (BQ, 4)
    r = mbr_ref[...]          # (BN, 4)
    b = bounds_ref[...]       # (BQ, 2) int32 [start, end)
    inter = (
        (w[:, None, 0] <= r[None, :, 2])
        & (r[None, :, 0] <= w[:, None, 2])
        & (w[:, None, 1] <= r[None, :, 3])
        & (r[None, :, 1] <= w[:, None, 3])
    )
    slot = nb * bn + jax.lax.broadcasted_iota(jnp.int32, inter.shape, 1)
    in_run = (slot >= b[:, 0:1]) & (slot < b[:, 1:2])
    return inter & in_run


def _mask_kernel(win_ref, bounds_ref, mbr_ref, out_ref, *, bn):
    nb = pl.program_id(1)
    out_ref[...] = _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn).astype(jnp.int8)


def _count_kernel(win_ref, bounds_ref, mbr_ref, out_ref, *, bn):
    nb = pl.program_id(1)
    mask = _tile_mask(win_ref, mbr_ref, bounds_ref, nb, bn)
    partial_counts = mask.sum(axis=1).astype(jnp.int32)

    @pl.when(nb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial_counts


def _compact_tile_mask(win_ref, lmbr_ref, rmbr_ref, bounds_ref, nb, bn,
                       prefilter):
    """Fused interval + leaf-MBR + record-MBR tests -> (BQ, BN) bool.

    ``win_ref`` holds the PROBE window (already padded for dwithin-style
    relations); ``prefilter`` selects the record-MBR test shape:
    "intersects" (record MBR meets the probe window) or "contains" (record
    MBR covers the window — the ``within`` prefilter)."""
    w = win_ref[...]          # (BQ, 4) probe windows
    lm = lmbr_ref[...]        # (BN, 4) per-slot leaf MBRs
    rm = rmbr_ref[...]        # (BN, 4) per-slot record MBRs
    b = bounds_ref[...]       # (BQ, 2) int32 [start, end)
    leaf_ok = (
        (w[:, None, 0] <= lm[None, :, 2])
        & (lm[None, :, 0] <= w[:, None, 2])
        & (w[:, None, 1] <= lm[None, :, 3])
        & (lm[None, :, 1] <= w[:, None, 3])
    )
    if prefilter == "contains":
        rec_ok = (
            (rm[None, :, 0] <= w[:, None, 0])
            & (rm[None, :, 1] <= w[:, None, 1])
            & (w[:, None, 2] <= rm[None, :, 2])
            & (w[:, None, 3] <= rm[None, :, 3])
        )
    else:
        rec_ok = (
            (w[:, None, 0] <= rm[None, :, 2])
            & (rm[None, :, 0] <= w[:, None, 2])
            & (w[:, None, 1] <= rm[None, :, 3])
            & (rm[None, :, 1] <= w[:, None, 3])
        )
    slot = nb * bn + jax.lax.broadcasted_iota(jnp.int32, leaf_ok.shape, 1)
    in_run = (slot >= b[:, 0:1]) & (slot < b[:, 1:2])
    return leaf_ok & rec_ok & in_run


def _compact_kernel(win_ref, bounds_ref, lmbr_ref, rmbr_ref,
                    slots_ref, count_ref, *, bn, budget, prefilter):
    """Grid step (i, j): mask the (BQ, BN) tile, then prefix-sum compact the
    survivors into the revisited (BQ, budget) output block.

    ``count_ref`` carries the running per-query survivor count across the
    record axis; a survivor's output column is that running count plus its
    exclusive within-tile prefix sum. The scatter itself is a one-hot
    reduction over the tile (TPU vector units have no scatter): survivors
    past ``budget`` only advance the count — overflow is ``count > budget``,
    signalled to the caller, never silent truncation."""
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        slots_ref[...] = jnp.full_like(slots_ref, -1)
        count_ref[...] = jnp.zeros_like(count_ref)

    mask = _compact_tile_mask(win_ref, lmbr_ref, rmbr_ref, bounds_ref, nb, bn,
                              prefilter)
    m32 = mask.astype(jnp.int32)
    base = count_ref[...]                            # (BQ,)
    excl = jnp.cumsum(m32, axis=1) - m32             # exclusive prefix
    pos = base[:, None] + excl                       # output column per slot
    sel = mask & (pos < budget)
    slot = nb * bn + jax.lax.broadcasted_iota(jnp.int32, mask.shape, 1)
    # one-hot scatter: out[q, k] = slot of the survivor whose pos == k
    cols = jax.lax.broadcasted_iota(jnp.int32, (mask.shape[0], bn, budget), 2)
    hot = (pos[:, :, None] == cols) & sel[:, :, None]
    written = (hot * (slot + 1)[:, :, None]).sum(axis=1)   # 0 where no write
    slots_ref[...] = jnp.where(written > 0, written - 1, slots_ref[...])
    count_ref[...] = base + m32.sum(axis=1)


def _grids(q, n, bq, bn):
    """Grid over internally padded operand shapes (no divisibility demands)."""
    return (pl.cdiv(q, bq), pl.cdiv(n, bn))


def _pad_inputs(windows, bounds, bq, bn, *mbr_tables):
    """Pad Q to a multiple of bq and N to a multiple of bn. Padded MBR rows
    sit at ``_NEVER`` (intersect nothing, contain nothing); padded query rows
    get empty [0, 0) runs. Callers slice outputs back to (q, n)."""
    q, n = windows.shape[0], mbr_tables[0].shape[0]
    qp, np_ = (-q) % bq, (-n) % bn
    if qp:
        windows = jnp.pad(windows, ((0, qp), (0, 0)))
        bounds = jnp.pad(bounds, ((0, qp), (0, 0)))
    padded = []
    for m in mbr_tables:
        if np_:
            m = jnp.pad(m, ((0, np_), (0, 0)), constant_values=_NEVER)
        padded.append(m)
    return windows, bounds, padded


def refine_mask_pallas(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array,
                       bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                       interpret: bool = False) -> jax.Array:
    """windows (Q,4) f32, bounds (Q,2) i32, mbrs (N,4) f32 -> (Q,N) int8."""
    q, n = windows.shape[0], mbrs.shape[0]
    windows, bounds, (mbrs,) = _pad_inputs(windows, bounds, bq, bn, mbrs)
    qp, np_ = windows.shape[0], mbrs.shape[0]
    out = pl.pallas_call(
        partial(_mask_kernel, bn=bn),
        grid=_grids(qp, np_, bq, bn),
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.int8),
        cost_estimate=_cost_estimate("mask", qp, np_),
        interpret=interpret,
    )(windows, bounds, mbrs)
    return out[:q, :n]


def refine_count_pallas(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array,
                        bq: int = DEFAULT_BQ, bn: int = DEFAULT_BN,
                        interpret: bool = False) -> jax.Array:
    """Same inputs -> (Q,) int32 match counts (reduction over the N grid axis,
    accumulated in the revisited output block)."""
    q = windows.shape[0]
    windows, bounds, (mbrs,) = _pad_inputs(windows, bounds, bq, bn, mbrs)
    qp, np_ = windows.shape[0], mbrs.shape[0]
    out = pl.pallas_call(
        partial(_count_kernel, bn=bn),
        grid=_grids(qp, np_, bq, bn),
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((qp,), jnp.int32),
        cost_estimate=_cost_estimate("count", qp, np_),
        interpret=interpret,
    )(windows, bounds, mbrs)
    return out[:q]


def refine_compact_pallas(windows: jax.Array, bounds: jax.Array,
                          leaf_mbrs: jax.Array, rec_mbrs: jax.Array,
                          budget: int, prefilter: str = "intersects",
                          bq: int = DEFAULT_BQ, bn: int = COMPACT_BN,
                          interpret: bool = False):
    """Fused mask + in-VMEM compaction.

    windows (Q,4) f32 PROBE windows, bounds (Q,2) i32 slot runs,
    leaf_mbrs/rec_mbrs (N,4) f32 slot-aligned MBR tables ->
    (slots (Q, budget) int32 [-1 padded, ascending slot order],
     counts (Q,) int32 TOTAL mask survivors — ``counts > budget`` means the
     compacted list is truncated and the caller must re-issue).
    """
    if prefilter not in ("intersects", "contains"):
        raise ValueError(f"unsupported prefilter {prefilter!r}")
    if budget > MAX_COMPACT_BUDGET:
        raise ValueError(
            f"budget {budget} exceeds MAX_COMPACT_BUDGET="
            f"{MAX_COMPACT_BUDGET}: the (bq, bn, budget) one-hot scatter "
            "block would not fit VMEM — use the jnp reference "
            "(use_pallas=False / compaction='scan') for larger budgets")
    q = windows.shape[0]
    # the one-hot scatter block is (bq, bn, budget) int32 in VMEM: keep the
    # budget axis lane-aligned
    bud = max(128, -(-budget // 128) * 128)
    windows, bounds, (leaf_mbrs, rec_mbrs) = _pad_inputs(
        windows, bounds, bq, bn, leaf_mbrs, rec_mbrs)
    qp, np_ = windows.shape[0], leaf_mbrs.shape[0]
    slots, counts = pl.pallas_call(
        partial(_compact_kernel, bn=bn, budget=bud, prefilter=prefilter),
        grid=_grids(qp, np_, bq, bn),
        in_specs=[
            pl.BlockSpec((bq, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 4), lambda i, j: (j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bq, bud), lambda i, j: (i, 0)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((qp, bud), jnp.int32),
            jax.ShapeDtypeStruct((qp,), jnp.int32),
        ),
        cost_estimate=_cost_estimate("compact", qp, np_, bud),
        interpret=interpret,
    )(windows, bounds, leaf_mbrs, rec_mbrs)
    return slots[:q, :budget], counts[:q]


# ---------------------------------------------------------------------------
# Analytic cost model (compiler CostEstimate + roofline_report --kernels)
# ---------------------------------------------------------------------------
def refine_cost(kind: str, q: int, n: int, budget: int = 0,
                verts: int = 0, bq: int = DEFAULT_BQ,
                bn: int = DEFAULT_BN) -> dict:
    """Bytes/flops model of one kernel invocation.

    ``kind``: "mask" | "count" | "compact" | "exact" — "exact" models the
    downstream exact-shape refinement stage over the compacted (Q, budget)
    survivors, so the roofline report covers the full compact+refine
    pipeline, not just candidate counting. ``verts`` is the gather width of
    the batch's widest surviving pow2 width-bucket (the vertex-pool pods
    gather per-bucket, see ``core.device.VertexPods``), NOT the store-wide
    dense padding — callers should pass ``pow2ceil`` of the surviving ring
    width they expect.
    """
    tiles_q = -(-q // bq)
    if kind == "exact":
        # per-bucket pod gather + predicate over compacted survivors:
        # verts = widest surviving bucket width, (verts, 2) f32 rings plus
        # the (off, nverts, kind, bucket) record header; ~40 flops per
        # vertex (edge clip + ray cast)
        bytes_accessed = q * budget * (verts * 8 + 16) + q * budget * 4
        flops = q * budget * verts * 40
        return {"flops": float(flops), "bytes_accessed": float(bytes_accessed),
                "transcendentals": 0}
    # streaming kernels: each query row-tile streams the full MBR table(s)
    streams = 2 if kind == "compact" else 1
    bytes_accessed = tiles_q * n * 16 * streams + q * 24
    flops = q * n * 10.0          # interval + MBR comparisons per pair
    if kind == "mask":
        bytes_accessed += q * n   # int8 mask writeback
    elif kind == "count":
        bytes_accessed += tiles_q * bq * 4
    elif kind == "compact":
        flops += q * n * 6.0      # prefix sums
        flops += q * n * float(max(budget, 1)) * 2.0   # one-hot scatter
        bytes_accessed += q * (max(budget, 1) + 1) * 4
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")
    return {"flops": float(flops), "bytes_accessed": float(bytes_accessed),
            "transcendentals": 0}


def sharded_refine_cost(q: int, n: int, budget: int, shards: int,
                        verts: int = 0, bq: int = DEFAULT_BQ,
                        bn: int = DEFAULT_BN) -> dict:
    """Per-device cost of the SHARDED compact+refine pipeline
    (``core.distributed.build_glin_query_step`` with ``exact_budget``).

    Each of ``shards`` devices streams its N/shards slice of the slot-aligned
    MBR tables through the compact stage, exact-refines its own ``(Q,
    budget)`` survivor block, and contributes the block + its survivor count
    to the cross-shard result gather — ``collective_bytes`` models that
    all-gather of ``(Q, shards, budget+1)`` int32 (the only cross-shard
    traffic; the dense path moved ``(Q, shards, cap)``)."""
    n_local = -(-n // max(shards, 1))
    c = refine_cost("compact", q, n_local, budget, bq=bq, bn=bn)
    e = refine_cost("exact", q, n_local, budget, verts=verts)
    return {
        "flops": c["flops"] + e["flops"],
        "bytes_accessed": c["bytes_accessed"] + e["bytes_accessed"],
        "transcendentals": 0,
        # every device receives the other shards' survivor blocks + counts
        "collective_bytes": float(q * shards * (budget + 1) * 4),
    }


def _cost_estimate(kind: str, q: int, n: int, budget: int = 0):
    c = refine_cost(kind, q, n, budget)
    return pl.CostEstimate(flops=int(c["flops"]),
                           bytes_accessed=int(c["bytes_accessed"]),
                           transcendentals=0)
