"""Jit'd public wrappers around the Pallas kernels.

Each wrapper (a) pads/reshapes arbitrary inputs to the kernels' tile-aligned
layouts, (b) selects interpret mode automatically off-TPU (the kernels TARGET
TPU; interpret=True executes the same kernel body on CPU for validation), and
(c) exposes a ``use_pallas=False`` escape hatch that routes to the pure-jnp
reference (used by the XLA baselines in the perf comparisons).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .morton import LANES, morton_encode_pallas
from .refine import (knn_topk_pallas, refine_compact_pallas,
                     refine_count_pallas, refine_fused_pallas,
                     refine_mask_pallas)
from .ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------- morton ----
@partial(jax.jit, static_argnames=("use_pallas",))
def morton_encode(qx: jax.Array, qy: jax.Array, use_pallas: bool = True):
    """(N,) int32 coords -> (hi, lo) int32 limbs."""
    if not use_pallas:
        return ref.morton_ref(qx, qy)
    n = qx.shape[0]
    block = 8 * LANES
    pad = (-n) % block
    qxp = jnp.pad(qx, (0, pad)).reshape(-1, LANES)
    qyp = jnp.pad(qy, (0, pad)).reshape(-1, LANES)
    hi, lo = morton_encode_pallas(qxp, qyp, interpret=not _on_tpu())
    return hi.reshape(-1)[:n], lo.reshape(-1)[:n]


# ---------------------------------------------------------------- refine ----
@partial(jax.jit, static_argnames=("use_pallas",))
def refine_mask(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array,
                use_pallas: bool = True):
    """(Q,4) f32, (Q,2) i32, (N,4) f32 -> (Q,N) int8 candidate mask.
    The kernels pad internally — any Q and N work."""
    if not use_pallas:
        return ref.refine_mask_ref(windows, bounds, mbrs)
    return refine_mask_pallas(windows, bounds, mbrs, interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("use_pallas",))
def refine_count(windows: jax.Array, bounds: jax.Array, mbrs: jax.Array,
                 use_pallas: bool = True):
    if not use_pallas:
        return ref.refine_count_ref(windows, bounds, mbrs)
    return refine_count_pallas(windows, bounds, mbrs, interpret=not _on_tpu())


@partial(jax.jit, static_argnames=("budget", "prefilter", "use_pallas"))
def refine_compact(windows: jax.Array, bounds: jax.Array,
                   leaf_mbrs: jax.Array, rec_mbrs: jax.Array, *,
                   budget: int, prefilter: str = "intersects",
                   use_pallas: bool = True):
    """Fused mask + compaction: (Q,4) probe windows, (Q,2) i32 slot runs,
    slot-aligned (N,4) leaf/record MBR tables -> (slots (Q, budget) i32
    [-1 padded], counts (Q,) i32 total survivors; ``counts > budget``
    signals truncation)."""
    if not use_pallas:
        return ref.refine_compact_ref(windows, bounds, leaf_mbrs, rec_mbrs,
                                      budget, prefilter)
    return refine_compact_pallas(windows, bounds, leaf_mbrs, rec_mbrs,
                                 budget, prefilter, interpret=not _on_tpu())


def refine_fused(windows, probe_w, qkeys, keys, recs, leaf_i, leaf_f, node_i,
                 node_f, codes, pw, pod_i, pool, leaf_mbrs, rec_mbrs, *,
                 budget, prefilter, predicate, augment, search_steps, depth,
                 num_buckets, interpret=None):
    """One-dispatch probe + compact + exact refine (``refine_fused_pallas``
    operand layout — see ``core.device.batch_query_fused`` for the packing).
    ``interpret=None`` selects interpret mode automatically off-TPU like the
    jitted wrappers above; pass ``True`` to force it (the CI parity suite).
    Not jitted here: ``predicate`` is a traced-through callable, and the one
    caller (``batch_query_fused``) is already the jit boundary."""
    if interpret is None:
        interpret = not _on_tpu()
    return refine_fused_pallas(
        windows, probe_w, qkeys, keys, recs, leaf_i, leaf_f, node_i, node_f,
        codes, pw, pod_i, pool, leaf_mbrs, rec_mbrs, budget=budget,
        prefilter=prefilter, predicate=predicate, augment=augment,
        search_steps=search_steps, depth=depth, num_buckets=num_buckets,
        interpret=interpret)


@partial(jax.jit, static_argnames=("k", "use_pallas"))
def knn_topk(d: jax.Array, ids: jax.Array, *, k: int,
             use_pallas: bool = True):
    """Deterministic top-k by ascending (distance, id): d (Q, B) f32
    [+inf = dead lane], ids (Q, B) i32 -> ((Q, k) f32, (Q, k) i32).
    The jnp reference is the two-key ``lax.sort`` truncated to k columns;
    the kernel is a k-round partial selection sort (wins when k << B)."""
    if not use_pallas:
        ds, isrt = jax.lax.sort([d, ids], num_keys=2)
        return ds[:, :k], isrt[:, :k]
    return knn_topk_pallas(d, ids, k, interpret=not _on_tpu())


# ------------------------------------------------------------- attention ----
@partial(jax.jit, static_argnames=("window", "use_pallas", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 0, use_pallas: bool = True,
                    bq: int = 128, bk: int = 128):
    """Causal (optionally sliding-window) GQA attention.
    q (B,Hq,S,D); k,v (B,Hkv,S,D)."""
    if not use_pallas:
        return ref.attention_ref(q, k, v, window=window)
    s = q.shape[2]
    bq_ = min(bq, s) if s % min(bq, s) == 0 else s
    bk_ = min(bk, s) if s % min(bk, s) == 0 else s
    return flash_attention_pallas(q, k, v, window=window, bq=bq_, bk=bk_,
                                  interpret=not _on_tpu())


# ------------------------------------------------------------------- ssd ----
@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128, use_pallas: bool = True):
    """Mamba-2 SSD scan. x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N)."""
    if not use_pallas:
        return ref.ssd_ref(x, dt, a, b, c)
    s = x.shape[1]
    ch = min(chunk, s) if s % min(chunk, s) == 0 else s
    return ssd_scan_pallas(x, dt, a, b, c, chunk=ch, interpret=not _on_tpu())


# ------------------------------------------------------------ decode attn ---
@partial(jax.jit, static_argnames=("window", "use_pallas", "bk"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     abs_pos: jax.Array, pos: jax.Array, *,
                     window: int = 0, use_pallas: bool = True, bk: int = 256):
    """One-token decode attention over a ring KV cache.
    q (B,Hq,D); k/v (B,Hkv,W,D); abs_pos (B,W); pos (B,)."""
    from .decode_attention import decode_attention_pallas
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, abs_pos, pos, window=window)
    w = k.shape[2]
    bk_ = min(bk, w) if w % min(bk, w) == 0 else w
    return decode_attention_pallas(q, k, v, abs_pos, pos, window=window,
                                   bk=bk_, interpret=not _on_tpu())
