"""Pallas TPU kernels (+ ops.py jit wrappers, ref.py pure-jnp oracles).

morton            batched Z-address encode (int32 hi/lo limbs)
refine            tiled GLIN refinement masks/counts (records x queries)
flash_attention   blocked causal/SWA GQA attention (train/prefill)
decode_attention  one-token ring-cache attention (decode)
ssd_scan          Mamba-2 SSD chunked scan with carried VMEM state

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated against ref.py with interpret=True on CPU (tests/test_kernels.py).
"""
