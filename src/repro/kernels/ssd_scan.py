"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked scan.

TPU-native formulation of the SSD algorithm (arXiv:2405.21060): the sequence
is split into chunks of length L; within a chunk the recurrence is expressed
as dense (L×L)·(L×P) and (L×N)·(N×P) matmuls (MXU work), while the O(S)
recurrence survives only across chunks — carried as an (N, P) f32 state in
VMEM scratch along the minor (sequential) grid axis. All decay exponentials
are of non-positive arguments (A < 0, dt > 0), so the kernel is
overflow-free by construction.

Layout: x (B,S,H,P), dt (B,S,H) [post-softplus], A (H,) [negative],
B/C (B,S,N) [single SSM group]. Output y (B,S,H,P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state, *, chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (L,)
    a = a_ref[0].astype(jnp.float32)              # scalar A_h (negative)
    bm = b_ref[0].astype(jnp.float32)             # (L, N)
    cm = c_ref[0].astype(jnp.float32)             # (L, N)

    g = jnp.cumsum(dt * a)                        # (L,) non-increasing
    gtot = g[-1]

    # intra-chunk: Y_diag = ((C B^T) ∘ Γ ∘ dt_j) X,  Γ_ij = e^{g_i - g_j}, i>=j
    s = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    gamma = jnp.where(li >= lj, jnp.exp(g[:, None] - g[None, :]), 0.0)
    w = s * gamma * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, P)

    # carry-in: Y_off = (C state) ∘ e^{g}
    y += jax.lax.dot_general(cm, state[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * jnp.exp(g)[:, None]

    # state update: state' = e^{gtot} state + B^T (e^{gtot-g} ∘ dt ∘ X)
    xw = x * (jnp.exp(gtot - g) * dt)[:, None]
    state[...] = jnp.exp(gtot) * state[...] + jax.lax.dot_general(
        bm, xw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0, :, 0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array, *,
                    chunk: int = DEFAULT_CHUNK,
                    interpret: bool = False) -> jax.Array:
    """x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N) -> y (B,S,H,P)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (bsz, h, s // chunk)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, ci: (b_, ci, h_)),
            pl.BlockSpec((1,), lambda b_, h_, ci: (h_,)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ci: (b_, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, ci: (b_, ci, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
