"""Sharding: logical-axis rules + activation-constraint context.

Model code calls ``constrain(x, logical_axes)`` everywhere; outside a mesh
context that is the identity, inside ``use_rules(rules)`` it becomes a GSPMD
``with_sharding_constraint`` resolved through the rule table. This keeps model
code mesh-agnostic (smoke tests see no sharding at all).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

from .rules import MeshRules, logical_to_spec, spec_tree

__all__ = ["MeshRules", "logical_to_spec", "spec_tree", "use_rules",
           "constrain", "current_rules"]

_STATE = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def constrain(x, logical: Tuple[Optional[str], ...]):
    rules = current_rules()
    if rules is None:
        return x
    spec = logical_to_spec(rules, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
