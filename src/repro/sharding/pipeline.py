"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The multi-pod mesh folds ``pod`` into data parallelism by default
(DESIGN.md §4); this module provides the alternative mapping — pipeline
stages across pods — as a composable shard_map program:

* stage parameters are stacked on a leading axis sharded over ``pod``;
* microbatches stream through the classic GPipe schedule
  (M + S − 1 ticks for M microbatches over S stages);
* activations hop stages via ``ppermute`` (the cross-pod DCI link — exactly
  the transfer pipeline parallelism exists to amortize);
* the last stage's outputs are returned to all pods with one ``psum``
  (zeros elsewhere), which a caller can elide by keeping outputs sharded.

Bubble fraction = (S−1)/(M+S−1) — reported by :func:`bubble_fraction` so
launchers can size microbatch counts.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["gpipe", "bubble_fraction"]


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def gpipe(stage_fn: Callable, mesh: Mesh, stage_axis: str = "pod"):
    """Build a pipelined apply: ``f(stage_params, xs) -> ys``.

    ``stage_params``: pytree with a leading stage axis (sharded over
    ``stage_axis``); ``stage_fn(params_slice, x) -> y`` maps one microbatch
    through ONE stage; ``xs``: (M, ...) microbatches (replicated in; the
    schedule injects them at stage 0). Returns (M, ...) outputs.
    """
    s = mesh.shape[stage_axis]

    def inner(params, xs):
        # params leaves arrive as (1, ...) local stage slices
        local = jax.tree_util.tree_map(lambda a: a[0], params)
        idx = jax.lax.axis_index(stage_axis)
        m = xs.shape[0]
        state = jnp.zeros_like(xs[0])
        outs = []
        fwd = [(i, i + 1) for i in range(s - 1)]
        for t in range(m + s - 1):
            mb = jnp.minimum(t, m - 1)
            inject = (idx == 0) & (t < m)
            x_in = jnp.where(inject, xs[mb], state)
            y = stage_fn(local, x_in)
            # emit from the last stage during its active window
            emit = (idx == s - 1) & (t >= s - 1)
            outs.append(jnp.where(emit, y, jnp.zeros_like(y)))
            if s > 1:
                state = jax.lax.ppermute(y, stage_axis, fwd)
        ys = jnp.stack(outs[s - 1:])                 # (M, ...)
        return jax.lax.psum(ys, stage_axis)          # nonzero only at last

    from repro.utils.compat import shard_map as compat_shard_map
    return compat_shard_map(inner, mesh,
                            (P(stage_axis), P(*([None]))), P())
