"""Logical-axis sharding rules (GSPMD partitioning for the production mesh).

Weights and activations carry *logical* axis names; a rule table maps them to
mesh axes per mesh flavour:

    batch   -> ('pod', 'data')   data parallel (pod folds into DP by default)
    fsdp    -> ('pod', 'data')   parameter/optimizer sharding (ZeRO-3 style)
    heads   -> 'model'           tensor parallel attention
    kv      -> 'model'           TP for KV projections (replicated if indivisible)
    ff      -> 'model'           TP for MLP hidden
    vocab   -> 'model'           TP for embedding/LM head
    experts -> 'data'            expert parallel (falls back per-arch)
    seq     -> None | 'model'    sequence parallel (optional, §Perf lever)

``logical_to_spec`` resolves a tuple of logical names into a PartitionSpec,
dropping any axis whose dimension is not divisible by its mesh extent
(GSPMD would pad; we prefer replication for correct roofline accounting).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshRules", "logical_to_spec", "spec_tree"]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Rule table bound to a concrete mesh."""

    mesh: Mesh
    seq_sharding: bool = False     # sequence parallelism for the residual stream
    expert_axis: str = "data"

    def axis_for(self, logical: Optional[str]):
        m = self.mesh
        has_pod = "pod" in m.axis_names
        table = {
            None: None,
            "batch": ("pod", "data") if has_pod else ("data",),
            "fsdp": ("pod", "data") if has_pod else ("data",),
            "w_embed": ("pod", "data") if has_pod else ("data",),
            "heads": ("model",),
            "kv": ("model",),
            "kv_seq": ("model",),
            "ff": ("model",),
            "vocab": ("model",),
            "experts": (self.expert_axis,) if self.expert_axis else None,
            "moe_cap": ("pod", "data") if has_pod else ("data",),
            "seq": ("model",) if self.seq_sharding else None,
            "stage": ("pod",) if has_pod else None,
        }
        return table.get(logical, None)

    def extent(self, axes) -> int:
        if axes is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def logical_to_spec(rules: MeshRules, logical: Tuple[Optional[str], ...],
                    shape: Tuple[int, ...]) -> P:
    """Logical axes + concrete shape -> PartitionSpec with divisibility checks."""
    assert len(logical) == len(shape), (logical, shape)
    used = set()
    out = []
    for name, dim in zip(logical, shape):
        axes = rules.axis_for(name)
        if axes is None:
            out.append(None)
            continue
        axes = tuple(a for a in axes if a not in used)
        ext = rules.extent(axes)
        if ext <= 1 or dim % ext != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_tree(rules: MeshRules, logical_tree, shape_tree):
    """Map parallel trees of logical-axis tuples and shapes to PartitionSpecs."""
    import jax

    return jax.tree_util.tree_map(
        lambda lg, shp: logical_to_spec(rules, lg, tuple(shp)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def named(rules: MeshRules, spec: P) -> NamedSharding:
    return NamedSharding(rules.mesh, spec)
