"""Hymba-1.5B [arXiv:2411.13676]: hybrid parallel attention+Mamba heads,
SWA in local layers, 128 meta tokens. 32L d=1600 25H (GQA kv=5) d_ff=5504."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    window=1024, rope_theta=1e4,
    ssm_state=16, ssm_heads=50, ssm_head_dim=64, ssm_expand=2,
    hybrid=True, meta_tokens=128,
)
