"""Granite-34B-Code [arXiv:2405.04324]: deep MQA (kv=1) code model.
88L d=6144 48H kv=1 d_ff=24576 vocab=49152."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152, rope_theta=1e5, tie_embeddings=False,
    mlp_gated=False,
)
