"""Mixtral-8x22B [arXiv:2401.04088]: 8-expert top-2 MoE with sliding-window
attention. 56L d=6144 48H kv=8 expert d_ff=16384 vocab=32768."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768, window=4096, rope_theta=1e6,
    n_experts=8, top_k=2, tie_embeddings=False,
)
