"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family]: 128-expert top-8 MoE,
QK-norm. 94L d=4096 64H kv=4 expert d_ff=1536 vocab=151936."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, rope_theta=1e6, qk_norm=True,
    n_experts=128, top_k=8, tie_embeddings=False,
)
