"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]: qwen1.5 dense MHA.
32L d=4096 32H kv=32 d_ff=13440 vocab=92416."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab=92416, rope_theta=1e6, tie_embeddings=False,
)
