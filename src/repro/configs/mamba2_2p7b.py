"""Mamba2-2.7B [arXiv:2405.21060]: attention-free SSD (state-space duality).
64L d=2560 ssm_state=128 vocab=50280."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_heads=80, ssm_head_dim=64, ssm_expand=2,
)
