"""Qwen2-VL-2B [arXiv:2409.12191]: M-RoPE, dynamic-resolution ViT frontend
(stubbed: input_specs supplies patch embeddings). 28L d=1536 12H kv=2."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936,
    mrope=True, mrope_sections=(16, 24, 24), rope_theta=1e6,
    frontend="embed_stub",
)
