"""Architecture & shape configuration (assigned pool; see DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get_arch",
           "get_shape", "all_cells"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 => attention-free)
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention flavour
    window: int = 0             # sliding-window size (0 = full causal)
    rope_theta: float = 1e6
    mrope: bool = False         # Qwen2-VL M-RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    qk_norm: bool = False
    mlp_gated: bool = True      # SwiGLU (True) vs GELU 2-matrix MLP (False)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssd_chunk: int = 128        # SSD intra-chunk tile length
    # hybrid (Hymba): parallel attention + SSM heads per layer
    hybrid: bool = False
    meta_tokens: int = 0
    # IO
    frontend: str = "text"      # text | embed_stub (vision/audio frontends)
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return self.has_ssm or (self.window > 0)

    def param_count(self) -> int:
        """Analytic parameter count (sanity vs the published sizes)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per = 2 * d  # norms
        if self.has_attention:
            per += d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
            if self.qk_norm:
                per += 2 * self.head_dim
        if self.has_ssm:
            di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
            per += d * (2 * di + 2 * n + h)          # in_proj (z,x,B,C,dt)
            per += self.conv_width * (di + 2 * n)    # depthwise conv
            per += 3 * h + di                        # A, D, dt_bias, norm
            per += di * d                            # out_proj
        ff_mats = 3 if self.mlp_gated else 2
        if self.is_moe:
            per += d * self.n_experts + self.n_experts * ff_mats * d * f
        elif f > 0:
            per += ff_mats * d * f
        return emb + self.n_layers * per + d + self.meta_tokens * d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        ff_mats = 3 if self.mlp_gated else 2
        dense_like = (self.param_count()
                      - self.n_layers * self.n_experts * ff_mats * d * f)
        return dense_like + self.n_layers * self.top_k * ff_mats * d * f

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        heads = min(self.n_heads, 4) if self.n_heads else 0
        kv = max(1, min(self.n_kv_heads, heads)) if heads else 0
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            mrope_sections=(2, 3, 3),  # scaled to head_dim/2 = 8
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            window=min(self.window, 32) if self.window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            # keep the invariant ssm_heads * ssm_head_dim == ssm_expand * d_model
            ssm_heads=(self.ssm_expand * 64) // 16 if self.ssm_heads else 0,
            ssm_head_dim=16 if self.ssm_heads else 64,
            meta_tokens=min(self.meta_tokens, 8),
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "hymba_1p5b", "qwen2_vl_2b", "codeqwen1p5_7b", "phi4_mini_3p8b",
    "granite_34b", "granite_3_2b", "musicgen_medium", "mixtral_8x22b",
    "qwen3_moe_235b", "mamba2_2p7b", "glin",
]


def get_arch(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: long_500k needs "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""


def all_cells():
    """All (arch, shape) cells with support flags (40 LM cells)."""
    out = []
    for aid in ARCH_IDS:
        if aid == "glin":
            continue
        cfg = get_arch(aid)
        for sname, shp in SHAPES.items():
            ok, why = cell_supported(cfg, shp)
            out.append((aid, sname, ok, why))
    return out
