"""MusicGen-medium [arXiv:2306.05284]: decoder-only over EnCodec tokens
(frontend stubbed: input_specs supplies frame embeddings).
48L d=1536 24H kv=24 d_ff=6144 vocab=2048."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, rope_theta=1e4,
    mlp_gated=False,
    frontend="embed_stub", tie_embeddings=False,
)
