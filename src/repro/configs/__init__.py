"""Config registry: one module per assigned architecture + GLIN itself."""
from .base import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig, all_cells,
                   get_arch, get_shape)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeConfig", "all_cells",
           "get_arch", "get_shape"]
