"""Deterministic, restart-safe data pipeline.

Every batch is a pure function of ``(step, host_id)`` — no iterator state, no
shuffle buffers. Consequences for fault tolerance (DESIGN.md §4):

* a restarted (or elastically re-sharded) job resumes at step k and sees
  exactly the batches it would have seen — no data loss or duplication;
* stragglers can't skew data order: there is no inter-host coordination;
* the pipeline itself needs no checkpoint state beyond the step counter.

The synthetic corpus is a seeded Zipfian token stream with local n-gram
structure (so models actually learn and loss decreases in the examples).
A background prefetch thread keeps ``depth`` batches in flight.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

__all__ = ["SyntheticLM", "Prefetcher"]


class SyntheticLM:
    """Deterministic synthetic LM corpus: batch = f(seed, step, host)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 host_id: int = 0, num_hosts: int = 1):
        assert batch % num_hosts == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        # a fixed random "bigram table" gives the stream learnable structure
        rng = np.random.default_rng(seed ^ 0xC0FFEE)
        self.next_tok = rng.integers(0, vocab, size=(vocab, 4))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        b, s = self.local_batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, b)
        noise = rng.random((b, s))
        branch = rng.integers(0, 4, (b, s))
        rand_tok = rng.integers(0, self.vocab, (b, s))
        for t in range(s):
            follow = self.next_tok[toks[:, t], branch[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.8, follow, rand_tok[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}


class Prefetcher:
    """Double-buffered background prefetch of a (step -> batch) source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 transform=None):
        self.source = source
        self.transform = transform or (lambda x: x)
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.transform(self.source.batch_at(step))
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
