"""Model assembly for every assigned architecture (DESIGN.md §5).

One homogeneous pre-norm decoder stack covers the whole pool; the block body
dispatches on config flags:

    dense  : attention + (Sw)iGLU/GELU MLP
    moe    : attention + sort-dispatch MoE FFN
    ssm    : Mamba-2 mixer only (no MLP)
    hybrid : parallel attention + SSM heads (Hymba), averaged, + MLP
    vlm    : dense + M-RoPE, embeddings supplied by the (stubbed) frontend
    audio  : dense over EnCodec frame embeddings (stubbed frontend)

Weights are stacked along a leading layer axis and the stack is a single
``lax.scan`` (bounded HLO size — one compiled block regardless of depth);
``jax.checkpoint`` wraps the block for rematerialization in training.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import dense, he_init, rms_norm

__all__ = ["init_params", "logical_axes", "forward_train", "loss_fn",
           "prefill", "decode_step", "init_cache", "cache_logical",
           "pick_chunk"]


def pick_chunk(s: int, target: int = 1024) -> int:
    c = min(target, s)
    while s % c:
        c //= 2
    return max(c, 1)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def _mlp_init(cfg, key, dtype):
    nl, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wu": he_init(ks[1], (nl, d, f), d, dtype),
         "wd": he_init(ks[2], (nl, f, d), f, dtype)}
    if cfg.mlp_gated:
        p["wg"] = he_init(ks[0], (nl, d, f), d, dtype)
    return p


def _mlp_logical(cfg):
    p = {"wu": (None, "w_embed", "ff"), "wd": (None, "ff", "w_embed")}
    if cfg.mlp_gated:
        p["wg"] = (None, "w_embed", "ff")
    return p


def init_params(cfg, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    nl, d, v = cfg.n_layers, cfg.d_model, cfg.vocab
    params: Dict[str, Any] = {
        "embed": he_init(keys[0], (v, d), d, dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    blocks: Dict[str, Any] = {"ln1": jnp.ones((nl, d), dtype)}
    if cfg.has_attention:
        blocks["attn"] = attn.init_attn_params(cfg, keys[1], dtype)
    if cfg.has_ssm:
        blocks["ssm"] = ssm_mod.init_ssm_params(cfg, keys[2], dtype)
    if cfg.d_ff > 0:
        blocks["ln2"] = jnp.ones((nl, d), dtype)
        if cfg.is_moe:
            blocks["moe"] = moe_mod.init_moe_params(cfg, keys[3], dtype)
        else:
            blocks["mlp"] = _mlp_init(cfg, keys[3], dtype)
    params["blocks"] = blocks
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(keys[4], (d, v), d, dtype)
    if cfg.meta_tokens:
        params["meta"] = he_init(keys[5], (cfg.meta_tokens, d), d, dtype)
    return params


def logical_axes(cfg) -> Dict[str, Any]:
    blocks: Dict[str, Any] = {"ln1": (None, None)}
    if cfg.has_attention:
        blocks["attn"] = attn.attn_logical(cfg)
    if cfg.has_ssm:
        blocks["ssm"] = ssm_mod.ssm_logical(cfg)
    if cfg.d_ff > 0:
        blocks["ln2"] = (None, None)
        if cfg.is_moe:
            blocks["moe"] = moe_mod.moe_logical(cfg)
        else:
            blocks["mlp"] = _mlp_logical(cfg)
    out = {"embed": ("vocab", "w_embed"), "final_norm": (None,),
           "blocks": blocks}
    # tie/meta handled dynamically to mirror init_params' structure
    if not cfg.tie_embeddings:
        out["lm_head"] = ("w_embed", "vocab")
    if cfg.meta_tokens:
        out["meta"] = (None, None)
    return out


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _mlp_apply(x, p, cfg, constrain):
    if cfg.mlp_gated:
        h = (jax.nn.silu(dense(x, p["wg"]).astype(jnp.float32)).astype(x.dtype)
             * dense(x, p["wu"]))
    else:
        h = jax.nn.gelu(dense(x, p["wu"]).astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, ("batch", "seq", "ff"))
    return dense(h, p["wd"])


def _block_train(x, pl, cfg, positions, constrain, chunk):
    h = rms_norm(x, pl["ln1"])
    caches = {}
    mix = 0.0
    n_paths = 0
    if cfg.has_attention:
        a_out, kv = attn.attention_train(h, pl["attn"], cfg, positions,
                                         constrain, q_chunk=chunk)
        mix = mix + a_out
        caches["attn"] = kv
        n_paths += 1
    if cfg.has_ssm:
        s_out, sc = ssm_mod.ssm_mixer_train(h, pl["ssm"], cfg, constrain)
        mix = mix + s_out
        caches["ssm"] = sc
        n_paths += 1
    x = x + mix / n_paths
    if cfg.d_ff > 0:
        h2 = rms_norm(x, pl["ln2"])
        if cfg.is_moe:
            f = moe_mod.moe_ffn(h2, pl["moe"], cfg, constrain)
        else:
            f = _mlp_apply(h2, pl["mlp"], cfg, constrain)
        x = x + f
    x = constrain(x, ("batch", "seq", None))
    return x, caches


def _block_decode(x, pl, cfg, cache, constrain):
    h = rms_norm(x, pl["ln1"])
    new_cache = {}
    mix = 0.0
    n_paths = 0
    if cfg.has_attention:
        a_out, kv = attn.attention_decode(h, pl["attn"], cfg, cache["attn"],
                                          constrain)
        mix = mix + a_out
        new_cache["attn"] = kv
        n_paths += 1
    if cfg.has_ssm:
        s_out, sc = ssm_mod.ssm_mixer_decode(h, pl["ssm"], cfg, cache["ssm"],
                                             constrain)
        mix = mix + s_out
        new_cache["ssm"] = sc
        n_paths += 1
    x = x + mix / n_paths
    if cfg.d_ff > 0:
        h2 = rms_norm(x, pl["ln2"])
        if cfg.is_moe:
            f = moe_mod.moe_ffn(h2, pl["moe"], cfg, constrain)
        else:
            f = _mlp_apply(h2, pl["mlp"], cfg, constrain)
        x = x + f
    return x, new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg, batch, constrain):
    if cfg.frontend == "embed_stub":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = params["embed"][batch["tokens"]]
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None], (b, cfg.meta_tokens,
                                                       x.shape[-1]))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
        if positions.ndim == 2:
            mpos = jnp.arange(cfg.meta_tokens, dtype=jnp.int32)[None, :].repeat(b, 0)
            positions = jnp.concatenate([mpos, positions + cfg.meta_tokens], 1)
        else:  # (B,3,S)
            mpos = jnp.arange(cfg.meta_tokens, dtype=jnp.int32)[None, None, :]
            mpos = jnp.broadcast_to(mpos, (b, 3, cfg.meta_tokens))
            positions = jnp.concatenate([mpos, positions + cfg.meta_tokens], -1)
    x = constrain(x, ("batch", "seq", None))
    return x, positions


def _lm_head(x, params, cfg, constrain):
    if cfg.tie_embeddings:
        logits = jax.lax.dot_general(
            x, params["embed"], (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        logits = jax.lax.dot_general(
            x, params["lm_head"], (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    logical = ("batch",) + ("seq",) * (logits.ndim - 2) + ("vocab",)
    return constrain(logits, logical)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def forward_train(params, cfg, batch, constrain, remat: bool = True,
                  collect_cache: bool = False, logits_last_only: bool = False):
    """Full-sequence forward. Returns (logits fp32 (B,S,V), caches|None).
    ``logits_last_only`` skips the LM head for all but the final position
    (prefill: a ~2·T·d·V FLOP and O(T·V) memory saving)."""
    x, positions = _embed_inputs(params, cfg, batch, constrain)
    chunk = pick_chunk(x.shape[1])

    def body(x, pl):
        y, caches = _block_train(x, pl, cfg, positions, constrain, chunk)
        return y, (caches if collect_cache else None)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, caches = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"])
    if cfg.meta_tokens:
        x = x[:, cfg.meta_tokens:]
    if logits_last_only:
        x = x[:, -1:]
    logits = _lm_head(x, params, cfg, constrain)
    return logits, caches


def loss_fn(params, cfg, batch, constrain, remat: bool = True):
    logits, _ = forward_train(params, cfg, batch, constrain, remat=remat)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def prefill(params, cfg, batch, constrain, seq_len_cache: Optional[int] = None):
    """Prefill: forward + publish decode caches.

    Returns (last-token logits (B,V), cache pytree with stacked L axis)."""
    logits, caches = forward_train(params, cfg, batch, constrain, remat=False,
                                   collect_cache=True, logits_last_only=True)
    out = {}
    if cfg.has_attention:
        kv = caches["attn"]                       # k,v: (L,B,S',Hkv,Dh)
        s_tot = kv["k"].shape[2]
        w = attn.cache_window(cfg, max(seq_len_cache or s_tot, s_tot))
        # Ring invariant shared with decode: abs position p lives in slot
        # p % w. The ring layout of the last-w slice is a CYCLIC SHIFT, so
        # use static slice + roll — a gather along the model-sharded seq
        # axis would force GSPMD to replicate the stacked cache (§Perf log).
        slots = jnp.arange(w, dtype=jnp.int32)
        if w <= s_tot:
            r = (s_tot - w) % w
            k = kv["k"][:, :, s_tot - w:]
            v = kv["v"][:, :, s_tot - w:]
            if r:
                k = jnp.roll(k, r, axis=2)
                v = jnp.roll(v, r, axis=2)
            abs_pos = s_tot - w + (slots - r) % w
        else:  # decode headroom beyond the prompt: pad empty slots
            pad = [(0, 0), (0, 0), (0, w - s_tot), (0, 0), (0, 0)]
            k = jnp.pad(kv["k"], pad)
            v = jnp.pad(kv["v"], pad)
            abs_pos = jnp.where(slots < s_tot, slots, -1)
        lyr, bb = k.shape[0], k.shape[1]
        out["attn"] = {
            "k": k, "v": v,
            "abs_pos": jnp.broadcast_to(abs_pos, (lyr, bb, w)).astype(jnp.int32),
            "pos": jnp.full((lyr, bb), s_tot, jnp.int32),
        }
    if cfg.has_ssm:
        out["ssm"] = caches["ssm"]
    return logits[:, -1], out


def decode_step(params, cfg, batch, cache, constrain):
    """One decode step. batch: {tokens (B,)} or {embeds (B, d)}.
    Returns (logits (B,V), new cache)."""
    if cfg.frontend == "embed_stub":
        x = batch["embeds"][:, None, :].astype(jnp.dtype(cfg.dtype))
    else:
        x = params["embed"][batch["tokens"]][:, None, :]
    x = constrain(x, ("batch", None, None))

    def body(x, pl_cache):
        pl, lc = pl_cache
        return _block_decode(x, pl, cfg, lc, constrain)

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"])
    logits = _lm_head(x[:, 0], params, cfg, constrain)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, seq_len: int, as_specs: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    out = {}
    if cfg.has_attention:
        out["attn"] = attn.init_decode_cache(cfg, batch, seq_len, dtype,
                                             as_specs=as_specs)
    if cfg.has_ssm:
        out["ssm"] = ssm_mod.init_ssm_cache(cfg, batch, dtype, as_specs=as_specs)
    return out


def cache_logical(cfg):
    out = {}
    if cfg.has_attention:
        out["attn"] = attn.decode_cache_logical()
    if cfg.has_ssm:
        out["ssm"] = ssm_mod.ssm_cache_logical()
    return out
