"""Shared transformer layers (pure functional JAX)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope", "mrope", "swiglu", "dense", "he_init"]


def he_init(key, shape, in_axis_size, dtype):
    scale = (2.0 / max(1, in_axis_size)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim/2), fp32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rot(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D); cos/sin broadcastable to (B, S, 1, D/2)."""
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Standard RoPE. x (B,S,H,D), positions (B,S) or (S,)."""
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = _rope_angles(positions, x.shape[-1], theta)  # (B,S,D/2)
    return _apply_rot(x, cos[:, :, None, :], sin[:, :, None, :])


def mrope(x: jax.Array, positions: jax.Array, sections: Tuple[int, int, int],
          theta: float = 1e4) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the head dim is split into (t, h, w)
    sections, each rotated by its own position stream.
    x (B,S,H,D); positions (B,3,S); sections sum to D/2."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    cos_parts, sin_parts = [], []
    off = 0
    for i, sec in enumerate(sections):
        freq = theta ** (-(jnp.arange(off, off + sec, dtype=jnp.float32)) / half)
        ang = positions[:, i, :].astype(jnp.float32)[..., None] * freq
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        off += sec
    cos = jnp.concatenate(cos_parts, axis=-1)  # (B,S,half)
    sin = jnp.concatenate(sin_parts, axis=-1)
    return _apply_rot(x, cos[:, :, None, :], sin[:, :, None, :])


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """(…, in) @ (in, out). In-MXU accumulation is fp32 regardless; emitting
    the activation dtype keeps cross-shard partial-sum reductions (TP psum)
    at bf16 width — half the collective bytes (EXPERIMENTS.md §Perf)."""
    return jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=x.dtype)


def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
           constrain=None) -> jax.Array:
    h = jax.nn.silu(dense(x, wg).astype(jnp.float32)).astype(x.dtype) * dense(x, wu)
    if constrain is not None:
        h = constrain(h, ("batch", "seq", "ff"))
    return dense(h, wd)
