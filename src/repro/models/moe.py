"""Mixture-of-Experts FFN with sort-based (ragged) dispatch.

Cost-faithful pure-JAX MoE: tokens' (token, expert) replicas are sorted by
expert id, scattered into fixed-capacity per-expert buffers, processed with
batched expert matmuls (E×C×d×f FLOPs ≈ T·k·cf·d·f — the *active* compute,
not E× dense), and combined back with top-k gate weights. Overflowing a
capacity bucket drops the replica (standard capacity-factor semantics).

Sharding: expert buffers carry the ("experts", None, "ff") logical axes —
with experts mapped to the data axis this is expert parallelism and GSPMD
lowers the scatter/gather to all-to-all-style collectives; with experts
replicated (e.g. Mixtral's 8 experts on a 16-wide axis) weights shard over
(w_embed × ff) instead. See sharding/rules.py.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .layers import he_init

__all__ = ["init_moe_params", "moe_logical", "moe_ffn"]


def init_moe_params(cfg, key, dtype) -> Dict[str, jax.Array]:
    nl, d, f, e = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": he_init(ks[0], (nl, d, e), d, jnp.float32),
        "wg": he_init(ks[1], (nl, e, d, f), d, dtype),
        "wu": he_init(ks[2], (nl, e, d, f), d, dtype),
        "wd": he_init(ks[3], (nl, e, f, d), f, dtype),
    }
    if not cfg.mlp_gated:
        del p["wg"]
    return p


def moe_logical(cfg) -> Dict[str, tuple]:
    p = {
        "router": (None, "w_embed", None),
        "wg": (None, "experts", "w_embed", "ff"),
        "wu": (None, "experts", "w_embed", "ff"),
        "wd": (None, "experts", "ff", "w_embed"),
    }
    if not cfg.mlp_gated:
        del p["wg"]
    return p


CHUNK_TOKENS = 65536  # dispatch chunk: bounds live routing buffers (~GBs)


def _moe_chunk(xf: jax.Array, p: Dict[str, jax.Array], cfg, constrain,
               capacity_factor: float) -> jax.Array:
    """Dispatch + expert FFN + combine for one chunk of flat tokens (T, d)."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k

    # --- routing (fp32) ---
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)             # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- sort replicas by expert ---
    flat_e = eidx.reshape(-1).astype(jnp.int32)       # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    ranks = (jnp.arange(t * k, dtype=jnp.int32) - starts[se]).astype(jnp.int32)

    cap = int(math.ceil(t * k * capacity_factor / e / 128.0)) * 128
    cap = max(128, min(cap, t))
    keep = ranks < cap

    # --- gather into per-expert buffers ---
    # Dispatch data movement keeps rows unsharded and the FEATURE dim sharded
    # over 'model': GSPMD partitions gathers/scatters trivially when the
    # indexed dim is unsharded, but falls back to replicated u32 index
    # broadcasts of the full (slots, d) shape when it is (10 GiB/device on
    # the 235B MoE — EXPERIMENTS.md §Perf iteration log). The buffer is then
    # explicitly resharded to the expert-parallel layout for the matmuls.
    xrep = jnp.broadcast_to(xf[:, None, :], (t, k, d)).reshape(t * k, d)
    xrep = constrain(xrep, (None, "ff"))
    gathered = constrain(xrep[order], (None, "ff"))   # permutation gather
    # drops land in a padding column (cap..cap+127) sliced off below
    rk_safe = jnp.where(keep, ranks, cap)
    flat_slot = se * (cap + 128) + rk_safe
    buf = jnp.zeros((e * (cap + 128), d), xf.dtype)
    buf = buf.at[flat_slot].set(gathered, mode="drop")  # unique slots
    buf = constrain(buf, (None, "ff"))
    buf = buf.reshape(e, cap + 128, d)[:, :cap]
    # experts shard over data when divisible (EP); otherwise the capacity
    # axis takes the data shards (Mixtral: 8 experts on a 16-wide axis)
    buf = constrain(buf, ("experts", "moe_cap", None))

    # --- expert FFN (batched over E) ---
    dt_ = xf.dtype  # bf16 partial-sum reductions (see layers.dense)
    if cfg.mlp_gated:
        hg = jnp.einsum("ecd,edf->ecf", buf, p["wg"], preferred_element_type=dt_)
        hu = jnp.einsum("ecd,edf->ecf", buf, p["wu"], preferred_element_type=dt_)
        h = (jax.nn.silu(hg.astype(jnp.float32)).astype(dt_) * hu)
    else:
        hu = jnp.einsum("ecd,edf->ecf", buf, p["wu"], preferred_element_type=dt_)
        h = jax.nn.gelu(hu.astype(jnp.float32)).astype(dt_)
    h = constrain(h, ("experts", "moe_cap", "ff"))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"], preferred_element_type=dt_)

    # --- combine (inverse permutation + reduce over k) ---
    w = (gates.reshape(-1)[order] * keep).astype(xf.dtype)  # (T*k,)
    y_pad = jnp.concatenate([y_buf, jnp.zeros((e, 128, d), y_buf.dtype)], 1)
    y_flat = constrain(y_pad.reshape(e * (cap + 128), d), (None, "ff"))
    contrib = constrain(y_flat[flat_slot], (None, "ff")) * w[:, None]
    inv = jnp.argsort(order)                                # inverse perm
    gathered_back = constrain(contrib[inv], (None, "ff"))   # perm gather
    y = gathered_back.reshape(t, k, d).sum(axis=1)
    return constrain(y, ("batch", None))


def moe_ffn(x: jax.Array, p: Dict[str, jax.Array], cfg, constrain,
            capacity_factor: float = 1.25,
            chunk_tokens: int = CHUNK_TOKENS) -> jax.Array:
    """Chunked MoE: long prefills scan over ~64k-token dispatch chunks so the
    routing buffers stay O(chunk) instead of O(sequence) — 32k-prefill of the
    235B MoE would otherwise need hundreds of GB per device (EXPERIMENTS.md
    §Method). Training microbatches and decode fit in a single chunk."""
    b, s, d = x.shape
    t = b * s
    if t <= chunk_tokens:
        return _moe_chunk(x.reshape(t, d), p, cfg, constrain,
                          capacity_factor).reshape(b, s, d)

    # Chunk along the SEQUENCE axis so the batch axis (data-sharded) stays
    # the leading dim of every chunk — reshaping tokens across the batch
    # boundary makes GSPMD re-materialize replicated copies (§Perf log).
    chunk_s = max(1, chunk_tokens // b)
    while s % chunk_s:
        chunk_s //= 2
    n_chunks = s // chunk_s
    xc = jnp.moveaxis(x.reshape(b, n_chunks, chunk_s, d), 1, 0)
    xc = constrain(xc, (None, "batch", None, None))

    def body(_, xt):
        yt = _moe_chunk(xt.reshape(b * chunk_s, d), p, cfg, constrain,
                        capacity_factor)
        return None, yt.reshape(b, chunk_s, d)

    _, yc = jax.lax.scan(body, None, xc)
    yc = constrain(yc, (None, "batch", None, None))
    return jnp.moveaxis(yc, 0, 1).reshape(b, s, d)
