"""Attention: GQA / MQA / MHA with RoPE or M-RoPE, causal + sliding window.

Three entry points:

* ``attention_train``  — full-sequence, query-chunked online-softmax (exact,
  flash-style memory profile so 32k prefill never materializes S×S scores;
  scores for one (chunk × S) tile live at a time).
* ``attention_decode`` — one token vs a (possibly ring-buffered) KV cache with
  per-sequence positions; sliding-window archs keep only W slots.
* ``init_attn_params`` / ``attn_logical`` — parameters + logical sharding axes.

The Pallas flash kernel (kernels/flash_attention.py) is the TPU-target
implementation of ``attention_train``'s inner loop; the XLA path here is what
the CPU dry-run lowers (kernels don't lower on the CPU backend) and the
numerical oracle for it.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense, he_init, mrope, rms_norm, rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_attn_params(cfg, key, dtype) -> Dict[str, jax.Array]:
    nl, d = cfg.n_layers, cfg.d_model
    a, kv = cfg.attn_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": he_init(ks[0], (nl, d, a), d, dtype),
        "wk": he_init(ks[1], (nl, d, kv), d, dtype),
        "wv": he_init(ks[2], (nl, d, kv), d, dtype),
        "wo": he_init(ks[3], (nl, a, d), a, dtype),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.ones((nl, cfg.head_dim), dtype)
        p["kn"] = jnp.ones((nl, cfg.head_dim), dtype)
    return p


def attn_logical(cfg) -> Dict[str, tuple]:
    p = {
        "wq": (None, "w_embed", "heads"),
        "wk": (None, "w_embed", "kv"),
        "wv": (None, "w_embed", "kv"),
        "wo": (None, "heads", "w_embed"),
    }
    if cfg.qk_norm:
        p["qn"] = (None, None)
        p["kn"] = (None, None)
    return p


# ---------------------------------------------------------------------------
# Shared projection + rotary helpers
# ---------------------------------------------------------------------------
def _project_qkv(x, p, cfg, positions):
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(x, p["wq"]).reshape(b, s, hq, dh)
    k = dense(x, p["wk"]).reshape(b, s, hkv, dh)
    v = dense(x, p["wv"]).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"])
        k = rms_norm(k, p["kn"])
    if cfg.mrope:
        if positions.ndim == 2:  # text-only stream: t = h = w = pos
            positions = jnp.broadcast_to(positions[:, None, :],
                                         (b, 3, positions.shape[-1]))
        q = mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Full-sequence attention (train / prefill): chunked online softmax
# ---------------------------------------------------------------------------
def attention_train(x, p, cfg, positions, constrain, q_chunk: int = 1024
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (output (B,S,d), cache {k, v}) — cache is the rope'd K/V."""
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    q, k, v = _project_qkv(x, p, cfg, positions)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv", None))
    v = constrain(v, ("batch", "seq", "kv", None))

    scale = 1.0 / math.sqrt(dh)
    cq = min(q_chunk, s)
    assert s % cq == 0, (s, cq)
    nchunks = s // cq
    qg = q.reshape(b, nchunks, cq, hkv, g, dh)
    kidx = jnp.arange(s, dtype=jnp.int32)

    def chunk_fn(_, qc_i):
        qc, ci = qc_i                       # (B,cq,Hkv,G,Dh), scalar chunk id
        q0 = ci * cq
        qi = q0 + jnp.arange(cq, dtype=jnp.int32)
        sc = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                        k.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * scale
        mask = qi[:, None] >= kidx[None, :]
        if cfg.window > 0:
            mask &= (qi[:, None] - kidx[None, :]) < cfg.window
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        # probs in activation dtype: halves softmax->AV HBM traffic (fp32
        # softmax math, bf16 storage — what the Pallas flash kernel does)
        pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        oc = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v,
                        preferred_element_type=jnp.float32)
        return None, oc.astype(x.dtype)

    _, out = jax.lax.scan(
        chunk_fn, None,
        (jnp.moveaxis(qg, 1, 0), jnp.arange(nchunks, dtype=jnp.int32)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, hq * dh)
    out = constrain(out, ("batch", "seq", "heads"))
    y = dense(out, p["wo"])
    # the cache COPY is stacked over layers by prefill: shard its seq axis
    # over the model axis (kv heads may be indivisible) or the accumulated
    # (L,B,S,Hkv,Dh) tensor replicates across 'model'
    kc = constrain(k, ("batch", "kv_seq", "kv", None))
    vc = constrain(v, ("batch", "kv_seq", "kv", None))
    return y, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Decode: one token against a (ring) KV cache
# ---------------------------------------------------------------------------
def cache_window(cfg, seq_len: int) -> int:
    """Slots kept in the decode cache: W for SWA archs, full context else."""
    return min(cfg.window, seq_len) if cfg.window > 0 else seq_len


def attention_decode(x, p, cfg, cache, constrain
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B,1,d); cache {k,v (B,W,Hkv,Dh), pos (B,) abs position of the new
    token, abs_pos (B,W) absolute position of each slot (-1 = empty)}."""
    b = x.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    pos = cache["pos"]                      # (B,)
    q, k_new, v_new = _project_qkv(x, p, cfg, pos[:, None])

    w = cache["k"].shape[1]
    slot = pos % w                          # ring slot (== pos when w >= ctx)
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])
    abs_pos = cache["abs_pos"].at[bidx, slot].set(pos)
    k = constrain(k, ("batch", "kv_seq", "kv", None))
    v = constrain(v, ("batch", "kv_seq", "kv", None))

    scale = 1.0 / math.sqrt(dh)
    qh = q.reshape(b, hkv, g, dh)
    sc = jnp.einsum("bhgd,bkhd->bhgk", qh.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    valid = (abs_pos >= 0) & (abs_pos <= pos[:, None])
    if cfg.window > 0:
        valid &= (pos[:, None] - abs_pos) < cfg.window
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", pr, v.astype(jnp.float32))
    out = out.reshape(b, 1, hq * dh).astype(x.dtype)
    y = dense(out, p["wo"])
    new_cache = {"k": k, "v": v, "abs_pos": abs_pos, "pos": pos + 1}
    return y, new_cache


def init_decode_cache(cfg, batch: int, seq_len: int, dtype,
                      as_specs: bool = False):
    """Per-layer KV cache pytree ((L, B, W, Hkv, Dh) stacked)."""
    w = cache_window(cfg, seq_len)
    nl = cfg.n_layers
    shapes = {
        "k": ((nl, batch, w, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": ((nl, batch, w, cfg.n_kv_heads, cfg.head_dim), dtype),
        "abs_pos": ((nl, batch, w), jnp.int32),
        "pos": ((nl, batch), jnp.int32),
    }
    if as_specs:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    out = {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}
    out["abs_pos"] = out["abs_pos"] - 1  # -1 = empty slot
    return out


def decode_cache_logical():
    return {
        "k": (None, "batch", "kv_seq", "kv", None),
        "v": (None, "batch", "kv_seq", "kv", None),
        "abs_pos": (None, "batch", None),
        "pos": (None, "batch"),
    }
