"""Mamba-2 (SSD) mixer — chunked XLA path + single-step decode.

Train/prefill uses the chunked state-space-duality formulation (intra-chunk
dense matmuls + inter-chunk linear recurrence), scanning over heads to bound
live memory (DESIGN.md §2); the Pallas kernel (kernels/ssd_scan.py) is the
TPU-target version of the same math and is cross-validated in tests.

Decode keeps (conv_state, ssm_state) per layer and applies the exact
recurrence one token at a time.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense, he_init, rms_norm

__all__ = ["init_ssm_params", "ssm_logical", "ssd_chunked", "ssm_mixer_train",
           "ssm_mixer_decode", "init_ssm_cache", "ssm_cache_logical"]


def init_ssm_params(cfg, key, dtype) -> Dict[str, jax.Array]:
    nl, d = cfg.n_layers, cfg.d_model
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    return {
        "wz": he_init(ks[0], (nl, d, di), d, dtype),
        "wx": he_init(ks[1], (nl, d, di), d, dtype),
        "wb": he_init(ks[2], (nl, d, n), d, dtype),
        "wc": he_init(ks[3], (nl, d, n), d, dtype),
        "wdt": he_init(ks[4], (nl, d, h), d, dtype),
        "dt_bias": jnp.zeros((nl, h), jnp.float32) + 0.5,
        "a_log": jnp.zeros((nl, h), jnp.float32),         # A = -exp(a_log)
        "skip_d": jnp.ones((nl, h), jnp.float32),
        "conv_w": he_init(ks[5], (nl, cfg.conv_width, di + 2 * n),
                          cfg.conv_width, dtype),
        "norm": jnp.ones((nl, di), dtype),
        "out": he_init(ks[6], (nl, di, d), di, dtype),
    }


def ssm_logical(cfg) -> Dict[str, tuple]:
    return {
        "wz": (None, "w_embed", "ff"),
        "wx": (None, "w_embed", "ff"),
        "wb": (None, "w_embed", None),
        "wc": (None, "w_embed", None),
        "wdt": (None, "w_embed", None),
        "dt_bias": (None, None),
        "a_log": (None, None),
        "skip_d": (None, None),
        "conv_w": (None, None, "ff"),
        "norm": (None, "ff"),
        "out": (None, "ff", "w_embed"),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via K-1 shifted adds. x (B,S,C); w (K,C)."""
    k = w.shape[0]
    out = x * w[k - 1]
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[k - 1 - i]
    return out


def ssd_chunked(x, dt, a, bm, cm, chunk: int = 128) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. x (B,S,H,P), dt (B,S,H) fp32, a (H,) fp32 (<0),
    bm/cm (B,S,N). Returns (y (B,S,H,P), final_state (B,H,N,P)).

    Scans over heads (decay profiles differ per head; per-head tiles keep the
    (NC, L, L) Γ tensors O(S·L) instead of O(S·L·H))."""
    b, s, h, p = x.shape
    n = bm.shape[-1]
    ch = min(chunk, s)
    assert s % ch == 0
    nc = s // ch
    xc = x.reshape(b, nc, ch, h, p)
    dtc = dt.reshape(b, nc, ch, h).astype(jnp.float32)
    bc = bm.reshape(b, nc, ch, n).astype(jnp.float32)
    cc = cm.reshape(b, nc, ch, n).astype(jnp.float32)

    # shared across heads: CB^T score tiles (B, NC, L, L)
    scores = jnp.einsum("bcln,bcmn->bclm", cc, bc,
                        preferred_element_type=jnp.float32)
    li = jnp.arange(ch)

    def per_head(carry, inp):
        xh, dth, ah = inp              # (B,NC,L,P), (B,NC,L), scalar
        g = jnp.cumsum(dth * ah, axis=-1)            # (B,NC,L)
        gtot = g[..., -1]                            # (B,NC)
        # mask BEFORE exp: where(mask, exp(x), 0) propagates NaN grads
        # through the inf branch when x > 0 (upper triangle).
        delta = jnp.where(li[:, None] >= li[None, :],
                          g[..., :, None] - g[..., None, :], -jnp.inf)
        gamma = jnp.exp(delta)
        w = scores * gamma * dth[..., None, :]       # (B,NC,L,L)
        y = jnp.einsum("bclm,bcmp->bclp", w, xh.astype(jnp.float32))

        # chunk summaries: U_c = B^T (e^{gtot-g} dt x)   (B,NC,N,P)
        xw = xh.astype(jnp.float32) * (jnp.exp(gtot[..., None] - g) * dth)[..., None]
        u = jnp.einsum("bcln,bclp->bcnp", bc, xw)
        decay = jnp.exp(gtot)                        # (B,NC)

        def chunk_scan(state, du):
            dcy, u_c = du
            state = state * dcy[:, None, None] + u_c
            return state, state

        s0 = jnp.zeros((b, n, p), jnp.float32)
        final, states = jax.lax.scan(
            chunk_scan, s0, (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(u, 1, 0)))
        states = jnp.moveaxis(states, 0, 1)          # (B,NC,N,P) post-chunk
        prev = jnp.concatenate([jnp.zeros_like(states[:, :1]),
                                states[:, :-1]], axis=1)
        y = y + jnp.einsum("bcln,bcnp->bclp", cc, prev) * jnp.exp(g)[..., None]
        return carry, (y, final)

    _, (ys, finals) = jax.lax.scan(
        per_head, None,
        (jnp.moveaxis(xc, 3, 0), jnp.moveaxis(dtc, 3, 0), a.astype(jnp.float32)))
    y = jnp.moveaxis(ys, 0, 3).reshape(b, s, h, p)   # (B,S,H,P)
    return y.astype(x.dtype), jnp.moveaxis(finals, 0, 1)  # (B,H,N,P)


# ---------------------------------------------------------------------------
# Mixer (full block): in_proj -> conv -> SSD -> gate -> norm -> out_proj
# ---------------------------------------------------------------------------
def _in_proj(x, p, cfg, constrain=None):
    z = dense(x, p["wz"])
    xi = dense(x, p["wx"])
    bm = dense(x, p["wb"])
    cm = dense(x, p["wc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wdt"])
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    if constrain is not None and x.ndim == 3:
        # pin shardings so GSPMD never invents cross-axis layouts for the
        # SSM streams (multi-pod "involuntary full remat" otherwise)
        z = constrain(z, ("batch", "seq", "ff"))
        xi = constrain(xi, ("batch", "seq", "ff"))
        bm = constrain(bm, ("batch", "seq", None))
        cm = constrain(cm, ("batch", "seq", None))
        dt = constrain(dt, ("batch", "seq", None))
    return z, xi, bm, cm, dt, a


def ssm_mixer_train(x, p, cfg, constrain, chunk: int = 0
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    chunk = chunk or getattr(cfg, "ssd_chunk", 128)
    z, xi, bm, cm, dt, a = _in_proj(x, p, cfg, constrain)
    conv_in = jnp.concatenate([xi, bm, cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"]).astype(jnp.float32)
                           ).astype(x.dtype)
    xi, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)
    xi = constrain(xi, ("batch", "seq", "ff"))

    xh = xi.reshape(b, s, h, ph)
    y, final_state = ssd_chunked(xh, dt, a, bm, cm, chunk)
    y = y + xh.astype(jnp.float32) * p["skip_d"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["norm"])
    out = dense(y, p["out"])
    cache = {"conv": conv_in[:, -(cfg.conv_width - 1):, :],
             "state": final_state}
    return out, cache


def ssm_mixer_decode(x, p, cfg, cache, constrain
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B,1,d); cache {conv (B,K-1,di+2n), state (B,H,N,P)}."""
    b = x.shape[0]
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xi, bm, cm, dt, a = _in_proj(x, p, cfg)
    conv_in = jnp.concatenate([xi, bm, cm], axis=-1)     # (B,1,C)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,C)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32)))
    xi, bm, cm = jnp.split(conv_out.astype(x.dtype), [di, di + n], axis=-1)

    xh = xi.reshape(b, h, ph).astype(jnp.float32)
    dt1 = dt[:, 0]                                       # (B,H)
    decay = jnp.exp(dt1 * a[None, :])                    # (B,H)
    upd = jnp.einsum("bn,bhp,bh->bhnp", bm.astype(jnp.float32), xh, dt1)
    state = cache["state"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cm.astype(jnp.float32), state)
    y = y + xh * p["skip_d"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["norm"])
    out = dense(y, p["out"])
    return out, {"conv": window[:, 1:], "state": state}


def init_ssm_cache(cfg, batch: int, dtype, as_specs: bool = False):
    nl = cfg.n_layers
    shapes = {
        "conv": ((nl, batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state),
                 dtype),
        "state": ((nl, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                  jnp.float32),
    }
    if as_specs:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def ssm_cache_logical():
    return {
        "conv": (None, "batch", None, "ff"),
        "state": (None, "batch", None, None, None),
    }
