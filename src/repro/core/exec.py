"""The staged query-execution pipeline behind :meth:`SpatialIndex.query`.

GLIN's query path is ONE pipeline regardless of where it runs::

    probe -> compact -> refine -> delta-patch -> complement-finish
                                                       (knn: -> knn-rank)

What differs per backend is which *implementation* serves each stage and
how many adjacent stages it fuses: the host loop walks the mutable tree one
window at a time (probe+compact+refine in one pass), the jitted device
``batch_query`` composes the same three stages as THREE device dispatches
(probe, compact kernel, exact gather+check), ``batch_query_fused`` collapses
them into ONE (:class:`FusedDeviceStage`, selected by
``EngineConfig.fusion``), and the sharded step runs them per record shard
under a mesh. Delta patching and
complement finishing are backend-independent — they operate on id lists
against state frozen under the facade lock — so exactly ONE implementation
of each exists, here.

``SpatialIndex.plan()`` picks a backend; :func:`compile_plan` turns that
:class:`QueryPlan` into an :class:`ExecutionPlan` — an ordered stage tuple —
and :meth:`ExecutionPlan.execute` runs it, timing every stage into
:class:`StageStats` (wall time, survivor counts, overflow-ladder
escalations, delta sizes). The stats ride out on ``QueryResult.stages`` and
aggregate into ``SpatialIndex.stats()["stages"]``;
:meth:`SpatialIndex.explain` pretty-prints the compiled pipeline without
executing it.

**The overflow ladder** (:class:`OverflowLadder`) is the one shared
cap/budget escalation policy. Device-side refinement signals overflow with
negative counts: ``-(run length) - 1`` when a query's candidate run outgrew
``cap`` (magnitude > cap disambiguates), else ``-(survivors) - 1`` when the
MBR survivors outgrew ``exact_budget``. The ladder jumps the cap straight
to a sufficient power of two (a cheap bounds-only probe tells the two
overflows apart on the single-device path; the sharded step encodes the
exact local need), grows the budget geometrically past the true survivor
count, and escalates to the single-stage dense path only once the needed
budget exceeds ``MAX_COMPACT_BUDGET`` (or the cap — two-stage would no
longer shrink anything). One special case: the Pallas compact kernel and
the fused one-dispatch path scan the full local run (they are capless), so
with a budget active their overflow is ALWAYS the budget, even when
survivors exceed the cap — the fused retry therefore needs no
disambiguating bounds probe (:meth:`OverflowLadder.on_fused_overflow`).

**Locking contract** (unchanged from the monolithic backends, now stated
once): the host and sharded refine stages run under the facade lock — they
walk the mutable host tree or own every mesh device — and freeze the delta
/ live-id sets for the downstream stages in that same critical section; the
device refine stage freezes everything it needs under the lock, then runs
its device compute OUTSIDE it. Delta patching and complement finishing
always run lock-free on the frozen copies, so their answers are exact at
the frozen epoch no matter how writers interleave.

**Dispatch telemetry**: every stage counts the device dispatches it issued
into ``StageStats.dispatches`` (a staged two-stage attempt is 3 — probe,
compact, exact; a dense attempt 2; a fused attempt 1; each disambiguating
bounds probe adds 1). The counter is how the 3 -> 1 collapse of the fused
path is *asserted*, not just assumed — a regression that silently re-splits
the pipeline shows up in ``stats()["stages"]`` and ``explain()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .device import (batch_check_added, batch_knn_rank,
                     delta_table_from_host, knn_seed_radii)
from .index import QueryStats, initial_knn_radius
from .index import knn as _host_knn
from .relations import get_relation

__all__ = ["StageStats", "ExecContext", "Stage", "ExecutionPlan",
           "OverflowLadder", "compile_plan", "PIPELINE_STAGES"]

# canonical stage order (docs/api.md "Execution pipeline")
PIPELINE_STAGES = ("probe", "compact", "refine", "delta-patch",
                   "complement-finish", "knn-rank")


def _engine():
    """The engine module namespace, resolved at call time — tests monkeypatch
    ``repro.core.engine.batch_query`` and friends, and the stages must see
    the patched bindings (a ``from``-import here would freeze the originals).
    Deferred to avoid the circular import (engine imports this module)."""
    from . import engine
    return engine


# --------------------------------------------------------------- observability
@dataclasses.dataclass
class StageStats:
    """Per-stage telemetry for one executed query batch.

    ``survivors`` is the total id count LEAVING the stage (-1 when the stage
    does not produce ids, e.g. a skipped patch); ``escalations`` counts
    overflow-ladder retries; ``cap``/``budget`` are the settled ladder values
    a refine stage ended on (budget 0 = single-stage dense, -1 = n/a);
    ``dispatches`` counts device dispatches issued (staged two-stage attempt
    = 3, dense = 2, fused = 1, +1 per disambiguating bounds probe — 0 for
    host/shared stages that launch no device work)."""

    stage: str                       # primary canonical stage name
    impl: str                        # "host" | "device" | "fused" |
                                     # "sharded" | "shared"
    covers: Tuple[str, ...] = ()     # canonical stages this impl fuses
    wall_ms: float = 0.0
    queries: int = 0
    survivors: int = -1
    escalations: int = 0
    dispatches: int = 0
    cap: int = 0
    budget: int = -1
    delta_added: int = 0
    delta_tombstoned: int = 0
    skipped: bool = False            # compiled in, but a no-op this run
    note: str = ""
    # knn-rank telemetry (zero/empty on every other stage)
    rungs: int = 0                   # deepest per-point radius-ladder depth
    rung_hist: Tuple[int, ...] = ()  # points settled per rung; [0] = seeded
    seed_hits: int = 0               # points settled at their seeded radius
    seed_radius: float = 0.0         # median pow2-snapped seed radius
    merge_bytes: int = 0             # cross-shard k-merge collective bytes

    def asdict(self) -> dict:
        d = dataclasses.asdict(self)
        d["covers"] = list(self.covers)
        d["rung_hist"] = list(self.rung_hist)
        return d


@dataclasses.dataclass
class ExecContext:
    """Mutable state threaded through the stages of one execution.

    The refine stage freezes everything downstream stages read (``epoch``,
    ``frozen_delta``, ``live``, ``snap``) under the facade lock; the stages
    after it touch only this context, never the live index fields."""

    index: Any                       # the SpatialIndex facade
    batch: Any                       # QueryBatch
    plan: Any                        # QueryPlan
    rel: Any                         # Relation (None for knn)
    base: Any                        # probed base Relation (None for knn)
    replica: int = 0
    # frozen under the facade lock by the refine stage
    epoch: int = -1
    frozen_delta: Optional[Tuple] = None
    live: Optional[np.ndarray] = None
    snap: Any = None                 # snapshot whose grid params patch uses
    # outputs
    ids: Optional[List[np.ndarray]] = None
    distances: Optional[List[np.ndarray]] = None
    host_stats: Optional[List[QueryStats]] = None
    stage_stats: List[StageStats] = dataclasses.field(default_factory=list)


def _total(ids: Optional[List[np.ndarray]]) -> int:
    return -1 if ids is None else int(sum(r.shape[0] for r in ids))


# -------------------------------------------------------------- overflow ladder
class OverflowLadder:
    """THE cap/budget escalation policy, shared by every refine
    implementation (single-device and sharded). See the module docstring for
    the negative-count encoding contract this consumes.

    Holds the adaptive state for one query's retries; the settled ``cap`` is
    max-merged back into the facade by the refine stage so the ladder is
    walked once per workload, not once per call."""

    def __init__(self, config, cap: int, max_budget: Optional[int] = None):
        from repro.kernels.refine import MAX_COMPACT_BUDGET

        self.config = config
        self.cap = int(cap)
        self.budget = int(config.exact_budget)
        # budget-growth ceiling before the ladder falls back to the dense
        # single-stage path. Window refines keep the Pallas VMEM bound (a
        # dense retry only re-checks cheap predicates); knn raises it to
        # max_cap because the rank's exact-distance work scales with the
        # hit-matrix WIDTH — scan compaction at a large budget is far
        # cheaper than ranking a dense (Q, cap) matrix every rung.
        self.max_budget = (MAX_COMPACT_BUDGET if max_budget is None
                           else int(max_budget))
        self.escalations = 0

    @property
    def use_budget(self) -> int:
        """The budget the next call actually uses: two-stage refinement only
        pays for itself while the budget is positive AND below the cap."""
        b = self.budget
        return b if 0 < b < self.cap else 0

    def grow_cap(self, need: int) -> None:
        cfg = self.config
        if self.cap >= cfg.max_cap or need > cfg.max_cap:
            raise OverflowError(
                f"candidate run of {need} exceeded max_cap="
                f"{cfg.max_cap}; raise EngineConfig.max_cap or "
                f"narrow the windows")
        self.cap = min(max(self.cap * 2, 1 << (need - 1).bit_length()),
                       cfg.max_cap)

    def grow_budget(self, use_budget: int, survivors: int) -> None:
        """Budget overflow: the negative-count encoding carries the TRUE
        survivor count, so the budget grows geometrically straight past it
        (re-running compaction) and only falls back to the single-stage
        dense path (budget 0) once the needed budget exceeds
        ``max_budget`` (``MAX_COMPACT_BUDGET`` unless the caller raised it;
        ``engine._compaction`` already routes budgets past the Pallas VMEM
        bound to the jnp scan reference) or the cap."""
        target = max(use_budget * 2,
                     1 << max(survivors - 1, 0).bit_length())
        self.budget = (0 if target > self.max_budget or target >= self.cap
                       else target)

    def on_device_overflow(self, counts: np.ndarray, use_budget: int,
                           probe_bounds, batch_len: int) -> None:
        """Single-device retry: the overflow signal conflates run-length >
        cap with survivors > budget; ``probe_bounds`` (a cheap bounds-only
        probe) tells them apart, so the cap jumps straight to sufficiency —
        keeping the LOGICAL budget (one the old cap disabled because
        ``budget >= cap`` comes back into play once the cap outgrows it)."""
        self.escalations += 1
        start, end = probe_bounds()
        need = int(np.max(np.asarray(end - start))) if batch_len else 0
        if need > self.cap:
            self.grow_cap(need)
            return
        if not use_budget:
            raise AssertionError(
                "single-stage overflow with run <= cap")  # unreachable
        self.grow_budget(use_budget, int(-(counts.min()) - 1))

    def on_fused_overflow(self, counts: np.ndarray, use_budget: int) -> None:
        """Fused-path retry: the one-dispatch kernel is capless (its mask
        spans the whole slot table), so a negative count is ALWAYS budget
        overflow carrying the total survivor count — the budget jumps
        straight past it with no disambiguating bounds probe. A zeroed
        budget hands the retry to the staged dense path."""
        self.escalations += 1
        if not use_budget:
            raise AssertionError(
                "fused overflow without an active budget")  # unreachable
        self.grow_budget(use_budget, int(-(counts.min()) - 1))

    def on_sharded_overflow(self, counts: np.ndarray, use_budget: int,
                            compaction: str) -> None:
        """Sharded retry: the step encodes the exact LOCAL need — no global
        bounds probe, whose run is a useless overestimate of any one
        shard's. The Pallas kernel scans the full local run (capless), so
        with a budget active its overflow is ALWAYS the budget."""
        self.escalations += 1
        need = int(-(counts.min()) - 1)
        if use_budget and compaction == "pallas":
            self.grow_budget(use_budget, need)
        elif need > self.cap:
            self.grow_cap(need)
        elif not use_budget:
            raise AssertionError(
                "single-stage overflow with run <= cap")  # unreachable
        else:
            self.grow_budget(use_budget, need)


# ------------------------------------------------------------------- stages
class Stage:
    """One pipeline stage: fill ``ctx`` (and its own ``StageStats``). A
    fused implementation covers several adjacent canonical stages —
    ``covers`` names them for ``explain()`` and the telemetry.
    ``dispatches`` is the static per-attempt device-dispatch count of the
    implementation (what ``explain()`` prints before execution; the
    executed count lands in ``StageStats.dispatches``)."""

    name: str = "?"
    covers: Tuple[str, ...] = ()
    impl: str = "?"
    dispatches: int = 0

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        raise NotImplementedError


class HostRefineStage(Stage):
    """fp64 probe+compact+refine: one ``GLIN.query`` walk per window over
    the mutable host tree, under the facade lock. Queries the BASE relation
    only — complement finishing is the shared downstream stage (the live-id
    set it needs is frozen here, in the same critical section)."""

    name = "refine"
    covers = ("probe", "compact", "refine")
    impl = "host"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        idx, batch = ctx.index, ctx.batch
        stats = ([QueryStats() for _ in range(len(batch))]
                 if batch.collect_stats else None)
        ids: List[np.ndarray] = []
        with idx._lock:
            for i, w in enumerate(batch.windows):
                s = stats[i] if stats is not None else None
                ids.append(np.sort(idx.glin.query(w, ctx.base.name, s)))
            ctx.live = idx._freeze_live(ctx.rel)
            ctx.epoch = idx._epoch
        ctx.ids = ids
        ctx.host_stats = stats
        st.survivors = _total(ids)


class DeviceRefineStage(Stage):
    """The jitted fused probe+compact+refine dispatch (fp32). Freezes the
    served snapshot/payload (fanned to the requested replica), the delta
    and the live-id set under the facade lock, then runs the overflow-
    ladder retry loop OUTSIDE it — writers are never blocked by device
    compute, and the answer is exact at the frozen epoch."""

    name = "refine"
    covers = ("probe", "compact", "refine")
    impl = "device"
    dispatches = 3

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        eng = _engine()
        idx, batch = ctx.index, ctx.batch
        cfg = idx.config
        patch = ctx.plan.backend == "device+delta"
        with idx._lock:
            # freeze everything the unlocked compute below reads: the served
            # snapshot + payload (immutable device arrays), copies of the
            # delta sets and the live set — a writer landing after this
            # block changes none of them. device+delta serves the published
            # snapshot and patches the delta on top; plain device
            # republishes first — either way the answer reflects the frozen
            # epoch exactly.
            snap = idx._published_snapshot() if patch else idx.snapshot()
            payload = idx._device_payload(idx._snapshot_recs)
            snap, payload = idx._replica_view(ctx.replica, snap, payload)
            ctx.frozen_delta = idx._freeze_delta() if patch else None
            ctx.live = idx._freeze_live(ctx.rel)
            ctx.epoch = idx._epoch
            ladder = OverflowLadder(cfg, idx._cap)
        ctx.snap = snap
        pods, mb = payload
        q = len(batch.windows)
        wq = batch.windows.astype(np.float32)
        if cfg.pad_quantum > 0 and q:
            # bucket the query axis to a power of two: the jitted
            # batch_query compiles per windows shape, and a serving tier
            # draining adaptively-sized micro-batches would otherwise
            # compile once per distinct batch size. Padding rows repeat the
            # last window and are sliced off below.
            qb = 1 << (q - 1).bit_length()
            if qb > q:
                wq = np.concatenate([wq, np.repeat(wq[-1:], qb - q, 0)])
        wj = jnp.asarray(wq)
        base = ctx.base.name
        while True:
            ub = ladder.use_budget
            hits, counts = eng.batch_query(
                snap, wj, pods, mb, relation=base,
                cap=ladder.cap, exact_budget=ub,
                compaction=idx._compaction(base, ub or None))
            st.dispatches += 3 if ub else 2   # probe/compact/exact vs dense
            counts = np.asarray(counts)
            if (counts >= 0).all():
                with idx._lock:
                    # max-merge: a concurrent query may have grown it further
                    idx._cap = max(idx._cap, ladder.cap)
                break
            st.dispatches += 1                # disambiguating bounds probe
            ladder.on_device_overflow(
                counts, ub,
                lambda: eng.batch_query_bounds(snap, wj, relation=base), q)
        hits = np.asarray(hits)[:q]
        ctx.ids = [np.sort(row[row >= 0]).astype(np.int64) for row in hits]
        st.survivors = _total(ctx.ids)
        st.escalations = ladder.escalations
        st.cap, st.budget = ladder.cap, ladder.use_budget


class FusedDeviceStage(Stage):
    """ONE-dispatch probe+compact+refine: the whole staged pipeline of
    :class:`DeviceRefineStage` executed by a single fused kernel launch
    (``core.device.batch_query_fused``). Same freeze/retry/epilogue
    contract; what changes is the vehicle — and ``dispatches`` telemetry
    asserting the 3 -> 1 collapse.

    The fused path is two-stage only and VMEM-bounded, so the stage
    re-resolves ``SpatialIndex._fusion_mode`` every ladder step: a zeroed
    budget (dense escalation) or an envelope the store outgrew falls back
    to the staged ``batch_query`` for that attempt — correctness never
    depends on fusion being available."""

    name = "refine"
    covers = ("probe", "compact", "refine")
    impl = "fused"
    dispatches = 1

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        eng = _engine()
        idx, batch = ctx.index, ctx.batch
        cfg = idx.config
        patch = ctx.plan.backend == "device+delta"
        with idx._lock:
            snap = idx._published_snapshot() if patch else idx.snapshot()
            payload = idx._device_payload(idx._snapshot_recs)
            snap, payload = idx._replica_view(ctx.replica, snap, payload)
            ctx.frozen_delta = idx._freeze_delta() if patch else None
            ctx.live = idx._freeze_live(ctx.rel)
            ctx.epoch = idx._epoch
            ladder = OverflowLadder(cfg, idx._cap)
        ctx.snap = snap
        pods, mb = payload
        q = len(batch.windows)
        wq = batch.windows.astype(np.float32)
        if cfg.pad_quantum > 0 and q:
            qb = 1 << (q - 1).bit_length()
            if qb > q:
                wq = np.concatenate([wq, np.repeat(wq[-1:], qb - q, 0)])
        wj = jnp.asarray(wq)
        base = ctx.base.name
        while True:
            ub = ladder.use_budget
            mode = idx._fusion_mode(base, ub or None, snap=snap, pods=pods)
            if mode is None:
                # budget ladder left the fused envelope (dense escalation /
                # budget past the VMEM bound): staged fallback this attempt
                hits, counts = eng.batch_query(
                    snap, wj, pods, mb, relation=base,
                    cap=ladder.cap, exact_budget=ub,
                    compaction=idx._compaction(base, ub or None))
                st.dispatches += 3 if ub else 2
                st.note = "fused envelope exceeded: staged fallback"
                counts = np.asarray(counts)
                if (counts >= 0).all():
                    with idx._lock:
                        idx._cap = max(idx._cap, ladder.cap)
                    break
                st.dispatches += 1            # disambiguating bounds probe
                ladder.on_device_overflow(
                    counts, ub,
                    lambda: eng.batch_query_bounds(snap, wj, relation=base),
                    q)
                continue
            hits, counts = eng.batch_query_fused(
                snap, wj, pods, relation=base, exact_budget=ub, mode=mode)
            st.dispatches += 1
            counts = np.asarray(counts)
            if (counts >= 0).all():
                with idx._lock:
                    idx._cap = max(idx._cap, ladder.cap)
                break
            ladder.on_fused_overflow(counts, ub)   # capless: no bounds probe
        hits = np.asarray(hits)[:q]
        ctx.ids = [np.sort(row[row >= 0]).astype(np.int64) for row in hits]
        st.survivors = _total(ctx.ids)
        st.escalations = ladder.escalations
        st.cap, st.budget = ladder.cap, ladder.use_budget


class ShardedRefineStage(Stage):
    """Per-record-shard fused probe+compact+refine over the mesh
    (``core.distributed``), query windows sharded over the model axis. Runs
    entirely under the facade lock (the mesh owns every device — there is
    nothing to overlap with) and freezes the delta + live-id sets in that
    same critical section for the downstream shared stages."""

    name = "refine"
    covers = ("probe", "compact", "refine")
    impl = "sharded"
    dispatches = 3

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        idx, batch = ctx.index, ctx.batch
        cfg = idx.config
        with idx._lock:
            if ctx.plan.rebuild_snapshot:
                idx.snapshot()
            else:
                idx._published_snapshot()
            patch = idx.snapshot_is_stale()
            q = len(batch)
            # pad the batch to a model-axis multiple (shard_map divides Q
            # evenly); padded rows repeat the last window, sliced off after
            m = cfg.mesh.shape["model"]
            wins32 = batch.windows.astype(np.float32)
            qpad = (-q) % m
            if qpad:
                wins32 = np.concatenate(
                    [wins32, np.repeat(wins32[-1:], qpad, axis=0)])
            wj = jnp.asarray(wins32)
            snap_repl, table, _, maxw = idx._sharded_placement()
            ladder = OverflowLadder(cfg, idx._cap)
            base = ctx.base.name
            while True:
                ub = ladder.use_budget
                comp = idx._compaction(base, ub or None)
                if comp == "sort":  # legacy argsort baseline: 1-device only
                    comp = "scan"
                step = idx._sharded_step(base, ladder.cap, ub, comp, maxw)
                hits, counts = step(snap_repl, wj, table)
                st.dispatches += 3 if ub else 2
                counts = np.asarray(counts)
                if (counts >= 0).all():
                    idx._cap = max(idx._cap, ladder.cap)
                    break
                ladder.on_sharded_overflow(counts, ub, comp)
            hits = np.asarray(hits)[:q]              # (Q, shards, K)
            ctx.ids = [np.sort(row[row >= 0]).astype(np.int64)
                       for row in hits.reshape(q, -1)]
            ctx.frozen_delta = idx._freeze_delta() if patch else None
            ctx.live = idx._freeze_live(ctx.rel)
            ctx.epoch = idx._epoch
            ctx.snap = idx._snapshot
        st.survivors = _total(ctx.ids)
        st.escalations = ladder.escalations
        st.cap, st.budget = ladder.cap, ladder.use_budget


class DeltaPatchStage(Stage):
    """Restore exactness of snapshot results at the frozen epoch: mask out
    tombstoned records and check the added set (fp32, matching the device
    precision contract) against the *base* relation — complement finishing
    happens after, on top of the patched ids.

    Operates only on the ``ExecContext`` freeze (the refine stage captured
    the delta under the lock), so it runs lock-free on every backend —
    THE one patch implementation. Small added sets are brute-force checked
    in a host loop; past ``EngineConfig.delta_device_min`` the check runs on
    device through the Zmin-sorted :class:`~repro.core.device.DeltaTable`
    (one vectorized (Q x A) pass, no per-batch host round-trip)."""

    name = "delta-patch"
    covers = ("delta-patch",)
    impl = "shared"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        frozen = ctx.frozen_delta
        if frozen is None:
            st.skipped = True
            st.note = "no delta against the served snapshot"
            return
        tombs, added, table, av, an, ak = frozen
        st.delta_added = int(added.shape[0])
        st.delta_tombstoned = 0 if tombs is None else int(tombs.shape[0])
        batch, snap = ctx.batch, ctx.snap
        base = ctx.base.name
        added_hits: Optional[List[np.ndarray]] = None
        if table is not None:
            wj = jnp.asarray(batch.windows.astype(np.float32))
            st.dispatches += 1           # device DeltaTable added-set check
            ok = np.asarray(batch_check_added(
                table, wj, base, snap.grid_x0, snap.grid_y0, snap.grid_cell))
            tbl_ids = np.asarray(table.ids, np.int64)
            added_hits = [np.sort(tbl_ids[row]) for row in ok]
        elif added.shape[0]:
            pred = get_relation(base).predicate
            added_hits = []
            for qi in range(len(ctx.ids)):
                w32 = batch.windows[qi].astype(np.float32)
                added_hits.append(added[np.asarray(pred(w32, av, an, ak))])
        out: List[np.ndarray] = []
        for qi, h in enumerate(ctx.ids):
            if tombs is not None:
                h = h[~np.isin(h, tombs)]
            if added_hits is not None:
                # added ids all postdate (exceed) every snapshot id, so the
                # concatenation stays ascending
                h = np.concatenate([h, added_hits[qi]])
            out.append(h)
        ctx.ids = out
        st.survivors = _total(out)


class ComplementFinishStage(Stage):
    """Complement relations (e.g. ``disjoint``): subtract the base hits from
    the live-id set the refine stage froze under the lock — THE one
    complement implementation, identical lock story on every backend."""

    name = "complement-finish"
    covers = ("complement-finish",)
    impl = "shared"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        rel = ctx.rel
        if not rel.is_complement:
            st.skipped = True
            st.note = "relation is not a complement"
            return
        live = ctx.live
        if live is None:   # refine stages freeze it whenever rel needs it
            with ctx.index._lock:
                live = ctx.index._freeze_live(rel)
        ctx.ids = [np.setdiff1d(live, r) for r in ctx.ids]
        if ctx.host_stats is not None:
            # candidates/checked/leaves_* honestly describe the base
            # probe's work, but the hit count must be the complement's
            for s, r in zip(ctx.host_stats, ctx.ids):
                s.results = int(r.shape[0])
        st.survivors = _total(ctx.ids)


class KnnHostStage(Stage):
    """knn on the mutable host tree, one point at a time under the lock."""

    name = "knn-rank"
    covers = ("probe", "refine", "knn-rank")
    impl = "host"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        idx, batch = ctx.index, ctx.batch
        ids, dists = [], []
        with idx._lock:      # the host knn walks the mutable tree
            for p in batch.points:
                i, d = _host_knn(idx.glin, p, batch.k)
                ids.append(np.asarray(i, np.int64))
                dists.append(np.asarray(d))
            ctx.epoch = idx._epoch
        ctx.ids, ctx.distances = ids, dists
        st.survivors = _total(ids)


def _pow2_radii(r: np.ndarray) -> np.ndarray:
    """Per-point power-of-two radius snap: each (bucket, radius) pair
    compiles once, not once per distinct estimate."""
    return np.power(2.0, np.ceil(np.log2(np.maximum(r, 1e-9))))


def _seed_radii(snap, wins, q, k, seed_mode, r_global, st,
                pow2: bool = True) -> np.ndarray:
    """Initial radii for ``q`` degenerate windows. CDF seeds route through
    the published model (``device.knn_seed_radii``); a seed that comes back
    non-finite or non-positive (a point routed to an empty leaf, whose
    aggregate-MBR sentinel has no area) falls back to the global density
    radius — the seed is a performance prior, never allowed to poison the
    probe relation. ``pow2`` snaps UP to powers of two — required where the
    radius is a traced relation constant (the sharded ``dwithin:<r>``
    classes); the device stage passes ``pow2=False`` because its radii ride
    in the window coords and an up-snap only doubles the probe area."""
    if seed_mode == "cdf":
        wq = wins.astype(np.float32)
        qb = 1 << max(q - 1, 0).bit_length()
        if qb > q:
            wq = np.concatenate([wq, np.repeat(wq[-1:], qb - q, 0)])
        seeds = np.asarray(knn_seed_radii(
            snap, jnp.asarray(wq), jnp.float32(k)))[:q].astype(np.float64)
        st.dispatches += 1
        bad = ~np.isfinite(seeds) | (seeds <= 0.0)
        if bad.any():
            seeds[bad] = r_global
    else:
        seeds = np.full(q, r_global)
    return _pow2_radii(seeds) if pow2 else seeds


def _knn_backstop(idx, cfg) -> tuple:
    """Resolve the knn config knobs under the caller's lock: (seed mode,
    top-k impl). ``knn_seed=None`` -> the CDF density seed (the planner only
    routes knn to a device backend when the learned model is published);
    ``knn_topk=None`` -> the Pallas partial-selection kernel on TPU, the
    two-key ``lax.sort`` reference elsewhere."""
    seed = cfg.knn_seed or "cdf"
    if seed not in ("cdf", "global"):
        raise ValueError(f"unknown knn_seed {cfg.knn_seed!r} "
                         "(use 'cdf' or 'global')")
    impl = cfg.knn_topk or (
        "pallas" if jax.default_backend() == "tpu" else "sort")
    if impl not in ("sort", "pallas"):
        raise ValueError(f"unknown knn_topk {cfg.knn_topk!r} "
                         "(use 'sort' or 'pallas')")
    return seed, impl


class KnnDeviceStage(Stage):
    """Device-complete knn (cf. LISA): each point probes at its OWN seeded
    radius and the survivors are ranked ON DEVICE by exact squared distance
    (:func:`~repro.core.device.batch_knn_rank`) — only the final ``(Q, k)``
    ids + distances and the within-radius counts that drive the ladder ever
    cross back to the host. Candidate sets never do.

    Each rung probes EVERY still-undone point in ONE dispatch: the probe
    window is the per-point L-inf inflation of the query point by its own
    radius (``relations._pad_window``'s dwithin geometry, applied per row),
    run through the plain ``intersects`` pipeline — a square superset of
    the dwithin disc whose corner candidates the exact distance test in the
    rank discards, so settlement stays exact while rung cost never
    fragments across radius classes (and every rung reuses the one
    ``intersects`` compile instead of one ``dwithin:<r>`` compile per
    class). A point is DONE once its within-radius candidate count reaches
    k (the within set is exactly {distance <= r} — no closer geometry can
    be missing) or covers every live record (k > live).

    Radius selection: the published learned index doubles as a density
    estimate (``knn_seed_radii``), seeding each point near its expected
    k-th-neighbour distance (pow2-snapped). Between rungs an undone point
    grows by the 2D density scaling ``d_within * sqrt(k / within)`` of the
    exact distances it already holds, clamped to [2r, 4r] — at least the
    doubling backstop (a bad estimate costs rungs, never hits), at most one
    quadrupling, which is also what a still-empty point racing across empty
    space takes. Since the radius rides in the window COORDS (not a traced
    relation constant), per-point growth costs no extra compiles. The
    overflow ladder runs with ``max_budget=max_cap``: survivor compaction
    keeps paying for itself in rank width long past the Pallas VMEM bound
    (the scan reference has none), so a dense-width rank is the last
    resort, not the second rung. On ``device+delta`` the frozen tombstones
    are masked out of the ranking and the unpublished added set is
    distance-merged before the top-k — inserted-but-unpublished records are
    rankable with no republish. ``rung_hist`` / ``seed_hits`` /
    ``seed_radius`` report how well the seeding worked; ``escalations``
    counts overflow-ladder retries (NOT rungs — those are ``rungs``)."""

    name = "knn-rank"
    covers = ("probe", "compact", "refine", "knn-rank")
    impl = "device"
    dispatches = 4

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        eng = _engine()
        idx, batch = ctx.index, ctx.batch
        cfg = idx.config
        pts = np.asarray(batch.points, np.float64)
        q, k = len(batch), int(batch.k)
        wins = np.concatenate([pts, pts], axis=1)    # degenerate windows
        patch = ctx.plan.backend == "device+delta"
        with idx._lock:
            # same freeze contract as DeviceRefineStage: snapshot + payload
            # + delta copies captured under the lock, device compute outside
            # it — every rung serves the SAME frozen epoch
            snap = idx._published_snapshot() if patch else idx.snapshot()
            payload = idx._device_payload(idx._snapshot_recs)
            snap, payload = idx._replica_view(ctx.replica, snap, payload)
            ctx.frozen_delta = idx._freeze_delta() if patch else None
            ctx.epoch = idx._epoch
            ladder = OverflowLadder(cfg, idx._cap, max_budget=cfg.max_cap)
            n_live = idx.glin.num_records
            r_global = initial_knn_radius(idx.glin, k)
            seed_mode, impl = _knn_backstop(idx, cfg)
            # the rank needs the added set as a device DeltaTable regardless
            # of the host/device patching threshold (engine caches it per
            # mutation epoch)
            dtab = (idx._delta_table() if patch and idx._added else None)
        ctx.snap = snap
        pods, mb = payload
        ctx.ids = [np.empty(0, np.int64) for _ in range(q)]
        ctx.distances = [np.empty(0, np.float64) for _ in range(q)]
        if k <= 0 or n_live == 0 or q == 0:
            st.survivors = 0
            return
        tomb = None
        if ctx.frozen_delta is not None:
            tombs, added = ctx.frozen_delta[0], ctx.frozen_delta[1]
            st.delta_added = int(added.shape[0])
            st.delta_tombstoned = 0 if tombs is None else int(tombs.shape[0])
            if tombs is not None:
                tomb = jnp.asarray(tombs.astype(np.int32))
        radius = _seed_radii(snap, wins, q, k, seed_mode, r_global, st,
                             pow2=False)
        st.seed_radius = float(np.median(radius))
        st.note = f"seed={seed_mode} topk={impl}"
        # tier-1 budget: the CONFIGURED exact budget, pinned — the rank
        # stays narrow for the common case and only fat rows escalate
        # through `ladder` below. The tier-1 CAP tracks the ladder
        # (z-interval runs are a property of the data, every row pays them)
        b0 = int(cfg.exact_budget)
        done = np.zeros(q, bool)
        probes = np.zeros(q, np.int32)
        out_ids: List[Optional[np.ndarray]] = list(ctx.ids)
        out_d: List[Optional[np.ndarray]] = list(ctx.distances)
        for _ in range(64):
            todo = np.nonzero(~done)[0]
            if todo.size == 0:
                break
            ctr = wins[todo].astype(np.float32)
            rr = radius[todo].astype(np.float32)
            # per-point inflated square probe (the dwithin probe_pad
            # geometry, applied per row): ONE intersects dispatch covers
            # every undone point at its own radius — the exact d2 <= r^2
            # test in the rank discards the square's corner candidates
            sq = np.stack([ctr[:, 0] - rr, ctr[:, 1] - rr,
                           ctr[:, 2] + rr, ctr[:, 3] + rr], axis=1)
            # pow2 query bucket (repeating the last row): each bucket
            # compiles once and shares the intersects pipeline's cache
            bucket = 1 << max(len(todo) - 1, 0).bit_length()
            if bucket > len(todo):
                padq = bucket - len(todo)
                sq = np.concatenate([sq, np.repeat(sq[-1:], padq, 0)])
                ctr = np.concatenate([ctr, np.repeat(ctr[-1:], padq, 0)])
                rr = np.concatenate([rr, np.repeat(rr[-1:], padq, 0)])
            probes[todo] += 1
            # tier 1: ONE fixed-budget dispatch for every undone point.
            # Typical rows fit — a fat row (a square that swallowed a dense
            # core) signals a negative count and is re-dispatched below in
            # its own small batch, so one straggler never drags the whole
            # batch onto a wide budget.
            c1 = ladder.cap
            ub = b0 if 0 < b0 < c1 else 0
            hits, ch = eng.batch_query(
                snap, jnp.asarray(sq), pods, mb, relation="intersects",
                cap=c1, exact_budget=ub,
                compaction=idx._compaction("intersects", ub or None))
            st.dispatches += 3 if ub else 2
            ch = np.asarray(ch)[: len(todo)]
            good = ch >= 0
            idk, dk, within = batch_knn_rank(
                jnp.asarray(ctr), pods, hits, jnp.asarray(rr), k, impl,
                tombstones=tomb, delta=dtab)
            st.dispatches += 1
            idk = np.array(idk[: len(todo)])     # writable: fat rows splice
            dk = np.array(dk[: len(todo)])
            within = np.array(within[: len(todo)])
            fat = np.nonzero(~good)[0]
            if fat.size:
                # tier 2: only the overflowed rows walk the escalating
                # ladder (cap/budget grow to fit THEM, nobody else pays).
                # The negative-count encoding already carries each fat
                # row's TRUE survivor count, so the budget is right-sized
                # for THIS rung's fat set up front — never the high-water
                # mark of an earlier, fatter rung
                need = int((-ch[fat] - 1).max())
                t = 1 << max(need - 1, b0 - 1, 1).bit_length()
                ladder.budget = (0 if t > ladder.max_budget
                                 or t >= ladder.cap else t)
                fb = 1 << max(fat.size - 1, 0).bit_length()
                fi = (np.concatenate([fat, np.repeat(fat[-1:],
                                                     fb - fat.size)])
                      if fb > fat.size else fat)
                try:
                    fhits = _knn_refine(idx, eng, snap, pods, mb,
                                        jnp.asarray(sq[fi]), "intersects",
                                        ladder, st)
                except OverflowError:
                    # a straggler's radius outgrew max_cap: the host loop
                    # has no cap — finish the stragglers there instead of
                    # failing the whole batch
                    st.note = ("straggler radius outgrew max_cap: "
                               "host fallback")
                    with idx._lock:
                        for i in todo[fat]:
                            hi, hd = _host_knn(idx.glin, pts[int(i)], k)
                            out_ids[int(i)] = np.asarray(hi, np.int64)
                            out_d[int(i)] = np.asarray(hd)
                    done[todo[fat]] = True
                else:
                    fidk, fdk, fwit = batch_knn_rank(
                        jnp.asarray(ctr[fi]), pods, fhits,
                        jnp.asarray(rr[fi]), k, impl,
                        tombstones=tomb, delta=dtab)
                    st.dispatches += 1
                    idk[fat] = np.asarray(fidk)[: fat.size]
                    dk[fat] = np.asarray(fdk)[: fat.size]
                    within[fat] = np.asarray(fwit)[: fat.size]
                    good[fat] = True
            settle = good & ((within >= k) | (within >= n_live))
            for j in np.nonzero(settle)[0]:
                i = int(todo[j])
                keep = idk[j] >= 0
                out_ids[i] = idk[j][keep].astype(np.int64)
                out_d[i] = dk[j][keep].astype(np.float64)
            done[todo[settle]] = True
            und = np.nonzero(~settle)[0]
            if und.size:
                # count-informed radius growth: an undone row already has
                # the exact distances of its `within` (< k) nearest, so the
                # 2D density scaling d_within * sqrt(k / within) estimates
                # the k-th-neighbour radius directly. Clamped to [2r, 4r]:
                # at least the doubling backstop, at most one quadrupling
                # (which is also what an empty row — a point still racing
                # across empty space toward the data — takes).
                ru = radius[todo[und]]
                cnt = within[und].astype(np.float64)
                dlast = dk[und, np.maximum(within[und] - 1, 0)]
                est = np.where(
                    cnt > 0,
                    dlast.astype(np.float64)
                    * np.sqrt(k / np.maximum(cnt, 1.0)),
                    np.inf)
                radius[todo[und]] = np.maximum(
                    2.0 * ru, np.minimum(est, 4.0 * ru))
        else:
            raise RuntimeError("knn did not converge")
        ctx.ids, ctx.distances = out_ids, out_d
        st.survivors = _total(out_ids)
        st.escalations = ladder.escalations
        st.cap, st.budget = ladder.cap, ladder.use_budget
        maxp = int(probes.max()) if q else 0
        st.rungs = maxp
        st.rung_hist = tuple(int((probes == i).sum())
                             for i in range(1, maxp + 1))
        st.seed_hits = int((probes == 1).sum())


def _knn_refine(idx, eng, snap, pods, mb, wj, rel, ladder, st):
    """One knn rung through the staged device refine — per-point inflated
    square windows over the plain ``intersects`` pipeline — under the
    shared overflow ladder (DeviceRefineStage's retry contract).
    The hit matrix STAYS ON DEVICE — the caller hands it straight to
    ``batch_knn_rank``; only the overflow-signal counts cross to the host."""
    while True:
        ub = ladder.use_budget
        hits, counts = eng.batch_query(
            snap, wj, pods, mb, relation=rel,
            cap=ladder.cap, exact_budget=ub,
            compaction=idx._compaction(rel, ub or None))
        st.dispatches += 3 if ub else 2
        ch = np.asarray(counts)
        if (ch >= 0).all():
            with idx._lock:
                idx._cap = max(idx._cap, ladder.cap)
            return hits
        st.dispatches += 1                    # disambiguating bounds probe
        ladder.on_device_overflow(
            ch, ub, lambda: eng.batch_query_bounds(snap, wj, relation=rel),
            wj.shape[0])


class KnnShardedStage(Stage):
    """Device-complete knn over the mesh: every record shard ranks its own
    dwithin survivors to a local ``(Q, k)`` block INSIDE the shard_map
    (exact squared distances gathered from the shard-local vertex pool at
    the widest surviving width bucket), then ONE collective all-gathers the
    ``(shards, Q, k)`` blocks for a replicated two-key k-merge — the host
    sees only the final ``(Q, k)`` ids + distances plus the per-shard
    within-radius counts driving the ladder. ``merge_bytes`` accounts the
    collective's payload (the ``roofline_terms`` collective term of
    ``kernels.refine.sharded_knn_cost``).

    Exactness contract: the sharded k-merge ranks SNAPSHOT records only, so
    a stale snapshot is always republished before probing — the fresh
    snapshot has no delta to merge, and results are exact at the published
    epoch. Same per-point seeding / radius-class rung scheduling as
    :class:`KnnDeviceStage`."""

    name = "knn-rank"
    covers = ("probe", "compact", "refine", "knn-rank")
    impl = "sharded"
    dispatches = 4

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        idx, batch = ctx.index, ctx.batch
        cfg = idx.config
        pts = np.asarray(batch.points, np.float64)
        q, k = len(batch), int(batch.k)
        wins = np.concatenate([pts, pts], axis=1)
        with idx._lock:     # the mesh owns every device: run under the lock
            if idx.snapshot_is_stale():
                idx.snapshot()         # k-merge exactness: no delta on top
            else:
                idx._published_snapshot()
            snap_repl, table, shards, maxw = idx._sharded_placement()
            snap = idx._snapshot
            ctx.snap = snap
            ctx.epoch = idx._epoch
            n_live = idx.glin.num_records
            r_global = initial_knn_radius(idx.glin, k)
            seed_mode, _ = _knn_backstop(idx, cfg)
            ladder = OverflowLadder(cfg, idx._cap, max_budget=cfg.max_cap)
            m = cfg.mesh.shape["model"]
            ctx.ids = [np.empty(0, np.int64) for _ in range(q)]
            ctx.distances = [np.empty(0, np.float64) for _ in range(q)]
            if k <= 0 or n_live == 0 or q == 0:
                st.survivors = 0
                return
            radius = _seed_radii(snap, wins, q, k, seed_mode, r_global, st)
            st.seed_radius = float(np.median(radius))
            st.note = f"seed={seed_mode}"
            done = np.zeros(q, bool)
            probes = np.zeros(q, np.int32)
            out_ids: List[Optional[np.ndarray]] = list(ctx.ids)
            out_d: List[Optional[np.ndarray]] = list(ctx.distances)
            for _ in range(64):
                todo = np.nonzero(~done)[0]
                if todo.size == 0:
                    break
                for r in [float(v) for v in np.unique(radius[todo])]:
                    sel = todo[radius[todo] == r]
                    sub = wins[sel].astype(np.float32)
                    # pow2 bucket rounded up to a model-axis multiple
                    # (shard_map divides Q evenly)
                    b = 1 << max(len(sel) - 1, 0).bit_length()
                    b += (-b) % m
                    if b > len(sel):
                        sub = np.concatenate(
                            [sub, np.repeat(sub[-1:], b - len(sel), 0)])
                    wj = jnp.asarray(sub)
                    relname = f"dwithin:{r:.17g}"
                    probes[sel] += 1
                    try:
                        idk, dk, within = self._rank(idx, snap_repl, table,
                                                     wj, relname, k, maxw,
                                                     ladder, st, b, shards)
                    except OverflowError:
                        st.note = ("straggler radius outgrew max_cap: "
                                   "host fallback")
                        for i in sel:
                            hi, hd = _host_knn(idx.glin, pts[int(i)], k)
                            out_ids[int(i)] = np.asarray(hi, np.int64)
                            out_d[int(i)] = np.asarray(hd)
                        done[sel] = True
                        continue
                    idk = idk[: len(sel)]
                    dk = dk[: len(sel)]
                    within = within[: len(sel)]
                    settle = (within >= k) | (within >= n_live)
                    for j in np.nonzero(settle)[0]:
                        i = int(sel[j])
                        keep = idk[j] >= 0
                        out_ids[i] = idk[j][keep].astype(np.int64)
                        out_d[i] = dk[j][keep].astype(np.float64)
                    done[sel[settle]] = True
                radius[~done] *= 2.0
            else:
                raise RuntimeError("knn did not converge")
        ctx.ids, ctx.distances = out_ids, out_d
        st.survivors = _total(out_ids)
        st.escalations = ladder.escalations
        st.cap, st.budget = ladder.cap, ladder.use_budget
        maxp = int(probes.max()) if q else 0
        st.rungs = maxp
        st.rung_hist = tuple(int((probes == i).sum())
                             for i in range(1, maxp + 1))
        st.seed_hits = int((probes == 1).sum())

    @staticmethod
    def _rank(idx, snap_repl, table, wj, relname, k, maxw, ladder, st,
              qpad, shards):
        """One sharded probe+rank+k-merge dispatch under the ladder. Caller
        holds the facade lock (ShardedRefineStage's contract)."""
        while True:
            ub = ladder.use_budget
            comp = idx._compaction(relname, ub or None)
            if comp == "sort":   # legacy argsort baseline: 1-device only
                comp = "scan"
            step = idx._sharded_knn_step(relname, k, ladder.cap, ub, comp,
                                         maxw)
            idk, dk, counts = step(snap_repl, wj, table)
            st.dispatches += 4 if ub else 3
            # all-gathered (shards, Q, k) blocks — k f32 distances + k i32
            # ids per shard — plus the (Q, shards) i32 counts
            st.merge_bytes += qpad * shards * (k * 8 + 4)
            counts = np.asarray(counts)
            if (counts >= 0).all():
                idx._cap = max(idx._cap, ladder.cap)
                return (np.asarray(idk), np.asarray(dk),
                        counts.sum(axis=1))
            ladder.on_sharded_overflow(counts, ub, comp)


# ------------------------------------------------------------- execution plan
@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The compiled stage composition for one planned backend."""

    backend: str
    stages: Tuple[Stage, ...]

    def execute(self, ctx: ExecContext) -> ExecContext:
        for stage in self.stages:
            st = StageStats(stage=stage.name, impl=stage.impl,
                            covers=stage.covers, queries=len(ctx.batch))
            t0 = time.perf_counter()
            stage.run(ctx, st)
            st.wall_ms = 1e3 * (time.perf_counter() - t0)
            ctx.stage_stats.append(st)
        return ctx

    def describe(self) -> List[str]:
        return [f"{i}. {s.name:<18} impl={s.impl:<8} "
                f"covers={'+'.join(s.covers)}"
                + (f" dispatches={s.dispatches}" if s.dispatches else "")
                for i, s in enumerate(self.stages)]


def compile_plan(plan) -> ExecutionPlan:
    """``QueryPlan`` -> ordered stage tuple. Every backend ends in the SAME
    shared delta-patch / complement-finish implementations; conditional
    stages (an empty delta, a non-complement relation) stay compiled in and
    no-op with ``skipped=True`` so the pipeline shape is static per
    backend."""
    if plan.kind == "knn":
        if plan.backend == "sharded":
            stage: Stage = KnnShardedStage()
        elif plan.backend in ("device", "device+delta"):
            stage = KnnDeviceStage()
        else:
            stage = KnnHostStage()
        return ExecutionPlan(plan.backend, (stage,))
    if plan.backend == "host":
        return ExecutionPlan("host", (HostRefineStage(),
                                      ComplementFinishStage()))
    if plan.backend == "device":
        refine = (FusedDeviceStage() if getattr(plan, "fused", False)
                  else DeviceRefineStage())
        return ExecutionPlan("device", (refine, ComplementFinishStage()))
    if plan.backend == "device+delta":
        refine = (FusedDeviceStage() if getattr(plan, "fused", False)
                  else DeviceRefineStage())
        return ExecutionPlan("device+delta", (refine,
                                              DeltaPatchStage(),
                                              ComplementFinishStage()))
    if plan.backend == "sharded":
        return ExecutionPlan("sharded", (ShardedRefineStage(),
                                         DeltaPatchStage(),
                                         ComplementFinishStage()))
    raise ValueError(f"unknown backend {plan.backend!r}")
