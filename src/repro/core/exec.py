"""The staged query-execution pipeline behind :meth:`SpatialIndex.query`.

GLIN's query path is ONE pipeline regardless of where it runs::

    probe -> compact -> refine -> delta-patch -> complement-finish
                                                       (knn: -> knn-rank)

What differs per backend is which *implementation* serves each stage and
how many adjacent stages it fuses: the host loop walks the mutable tree one
window at a time (probe+compact+refine in one pass), the jitted device
``batch_query`` composes the same three stages as THREE device dispatches
(probe, compact kernel, exact gather+check), ``batch_query_fused`` collapses
them into ONE (:class:`FusedDeviceStage`, selected by
``EngineConfig.fusion``), and the sharded step runs them per record shard
under a mesh. Delta patching and
complement finishing are backend-independent — they operate on id lists
against state frozen under the facade lock — so exactly ONE implementation
of each exists, here.

``SpatialIndex.plan()`` picks a backend; :func:`compile_plan` turns that
:class:`QueryPlan` into an :class:`ExecutionPlan` — an ordered stage tuple —
and :meth:`ExecutionPlan.execute` runs it, timing every stage into
:class:`StageStats` (wall time, survivor counts, overflow-ladder
escalations, delta sizes). The stats ride out on ``QueryResult.stages`` and
aggregate into ``SpatialIndex.stats()["stages"]``;
:meth:`SpatialIndex.explain` pretty-prints the compiled pipeline without
executing it.

**The overflow ladder** (:class:`OverflowLadder`) is the one shared
cap/budget escalation policy. Device-side refinement signals overflow with
negative counts: ``-(run length) - 1`` when a query's candidate run outgrew
``cap`` (magnitude > cap disambiguates), else ``-(survivors) - 1`` when the
MBR survivors outgrew ``exact_budget``. The ladder jumps the cap straight
to a sufficient power of two (a cheap bounds-only probe tells the two
overflows apart on the single-device path; the sharded step encodes the
exact local need), grows the budget geometrically past the true survivor
count, and escalates to the single-stage dense path only once the needed
budget exceeds ``MAX_COMPACT_BUDGET`` (or the cap — two-stage would no
longer shrink anything). One special case: the Pallas compact kernel and
the fused one-dispatch path scan the full local run (they are capless), so
with a budget active their overflow is ALWAYS the budget, even when
survivors exceed the cap — the fused retry therefore needs no
disambiguating bounds probe (:meth:`OverflowLadder.on_fused_overflow`).

**Locking contract** (unchanged from the monolithic backends, now stated
once): the host and sharded refine stages run under the facade lock — they
walk the mutable host tree or own every mesh device — and freeze the delta
/ live-id sets for the downstream stages in that same critical section; the
device refine stage freezes everything it needs under the lock, then runs
its device compute OUTSIDE it. Delta patching and complement finishing
always run lock-free on the frozen copies, so their answers are exact at
the frozen epoch no matter how writers interleave.

**Dispatch telemetry**: every stage counts the device dispatches it issued
into ``StageStats.dispatches`` (a staged two-stage attempt is 3 — probe,
compact, exact; a dense attempt 2; a fused attempt 1; each disambiguating
bounds probe adds 1). The counter is how the 3 -> 1 collapse of the fused
path is *asserted*, not just assumed — a regression that silently re-splits
the pipeline shows up in ``stats()["stages"]`` and ``explain()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import geometry as geom
from .device import batch_check_added
from .index import QueryStats, initial_knn_radius
from .index import knn as _host_knn
from .relations import get_relation

__all__ = ["StageStats", "ExecContext", "Stage", "ExecutionPlan",
           "OverflowLadder", "compile_plan", "PIPELINE_STAGES"]

# canonical stage order (docs/api.md "Execution pipeline")
PIPELINE_STAGES = ("probe", "compact", "refine", "delta-patch",
                   "complement-finish", "knn-rank")


def _engine():
    """The engine module namespace, resolved at call time — tests monkeypatch
    ``repro.core.engine.batch_query`` and friends, and the stages must see
    the patched bindings (a ``from``-import here would freeze the originals).
    Deferred to avoid the circular import (engine imports this module)."""
    from . import engine
    return engine


# --------------------------------------------------------------- observability
@dataclasses.dataclass
class StageStats:
    """Per-stage telemetry for one executed query batch.

    ``survivors`` is the total id count LEAVING the stage (-1 when the stage
    does not produce ids, e.g. a skipped patch); ``escalations`` counts
    overflow-ladder retries; ``cap``/``budget`` are the settled ladder values
    a refine stage ended on (budget 0 = single-stage dense, -1 = n/a);
    ``dispatches`` counts device dispatches issued (staged two-stage attempt
    = 3, dense = 2, fused = 1, +1 per disambiguating bounds probe — 0 for
    host/shared stages that launch no device work)."""

    stage: str                       # primary canonical stage name
    impl: str                        # "host" | "device" | "fused" |
                                     # "sharded" | "shared"
    covers: Tuple[str, ...] = ()     # canonical stages this impl fuses
    wall_ms: float = 0.0
    queries: int = 0
    survivors: int = -1
    escalations: int = 0
    dispatches: int = 0
    cap: int = 0
    budget: int = -1
    delta_added: int = 0
    delta_tombstoned: int = 0
    skipped: bool = False            # compiled in, but a no-op this run
    note: str = ""

    def asdict(self) -> dict:
        d = dataclasses.asdict(self)
        d["covers"] = list(self.covers)
        return d


@dataclasses.dataclass
class ExecContext:
    """Mutable state threaded through the stages of one execution.

    The refine stage freezes everything downstream stages read (``epoch``,
    ``frozen_delta``, ``live``, ``snap``) under the facade lock; the stages
    after it touch only this context, never the live index fields."""

    index: Any                       # the SpatialIndex facade
    batch: Any                       # QueryBatch
    plan: Any                        # QueryPlan
    rel: Any                         # Relation (None for knn)
    base: Any                        # probed base Relation (None for knn)
    replica: int = 0
    # frozen under the facade lock by the refine stage
    epoch: int = -1
    frozen_delta: Optional[Tuple] = None
    live: Optional[np.ndarray] = None
    snap: Any = None                 # snapshot whose grid params patch uses
    # outputs
    ids: Optional[List[np.ndarray]] = None
    distances: Optional[List[np.ndarray]] = None
    host_stats: Optional[List[QueryStats]] = None
    stage_stats: List[StageStats] = dataclasses.field(default_factory=list)


def _total(ids: Optional[List[np.ndarray]]) -> int:
    return -1 if ids is None else int(sum(r.shape[0] for r in ids))


# -------------------------------------------------------------- overflow ladder
class OverflowLadder:
    """THE cap/budget escalation policy, shared by every refine
    implementation (single-device and sharded). See the module docstring for
    the negative-count encoding contract this consumes.

    Holds the adaptive state for one query's retries; the settled ``cap`` is
    max-merged back into the facade by the refine stage so the ladder is
    walked once per workload, not once per call."""

    def __init__(self, config, cap: int):
        self.config = config
        self.cap = int(cap)
        self.budget = int(config.exact_budget)
        self.escalations = 0

    @property
    def use_budget(self) -> int:
        """The budget the next call actually uses: two-stage refinement only
        pays for itself while the budget is positive AND below the cap."""
        b = self.budget
        return b if 0 < b < self.cap else 0

    def grow_cap(self, need: int) -> None:
        cfg = self.config
        if self.cap >= cfg.max_cap or need > cfg.max_cap:
            raise OverflowError(
                f"candidate run of {need} exceeded max_cap="
                f"{cfg.max_cap}; raise EngineConfig.max_cap or "
                f"narrow the windows")
        self.cap = min(max(self.cap * 2, 1 << (need - 1).bit_length()),
                       cfg.max_cap)

    def grow_budget(self, use_budget: int, survivors: int) -> None:
        """Budget overflow: the negative-count encoding carries the TRUE
        survivor count, so the budget grows geometrically straight past it
        (re-running compaction) and only falls back to the single-stage
        dense path (budget 0) once the needed budget exceeds
        ``MAX_COMPACT_BUDGET`` or the cap."""
        from repro.kernels.refine import MAX_COMPACT_BUDGET

        target = max(use_budget * 2,
                     1 << max(survivors - 1, 0).bit_length())
        self.budget = (0 if target > MAX_COMPACT_BUDGET or target >= self.cap
                       else target)

    def on_device_overflow(self, counts: np.ndarray, use_budget: int,
                           probe_bounds, batch_len: int) -> None:
        """Single-device retry: the overflow signal conflates run-length >
        cap with survivors > budget; ``probe_bounds`` (a cheap bounds-only
        probe) tells them apart, so the cap jumps straight to sufficiency —
        keeping the LOGICAL budget (one the old cap disabled because
        ``budget >= cap`` comes back into play once the cap outgrows it)."""
        self.escalations += 1
        start, end = probe_bounds()
        need = int(np.max(np.asarray(end - start))) if batch_len else 0
        if need > self.cap:
            self.grow_cap(need)
            return
        if not use_budget:
            raise AssertionError(
                "single-stage overflow with run <= cap")  # unreachable
        self.grow_budget(use_budget, int(-(counts.min()) - 1))

    def on_fused_overflow(self, counts: np.ndarray, use_budget: int) -> None:
        """Fused-path retry: the one-dispatch kernel is capless (its mask
        spans the whole slot table), so a negative count is ALWAYS budget
        overflow carrying the total survivor count — the budget jumps
        straight past it with no disambiguating bounds probe. A zeroed
        budget hands the retry to the staged dense path."""
        self.escalations += 1
        if not use_budget:
            raise AssertionError(
                "fused overflow without an active budget")  # unreachable
        self.grow_budget(use_budget, int(-(counts.min()) - 1))

    def on_sharded_overflow(self, counts: np.ndarray, use_budget: int,
                            compaction: str) -> None:
        """Sharded retry: the step encodes the exact LOCAL need — no global
        bounds probe, whose run is a useless overestimate of any one
        shard's. The Pallas kernel scans the full local run (capless), so
        with a budget active its overflow is ALWAYS the budget."""
        self.escalations += 1
        need = int(-(counts.min()) - 1)
        if use_budget and compaction == "pallas":
            self.grow_budget(use_budget, need)
        elif need > self.cap:
            self.grow_cap(need)
        elif not use_budget:
            raise AssertionError(
                "single-stage overflow with run <= cap")  # unreachable
        else:
            self.grow_budget(use_budget, need)


# ------------------------------------------------------------------- stages
class Stage:
    """One pipeline stage: fill ``ctx`` (and its own ``StageStats``). A
    fused implementation covers several adjacent canonical stages —
    ``covers`` names them for ``explain()`` and the telemetry.
    ``dispatches`` is the static per-attempt device-dispatch count of the
    implementation (what ``explain()`` prints before execution; the
    executed count lands in ``StageStats.dispatches``)."""

    name: str = "?"
    covers: Tuple[str, ...] = ()
    impl: str = "?"
    dispatches: int = 0

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        raise NotImplementedError


class HostRefineStage(Stage):
    """fp64 probe+compact+refine: one ``GLIN.query`` walk per window over
    the mutable host tree, under the facade lock. Queries the BASE relation
    only — complement finishing is the shared downstream stage (the live-id
    set it needs is frozen here, in the same critical section)."""

    name = "refine"
    covers = ("probe", "compact", "refine")
    impl = "host"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        idx, batch = ctx.index, ctx.batch
        stats = ([QueryStats() for _ in range(len(batch))]
                 if batch.collect_stats else None)
        ids: List[np.ndarray] = []
        with idx._lock:
            for i, w in enumerate(batch.windows):
                s = stats[i] if stats is not None else None
                ids.append(np.sort(idx.glin.query(w, ctx.base.name, s)))
            ctx.live = idx._freeze_live(ctx.rel)
            ctx.epoch = idx._epoch
        ctx.ids = ids
        ctx.host_stats = stats
        st.survivors = _total(ids)


class DeviceRefineStage(Stage):
    """The jitted fused probe+compact+refine dispatch (fp32). Freezes the
    served snapshot/payload (fanned to the requested replica), the delta
    and the live-id set under the facade lock, then runs the overflow-
    ladder retry loop OUTSIDE it — writers are never blocked by device
    compute, and the answer is exact at the frozen epoch."""

    name = "refine"
    covers = ("probe", "compact", "refine")
    impl = "device"
    dispatches = 3

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        eng = _engine()
        idx, batch = ctx.index, ctx.batch
        cfg = idx.config
        patch = ctx.plan.backend == "device+delta"
        with idx._lock:
            # freeze everything the unlocked compute below reads: the served
            # snapshot + payload (immutable device arrays), copies of the
            # delta sets and the live set — a writer landing after this
            # block changes none of them. device+delta serves the published
            # snapshot and patches the delta on top; plain device
            # republishes first — either way the answer reflects the frozen
            # epoch exactly.
            snap = idx._published_snapshot() if patch else idx.snapshot()
            payload = idx._device_payload(idx._snapshot_recs)
            snap, payload = idx._replica_view(ctx.replica, snap, payload)
            ctx.frozen_delta = idx._freeze_delta() if patch else None
            ctx.live = idx._freeze_live(ctx.rel)
            ctx.epoch = idx._epoch
            ladder = OverflowLadder(cfg, idx._cap)
        ctx.snap = snap
        pods, mb = payload
        q = len(batch.windows)
        wq = batch.windows.astype(np.float32)
        if cfg.pad_quantum > 0 and q:
            # bucket the query axis to a power of two: the jitted
            # batch_query compiles per windows shape, and a serving tier
            # draining adaptively-sized micro-batches would otherwise
            # compile once per distinct batch size. Padding rows repeat the
            # last window and are sliced off below.
            qb = 1 << (q - 1).bit_length()
            if qb > q:
                wq = np.concatenate([wq, np.repeat(wq[-1:], qb - q, 0)])
        wj = jnp.asarray(wq)
        base = ctx.base.name
        while True:
            ub = ladder.use_budget
            hits, counts = eng.batch_query(
                snap, wj, pods, mb, relation=base,
                cap=ladder.cap, exact_budget=ub,
                compaction=idx._compaction(base, ub or None))
            st.dispatches += 3 if ub else 2   # probe/compact/exact vs dense
            counts = np.asarray(counts)
            if (counts >= 0).all():
                with idx._lock:
                    # max-merge: a concurrent query may have grown it further
                    idx._cap = max(idx._cap, ladder.cap)
                break
            st.dispatches += 1                # disambiguating bounds probe
            ladder.on_device_overflow(
                counts, ub,
                lambda: eng.batch_query_bounds(snap, wj, relation=base), q)
        hits = np.asarray(hits)[:q]
        ctx.ids = [np.sort(row[row >= 0]).astype(np.int64) for row in hits]
        st.survivors = _total(ctx.ids)
        st.escalations = ladder.escalations
        st.cap, st.budget = ladder.cap, ladder.use_budget


class FusedDeviceStage(Stage):
    """ONE-dispatch probe+compact+refine: the whole staged pipeline of
    :class:`DeviceRefineStage` executed by a single fused kernel launch
    (``core.device.batch_query_fused``). Same freeze/retry/epilogue
    contract; what changes is the vehicle — and ``dispatches`` telemetry
    asserting the 3 -> 1 collapse.

    The fused path is two-stage only and VMEM-bounded, so the stage
    re-resolves ``SpatialIndex._fusion_mode`` every ladder step: a zeroed
    budget (dense escalation) or an envelope the store outgrew falls back
    to the staged ``batch_query`` for that attempt — correctness never
    depends on fusion being available."""

    name = "refine"
    covers = ("probe", "compact", "refine")
    impl = "fused"
    dispatches = 1

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        eng = _engine()
        idx, batch = ctx.index, ctx.batch
        cfg = idx.config
        patch = ctx.plan.backend == "device+delta"
        with idx._lock:
            snap = idx._published_snapshot() if patch else idx.snapshot()
            payload = idx._device_payload(idx._snapshot_recs)
            snap, payload = idx._replica_view(ctx.replica, snap, payload)
            ctx.frozen_delta = idx._freeze_delta() if patch else None
            ctx.live = idx._freeze_live(ctx.rel)
            ctx.epoch = idx._epoch
            ladder = OverflowLadder(cfg, idx._cap)
        ctx.snap = snap
        pods, mb = payload
        q = len(batch.windows)
        wq = batch.windows.astype(np.float32)
        if cfg.pad_quantum > 0 and q:
            qb = 1 << (q - 1).bit_length()
            if qb > q:
                wq = np.concatenate([wq, np.repeat(wq[-1:], qb - q, 0)])
        wj = jnp.asarray(wq)
        base = ctx.base.name
        while True:
            ub = ladder.use_budget
            mode = idx._fusion_mode(base, ub or None, snap=snap, pods=pods)
            if mode is None:
                # budget ladder left the fused envelope (dense escalation /
                # budget past the VMEM bound): staged fallback this attempt
                hits, counts = eng.batch_query(
                    snap, wj, pods, mb, relation=base,
                    cap=ladder.cap, exact_budget=ub,
                    compaction=idx._compaction(base, ub or None))
                st.dispatches += 3 if ub else 2
                st.note = "fused envelope exceeded: staged fallback"
                counts = np.asarray(counts)
                if (counts >= 0).all():
                    with idx._lock:
                        idx._cap = max(idx._cap, ladder.cap)
                    break
                st.dispatches += 1            # disambiguating bounds probe
                ladder.on_device_overflow(
                    counts, ub,
                    lambda: eng.batch_query_bounds(snap, wj, relation=base),
                    q)
                continue
            hits, counts = eng.batch_query_fused(
                snap, wj, pods, relation=base, exact_budget=ub, mode=mode)
            st.dispatches += 1
            counts = np.asarray(counts)
            if (counts >= 0).all():
                with idx._lock:
                    idx._cap = max(idx._cap, ladder.cap)
                break
            ladder.on_fused_overflow(counts, ub)   # capless: no bounds probe
        hits = np.asarray(hits)[:q]
        ctx.ids = [np.sort(row[row >= 0]).astype(np.int64) for row in hits]
        st.survivors = _total(ctx.ids)
        st.escalations = ladder.escalations
        st.cap, st.budget = ladder.cap, ladder.use_budget


class ShardedRefineStage(Stage):
    """Per-record-shard fused probe+compact+refine over the mesh
    (``core.distributed``), query windows sharded over the model axis. Runs
    entirely under the facade lock (the mesh owns every device — there is
    nothing to overlap with) and freezes the delta + live-id sets in that
    same critical section for the downstream shared stages."""

    name = "refine"
    covers = ("probe", "compact", "refine")
    impl = "sharded"
    dispatches = 3

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        idx, batch = ctx.index, ctx.batch
        cfg = idx.config
        with idx._lock:
            if ctx.plan.rebuild_snapshot:
                idx.snapshot()
            else:
                idx._published_snapshot()
            patch = idx.snapshot_is_stale()
            q = len(batch)
            # pad the batch to a model-axis multiple (shard_map divides Q
            # evenly); padded rows repeat the last window, sliced off after
            m = cfg.mesh.shape["model"]
            wins32 = batch.windows.astype(np.float32)
            qpad = (-q) % m
            if qpad:
                wins32 = np.concatenate(
                    [wins32, np.repeat(wins32[-1:], qpad, axis=0)])
            wj = jnp.asarray(wins32)
            snap_repl, table, _, maxw = idx._sharded_placement()
            ladder = OverflowLadder(cfg, idx._cap)
            base = ctx.base.name
            while True:
                ub = ladder.use_budget
                comp = idx._compaction(base, ub or None)
                if comp == "sort":  # legacy argsort baseline: 1-device only
                    comp = "scan"
                step = idx._sharded_step(base, ladder.cap, ub, comp, maxw)
                hits, counts = step(snap_repl, wj, table)
                st.dispatches += 3 if ub else 2
                counts = np.asarray(counts)
                if (counts >= 0).all():
                    idx._cap = max(idx._cap, ladder.cap)
                    break
                ladder.on_sharded_overflow(counts, ub, comp)
            hits = np.asarray(hits)[:q]              # (Q, shards, K)
            ctx.ids = [np.sort(row[row >= 0]).astype(np.int64)
                       for row in hits.reshape(q, -1)]
            ctx.frozen_delta = idx._freeze_delta() if patch else None
            ctx.live = idx._freeze_live(ctx.rel)
            ctx.epoch = idx._epoch
            ctx.snap = idx._snapshot
        st.survivors = _total(ctx.ids)
        st.escalations = ladder.escalations
        st.cap, st.budget = ladder.cap, ladder.use_budget


class DeltaPatchStage(Stage):
    """Restore exactness of snapshot results at the frozen epoch: mask out
    tombstoned records and check the added set (fp32, matching the device
    precision contract) against the *base* relation — complement finishing
    happens after, on top of the patched ids.

    Operates only on the ``ExecContext`` freeze (the refine stage captured
    the delta under the lock), so it runs lock-free on every backend —
    THE one patch implementation. Small added sets are brute-force checked
    in a host loop; past ``EngineConfig.delta_device_min`` the check runs on
    device through the Zmin-sorted :class:`~repro.core.device.DeltaTable`
    (one vectorized (Q x A) pass, no per-batch host round-trip)."""

    name = "delta-patch"
    covers = ("delta-patch",)
    impl = "shared"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        frozen = ctx.frozen_delta
        if frozen is None:
            st.skipped = True
            st.note = "no delta against the served snapshot"
            return
        tombs, added, table, av, an, ak = frozen
        st.delta_added = int(added.shape[0])
        st.delta_tombstoned = 0 if tombs is None else int(tombs.shape[0])
        batch, snap = ctx.batch, ctx.snap
        base = ctx.base.name
        added_hits: Optional[List[np.ndarray]] = None
        if table is not None:
            wj = jnp.asarray(batch.windows.astype(np.float32))
            st.dispatches += 1           # device DeltaTable added-set check
            ok = np.asarray(batch_check_added(
                table, wj, base, snap.grid_x0, snap.grid_y0, snap.grid_cell))
            tbl_ids = np.asarray(table.ids, np.int64)
            added_hits = [np.sort(tbl_ids[row]) for row in ok]
        elif added.shape[0]:
            pred = get_relation(base).predicate
            added_hits = []
            for qi in range(len(ctx.ids)):
                w32 = batch.windows[qi].astype(np.float32)
                added_hits.append(added[np.asarray(pred(w32, av, an, ak))])
        out: List[np.ndarray] = []
        for qi, h in enumerate(ctx.ids):
            if tombs is not None:
                h = h[~np.isin(h, tombs)]
            if added_hits is not None:
                # added ids all postdate (exceed) every snapshot id, so the
                # concatenation stays ascending
                h = np.concatenate([h, added_hits[qi]])
            out.append(h)
        ctx.ids = out
        st.survivors = _total(out)


class ComplementFinishStage(Stage):
    """Complement relations (e.g. ``disjoint``): subtract the base hits from
    the live-id set the refine stage froze under the lock — THE one
    complement implementation, identical lock story on every backend."""

    name = "complement-finish"
    covers = ("complement-finish",)
    impl = "shared"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        rel = ctx.rel
        if not rel.is_complement:
            st.skipped = True
            st.note = "relation is not a complement"
            return
        live = ctx.live
        if live is None:   # refine stages freeze it whenever rel needs it
            with ctx.index._lock:
                live = ctx.index._freeze_live(rel)
        ctx.ids = [np.setdiff1d(live, r) for r in ctx.ids]
        if ctx.host_stats is not None:
            # candidates/checked/leaves_* honestly describe the base
            # probe's work, but the hit count must be the complement's
            for s, r in zip(ctx.host_stats, ctx.ids):
                s.results = int(r.shape[0])
        st.survivors = _total(ctx.ids)


class KnnHostStage(Stage):
    """knn on the mutable host tree, one point at a time under the lock."""

    name = "knn-rank"
    covers = ("probe", "refine", "knn-rank")
    impl = "host"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        idx, batch = ctx.index, ctx.batch
        ids, dists = [], []
        with idx._lock:      # the host knn walks the mutable tree
            for p in batch.points:
                i, d = _host_knn(idx.glin, p, batch.k)
                ids.append(np.asarray(i, np.int64))
                dists.append(np.asarray(d))
            ctx.epoch = idx._epoch
        ctx.ids, ctx.distances = ids, dists
        st.survivors = _total(ids)


class KnnDeviceStage(Stage):
    """knn through ``dwithin`` (cf. LISA): every point becomes a degenerate
    window probed with ``dwithin:<r>`` at doubling radii — ONE batched
    facade query per radius rung, so the planner takes the device path
    instead of Q sequential host walks. A point is done once it has >= k
    candidates whose k-th exact distance fits inside r (the dwithin
    candidate set is exactly {distance <= r}, so no closer geometry can be
    missing). Radii are snapped to powers of two: each rung compiles once
    and is shared by every knn call. ``escalations`` counts the extra rungs
    past the first."""

    name = "knn-rank"
    covers = ("probe", "compact", "refine", "knn-rank")
    impl = "device"

    def run(self, ctx: ExecContext, st: StageStats) -> None:
        idx, batch = ctx.index, ctx.batch
        pts = batch.points
        q, k = len(batch), batch.k
        wins = np.concatenate([pts, pts], axis=1)    # degenerate windows
        with idx._lock:    # the radius estimate reads the mutable tree
            r = initial_knn_radius(idx.glin, k)
        r = float(2.0 ** np.ceil(np.log2(max(r, 1e-9))))
        done = np.zeros(q, bool)
        out_ids: List[Optional[np.ndarray]] = [None] * q
        out_d: List[Optional[np.ndarray]] = [None] * q
        for rung in range(64):
            # only the still-undone points ride the next rung: finished
            # points must not re-probe at (exponentially) wider radii, which
            # would also inflate the shared adaptive candidate cap. The
            # shrinking batch is padded to a power-of-two bucket (repeating
            # the last window) so each (bucket, radius) pair compiles once,
            # not each distinct todo-count
            todo = np.nonzero(~done)[0]
            sub = wins[todo]
            bucket = 1 << max(len(sub) - 1, 0).bit_length()
            if bucket > len(sub):
                sub = np.concatenate(
                    [sub, np.repeat(sub[-1:], bucket - len(sub), axis=0)])
            eng = _engine()
            try:
                res = idx.query(
                    eng.QueryBatch.window(sub, f"dwithin:{r:.17g}"))
            except OverflowError:
                # a straggler's radius outgrew max_cap: the host loop has
                # no cap — finish the stragglers there instead of failing
                # the whole batch
                st.note = "straggler radius outgrew max_cap: host fallback"
                with idx._lock:
                    for i in todo:
                        hi, hd = _host_knn(idx.glin, pts[int(i)], k)
                        out_ids[int(i)] = np.asarray(hi, np.int64)
                        out_d[int(i)] = np.asarray(hd)
                    ctx.epoch = idx._epoch
                ctx.ids, ctx.distances = out_ids, out_d
                st.escalations = rung
                st.survivors = _total(out_ids)
                return
            # the store is append-only (arrays are replaced, never
            # mutated): a fresh reference covers every candidate id the
            # rung returned
            gs = idx.glin.gs
            for ti, i in enumerate(todo):
                cand = res[ti]
                if cand.shape[0] < k:
                    continue
                d = np.sqrt(geom.rect_geom_sqdist(
                    wins[i], gs.padded(cand), gs.nverts[cand],
                    gs.kinds[cand]))
                order = np.lexsort((cand, d))
                if d[order[k - 1]] <= r:
                    sel = order[:k]
                    out_ids[int(i)] = cand[sel].astype(np.int64)
                    out_d[int(i)] = d[sel]
                    done[i] = True
            if done.all():
                ctx.ids, ctx.distances = out_ids, out_d
                ctx.epoch = idx._epoch
                st.escalations = rung
                st.survivors = _total(out_ids)
                return
            r *= 2.0
        raise RuntimeError("knn did not converge")


# ------------------------------------------------------------- execution plan
@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The compiled stage composition for one planned backend."""

    backend: str
    stages: Tuple[Stage, ...]

    def execute(self, ctx: ExecContext) -> ExecContext:
        for stage in self.stages:
            st = StageStats(stage=stage.name, impl=stage.impl,
                            covers=stage.covers, queries=len(ctx.batch))
            t0 = time.perf_counter()
            stage.run(ctx, st)
            st.wall_ms = 1e3 * (time.perf_counter() - t0)
            ctx.stage_stats.append(st)
        return ctx

    def describe(self) -> List[str]:
        return [f"{i}. {s.name:<18} impl={s.impl:<8} "
                f"covers={'+'.join(s.covers)}"
                + (f" dispatches={s.dispatches}" if s.dispatches else "")
                for i, s in enumerate(self.stages)]


def compile_plan(plan) -> ExecutionPlan:
    """``QueryPlan`` -> ordered stage tuple. Every backend ends in the SAME
    shared delta-patch / complement-finish implementations; conditional
    stages (an empty delta, a non-complement relation) stay compiled in and
    no-op with ``skipped=True`` so the pipeline shape is static per
    backend."""
    if plan.kind == "knn":
        stage = KnnDeviceStage() if plan.backend == "device" \
            else KnnHostStage()
        return ExecutionPlan(plan.backend, (stage,))
    if plan.backend == "host":
        return ExecutionPlan("host", (HostRefineStage(),
                                      ComplementFinishStage()))
    if plan.backend == "device":
        refine = (FusedDeviceStage() if getattr(plan, "fused", False)
                  else DeviceRefineStage())
        return ExecutionPlan("device", (refine, ComplementFinishStage()))
    if plan.backend == "device+delta":
        refine = (FusedDeviceStage() if getattr(plan, "fused", False)
                  else DeviceRefineStage())
        return ExecutionPlan("device+delta", (refine,
                                              DeltaPatchStage(),
                                              ComplementFinishStage()))
    if plan.backend == "sharded":
        return ExecutionPlan("sharded", (ShardedRefineStage(),
                                         DeltaPatchStage(),
                                         ComplementFinishStage()))
    raise ValueError(f"unknown backend {plan.backend!r}")
