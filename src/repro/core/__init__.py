"""GLIN core — the paper's contribution (learned index for complex geometries)."""
from .datasets import GeometrySet, generate, make_query_windows
from .index import GLIN, GLINConfig, QueryStats
from .model import GLINModelConfig
from .piecewise import PiecewiseFunction
from .device import GLINSnapshot, snapshot_from_host, batch_query
from .delta import SnapshotManager

__all__ = [
    "GeometrySet", "generate", "make_query_windows",
    "GLIN", "GLINConfig", "QueryStats", "GLINModelConfig",
    "PiecewiseFunction", "GLINSnapshot", "snapshot_from_host", "batch_query",
    "SnapshotManager",
]
