"""GLIN core — the paper's contribution (learned index for complex geometries).

Public API: build a :class:`SpatialIndex` and call :meth:`SpatialIndex.query`.
The mutable host :class:`GLIN`, the flattened :class:`GLINSnapshot` and the
``snapshot_from_host`` / ``batch_query`` device functions remain available as
the low-level layer the facade is built on.
"""
from .datasets import GeometrySet, generate, make_query_windows
from .index import GLIN, GLINConfig, QueryStats
from .model import GLINModelConfig
from .piecewise import PiecewiseFunction
from .relations import Relation, get_relation, register_relation, relation_names
from .device import GLINSnapshot, snapshot_from_host, batch_query
from .engine import (EngineConfig, QueryBatch, QueryPlan, QueryResult,
                     SpatialIndex)
from .exec import PIPELINE_STAGES, ExecutionPlan, OverflowLadder, StageStats

__all__ = [
    "GeometrySet", "generate", "make_query_windows",
    "GLIN", "GLINConfig", "QueryStats", "GLINModelConfig",
    "PiecewiseFunction", "GLINSnapshot", "snapshot_from_host", "batch_query",
    "Relation", "get_relation", "register_relation", "relation_names",
    "EngineConfig", "QueryBatch", "QueryPlan", "QueryResult", "SpatialIndex",
    "PIPELINE_STAGES", "ExecutionPlan", "OverflowLadder", "StageStats",
]
