"""Baseline spatial indexes the paper compares against (§IX-A).

* :class:`RTree`     — Boost-style R-Tree: STR bulk load, min-enlargement
                       insertion with linear split (paper uses Boost R-Tree
                       defaults, max 16 entries).
* :class:`QuadTree`  — GEOS-style region quadtree: items live at the deepest
                       node whose quadrant fully contains their MBR.
* :class:`SortedArray` — non-learned ablation: the same Zmin-sorted record
                       array probed by binary search instead of the learned
                       model (isolates the learned-CDF contribution).

All three expose ``query(window, relation)`` with the same probe → exact-shape
refinement split as GLIN, so probing time / refinement checks / sizes are
directly comparable.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from . import geometry as geom
from .datasets import GeometrySet
from .index import QueryStats
from .piecewise import PiecewiseFunction
from .relations import get_relation
from .zorder import mbr_to_zinterval_np

__all__ = ["RTree", "QuadTree", "SortedArray"]


def _refine(gs: GeometrySet, cand: np.ndarray, window: np.ndarray,
            relation: str, st: QueryStats) -> np.ndarray:
    rel = get_relation(relation)
    if rel.complement_of is not None:
        # the tree probes only surface MBR-intersecting candidates, so a
        # complement's true hits (records far from the window) are never
        # visited — refuse rather than silently return near-boundary records
        raise NotImplementedError(
            f"baseline indexes do not implement complement relation "
            f"{relation!r}; use SpatialIndex")
    st.checked += int(cand.shape[0])
    if cand.shape[0] == 0:
        return np.empty(0, np.int64)
    # gather only THIS candidate set's rings from the pool, padded to the
    # set's own widest record — never the store-wide dense block
    ok = rel.predicate(window, gs.padded(cand), gs.nverts[cand],
                       gs.kinds[cand])
    return cand[ok]


# ---------------------------------------------------------------------------
# R-Tree (STR bulk load; Guttman insert with linear split)
# ---------------------------------------------------------------------------
class _RNode:
    __slots__ = ("mbr", "children", "entries", "is_leaf")

    def __init__(self, is_leaf: bool):
        self.is_leaf = is_leaf
        self.mbr = np.array([np.inf, np.inf, -np.inf, -np.inf], np.float64)
        self.children: List["_RNode"] = []
        self.entries: List[int] = []  # record ids (leaves only)

    def recompute_mbr(self, gs_mbrs) -> None:
        if self.is_leaf:
            if self.entries:
                m = gs_mbrs[np.asarray(self.entries)]
                self.mbr = np.array([m[:, 0].min(), m[:, 1].min(),
                                     m[:, 2].max(), m[:, 3].max()])
        else:
            ms = np.stack([c.mbr for c in self.children])
            self.mbr = np.array([ms[:, 0].min(), ms[:, 1].min(),
                                 ms[:, 2].max(), ms[:, 3].max()])


class RTree:
    MAX_ENTRIES = 16

    def __init__(self, gs: GeometrySet):
        self.gs = gs

    @classmethod
    def build(cls, gs: GeometrySet) -> "RTree":
        """Sort-Tile-Recursive bulk load."""
        self = cls(gs)
        mbrs = gs.mbrs
        n = len(gs)
        cx = (mbrs[:, 0] + mbrs[:, 2]) * 0.5
        cy = (mbrs[:, 1] + mbrs[:, 3]) * 0.5
        cap = self.MAX_ENTRIES
        idx = np.argsort(cx, kind="stable")
        s = int(np.ceil(np.sqrt(np.ceil(n / cap))))
        slice_sz = s * cap
        leaves: List[_RNode] = []
        for i in range(0, n, slice_sz):
            sl = idx[i : i + slice_sz]
            sl = sl[np.argsort(cy[sl], kind="stable")]
            for j in range(0, sl.shape[0], cap):
                node = _RNode(True)
                node.entries = sl[j : j + cap].tolist()
                node.recompute_mbr(mbrs)
                leaves.append(node)
        level = leaves
        while len(level) > 1:
            nxt: List[_RNode] = []
            order = np.argsort([0.5 * (nd.mbr[0] + nd.mbr[2]) for nd in level],
                               kind="stable")
            lv = [level[i] for i in order]
            s = int(np.ceil(np.sqrt(np.ceil(len(lv) / cap))))
            slice_sz = s * cap
            for i in range(0, len(lv), slice_sz):
                sl = lv[i : i + slice_sz]
                sl.sort(key=lambda nd: 0.5 * (nd.mbr[1] + nd.mbr[3]))
                for j in range(0, len(sl), cap):
                    node = _RNode(False)
                    node.children = sl[j : j + cap]
                    node.recompute_mbr(mbrs)
                    nxt.append(node)
            level = nxt
        self.root = level[0] if level else _RNode(True)
        return self

    # -- query ---------------------------------------------------------------
    def probe(self, window: np.ndarray, st: QueryStats) -> np.ndarray:
        out: List[int] = []
        stack = [self.root]
        gs_mbrs = self.gs.mbrs
        while stack:
            node = stack.pop()
            if not bool(geom.mbr_intersects(node.mbr, window)):
                st.leaves_skipped += 1
                continue
            if node.is_leaf:
                st.leaves_visited += 1
                if node.entries:
                    e = np.asarray(node.entries)
                    hit = geom.mbr_intersects(gs_mbrs[e], window[None, :])
                    out.extend(e[hit].tolist())
            else:
                stack.extend(node.children)
        return np.asarray(out, np.int64)

    def query(self, window: np.ndarray, relation: str = "contains",
              stats: Optional[QueryStats] = None) -> np.ndarray:
        st = stats if stats is not None else QueryStats()
        window = np.asarray(window, np.float64)
        rel = get_relation(relation)
        cand = self.probe(rel.probe_window(window), st)
        st.candidates += int(cand.shape[0])
        res = _refine(self.gs, cand, window, relation, st)
        st.results = int(res.shape[0])
        return res

    # -- maintenance -----------------------------------------------------------
    def insert(self, rec: int) -> None:
        mbr = self.gs.mbrs[rec]

        def enlarge(m, b):
            return ((max(m[2], b[2]) - min(m[0], b[0]))
                    * (max(m[3], b[3]) - min(m[1], b[1]))
                    - (m[2] - m[0]) * (m[3] - m[1]))

        node = self.root
        path = [node]
        while not node.is_leaf:
            best = min(node.children, key=lambda c: enlarge(c.mbr, mbr))
            node = best
            path.append(node)
        node.entries.append(rec)
        for nd in reversed(path):
            nd.mbr[0] = min(nd.mbr[0], mbr[0])
            nd.mbr[1] = min(nd.mbr[1], mbr[1])
            nd.mbr[2] = max(nd.mbr[2], mbr[2])
            nd.mbr[3] = max(nd.mbr[3], mbr[3])
        if len(node.entries) > self.MAX_ENTRIES:
            self._split_leaf(path)

    def _split_leaf(self, path: List[_RNode]) -> None:
        leaf = path[-1]
        mbrs = self.gs.mbrs
        e = np.asarray(leaf.entries)
        cx = (mbrs[e, 0] + mbrs[e, 2]) * 0.5
        order = np.argsort(cx)  # linear split along x
        half = e.shape[0] // 2
        a, b = _RNode(True), _RNode(True)
        a.entries = e[order[:half]].tolist()
        b.entries = e[order[half:]].tolist()
        a.recompute_mbr(mbrs)
        b.recompute_mbr(mbrs)
        if len(path) == 1:
            new_root = _RNode(False)
            new_root.children = [a, b]
            new_root.recompute_mbr(mbrs)
            self.root = new_root
            return
        parent = path[-2]
        parent.children.remove(leaf)
        parent.children.extend([a, b])
        if len(parent.children) > self.MAX_ENTRIES:
            # split internal node the same way
            ms = np.stack([c.mbr for c in parent.children])
            order = np.argsort((ms[:, 0] + ms[:, 2]) * 0.5)
            half = len(parent.children) // 2
            kids = [parent.children[i] for i in order]
            a2, b2 = _RNode(False), _RNode(False)
            a2.children = kids[:half]
            b2.children = kids[half:]
            a2.recompute_mbr(ms)
            b2.recompute_mbr(ms)
            if len(path) == 2:
                new_root = _RNode(False)
                new_root.children = [a2, b2]
                new_root.recompute_mbr(ms)
                self.root = new_root
            else:
                gp = path[-3]
                gp.children.remove(parent)
                gp.children.extend([a2, b2])

    def delete(self, rec: int) -> bool:
        mbr = self.gs.mbrs[rec]
        stack = [self.root]
        while stack:
            node = stack.pop()
            if not bool(geom.mbr_intersects(node.mbr, mbr)):
                continue
            if node.is_leaf:
                if rec in node.entries:
                    node.entries.remove(rec)
                    return True
            else:
                stack.extend(node.children)
        return False

    def stats(self) -> dict:
        n_nodes = n_leaf = size = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            n_nodes += 1
            size += 32 + 8  # node MBR + header
            if node.is_leaf:
                n_leaf += 1
                size += 40 * len(node.entries)  # entry MBR + id (Boost layout)
            else:
                size += 40 * len(node.children)  # child MBR + pointer
                stack.extend(node.children)
        return {"nodes": n_nodes, "leaf_nodes": n_leaf, "index_bytes": size,
                "total_index_bytes": size}


# ---------------------------------------------------------------------------
# Quad-Tree (GEOS-style: items at deepest fully-containing quadrant)
# ---------------------------------------------------------------------------
class _QNode:
    __slots__ = ("x0", "y0", "x1", "y1", "items", "children")

    def __init__(self, x0, y0, x1, y1):
        self.x0, self.y0, self.x1, self.y1 = x0, y0, x1, y1
        self.items: List[int] = []
        self.children: Optional[List["_QNode"]] = None

    def quadrant(self, mbr) -> int:
        mx = (self.x0 + self.x1) * 0.5
        my = (self.y0 + self.y1) * 0.5
        if mbr[2] <= mx and mbr[3] <= my:
            return 0
        if mbr[0] >= mx and mbr[3] <= my:
            return 1
        if mbr[2] <= mx and mbr[1] >= my:
            return 2
        if mbr[0] >= mx and mbr[1] >= my:
            return 3
        return -1  # straddles a midline: stays at this node

    def child_box(self, q: int):
        mx = (self.x0 + self.x1) * 0.5
        my = (self.y0 + self.y1) * 0.5
        return [(self.x0, self.y0, mx, my), (mx, self.y0, self.x1, my),
                (self.x0, my, mx, self.y1), (mx, my, self.x1, self.y1)][q]


class QuadTree:
    MAX_ITEMS = 8
    MAX_DEPTH = 24

    def __init__(self, gs: GeometrySet):
        self.gs = gs
        self.root = _QNode(0.0, 0.0, 1.0, 1.0)

    @classmethod
    def build(cls, gs: GeometrySet) -> "QuadTree":
        self = cls(gs)
        x0 = float(gs.mbrs[:, 0].min()) if len(gs) else 0.0
        y0 = float(gs.mbrs[:, 1].min()) if len(gs) else 0.0
        x1 = float(gs.mbrs[:, 2].max()) if len(gs) else 1.0
        y1 = float(gs.mbrs[:, 3].max()) if len(gs) else 1.0
        self.root = _QNode(x0, y0, x1, y1)
        for rec in range(len(gs)):
            self.insert(rec)
        return self

    def insert(self, rec: int) -> None:
        mbr = self.gs.mbrs[rec]
        node, depth = self.root, 0
        while True:
            if node.children is None:
                if len(node.items) < self.MAX_ITEMS or depth >= self.MAX_DEPTH:
                    node.items.append(rec)
                    return
                node.children = [_QNode(*node.child_box(q)) for q in range(4)]
                stay: List[int] = []
                for it in node.items:
                    q = node.quadrant(self.gs.mbrs[it])
                    (stay if q < 0 else node.children[q].items).append(it)
                node.items = stay
            q = node.quadrant(mbr)
            if q < 0:
                node.items.append(rec)
                return
            node = node.children[q]
            depth += 1

    def delete(self, rec: int) -> bool:
        mbr = self.gs.mbrs[rec]
        node = self.root
        while node is not None:
            if rec in node.items:
                node.items.remove(rec)
                return True
            if node.children is None:
                return False
            q = node.quadrant(mbr)
            if q < 0:
                return False
            node = node.children[q]
        return False

    def probe(self, window: np.ndarray, st: QueryStats) -> np.ndarray:
        out: List[int] = []
        gs_mbrs = self.gs.mbrs
        stack = [self.root]
        while stack:
            node = stack.pop()
            if (node.x1 < window[0] or window[2] < node.x0
                    or node.y1 < window[1] or window[3] < node.y0):
                st.leaves_skipped += 1
                continue
            st.leaves_visited += 1
            if node.items:
                e = np.asarray(node.items)
                hit = geom.mbr_intersects(gs_mbrs[e], window[None, :])
                out.extend(e[hit].tolist())
            if node.children is not None:
                stack.extend(node.children)
        return np.asarray(out, np.int64)

    def query(self, window: np.ndarray, relation: str = "contains",
              stats: Optional[QueryStats] = None) -> np.ndarray:
        st = stats if stats is not None else QueryStats()
        window = np.asarray(window, np.float64)
        rel = get_relation(relation)
        cand = self.probe(rel.probe_window(window), st)
        st.candidates += int(cand.shape[0])
        res = _refine(self.gs, cand, window, relation, st)
        st.results = int(res.shape[0])
        return res

    def stats(self) -> dict:
        n_nodes = size = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            n_nodes += 1
            size += 32 + 4 * 8 + 8  # box + 4 child ptrs + header
            size += 8 * len(node.items)
            if node.children is not None:
                stack.extend(node.children)
        return {"nodes": n_nodes, "index_bytes": size, "total_index_bytes": size}


# ---------------------------------------------------------------------------
# Sorted array + binary search (GLIN-without-the-model ablation)
# ---------------------------------------------------------------------------
class SortedArray:
    def __init__(self, gs: GeometrySet, piece_limitation: int = 10000):
        self.gs = gs
        zmin, zmax = mbr_to_zinterval_np(gs.mbrs, gs.grid)
        order = np.argsort(zmin, kind="stable")
        self.keys = zmin[order]
        self.recs = order.astype(np.int64)
        self.pw = PiecewiseFunction.build(zmin, zmax, piece_limitation)

    @classmethod
    def build(cls, gs: GeometrySet, piece_limitation: int = 10000) -> "SortedArray":
        return cls(gs, piece_limitation)

    def query(self, window: np.ndarray, relation: str = "contains",
              stats: Optional[QueryStats] = None) -> np.ndarray:
        st = stats if stats is not None else QueryStats()
        window = np.asarray(window, np.float64)
        rel = get_relation(relation)
        probe_win = rel.probe_window(window)
        zmin_q, zmax_q = (int(v[0]) for v in
                          mbr_to_zinterval_np(probe_win[None, :],
                                              self.gs.grid))
        if rel.augment:
            zmin_q = self.pw.augment(zmin_q)
        lo = int(np.searchsorted(self.keys, zmin_q, side="left"))
        hi = int(np.searchsorted(self.keys, zmax_q, side="right"))
        cand = self.recs[lo:hi]
        st.candidates += int(cand.shape[0])
        res = _refine(self.gs, cand, window, relation, st)
        st.results = int(res.shape[0])
        return res

    def stats(self) -> dict:
        return {"nodes": 1, "index_bytes": self.pw.nbytes() + 16,
                "total_index_bytes": self.pw.nbytes() + 16}
