"""GLIN — the lightweight learned spatial index (paper §III–§VIII).

Host-side reference system: builds the hierarchical learned model over
Zmin addresses, answers *Contains* / *Intersects* range queries with the
two-step probe + refine algorithm (Alg 1), augments *Intersects* queries with
the piecewise function (Alg 2), and maintains the structure under insertion /
deletion (ALEX-style leaf grow / split / merge).

Device-resident batched querying lives in ``core.device`` (flattened snapshot)
and ``kernels/refine`` (Pallas); both are validated against this class.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import geometry as geom
from .datasets import GeometrySet
from .model import (GLINModelConfig, InternalNode, LeafNode, build_tree,
                    probe, tree_stats)
from .piecewise import PiecewiseFunction
from .relations import get_relation
from .zorder import mbr_to_zinterval_np

__all__ = ["GLINConfig", "GLIN", "QueryStats"]


@dataclasses.dataclass(frozen=True)
class GLINConfig:
    model: GLINModelConfig = GLINModelConfig()
    piece_limitation: int = 10000
    enable_piecewise: bool = True      # "GLIN-piecewise" vs plain "GLIN"
    record_mbr_prefilter: bool = False  # beyond-paper: record-level MBR test
                                        # before the exact-shape check


@dataclasses.dataclass
class QueryStats:
    """Instrumentation mirroring the paper's reported quantities."""

    candidates: int = 0       # records between probe start and Zmax_Q
    checked: int = 0          # records that underwent the exact-shape check
    leaves_visited: int = 0
    leaves_skipped: int = 0   # skipped via leaf-MBR pruning (§V-C)
    results: int = 0


class GLIN:
    def __init__(self, cfg: GLINConfig = GLINConfig()):
        self.cfg = cfg
        self.root = None
        self.leaves: List[LeafNode] = []
        self.pw: Optional[PiecewiseFunction] = None
        self.gs: Optional[GeometrySet] = None
        self.zmin: Optional[np.ndarray] = None  # per-record, aligned with gs
        self.zmax: Optional[np.ndarray] = None
        self.num_records = 0

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, gs: GeometrySet, cfg: GLINConfig = GLINConfig()) -> "GLIN":
        self = cls(cfg)
        self.gs = gs
        zmin, zmax = mbr_to_zinterval_np(gs.mbrs, gs.grid)
        self.zmin, self.zmax = zmin, zmax
        # Step 1 (§V-A): sort by Zmin; Zmax is dropped from the tree build.
        order = np.argsort(zmin, kind="stable")
        keys = zmin[order]
        recs = order.astype(np.int64)
        # Step 2 (§V-B): hierarchical model.
        self.root, self.leaves = build_tree(keys, recs, cfg.model)
        # Step 3 (§V-C): aggregate MBR per leaf.
        for leaf in self.leaves:
            leaf.set_mbr_from(gs.mbrs[leaf.recs[: leaf.size]])
        # §VIII-B: piecewise function from the transient Zmax-sorted order.
        if cfg.enable_piecewise:
            self.pw = PiecewiseFunction.build(zmin, zmax, cfg.piece_limitation)
        self.num_records = len(gs)
        return self

    # ------------------------------------------------------------------ sizes
    def stats(self) -> Dict:
        st = tree_stats(self.root)
        st["piecewise_bytes"] = self.pw.nbytes() if self.pw else 0
        st["piecewise_pieces"] = self.pw.num_pieces if self.pw else 0
        st["total_index_bytes"] = st["index_bytes"] + st["piecewise_bytes"]
        return st

    # ------------------------------------------------------------------ query
    def query(self, window: np.ndarray, relation: str = "contains",
              stats: Optional[QueryStats] = None) -> np.ndarray:
        """Algorithm 1 for any registered relation. ``window``: (4,)
        [xmin, ymin, xmax, ymax]. Returns record ids satisfying the relation,
        in Zmin order (complement relations: ascending record id)."""
        rel = get_relation(relation)
        window = np.asarray(window, np.float64)
        if rel.complement_of is not None:
            base = self.query(window, rel.complement_of, stats)
            live = np.nonzero(self._live_mask())[0].astype(np.int64)
            res = np.setdiff1d(live, base)
            if stats is not None:
                # candidates/checked/leaves_* honestly describe the base
                # probe's work, but the hit count must be the complement's
                stats.results = int(res.shape[0])
            return res
        # dwithin-style relations probe (and prune leaves with) the window
        # expanded by the relation's pad; the exact predicate still sees the
        # caller's window.
        probe_win = rel.probe_window(window)
        zmin_q, zmax_q = (int(v[0]) for v in
                          mbr_to_zinterval_np(probe_win[None, :],
                                              self.gs.grid))
        if rel.augment:
            if self.pw is None:
                raise ValueError(f"{relation} requires the piecewise function "
                                 "(cfg.enable_piecewise=True)")
            zmin_q = self.pw.augment(zmin_q)  # §VIII query augmentation

        leaf, slot = probe(self.root, zmin_q)
        out: List[np.ndarray] = []
        st = stats if stats is not None else QueryStats()
        gs = self.gs
        while leaf is not None:
            n = leaf.size
            if n == 0 or slot >= n:
                leaf, slot = leaf.next, 0
                continue
            if int(leaf.keys[slot]) > zmax_q:
                break
            # End of the in-range run inside this leaf.
            end = int(np.searchsorted(leaf.keys[:n], zmax_q, side="right"))
            cand = leaf.recs[slot:end]
            st.candidates += int(cand.shape[0])
            # Leaf-MBR pruning (§V-C): skip the node wholesale.
            if not bool(geom.mbr_intersects(leaf.mbr, probe_win)):
                st.leaves_skipped += 1
            else:
                st.leaves_visited += 1
                sel = cand
                if self.cfg.record_mbr_prefilter:
                    keep = rel.mbr_prefilter(gs.mbrs[sel], window[None, :])
                    sel = sel[keep]
                st.checked += int(sel.shape[0])
                if sel.shape[0]:
                    # ragged store: gather only this candidate set's widest
                    # ring, not the global max width
                    ok = rel.predicate(window, gs.padded(sel), gs.nverts[sel],
                                       gs.kinds[sel])
                    hits = sel[ok]
                    if hits.shape[0]:
                        out.append(hits)
            if end < n:
                break  # zmax_q falls inside this leaf
            leaf, slot = leaf.next, 0
        res = np.concatenate(out) if out else np.empty(0, np.int64)
        st.results = int(res.shape[0])
        return res

    def query_bruteforce(self, window: np.ndarray, relation: str = "contains"
                         ) -> np.ndarray:
        """Oracle for correctness tests: exact check on every live record."""
        gs = self.gs
        rel = get_relation(relation)
        window = np.asarray(window, np.float64)
        live = self._live_mask()
        ok = rel.predicate(window, gs.verts, gs.nverts, gs.kinds)
        return np.nonzero(ok & live)[0].astype(np.int64)

    def _live_mask(self) -> np.ndarray:
        live = np.zeros(len(self.gs), bool)
        for leaf in self.leaves:
            live[leaf.recs[: leaf.size]] = True
        return live

    # ------------------------------------------------------------ maintenance
    def insert(self, verts: np.ndarray, nverts: int, kind: int) -> int:
        """Insert one geometry; returns its record id (§VII).

        The CSR vertex pool appends exactly this record's ring — O(width)
        bytes moved (amortized), regardless of how wide the new geometry is
        relative to the rest of the store. Nothing is re-padded and nothing
        is truncated, so the MBR and exact-shape checks always see the full
        input ring."""
        gs = self.gs
        verts = np.asarray(verts, np.float64)
        nverts = int(nverts)
        if verts.ndim != 2 or verts.shape[1] != 2 or not 1 <= nverts <= verts.shape[0]:
            raise ValueError(
                f"verts must be (>=nverts, 2) with nverts >= 1; got "
                f"shape {verts.shape}, nverts={nverts}")
        keep = verts[:nverts]
        mbr = np.array([keep[:, 0].min(), keep[:, 1].min(),
                        keep[:, 0].max(), keep[:, 1].max()])
        rec = gs.append(keep, nverts, kind, mbr)
        zmin, zmax = mbr_to_zinterval_np(mbr[None, :], gs.grid)
        zmin, zmax = int(zmin[0]), int(zmax[0])
        self.zmin = np.append(self.zmin, np.int64(zmin))
        self.zmax = np.append(self.zmax, np.int64(zmax))

        leaf, slot = probe(self.root, zmin)
        leaf.insert_at(slot, zmin, rec)
        leaf.expand_mbr(mbr)  # §VII: expand, never shrink
        self._maybe_split(leaf)
        if self.pw is not None:
            self.pw.insert(zmin, zmax)
        self.num_records += 1
        return rec

    def delete(self, rec: int) -> bool:
        """Delete a record by id (paper: by geometry key; several geometries
        may share a Zmin — only the matching record is erased)."""
        zmin = int(self.zmin[rec])
        leaf, slot = probe(self.root, zmin)
        n = leaf.size
        # scan the duplicate-key run for the matching record id
        pos = -1
        j = slot
        while j < n and int(leaf.keys[j]) == zmin:
            if int(leaf.recs[j]) == rec:
                pos = j
                break
            j += 1
        if pos < 0:
            return False
        leaf.delete_at(pos)
        # MBR intentionally NOT shrunk (§VII) — stale MBRs only add false
        # positives, never true negatives. The store tombstones the ring;
        # its pool space is reclaimed by the compaction pass at the next
        # snapshot republish (published snapshots may still read it).
        self.gs.mark_dead(rec)
        self._maybe_merge(leaf)
        if self.pw is not None:
            self.pw.delete(zmin, int(self.zmax[rec]))
        self.num_records -= 1
        return True

    # -- ALEX-style node expansion / splitting / merging (§VII) -------------
    def _maybe_split(self, leaf: LeafNode) -> None:
        cfg = self.cfg.model
        if leaf.size < cfg.max_leaf * 2:
            if leaf.size >= cfg.upper_density * leaf.keys.shape[0]:
                leaf.grow()       # gapped-array expansion
                leaf.refit()
            return
        width = leaf.dhi - leaf.dlo
        if width < cfg.min_split_width:
            leaf.grow()  # unsplittable domain: keep absorbing via expansion
            leaf.refit()
            return
        # Split: replace the leaf with a fanout-2 internal node.
        node = InternalNode(leaf.dlo, leaf.dhi, 2)
        mid = leaf.dlo + width // 2
        n = leaf.size
        cut = int(np.searchsorted(leaf.keys[:n], mid, side="left"))
        gs_mbrs = self.gs.mbrs
        left = LeafNode(leaf.keys[:cut], leaf.recs[:cut], leaf.dlo, mid)
        right = LeafNode(leaf.keys[cut:n], leaf.recs[cut:n], mid, leaf.dhi)
        left.set_mbr_from(gs_mbrs[left.recs[: left.size]])
        right.set_mbr_from(gs_mbrs[right.recs[: right.size]])
        left.parent = right.parent = node
        left.cell, right.cell = 0, 1
        node.children[0], node.children[1] = left, right
        self._replace_child(leaf, node)
        # relink the leaf chain
        idx = self.leaves.index(leaf)
        prev = self.leaves[idx - 1] if idx > 0 else None
        left.next = right
        right.next = leaf.next
        if prev is not None:
            prev.next = left
        self.leaves[idx : idx + 1] = [left, right]

    def _maybe_merge(self, leaf: LeafNode) -> None:
        cfg = self.cfg.model
        parent = leaf.parent
        if (parent is None or parent.fanout != 2
                or leaf.size > cfg.lower_density * cfg.max_leaf):
            return
        sib = parent.children[1 - leaf.cell]
        if not isinstance(sib, LeafNode):
            return
        if leaf.size + sib.size > cfg.max_leaf:
            return
        lo_leaf, hi_leaf = (leaf, sib) if leaf.cell == 0 else (sib, leaf)
        keys = np.concatenate([lo_leaf.keys[: lo_leaf.size],
                               hi_leaf.keys[: hi_leaf.size]])
        recs = np.concatenate([lo_leaf.recs[: lo_leaf.size],
                               hi_leaf.recs[: hi_leaf.size]])
        merged = LeafNode(keys, recs, parent.dlo, parent.dhi)
        # fresh MBR (§VII)
        merged.set_mbr_from(self.gs.mbrs[merged.recs[: merged.size]])
        self._replace_child(parent, merged)
        idx = self.leaves.index(lo_leaf)
        prev = self.leaves[idx - 1] if idx > 0 else None
        merged.next = hi_leaf.next
        if prev is not None:
            prev.next = merged
        self.leaves[idx : idx + 2] = [merged]

    def _replace_child(self, old, new) -> None:
        parent = old.parent
        new.parent = parent
        new.cell = old.cell
        if parent is None:
            self.root = new
        else:
            parent.children[old.cell] = new

    # ---------------------------------------------------------------- helpers
    def all_leaf_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(keys, recs, leaf_start, leaf_mbr) packed over live records, used by
        the device snapshot and by rebuilds."""
        total = sum(lf.size for lf in self.leaves)
        keys = np.empty(total, np.int64)
        recs = np.empty(total, np.int64)
        starts = np.empty(len(self.leaves) + 1, np.int64)
        mbrs = np.empty((len(self.leaves), 4), np.float64)
        off = 0
        for i, lf in enumerate(self.leaves):
            starts[i] = off
            keys[off : off + lf.size] = lf.keys[: lf.size]
            recs[off : off + lf.size] = lf.recs[: lf.size]
            mbrs[i] = lf.mbr
            off += lf.size
        starts[-1] = off
        return keys, recs, starts, mbrs


def initial_knn_radius(glin: GLIN, k: int) -> float:
    """First search radius from global density: expect ~k hits inside it."""
    gs = glin.gs
    n = max(glin.num_records, 1)
    span_x = float(gs.mbrs[:, 2].max() - gs.mbrs[:, 0].min()) or 1.0
    span_y = float(gs.mbrs[:, 3].max() - gs.mbrs[:, 1].min()) or 1.0
    return max(1e-9, float(np.sqrt(span_x * span_y * k / n)))


def knn(glin: GLIN, point, k: int):
    """K-nearest-neighbour query — the paper's stated future work (§XI).

    knn through ``dwithin`` (cf. LISA): the point becomes a degenerate window
    probed with ``dwithin:<r>`` at doubling radii. The candidate set at
    radius r is exactly {geometries with Euclidean distance <= r}, so once k
    candidates lie within r no closer geometry can be missing. Candidates are
    ranked by exact point-to-geometry distance (``geometry.rect_geom_sqdist``;
    0 inside a polygon) under the shared ``geometry.rank_knn`` (distance, id)
    ordering contract. Settled candidates carry across rungs: dwithin radii
    nest, so each rung's candidate set is a superset of the last and only
    NEWLY probed records get an exact-distance evaluation (the PR-4 ladder
    re-ranked the full candidate set every rung). Indexes built without the
    piecewise function fall back to an Intersects probe over the square
    window of half-side r — a superset of the dwithin candidates, so the
    same count-within-r termination rule holds.

    Returns (ids, distances) sorted by (distance, id); fewer than k entries
    when fewer than k records are live (the ladder stops once the candidate
    set covers every record — it can never grow past that).
    """
    gs = glin.gs
    px, py = float(point[0]), float(point[1])
    rect = np.array([px, py, px, py])
    k = int(k)
    if k <= 0 or glin.num_records == 0:
        return np.empty(0, np.int64), np.empty(0, np.float64)
    r = initial_knn_radius(glin, k)

    ids = np.empty(0, np.int64)          # settled candidates (exact distance
    dists = np.empty(0, np.float64)      # computed exactly once per record)
    for _ in range(64):
        if glin.pw is not None:
            cand = glin.query(rect, f"dwithin:{r:.17g}")
        else:
            cand = glin.query(np.array([px - r, py - r, px + r, py + r]),
                              "intersects")
        new = np.setdiff1d(cand, ids, assume_unique=True)
        if new.shape[0]:
            nd = np.sqrt(geom.rect_geom_sqdist(
                rect, gs.padded(new), gs.nverts[new], gs.kinds[new]))
            ids = np.concatenate([ids, new])
            dists = np.concatenate([dists, nd])
        if (int((dists <= r).sum()) >= k
                or cand.shape[0] >= glin.num_records):
            return geom.rank_knn(ids, dists, k)
        r *= 2.0
    raise RuntimeError("knn did not converge")
