"""Device-resident GLIN: flattened snapshot + jitted batch query path.

This is the TPU-native half of the adaptation (DESIGN.md §2): the host tree is
flattened into struct-of-arrays form and thousands of query windows are probed
*simultaneously* with pure array ops:

* model traversal  — bounded ``fori_loop`` of gathers over the flattened node
  table (equal-width routing in re-centred fp32; exactness restored by a ±2
  leaf fix-up against integer leaf-domain boundaries);
* leaf search      — fp32 linear model prediction + fixed-trip binary search
  whose window is the *device-side* max model error (recomputed in fp32 at
  snapshot time so the fp64→fp32 drop can never shrink the window);
* refinement       — fixed-capacity candidate tiles: leaf-MBR skip, record-MBR
  mask and exact-shape checks as masked vector ops.

Z-addresses are (hi, lo) int32 limb pairs throughout — no 64-bit integers.
Every public function is shape-polymorphic in the query batch and jittable.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import geometry as geom
from .model import InternalNode, LeafNode
from .relations import get_relation
from .zorder import (LO_LIMB_SIZE, mbr_to_zinterval_hilo, split_hilo_np,
                     z_less_hilo)

__all__ = ["GLINSnapshot", "HostCapture", "VertexPods", "pack_pods",
           "pods_from_store", "snapshot_capture", "snapshot_from_capture",
           "snapshot_from_host", "batch_probe", "batch_query_bounds",
           "batch_query", "batch_query_fused", "DeltaTable",
           "delta_table_from_host",
           "batch_check_added", "knn_seed_radii", "batch_knn_rank",
           "input_specs_like"]

_I32 = jnp.int32
_INF_HI = np.int32(2**30)  # > any valid 30-bit limb


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GLINSnapshot:
    """Flattened GLIN index as device arrays."""

    # sorted record table
    keys_hi: jax.Array      # (N,) int32
    keys_lo: jax.Array      # (N,) int32
    recs: jax.Array         # (N,) int32 record ids
    rec_leaf: jax.Array     # (N,) int32 leaf id of each slot
    # slot-aligned fp32 MBR tables (built once per publish): the refinement
    # mask streams/loads these directly instead of chaining
    # leaf_mbr[rec_leaf[slot]] / mbrs[recs[slot]] gathers per query
    slot_lmbr: jax.Array    # (N, 4) float32 leaf MBR of each slot
    slot_rmbr: jax.Array    # (N, 4) float32 record MBR of each slot
    # leaf tables (L leaves; +1 sentinel on boundaries)
    leaf_start: jax.Array   # (L+1,) int32 slot offsets
    leaf_dlo_hi: jax.Array  # (L+1,) int32 leaf domain lower bounds
    leaf_dlo_lo: jax.Array  # (L+1,) int32
    leaf_mbr: jax.Array     # (L, 4) float32 aggregate MBRs
    leaf_k0_hi: jax.Array   # (L,) int32 model re-centring key
    leaf_k0_lo: jax.Array   # (L,) int32
    leaf_slope: jax.Array   # (L,) float32
    leaf_icpt: jax.Array    # (L,) float32
    # flattened internal nodes
    node_dlo_hi: jax.Array  # (M,) int32
    node_dlo_lo: jax.Array  # (M,) int32
    node_scale: jax.Array   # (M,) float32  fanout / domain-width
    node_fanout: jax.Array  # (M,) int32
    node_child_base: jax.Array  # (M,) int32 into child_codes
    child_codes: jax.Array  # (C,) int32  >=0: internal node id; <0: -(leaf+1)
    # piecewise augmentation (suffix-min form)
    pw_zmax_hi: jax.Array   # (P,) int32
    pw_zmax_lo: jax.Array   # (P,) int32
    pw_sufmin_hi: jax.Array  # (P,) int32
    pw_sufmin_lo: jax.Array  # (P,) int32
    # static meta
    search_steps: int = dataclasses.field(metadata=dict(static=True))
    depth: int = dataclasses.field(metadata=dict(static=True))
    grid_x0: float = dataclasses.field(metadata=dict(static=True))
    grid_y0: float = dataclasses.field(metadata=dict(static=True))
    grid_cell: float = dataclasses.field(metadata=dict(static=True))

    @property
    def num_slots(self) -> int:
        return self.keys_hi.shape[0]

    @property
    def num_leaves(self) -> int:
        return self.leaf_mbr.shape[0]


# ---------------------------------------------------------------------------
# Width-bucketed vertex pods (device half of the CSR vertex pool)
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VertexPods:
    """Device-resident ragged geometry: one flat fp32 vertex pod pool plus
    per-record ``(off, nv)`` CSR addressing.

    Records are grouped by pow2 vertex-count bucket and each record's ring
    is padded (with its last valid vertex) to its bucket width, so every
    bucket is a contiguous run of equal-width, slot-aligned pods. Pod memory
    is <= 2x the tight ring total — independent of the widest geometry in
    the store, unlike the dense ``(N, V, 2)`` block it replaces.

    The exact-refine stage gathers survivors at the widest bucket PRESENT in
    the batch (``lax.switch`` over the static width ladder ``1, 2, ...,
    max_width``), not at the global max width: a batch of point/polyline
    survivors never pays a 64-vertex gather because one wide ring exists
    somewhere in the store.
    """

    pool: jax.Array    # (P, 2) float32 bucket-grouped padded pods
    off: jax.Array     # (N,) int32 pod start of each record
    nv: jax.Array      # (N,) int32 valid vertices of each record
    kd: jax.Array      # (N,) int32 GeomKind of each record
    bucket: jax.Array  # (N,) int32 pow2 bucket index (width = 1 << bucket)
    # static pow2 width ceiling; the branch ladder is 1 << (0..log2(max))
    max_width: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_records(self) -> int:
        return self.off.shape[0]

    @property
    def num_buckets(self) -> int:
        return int(math.log2(self.max_width)) + 1


def _pow2ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def pack_pods(pool: np.ndarray, offsets: np.ndarray, nverts: np.ndarray,
              kinds: np.ndarray, *, pad_records_to: int = 0,
              pool_pad_to: int = 0, max_width: int = 0,
              dtype=np.float32) -> dict:
    """Pack host CSR rings into the bucket-grouped pod layout (numpy).

    Returns ``{"pool", "off", "nv", "kd", "bucket", "max_width"}``; arrays
    are numpy so callers control the upload (replicated payload, per-shard
    slices, tests). Records beyond ``len(nverts)`` (up to ``pad_records_to``)
    are inert: ``off=0, nv=1, bucket=0`` — in-bounds reads, masked upstream.
    ``max_width`` forces a wider static ladder than the data needs (sticky
    jit-signature floors); ``pool_pad_to`` likewise floors the pod count.
    """
    nverts = np.asarray(nverts, np.int64)
    n = nverts.shape[0]
    maxw = _pow2ceil(max(int(nverts.max()) if n else 1, 1))
    if max_width:
        if max_width != _pow2ceil(max_width):
            raise ValueError(f"max_width must be a power of 2, got {max_width}")
        maxw = max(maxw, int(max_width))
    ladder = 1 << np.arange(int(math.log2(maxw)) + 1, dtype=np.int64)
    bucket = np.searchsorted(ladder, nverts).astype(np.int32)
    widths = ladder[bucket]
    order = np.argsort(bucket, kind="stable")   # bucket-grouped, stable
    w_seq = widths[order]
    start_seq = np.zeros(n, np.int64)
    if n:
        np.cumsum(w_seq[:-1], out=start_seq[1:])
    total = int(w_seq.sum())
    p = max(total, int(pool_pad_to), 1)
    pod = np.zeros((p, 2), dtype)
    if total:
        lane = np.arange(total) - np.repeat(start_seq, w_seq)
        src_rec = np.repeat(order, w_seq)
        src = (np.asarray(offsets, np.int64)[src_rec]
               + np.minimum(lane, nverts[src_rec] - 1))
        pod[:total] = pool[src]
    m = max(n, int(pad_records_to))
    off = np.zeros(m, np.int32)
    nv = np.ones(m, np.int32)
    kd = np.zeros(m, np.int32)
    bk = np.zeros(m, np.int32)
    off[order] = start_seq.astype(np.int32)
    nv[:n] = nverts
    kd[:n] = np.asarray(kinds)
    bk[:n] = bucket
    return {"pool": pod, "off": off, "nv": nv, "kd": kd, "bucket": bk,
            "max_width": maxw}


def pods_from_store(gs, pad_records_to: int = 0, pool_pad_to: int = 0,
                    max_width: int = 0) -> VertexPods:
    """Pack a GeometrySet's pool into device-resident :class:`VertexPods`."""
    p = pack_pods(gs.pool, gs.offsets, gs.nverts, gs.kinds,
                  pad_records_to=pad_records_to, pool_pad_to=pool_pad_to,
                  max_width=max_width)
    return VertexPods(pool=jnp.asarray(p["pool"]), off=jnp.asarray(p["off"]),
                      nv=jnp.asarray(p["nv"]), kd=jnp.asarray(p["kd"]),
                      bucket=jnp.asarray(p["bucket"]),
                      max_width=p["max_width"])


# ---------------------------------------------------------------------------
# Host tree -> capture -> snapshot
#
# The flatten is split in two so a republish can run OFF the caller's thread
# (engine double-buffering): ``snapshot_capture`` touches the live, mutable
# host structure (leaf list, node tree, piecewise arrays) and must be called
# synchronously with respect to insert/delete; ``snapshot_from_capture`` does
# the heavy O(N) numpy work and the device uploads on plain numpy copies (or
# append-immutable store arrays) and is safe to run on a background thread
# while writes keep mutating the host index.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HostCapture:
    """A consistent host-side flattening of the index at one epoch.

    ``keys``/``recs``/``starts``/``leaf_mbrs`` are fresh copies; the geometry
    store fields alias the store's live views, which are immutable once
    captured (the CSR pool only ever appends past the captured length, and
    growth/compaction replace the buffer rather than mutating it) — so the
    capture stays valid while the live index keeps mutating."""

    keys: np.ndarray        # (N,) int64 Zmin keys in slot order
    recs: np.ndarray        # (N,) int64 record ids in slot order
    starts: np.ndarray      # (L+1,) int64 leaf slot offsets
    leaf_mbrs: np.ndarray   # (L, 4) f64 aggregate leaf MBRs
    dlo_hi: np.ndarray      # (L+1,) int32 leaf domain bounds
    dlo_lo: np.ndarray
    k0_hi: np.ndarray       # (L,) int32 leaf model re-centring keys
    k0_lo: np.ndarray
    slope: np.ndarray       # (L,) float32
    icpt: np.ndarray        # (L,) float32
    node_dlo_hi: np.ndarray
    node_dlo_lo: np.ndarray
    node_scale: np.ndarray
    node_fanout: np.ndarray
    node_child_base: np.ndarray
    child_codes: np.ndarray
    depth: int
    pw_zmax_hi: np.ndarray
    pw_zmax_lo: np.ndarray
    pw_sufmin_hi: np.ndarray
    pw_sufmin_lo: np.ndarray
    grid_x0: float
    grid_y0: float
    grid_cell: float
    # geometry store at capture time (aliases; see class docstring)
    gs_mbrs: np.ndarray
    gs_pool: np.ndarray     # (P, 2) f64 CSR vertex pool (live view)
    gs_offsets: np.ndarray  # (N,) i64 ring starts into the pool
    gs_nverts: np.ndarray
    gs_kinds: np.ndarray
    num_records: int        # store length at capture time

    @property
    def num_leaves(self) -> int:
        return self.leaf_mbrs.shape[0]


def snapshot_capture(glin) -> HostCapture:
    """Flatten the live host tree into plain numpy (synchronous part).

    Also runs the store's pool compaction pass: records tombstoned since the
    last publish give their ring storage back here, where it's safe — the
    new snapshot's tree no longer references them, previously captured pool
    views are untouched (compaction replaces buffers), and device payloads
    key on the store's ``pool_version`` so they re-upload the slimmer pool.
    """
    glin.gs.compact()
    keys, recs, starts, mbrs = glin.all_leaf_arrays()
    leaves = glin.leaves
    L = len(leaves)

    dlos = np.array([lf.dlo for lf in leaves] + [leaves[-1].dhi if L else 1],
                    dtype=object)
    dlo_hi = np.array([int(d) >> 30 for d in dlos], np.int64).astype(np.int32)
    dlo_lo = np.array([int(d) & (LO_LIMB_SIZE - 1) for d in dlos], np.int32)

    k0_hi, k0_lo = split_hilo_np(
        np.array([lf.key0 for lf in leaves], np.int64))
    slope = np.array([lf.slope for lf in leaves], np.float32)
    icpt = np.array([lf.intercept for lf in leaves], np.float32)

    # Flatten internal nodes (BFS). A leaf root is wrapped in a fanout-1 node.
    leaf_ids = {id(lf): i for i, lf in enumerate(leaves)}
    root = glin.root
    if isinstance(root, LeafNode):
        wrapper = InternalNode(root.dlo, root.dhi, 1)
        wrapper.children[0] = root
        root = wrapper
    order = [root]
    index_of = {id(root): 0}
    qi = 0
    while qi < len(order):
        node = order[qi]
        qi += 1
        for c in node.children:
            if isinstance(c, InternalNode):
                index_of[id(c)] = len(order)
                order.append(c)
    M = len(order)
    n_dlo_hi = np.empty(M, np.int32)
    n_dlo_lo = np.empty(M, np.int32)
    n_scale = np.empty(M, np.float32)
    n_fan = np.empty(M, np.int32)
    n_base = np.empty(M, np.int32)
    codes = []
    depth = 1
    for i, node in enumerate(order):
        n_dlo_hi[i] = node.dlo >> 30
        n_dlo_lo[i] = node.dlo & (LO_LIMB_SIZE - 1)
        n_scale[i] = np.float32(node.fanout / float(node.dhi - node.dlo))
        n_fan[i] = node.fanout
        n_base[i] = len(codes)
        for c in node.children:
            if isinstance(c, InternalNode):
                codes.append(index_of[id(c)])
            else:
                codes.append(-(leaf_ids[id(c)] + 1))
    # tree depth for the fixed traversal trip count
    def _depth(node, d):
        nonlocal depth
        depth = max(depth, d)
        if isinstance(node, InternalNode):
            for c in node.children:
                _depth(c, d + 1)
    _depth(root, 1)

    # Piecewise function in suffix-min form (copied: pw mutates in place).
    if glin.pw is not None and glin.pw.num_pieces:
        pw = glin.pw
        pz_hi, pz_lo = split_hilo_np(np.array(pw.zmax_end, np.int64))
        ps_hi, ps_lo = split_hilo_np(pw.suffix_min().astype(np.int64))
    else:
        pz_hi = pz_lo = ps_hi = ps_lo = np.empty(0, np.int32)

    gs = glin.gs
    grid = gs.grid
    return HostCapture(
        keys=keys, recs=recs, starts=starts, leaf_mbrs=mbrs,
        dlo_hi=dlo_hi, dlo_lo=dlo_lo, k0_hi=k0_hi, k0_lo=k0_lo,
        slope=slope, icpt=icpt,
        node_dlo_hi=n_dlo_hi, node_dlo_lo=n_dlo_lo, node_scale=n_scale,
        node_fanout=n_fan, node_child_base=n_base,
        child_codes=np.asarray(codes, np.int32), depth=depth,
        pw_zmax_hi=pz_hi, pw_zmax_lo=pz_lo,
        pw_sufmin_hi=ps_hi, pw_sufmin_lo=ps_lo,
        grid_x0=float(grid.x0), grid_y0=float(grid.y0),
        grid_cell=float(grid.cell_size),
        gs_mbrs=gs.mbrs, gs_pool=gs.pool, gs_offsets=gs.offsets,
        gs_nverts=gs.nverts, gs_kinds=gs.kinds, num_records=len(gs),
    )


def snapshot_from_capture(c: HostCapture) -> GLINSnapshot:
    """Heavy O(N) flattening + device upload over a capture (thread-safe)."""
    keys, recs, starts = c.keys, c.recs, c.starts
    L = c.num_leaves
    k_hi, k_lo = split_hilo_np(keys)
    rec_leaf = np.repeat(np.arange(L, dtype=np.int32),
                         np.diff(starts).astype(np.int64))

    # Device-side max error: re-evaluate the fp32 model on every key so the
    # binary-search window provably brackets the answer on device.
    max_err = 1
    key_f = ((k_hi - c.k0_hi[rec_leaf]).astype(np.float32)
             * np.float32(LO_LIMB_SIZE)
             + (k_lo - c.k0_lo[rec_leaf]).astype(np.float32))
    pred = np.rint(c.slope[rec_leaf] * key_f
                   + c.icpt[rec_leaf]).astype(np.int64)
    local = np.arange(keys.shape[0], dtype=np.int64) - starts[rec_leaf]
    if keys.shape[0]:
        max_err = max(1, int(np.max(np.abs(pred - local))))
    search_steps = max(1, math.ceil(math.log2(2 * max_err + 4)))

    mbrs32 = c.leaf_mbrs.astype(np.float32)
    return GLINSnapshot(
        keys_hi=jnp.asarray(k_hi), keys_lo=jnp.asarray(k_lo),
        recs=jnp.asarray(recs.astype(np.int32)),
        rec_leaf=jnp.asarray(rec_leaf),
        slot_lmbr=jnp.asarray(mbrs32[rec_leaf] if L else
                              np.empty((0, 4), np.float32)),
        slot_rmbr=jnp.asarray(c.gs_mbrs[recs].astype(np.float32)),
        leaf_start=jnp.asarray(starts.astype(np.int32)),
        leaf_dlo_hi=jnp.asarray(c.dlo_hi), leaf_dlo_lo=jnp.asarray(c.dlo_lo),
        leaf_mbr=jnp.asarray(mbrs32),
        leaf_k0_hi=jnp.asarray(c.k0_hi), leaf_k0_lo=jnp.asarray(c.k0_lo),
        leaf_slope=jnp.asarray(c.slope), leaf_icpt=jnp.asarray(c.icpt),
        node_dlo_hi=jnp.asarray(c.node_dlo_hi),
        node_dlo_lo=jnp.asarray(c.node_dlo_lo),
        node_scale=jnp.asarray(c.node_scale),
        node_fanout=jnp.asarray(c.node_fanout),
        node_child_base=jnp.asarray(c.node_child_base),
        child_codes=jnp.asarray(c.child_codes),
        pw_zmax_hi=jnp.asarray(c.pw_zmax_hi),
        pw_zmax_lo=jnp.asarray(c.pw_zmax_lo),
        pw_sufmin_hi=jnp.asarray(c.pw_sufmin_hi),
        pw_sufmin_lo=jnp.asarray(c.pw_sufmin_lo),
        search_steps=search_steps, depth=c.depth,
        grid_x0=c.grid_x0, grid_y0=c.grid_y0, grid_cell=c.grid_cell,
    )


def snapshot_from_host(glin) -> GLINSnapshot:
    return snapshot_from_capture(snapshot_capture(glin))


# ---------------------------------------------------------------------------
# Batched probing
# ---------------------------------------------------------------------------
def _find_leaf(s: GLINSnapshot, q_hi: jax.Array, q_lo: jax.Array) -> jax.Array:
    """Model traversal (Alg 1 model_traversal), batched: (Q,) -> leaf ids."""

    def body(_, state):
        node, leaf, done = state
        dh = (q_hi - s.node_dlo_hi[node]).astype(jnp.float32)
        dl = (q_lo - s.node_dlo_lo[node]).astype(jnp.float32)
        key_f = dh * jnp.float32(LO_LIMB_SIZE) + dl
        cell_f = jnp.clip(jnp.floor(key_f * s.node_scale[node]), 0.0,
                          (s.node_fanout[node] - 1).astype(jnp.float32))
        cell = cell_f.astype(_I32)
        code = s.child_codes[s.node_child_base[node] + cell]
        is_leaf = code < 0
        new_leaf = jnp.where(is_leaf & ~done, -code - 1, leaf)
        new_node = jnp.where(is_leaf | done, node, code)
        return new_node, new_leaf, done | is_leaf

    q = q_hi.shape[0]
    node0 = jnp.zeros((q,), _I32)
    leaf0 = jnp.zeros((q,), _I32)
    done0 = jnp.zeros((q,), bool)
    _, leaf, _ = jax.lax.fori_loop(0, s.depth, body, (node0, leaf0, done0))

    # fp32 routing fix-up against exact integer leaf-domain boundaries.
    for _ in range(2):
        too_low = z_less_hilo(q_hi, q_lo, s.leaf_dlo_hi[leaf], s.leaf_dlo_lo[leaf])
        leaf = jnp.maximum(leaf - too_low.astype(_I32), 0)
        too_high = ~z_less_hilo(q_hi, q_lo, s.leaf_dlo_hi[leaf + 1],
                                s.leaf_dlo_lo[leaf + 1])
        leaf = jnp.minimum(leaf + too_high.astype(_I32), s.num_leaves - 1)
    return leaf


def model_window(s: GLINSnapshot, q_hi: jax.Array, q_lo: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Model traversal + leaf prediction -> global slot window [lo, hi)
    guaranteed to bracket lower_bound(q). Uses only the small replicated
    model tables (no record-level arrays)."""
    leaf = _find_leaf(s, q_hi, q_lo)
    start = s.leaf_start[leaf]
    end = s.leaf_start[leaf + 1]
    size = end - start

    key_f = ((q_hi - s.leaf_k0_hi[leaf]).astype(jnp.float32) * LO_LIMB_SIZE
             + (q_lo - s.leaf_k0_lo[leaf]).astype(jnp.float32))
    pred = jnp.rint(s.leaf_slope[leaf] * key_f + s.leaf_icpt[leaf]).astype(_I32)
    pred = jnp.clip(pred, 0, jnp.maximum(size - 1, 0))
    err = (1 << s.search_steps) // 2 + 2
    lo = jnp.maximum(pred - err, 0) + start
    hi = jnp.minimum(pred + err, size) + start
    return lo, hi


def lower_bound_in_window(keys_hi: jax.Array, keys_lo: jax.Array,
                          q_hi: jax.Array, q_lo: jax.Array,
                          lo: jax.Array, hi: jax.Array, steps: int) -> jax.Array:
    """Bounded binary search for the first key >= q within [lo, hi)."""

    def step(_, st):
        lo_i, hi_i = st
        live = lo_i < hi_i  # converged lanes must not move (clamped gathers)
        mid = (lo_i + hi_i) >> 1
        less = z_less_hilo(keys_hi[mid], keys_lo[mid], q_hi, q_lo) & live
        return jnp.where(less, mid + 1, lo_i), jnp.where(less | ~live, hi_i, mid)

    lo, hi = jax.lax.fori_loop(0, steps, step, (lo, hi))
    return lo


def batch_probe(s: GLINSnapshot, q_hi: jax.Array, q_lo: jax.Array) -> jax.Array:
    """Batched lower_bound: global slot of the first key >= query key."""
    lo, hi = model_window(s, q_hi, q_lo)
    return lower_bound_in_window(s.keys_hi, s.keys_lo, q_hi, q_lo, lo, hi,
                                 s.search_steps + 2)


def _augment(s: GLINSnapshot, q_hi, q_lo):
    """Suffix-min piecewise augmentation, batched (Alg 2 equivalent)."""
    p = s.pw_zmax_hi.shape[0]
    if p == 0:
        return q_hi, q_lo
    # binary search: first piece with zmax_end >= q
    lo = jnp.zeros_like(q_hi)
    hi = jnp.full_like(q_hi, p)
    steps = max(1, math.ceil(math.log2(p + 1)))

    def step(_, st):
        lo_i, hi_i = st
        mid = (lo_i + hi_i) >> 1
        less = z_less_hilo(s.pw_zmax_hi[mid], s.pw_zmax_lo[mid], q_hi, q_lo)
        return jnp.where(less, mid + 1, lo_i), jnp.where(less, hi_i, mid)

    lo, _ = jax.lax.fori_loop(0, steps, step, (lo, hi))
    in_range = lo < p
    idx = jnp.minimum(lo, p - 1)
    m_hi = jnp.where(in_range, s.pw_sufmin_hi[idx], _INF_HI)
    m_lo = jnp.where(in_range, s.pw_sufmin_lo[idx], 0)
    take = z_less_hilo(m_hi, m_lo, q_hi, q_lo)
    return jnp.where(take, m_hi, q_hi), jnp.where(take, m_lo, q_lo)


def _raw_query_keys(s: GLINSnapshot, windows: jax.Array, rel
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Window quantization WITHOUT the augmentation rewrite: (zmin, ub=
    zmax+1) hi/lo limbs. The fused kernel consumes these directly (its
    suffix-min search runs in-kernel); ``query_keys`` layers ``_augment``
    on top for the staged path."""
    from .zorder import ZGrid

    grid = ZGrid(s.grid_x0, s.grid_y0, s.grid_cell)
    # probe with the relation's (possibly padded) window; conservative fp32
    # quantization on top (never lose a candidate)
    (zmin_hi, zmin_lo), (zmax_hi, zmax_lo) = mbr_to_zinterval_hilo(
        rel.probe_window(windows, xp=jnp), grid,
        guard=ZGrid.FP32_GUARD_CELLS)
    carry = (zmax_lo + 1) >= LO_LIMB_SIZE
    ub_hi = zmax_hi + carry.astype(_I32)
    ub_lo = jnp.where(carry, 0, zmax_lo + 1)
    return zmin_hi, zmin_lo, ub_hi, ub_lo


def query_keys(s: GLINSnapshot, windows: jax.Array, relation: str
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Windows (Q,4) -> ((zmin', ub) hi/lo limbs): the probe key (augmented
    per the relation's rule) and the exclusive upper key zmax+1."""
    rel = _device_relation(relation)
    zmin_hi, zmin_lo, ub_hi, ub_lo = _raw_query_keys(s, windows, rel)
    if rel.augment:
        zmin_hi, zmin_lo = _augment(s, zmin_hi, zmin_lo)
    return zmin_hi, zmin_lo, ub_hi, ub_lo


def _device_relation(relation: str):
    """Registry lookup restricted to relations the batched path can serve."""
    rel = get_relation(relation)
    if not rel.device_native:
        raise ValueError(
            f"relation {relation!r} is not device-native (evaluate its base "
            f"relation {rel.base_name()!r} and finish on host — the "
            f"SpatialIndex facade does this automatically)")
    return rel


def batch_query_bounds(s: GLINSnapshot, windows: jax.Array,
                       relation: str = "contains"
                       ) -> Tuple[jax.Array, jax.Array]:
    """Windows (Q,4) float32 -> (start_slot, end_slot) per query."""
    zmin_hi, zmin_lo, ub_hi, ub_lo = query_keys(s, windows, relation)
    start = batch_probe(s, zmin_hi, zmin_lo)
    end = batch_probe(s, ub_hi, ub_lo)
    return start, end


def _exact_over(rel, windows: jax.Array, pods: VertexPods, rec: jax.Array,
                sel: jax.Array) -> jax.Array:
    """Exact predicates over gathered records ``rec`` (Q, M) -> bool.

    Gathers vertex pods at the widest pow2 bucket among the ``sel`` lanes:
    ``lax.switch`` over the static width ladder executes exactly one
    branch, so a batch whose survivors are all points/polylines never pays
    the widest ring's gather. Unselected lanes read real (clamped,
    in-bounds) data and are masked by the caller. Shared by every exact
    stage — ``batch_query``'s three compaction paths and the dense path —
    and mirrored inside the fused kernel (which runs the same ladder over
    its VMEM-resident pod pool, per query tile)."""
    off = pods.off[rec]
    nv = pods.nv[rec]
    kd = pods.kd[rec]
    b = jnp.max(jnp.where(sel, pods.bucket[rec], 0))

    def exact_for(w, vv, nn, kk):
        return rel.predicate(w, vv, nn, kk, xp=jnp)

    def branch(width):
        def run(off, nv, kd):
            lane = jnp.minimum(jnp.arange(width, dtype=_I32),
                               nv[..., None] - 1)
            idx = jnp.clip(off[..., None] + lane, 0,
                           pods.pool.shape[0] - 1)
            return jax.vmap(exact_for)(windows, pods.pool[idx], nv, kd)
        return run

    return jax.lax.switch(
        b, [branch(1 << i) for i in range(pods.num_buckets)], off, nv, kd)


def _sqdist_over(windows: jax.Array, pods: VertexPods, rec: jax.Array,
                 sel: jax.Array) -> jax.Array:
    """Exact squared window-to-geometry distances over gathered records
    ``rec`` (Q, M) -> f32: the distance twin of ``_exact_over``. Same
    widest-surviving-bucket pod gather (one ``lax.switch`` branch executes),
    with ``geometry.rect_geom_sqdist`` in place of the boolean predicate.
    Unselected lanes read real (clamped, in-bounds) data and are masked by
    the caller."""
    off = pods.off[rec]
    nv = pods.nv[rec]
    kd = pods.kd[rec]
    b = jnp.max(jnp.where(sel, pods.bucket[rec], 0))

    def dist_for(w, vv, nn, kk):
        return geom.rect_geom_sqdist(w, vv, nn, kk, xp=jnp)

    def branch(width):
        def run(off, nv, kd):
            lane = jnp.minimum(jnp.arange(width, dtype=_I32),
                               nv[..., None] - 1)
            idx = jnp.clip(off[..., None] + lane, 0,
                           pods.pool.shape[0] - 1)
            return jax.vmap(dist_for)(windows, pods.pool[idx], nv, kd)
        return run

    return jax.lax.switch(
        b, [branch(1 << i) for i in range(pods.num_buckets)], off, nv, kd)


def _exact_refine_compacted(rel, windows: jax.Array, s: GLINSnapshot,
                            pods: VertexPods, slots: jax.Array
                            ) -> Tuple[jax.Array, jax.Array]:
    """Exact-shape stage over compacted survivor slots (Q, kb) -> (hits,
    counts). Shared by the two-stage ``batch_query`` paths and the fused
    reference composition."""
    taken = slots >= 0
    slotc = jnp.maximum(slots, 0)
    rec = jnp.where(taken, s.recs[slotc], 0)
    fmask = taken & _exact_over(rel, windows, pods, rec, taken)
    hits = jnp.where(fmask, rec, -1)
    counts = fmask.sum(axis=1).astype(_I32)
    return hits, counts


@partial(jax.jit, static_argnames=("relation", "cap", "exact_budget",
                                   "compaction"))
def batch_query(s: GLINSnapshot, windows: jax.Array, pods: VertexPods,
                mbrs: jax.Array,
                relation: str = "contains", cap: int = 4096,
                exact_budget: int = 0, compaction: str = "scan"
                ) -> Tuple[jax.Array, jax.Array]:
    """Full two-step batched query.

    Returns ``(hits, counts)`` where ``hits`` is (Q, K) int32 record ids
    (-1 padded). ``cap`` bounds candidates per query; overflow is reported
    via negative counts, never silently. On the two-stage paths a negative
    count carries the exact need: ``-(run length) - 1`` when the slot run
    outgrew ``cap`` (scan/sort window stage 1 to the cap; the magnitude
    being > cap disambiguates), else ``-(TOTAL MBR survivors) - 1`` so the
    caller can grow its ``exact_budget`` ladder straight to a sufficient
    budget (``core.exec.OverflowLadder`` — the ONE escalation policy every
    backend's refine stage shares — does). On the single-stage dense path it
    encodes the truncated hit count and only signals that the slot run
    outgrew ``cap``.

    ``exact_budget`` > 0 enables TWO-STAGE refinement (beyond-paper, §Perf):
    stage 1 evaluates only the cheap interval + leaf-MBR + record-MBR masks;
    stage 2 compacts the survivors per query and runs exact-shape checks +
    vertex gathers on at most ``exact_budget`` candidates — the expensive
    (Q·cap·W) gather shrinks to (Q·budget·W), where W is the widest pow2
    vertex bucket among the batch's survivors (``VertexPods``), not the
    store's global max width. Budget overflow is signalled like cap
    overflow. ``compaction`` picks the stage-1 implementation:

    * ``"pallas"`` — the fused ``refine_compact`` kernel: interval + leaf-MBR
      + record-MBR mask with in-VMEM prefix-sum compaction over the whole
      slot table; only (Q, budget) slot ids reach HBM, no ``cap``-sized
      intermediate exists at all (``cap`` only bounds the dense fallback).
    * ``"scan"``   — jnp reference semantics: (Q, cap) candidate window from
      the probe run, masked via the slot-aligned MBR tables, compacted with
      a stable cumsum + scatter (no sort). The CPU/interpret parity path.
    * ``"sort"``   — the legacy stable-argsort compaction over chained
      ``leaf_mbr[rec_leaf[slot]]`` / ``mbrs[recs[slot]]`` gathers (kept for
      the old-vs-new refinement benchmark).
    """
    if compaction not in ("pallas", "scan", "sort"):
        raise ValueError(f"unknown compaction {compaction!r}")
    rel = _device_relation(relation)
    start, end = batch_query_bounds(s, windows, relation)
    q = windows.shape[0]

    def exact_over(rec, sel):
        return _exact_over(rel, windows, pods, rec, sel)

    def exact_refine_compacted(slots):
        return _exact_refine_compacted(rel, windows, s, pods, slots)

    if exact_budget and exact_budget < cap:
        kb = exact_budget
        probe_w = rel.probe_window(windows, xp=jnp)
        if compaction == "pallas":
            from repro.kernels import ops

            if rel.prefilter_kind == "custom":
                raise ValueError(
                    f"relation {relation!r} has a custom MBR prefilter; the "
                    "fused kernel cannot evaluate it — use compaction='scan'")
            bounds = jnp.stack([start, end], axis=1)
            slots, mbr_counts = ops.refine_compact(
                probe_w, bounds, s.slot_lmbr, s.slot_rmbr, budget=kb,
                prefilter=rel.prefilter_kind)
            hits, counts = exact_refine_compacted(slots)
            overflow = mbr_counts > kb
            # overflow encodes the TOTAL survivor count (-(survivors) - 1),
            # so the caller can size its budget ladder in one step
            return hits, jnp.where(overflow, -mbr_counts - 1, counts)

        pos = start[:, None] + jnp.arange(cap, dtype=_I32)[None, :]
        valid = pos < jnp.minimum(end, start + cap)[:, None]
        posc = jnp.minimum(pos, s.num_slots - 1)
        if compaction == "scan":
            # no leaf-MBR gather: every record MBR lies inside its leaf's
            # aggregate MBR (grow-only maintenance), so the record prefilter
            # implies the leaf test — the streaming kernel keeps the leaf
            # stage because there it prunes for free, but a second (Q, cap,
            # 4) gather here would only re-derive a weaker mask
            rmbr = s.slot_rmbr[posc]
            rec_ok = rel.mbr_prefilter(rmbr, windows[:, None, :], xp=jnp)
            mask = valid & rec_ok
            # stable cumsum + scatter compaction (no argsort): survivor j of
            # row q lands in column (exclusive prefix of mask)[q, j]
            m32 = mask.astype(_I32)
            excl = jnp.cumsum(m32, axis=1) - m32
            col = jnp.where(mask & (excl < kb), excl, kb)
            slots = jnp.full((q, kb), -1, _I32).at[
                jnp.arange(q, dtype=_I32)[:, None], col
            ].set(posc, mode="drop")
            hits, counts = exact_refine_compacted(slots)
            surv = m32.sum(axis=1)
            runlen = end - start
            run_over = runlen > cap
            overflow = run_over | (surv > kb)
            # run overflow reports the run length (> cap, so callers can
            # tell it from a survivor count <= cap and grow the right knob)
            enc = jnp.where(run_over, runlen, surv)
            return hits, jnp.where(overflow, -enc - 1, counts)

        # "sort": legacy argsort compaction over chained gathers
        leaf = s.rec_leaf[posc]
        lmbr = s.leaf_mbr[leaf]
        leaf_ok = geom.mbr_intersects(lmbr, probe_w[:, None, :], xp=jnp)
        rec = s.recs[posc]
        rmbr = mbrs[rec]
        rec_ok = rel.mbr_prefilter(rmbr, windows[:, None, :], xp=jnp)
        mask = valid & leaf_ok & rec_ok
        order = jnp.argsort(~mask, axis=1, stable=True)[:, :kb]  # (Q, kb)
        sub_rec = jnp.take_along_axis(rec, order, axis=1)
        sub_mask = jnp.take_along_axis(mask, order, axis=1)
        fmask = sub_mask & exact_over(sub_rec, sub_mask)
        hits = jnp.where(fmask, sub_rec, -1)
        counts = fmask.sum(axis=1).astype(_I32)
        surv = mask.sum(axis=1)
        runlen = end - start
        run_over = runlen > cap
        overflow = run_over | (surv > kb)
        enc = jnp.where(run_over, runlen, surv)
        return hits, jnp.where(overflow, -enc - 1, counts)

    # single-stage dense path (exact_budget disabled or >= cap)
    pos = start[:, None] + jnp.arange(cap, dtype=_I32)[None, :]  # (Q, cap)
    valid = pos < jnp.minimum(end, start + cap)[:, None]
    posc = jnp.minimum(pos, s.num_slots - 1)
    lmbr = s.slot_lmbr[posc]                     # (Q, cap, 4)
    wq = windows[:, None, :]                     # (Q, 1, 4)
    # leaf-MBR pruning against the padded probe window (a dwithin hit's leaf
    # may not overlap the raw window); the record prefilter pads internally
    leaf_ok = geom.mbr_intersects(
        lmbr, rel.probe_window(windows, xp=jnp)[:, None, :], xp=jnp)
    rec = s.recs[posc]
    rmbr = s.slot_rmbr[posc]
    rec_ok = rel.mbr_prefilter(rmbr, wq, xp=jnp)
    mask = valid & leaf_ok & rec_ok
    mask = mask & exact_over(rec, mask)          # (Q, cap) pod gathers
    hits = jnp.where(mask, rec, -1)
    counts = mask.sum(axis=1).astype(_I32)
    overflow = (end - start) > cap
    counts = jnp.where(overflow, -counts - 1, counts)  # signal truncation
    return hits, counts


def _fused_operands(s: GLINSnapshot) -> Tuple[jax.Array, ...]:
    """Pack the snapshot's model tables into the fused kernel's VMEM-resident
    column layouts (``kernels.refine.refine_fused_pallas`` documents them).
    Empty tables (a one-leaf tree has no internal nodes; a non-augmenting
    build has no pieces) pad to one zero row so every BlockSpec stays
    non-degenerate — the kernel never reads them (depth loops self-terminate
    on a done flag; ``augment=False`` skips the piecewise search)."""
    zi = jnp.zeros((1,), _I32)
    keys = jnp.stack([s.keys_hi, s.keys_lo], axis=1)
    recs = s.recs[:, None]
    leaf_i = jnp.stack([
        s.leaf_start, s.leaf_dlo_hi, s.leaf_dlo_lo,
        jnp.concatenate([s.leaf_k0_hi, zi]),
        jnp.concatenate([s.leaf_k0_lo, zi]),
    ], axis=1)
    leaf_f = jnp.stack([
        jnp.concatenate([s.leaf_slope, jnp.zeros((1,), jnp.float32)]),
        jnp.concatenate([s.leaf_icpt, jnp.zeros((1,), jnp.float32)]),
    ], axis=1)
    if s.node_dlo_hi.shape[0]:
        node_i = jnp.stack([s.node_dlo_hi, s.node_dlo_lo, s.node_fanout,
                            s.node_child_base], axis=1)
        node_f = s.node_scale[:, None]
    else:
        node_i = jnp.zeros((1, 4), _I32)
        node_f = jnp.zeros((1, 1), jnp.float32)
    codes = (s.child_codes[:, None] if s.child_codes.shape[0]
             else jnp.zeros((1, 1), _I32))
    if s.pw_zmax_hi.shape[0]:
        pw = jnp.stack([s.pw_zmax_hi, s.pw_zmax_lo,
                        s.pw_sufmin_hi, s.pw_sufmin_lo], axis=1)
    else:
        pw = jnp.zeros((1, 4), _I32)
    return keys, recs, leaf_i, leaf_f, node_i, node_f, codes, pw


@partial(jax.jit, static_argnames=("relation", "exact_budget", "mode"))
def batch_query_fused(s: GLINSnapshot, windows: jax.Array, pods: VertexPods,
                      relation: str = "contains", exact_budget: int = 256,
                      mode: str = "reference"
                      ) -> Tuple[jax.Array, jax.Array]:
    """ONE-dispatch batched query: learned-index probe + MBR prefilter with
    in-VMEM compaction + exact-shape refinement in a single kernel launch
    (vs ``batch_query``'s probe -> compact -> exact sequence).

    ``mode`` picks the execution vehicle, all three bit-identical to
    ``batch_query(..., compaction="scan")``:

    * ``"pallas"``    — the fused Pallas kernel (TPU; auto-interpret off-TPU).
    * ``"interpret"`` — force the kernel through interpret mode (the CI
      correctness path: same kernel body, CPU execution).
    * ``"reference"`` — single-jit XLA composition of the same three stages
      (probe bounds + cumsum/searchsorted compaction + shared exact stage).
      Usable on any backend; what the CPU benchmarks time.

    Returns ``(hits (Q, budget) i32 [-1 padded], counts (Q,) i32)``. The
    fused path is CAPLESS — the prefilter mask spans the whole slot table —
    so a negative count always means budget overflow and encodes the total
    MBR-survivor count ``-(survivors) - 1``
    (``core.exec.OverflowLadder.on_fused_overflow`` sizes the retry budget
    from it in one step, no disambiguating bounds probe needed)."""
    if mode not in ("pallas", "interpret", "reference"):
        raise ValueError(f"unknown fused mode {mode!r}")
    rel = _device_relation(relation)
    if rel.prefilter_kind == "custom":
        raise ValueError(
            f"relation {relation!r} has a custom MBR prefilter; the fused "
            "path cannot evaluate it — use the staged batch_query")
    if exact_budget <= 0:
        raise ValueError("the fused path is two-stage only: exact_budget "
                         "must be > 0")
    kb = exact_budget
    probe_w = rel.probe_window(windows, xp=jnp)

    if mode in ("pallas", "interpret"):
        from repro.kernels import ops

        zmin_hi, zmin_lo, ub_hi, ub_lo = _raw_query_keys(s, windows, rel)
        qkeys = jnp.stack([zmin_hi, zmin_lo, ub_hi, ub_lo], axis=1)
        pod_i = jnp.stack([pods.off, pods.nv, pods.kd, pods.bucket], axis=1)
        return ops.refine_fused(
            windows, probe_w, qkeys, *_fused_operands(s), pod_i, pods.pool,
            s.slot_lmbr, s.slot_rmbr, budget=kb,
            prefilter=rel.prefilter_kind,
            predicate=lambda w, vv, nn, kk: rel.predicate(w, vv, nn, kk,
                                                          xp=jnp),
            augment=bool(rel.augment) and s.pw_zmax_hi.shape[0] > 0,
            search_steps=s.search_steps, depth=s.depth,
            num_buckets=pods.num_buckets,
            interpret=True if mode == "interpret" else None)

    # "reference": the same probe + capless mask + (Q, kb) compaction +
    # exact stage as one XLA program. Compaction is cumsum + per-row
    # searchsorted for the k-th survivor position — no (Q, N) scatter, which
    # is what makes this composition beat the (Q, cap)-windowed scan path
    # on CPU as well
    start, end = batch_query_bounds(s, windows, relation)
    n = s.num_slots
    slot = jnp.arange(n, dtype=_I32)[None, :]
    in_run = (slot >= start[:, None]) & (slot < end[:, None])
    leaf_ok = geom.mbr_intersects(s.slot_lmbr[None, :, :],
                                  probe_w[:, None, :], xp=jnp)
    if rel.prefilter_kind == "contains":
        rec_ok = geom.mbr_contains(s.slot_rmbr[None, :, :],
                                   probe_w[:, None, :], xp=jnp)
    else:
        rec_ok = geom.mbr_intersects(s.slot_rmbr[None, :, :],
                                     probe_w[:, None, :], xp=jnp)
    mask = in_run & leaf_ok & rec_ok
    m32 = mask.astype(_I32)
    cum = jnp.cumsum(m32, axis=1)
    mbr_counts = cum[:, -1]
    kth = jnp.arange(1, kb + 1, dtype=_I32)
    pos = jax.vmap(
        lambda c: jnp.searchsorted(c, kth, side="left"))(cum)
    slots = jnp.where(pos < n, pos.astype(_I32), -1)
    hits, counts = _exact_refine_compacted(rel, windows, s, pods, slots)
    return hits, jnp.where(mbr_counts > kb, -mbr_counts - 1, counts)


# ---------------------------------------------------------------------------
# Delta side table: device-resident secondary index over the added set
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeltaTable:
    """Small device-resident secondary index over the added-set delta (the
    records inserted since the last snapshot publish), sorted by Zmin key.

    ``SpatialIndex`` builds one lazily per mutation epoch so ``device+delta``
    queries stop round-tripping to the host per batch: the added-set check
    becomes one vectorized (Q × A) z-interval + MBR + exact-predicate pass on
    device (``batch_check_added``). Rows are padded to a size bucket with
    inert entries (``ids == -1``, +inf keys, far-away MBRs) so the jitted
    check compiles once per bucket, not once per insert."""

    ids: jax.Array       # (A,) int32 record ids (-1 = padding), Zmin-sorted
    zmin_hi: jax.Array   # (A,) int32 z-interval lower key
    zmin_lo: jax.Array   # (A,) int32
    zmax_hi: jax.Array   # (A,) int32 z-interval upper key
    zmax_lo: jax.Array   # (A,) int32
    mbrs: jax.Array      # (A, 4) float32
    pool: jax.Array      # (P, 2) float32 CSR vertex pool over the added set
    off: jax.Array       # (A,) int32 ring starts (inert rows -> sentinel)
    nverts: jax.Array    # (A,) int32
    kinds: jax.Array     # (A,) int32
    # static pow2 ceiling of the added set's widths (dense-gather width)
    max_width: int = dataclasses.field(metadata=dict(static=True))

    @property
    def size(self) -> int:
        return self.ids.shape[0]


def delta_table_from_host(glin, added_ids, pad_to: int = 0) -> DeltaTable:
    """Build the added-set side table from the host index (one upload per
    publish epoch). ``added_ids`` is any iterable of record ids; rows are
    sorted by Zmin and padded to ``pad_to`` with inert entries."""
    ids = np.asarray(sorted(added_ids), np.int64)
    zmin = glin.zmin[ids] if ids.shape[0] else np.empty(0, np.int64)
    zmax = glin.zmax[ids] if ids.shape[0] else np.empty(0, np.int64)
    order = np.argsort(zmin, kind="stable")
    ids, zmin, zmax = ids[order], zmin[order], zmax[order]
    gs = glin.gs
    a = ids.shape[0]
    m = max(a, int(pad_to))
    pad = m - a
    zmin_hi, zmin_lo = split_hilo_np(zmin)
    zmax_hi, zmax_lo = split_hilo_np(zmax)
    out_ids = np.full(m, -1, np.int32)
    out_ids[:a] = ids
    mbrs = np.full((m, 4), 2e30, np.float32)      # intersects nothing
    nverts = np.ones(m, np.int32)
    kinds = np.zeros(m, np.int32)
    # CSR ring pool over the added set, with one far-away sentinel vertex
    # that every inert pad row points at (intersects nothing, dwithin fails)
    counts = gs.nverts[ids].astype(np.int64) if a else np.empty(0, np.int64)
    off = np.zeros(m, np.int32)
    # pow2-bucket the pool axis (with the row padding, the table's whole
    # shape signature), so the jitted added-set check compiles once per
    # bucket — NOT once per insert as the pool creeps one ring at a time
    total = int(counts.sum())
    pool_cap = 1 << max(6, total.bit_length())
    pool = np.full((pool_cap, 2), 2e30, np.float32)
    if a:
        starts = np.zeros(a, np.int64)
        np.cumsum(counts[:-1], out=starts[1:])
        pos = np.arange(total) - np.repeat(starts, counts)
        src = gs.offsets[ids]
        pool[:total] = gs.pool[np.repeat(src, counts) + pos]
        off[:a] = starts
        off[a:] = pool.shape[0] - 1               # the sentinel row
        mbrs[:a] = gs.mbrs[ids]
        nverts[:a] = gs.nverts[ids]
        kinds[:a] = gs.kinds[ids]
    else:
        off[:] = pool.shape[0] - 1
    max_width = _pow2ceil(int(counts.max()) if a else 1)

    def _padk(x, fill):
        return jnp.asarray(np.concatenate([x, np.full(pad, fill, np.int32)]))

    return DeltaTable(
        ids=jnp.asarray(out_ids),
        zmin_hi=_padk(zmin_hi, _INF_HI), zmin_lo=_padk(zmin_lo, 0),
        zmax_hi=_padk(zmax_hi, _INF_HI), zmax_lo=_padk(zmax_lo, 0),
        mbrs=jnp.asarray(mbrs), pool=jnp.asarray(pool),
        off=jnp.asarray(off), nverts=jnp.asarray(nverts),
        kinds=jnp.asarray(kinds), max_width=max_width)


@partial(jax.jit, static_argnames=("relation", "grid_x0", "grid_y0",
                                   "grid_cell"))
def batch_check_added(t: DeltaTable, windows: jax.Array, relation: str,
                      grid_x0: float, grid_y0: float, grid_cell: float
                      ) -> jax.Array:
    """Windows (Q,4) f32 × added-set table -> (Q, A) bool hit matrix.

    The z-interval prune mirrors the index mechanism: a window and a record
    whose MBRs intersect always have overlapping z-intervals (any shared
    cell's code lies inside both corner-code intervals), so pruning on
    ``[zmin_g, zmax_g] ∩ [zmin_q, zmax_q] != ∅`` never loses a hit and needs
    no piecewise augmentation over the (unpublished) added set."""
    from .zorder import ZGrid

    rel = _device_relation(relation)
    grid = ZGrid(grid_x0, grid_y0, grid_cell)
    probe = rel.probe_window(windows, xp=jnp)
    (qmin_hi, qmin_lo), (qmax_hi, qmax_lo) = mbr_to_zinterval_hilo(
        probe, grid, guard=ZGrid.FP32_GUARD_CELLS)
    lo_ok = ~z_less_hilo(t.zmax_hi[None, :], t.zmax_lo[None, :],
                         qmin_hi[:, None], qmin_lo[:, None])
    hi_ok = ~z_less_hilo(qmax_hi[:, None], qmax_lo[:, None],
                         t.zmin_hi[None, :], t.zmin_lo[None, :])
    cand = lo_ok & hi_ok & (t.ids[None, :] >= 0)
    pre = rel.mbr_prefilter(t.mbrs[None, :, :], windows[:, None, :], xp=jnp)

    # one dense ragged-view materialization of the (small) added set, shared
    # by every query row; inert pads read the far-away sentinel vertex
    verts = geom.ragged_padded(t.pool, t.off, t.nverts, t.max_width, xp=jnp)

    def exact_for(w):
        return rel.predicate(w, verts, t.nverts, t.kinds, xp=jnp)

    exact = jax.vmap(exact_for)(windows)
    return cand & pre & exact


# ---------------------------------------------------------------------------
# Device-complete kNN: CDF-seeded radii + exact-distance top-k ranking
# ---------------------------------------------------------------------------
_ID_PAD = np.int32(2**31 - 1)     # sorts after every real record id


@jax.jit
def knn_seed_radii(s: GLINSnapshot, windows: jax.Array, k: jax.Array
                   ) -> jax.Array:
    """CDF-seeded initial kNN radii: degenerate windows (Q, 4) -> (Q,) f32.

    The published learned index doubles as a density estimate (cf. "Spatial
    Interpolation-based Learned Index", PAPERS.md 2102.06789): each query
    point routes through the model to its leaf (``_find_leaf``); the leaf's
    record count over its aggregate-MBR area is the local intensity rho, and
    the expected k-th-neighbour distance of a planar process of intensity
    rho is ``sqrt(k / (pi * rho))``, offset by the point's distance to the
    leaf's aggregate MBR — a point routed to a leaf it doesn't touch (empty
    space between clusters) must at least REACH the data before density
    matters, so the gap keeps it from crawling the doubling ladder across
    the void. This is an ESTIMATE only — the growth ladder above it is the
    correctness backstop (an under-estimate costs extra rungs, never hits);
    the settlement test is always the exact within-radius count from
    :func:`batch_knn_rank`."""
    from .zorder import ZGrid

    grid = ZGrid(s.grid_x0, s.grid_y0, s.grid_cell)
    (zmin_hi, zmin_lo), _ = mbr_to_zinterval_hilo(
        windows, grid, guard=ZGrid.FP32_GUARD_CELLS)
    leaf = _find_leaf(s, zmin_hi, zmin_lo)
    count = (s.leaf_start[leaf + 1] - s.leaf_start[leaf]).astype(jnp.float32)
    m = s.leaf_mbr[leaf]
    area = jnp.maximum((m[:, 2] - m[:, 0]) * (m[:, 3] - m[:, 1]),
                       jnp.float32(1e-12))
    rho = jnp.maximum(count, 1.0) / area
    gx = jnp.maximum(jnp.maximum(m[:, 0] - windows[:, 0],
                                 windows[:, 0] - m[:, 2]), 0.0)
    gy = jnp.maximum(jnp.maximum(m[:, 1] - windows[:, 1],
                                 windows[:, 1] - m[:, 3]), 0.0)
    gap = jnp.sqrt(gx * gx + gy * gy)
    return gap + jnp.sqrt(k.astype(jnp.float32) / (jnp.float32(math.pi) * rho))


@partial(jax.jit, static_argnames=("k", "impl"))
def batch_knn_rank(windows: jax.Array, pods: VertexPods, hits: jax.Array,
                   radius: jax.Array, k: int, impl: str = "sort",
                   tombstones=None, delta=None
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Device top-k over dwithin survivors: (Q, B) hit ids -> ((Q, k) ids,
    (Q, k) distances, (Q,) within-radius candidate counts).

    ``hits`` is the refine stage's -1-padded id matrix; exact distances come
    from ONE widest-surviving-bucket pod gather (``_sqdist_over``), so the
    candidate set never leaves the device — only the (Q, k) result does.

    Ordering is the shared ``geometry.rank_knn`` (distance, id) contract.
    The selection sorts SQUARED distances (monotonic in the distance, and
    one rounding step more precise): ``impl="sort"`` is the XLA reference —
    a two-key ``lax.sort`` over ``[d2, ids]`` (plain ``lax.top_k`` cannot
    tie-break on ids); ``impl="pallas"`` routes the same selection through
    the ``kernels.refine.knn_topk_pallas`` partial-sort kernel (TPU target,
    interpret elsewhere; worthwhile once B is large), identical ordering.

    ``radius`` ((Q,) f32) is each point's OWN probe radius this rung — the
    caller probes every still-undone point in one dispatch at per-point
    inflated square windows, so the radius is per-row, not per-batch. The
    returned count is |{candidates with d2 <= radius^2}| over snapshot AND
    delta rows — compared in squared form, exactly the dwithin predicate's
    test, so the ladder's settlement rule (done once count >= k: dwithin
    candidacy is exact, no closer record can be missing) never over-counts.

    ``tombstones`` (T,) i32 masks deleted-but-published ids out of the
    ranking; ``delta`` (a :class:`DeltaTable`) merges the unpublished added
    set by exact distance before the top-k, so ``device+delta`` kNN ranks
    inserted records without a republish (added ids postdate snapshot ids —
    the two id sets never collide)."""
    q = windows.shape[0]
    inf = jnp.float32(jnp.inf)
    valid = hits >= 0
    rec = jnp.maximum(hits, 0)
    d2 = _sqdist_over(windows, pods, rec, valid)
    d2 = jnp.where(valid, d2, inf)
    ids = jnp.where(valid, hits, _ID_PAD)
    if tombstones is not None and tombstones.shape[0]:
        dead = (hits[:, :, None] == tombstones[None, None, :]).any(axis=2)
        d2 = jnp.where(dead, inf, d2)
        ids = jnp.where(dead, _ID_PAD, ids)
    if delta is not None:
        verts = geom.ragged_padded(delta.pool, delta.off, delta.nverts,
                                   delta.max_width, xp=jnp)
        ad2 = jax.vmap(lambda w: geom.rect_geom_sqdist(
            w, verts, delta.nverts, delta.kinds, xp=jnp))(windows)
        live = delta.ids[None, :] >= 0
        ad2 = jnp.where(live, ad2, inf)
        aid = jnp.where(live, delta.ids[None, :], _ID_PAD)
        d2 = jnp.concatenate([d2, ad2], axis=1)
        ids = jnp.concatenate(
            [ids, jnp.broadcast_to(aid, (q, delta.size))], axis=1)
    counts = (d2 <= (radius * radius)[:, None]).sum(axis=1).astype(_I32)
    if d2.shape[1] < k:                    # k > budget(+delta): pad columns
        padw = k - d2.shape[1]
        d2 = jnp.concatenate([d2, jnp.full((q, padw), inf)], axis=1)
        ids = jnp.concatenate([ids, jnp.full((q, padw), _ID_PAD, _I32)],
                              axis=1)
    if impl == "pallas":
        from repro.kernels import ops

        d2k, idk = ops.knn_topk(d2, ids, k=k)
    else:
        d2s, idss = jax.lax.sort([d2, ids], num_keys=2)
        d2k, idk = d2s[:, :k], idss[:, :k]
    dk = jnp.sqrt(jnp.maximum(d2k, 0.0))
    idk = jnp.where(jnp.isinf(d2k), -1, idk)
    return idk, dk, counts


def input_specs_like(num_queries: int):
    """ShapeDtypeStruct stand-ins for a query batch (dry-run use)."""
    return {
        "windows": jax.ShapeDtypeStruct((num_queries, 4), jnp.float32),
    }
