"""First-class spatial relations for the GLIN query engine.

The paper's central claim (§VI, §VIII) is that ONE interval-probe mechanism
answers many spatial relationships exactly, provided each relation brings two
things: an *exact predicate* for the refinement step and a *window-augmentation
rule* for the probe key. This module makes that pairing explicit: a
:class:`Relation` bundles

* ``predicate``      — the exact-shape check (array-namespace generic, so the
  same rule runs on the fp64 host path and the fp32 jitted device path);
* ``augment``        — whether the probe key ``Zmin_Q`` must be lowered by the
  piecewise function (Alg 2 / Lemma 2). Relations whose hits can have
  ``Zmin_GM < Zmin_Q`` (anything that admits geometries *overlapping* the
  window) need it; relations whose hits start inside the window do not;
* ``mbr_prefilter``  — a conservative record-MBR test (never drops a true hit)
  used by both the host refinement loop and the batched device kernel;
* ``probe_pad``      — margin added to every window side before the probe and
  the leaf-MBR pruning (``dwithin`` hits can lie entirely outside the window,
  up to the query distance away; the L∞ expansion is a conservative superset
  of the Euclidean dilation, so probing stays lossless);
* ``device_native``  — whether the batched device path evaluates it directly;
* ``complement_of``  — relations answered as the complement of another
  (``disjoint`` = live records minus ``intersects``); these are host-finished;
* ``parametric``/``bind`` — template relations instantiated per parameter by
  name (``dwithin:0.05``); bound relations are cached by their full name.

Every query layer — host ``GLIN.query``, the jitted ``core.device`` batch
path, the sharded ``core.distributed`` step, the baselines' refinement, and
the ``SpatialIndex`` facade — dispatches through this registry, so adding a
relation is one ``register_relation`` call, not five string branches.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from . import geometry as geom

__all__ = ["Relation", "RELATIONS", "register_relation", "get_relation",
           "relation_names", "check_registry"]

# predicate(window(4,), verts(N,V,2), nverts(N,), kinds(N,), xp) -> (N,) bool
Predicate = Callable[..., np.ndarray]
# prefilter(rec_mbr(...,4), window(...,4), xp) -> bool mask (broadcasting)
MbrPrefilter = Callable[..., np.ndarray]


def _pad_window(window, pad: float, xp=np):
    """Window expanded by ``pad`` on every side (L∞ dilation). The single
    source of the expansion used by probing, leaf pruning and the dwithin
    MBR prefilter."""
    if not pad:
        return window
    delta = xp.asarray([-pad, -pad, pad, pad], dtype=window.dtype)
    return window + delta


@dataclasses.dataclass(frozen=True)
class Relation:
    """A spatial relationship between a rectangular query window and the
    stored geometries, with everything the probe + refine pipeline needs."""

    name: str
    predicate: Predicate
    augment: bool                 # probe key needs piecewise augmentation
    mbr_prefilter: MbrPrefilter
    device_native: bool = True    # batched device path evaluates it directly
    complement_of: Optional[str] = None
    probe_pad: float = 0.0        # widen the probe / leaf-prune window
    prefilter_kind: str = "intersects"  # static shape of mbr_prefilter for
                                  # fused kernels: "intersects" (record MBR
                                  # meets the PROBE window — covers dwithin,
                                  # whose prefilter pads by the same amount),
                                  # "contains" (record MBR covers the raw
                                  # window, e.g. within), or "custom"
                                  # (kernel unusable; jnp prefilter only)
    parametric: bool = False      # template: requires "name:<param>" lookup
    bind: Optional[Callable[[float, str], "Relation"]] = None
    doc: str = ""

    def base_name(self) -> str:
        """Relation whose candidate interval is actually probed."""
        return self.complement_of if self.complement_of else self.name

    @property
    def is_complement(self) -> bool:
        """True when hits are ``live \\ base`` — the execution pipeline
        queries :meth:`base_name` and the shared complement-finish stage
        subtracts the base hits from the frozen live-id set."""
        return self.complement_of is not None

    def probe_window(self, window, xp=np):
        """The window used for probing and MBR-level pruning: the query
        window itself, expanded by ``probe_pad`` on every side for relations
        whose hits may lie outside it. ``probe_pad`` is a trace-time
        constant, so jitted callers fold the expansion away when zero."""
        return _pad_window(window, self.probe_pad, xp=xp)


RELATIONS: Dict[str, Relation] = {}
_BOUND: Dict[str, Relation] = {}   # "name:param" -> bound Relation cache


def register_relation(rel: Relation, replace: bool = False) -> Relation:
    """Add ``rel`` to the registry. Duplicate names raise (a silent overwrite
    would re-route every query layer at a distance) unless ``replace=True``
    is passed explicitly."""
    if rel.name in RELATIONS and not replace:
        raise ValueError(
            f"relation {rel.name!r} is already registered; pass replace=True "
            "to overwrite it deliberately")
    if rel.complement_of is not None:
        base = RELATIONS.get(rel.complement_of)
        if base is None:
            raise ValueError(f"complement_of {rel.complement_of!r} is unknown "
                             "(register the base relation first)")
        if base.complement_of is not None:
            raise ValueError(
                f"complement_of {rel.complement_of!r} is itself a complement; "
                "chain complements are not supported")
    if rel.parametric and rel.bind is None:
        raise ValueError(f"parametric relation {rel.name!r} needs a bind "
                         "factory")
    RELATIONS[rel.name] = rel
    _BOUND.clear()   # bound relations may shadow a replaced template
    return rel


def get_relation(name: str) -> Relation:
    rel = RELATIONS.get(name) or _BOUND.get(name)
    if rel is None and ":" in name:
        base, _, arg = name.partition(":")
        tmpl = RELATIONS.get(base)
        if tmpl is not None and tmpl.parametric:
            try:
                param = float(arg)
            except ValueError:
                raise ValueError(
                    f"bad parameter {arg!r} in relation {name!r}") from None
            rel = _BOUND.setdefault(name, tmpl.bind(param, name))
    if rel is None:
        raise ValueError(
            f"unknown relation {name!r}; registered: {sorted(RELATIONS)}")
    if rel.parametric:
        raise ValueError(
            f"relation {name!r} requires a parameter: query it as "
            f"'{name}:<value>' (e.g. '{name}:0.05')")
    return rel


def relation_names(device_native: Optional[bool] = None) -> Tuple[str, ...]:
    names = (n for n, r in RELATIONS.items()
             if device_native is None or r.device_native == device_native)
    return tuple(sorted(names))


def check_registry() -> Tuple[str, ...]:
    """Validate registry invariants (used by the self-check test and safe to
    call at any time): complements resolve to registered, non-complement,
    device-native bases; parametric templates carry a bind factory; bound
    cache entries agree with their template family. Returns the names."""
    for name, rel in RELATIONS.items():
        if rel.name != name:
            raise AssertionError(f"registry key {name!r} != Relation.name "
                                 f"{rel.name!r}")
        if rel.complement_of is not None:
            base = RELATIONS.get(rel.complement_of)
            if base is None:
                raise AssertionError(f"{name!r}: complement base "
                                     f"{rel.complement_of!r} not registered")
            if base.complement_of is not None:
                raise AssertionError(f"{name!r}: complement of a complement")
            # (a host-only base is fine: the planner routes such relations
            # to the host backend)
        if rel.parametric and rel.bind is None:
            raise AssertionError(f"{name!r}: parametric without bind")
        if rel.probe_pad < 0:
            raise AssertionError(f"{name!r}: negative probe_pad")
        if rel.prefilter_kind not in ("intersects", "contains", "custom"):
            raise AssertionError(f"{name!r}: unknown prefilter_kind "
                                 f"{rel.prefilter_kind!r}")
    for name, rel in _BOUND.items():
        family = name.partition(":")[0]
        if family not in RELATIONS or not RELATIONS[family].parametric:
            raise AssertionError(f"bound relation {name!r} has no parametric "
                                 "template")
        if rel.parametric:
            raise AssertionError(f"bound relation {name!r} is still "
                                 "parametric")
    return relation_names()


# ---------------------------------------------------------------------------
# Built-in relations. Window W is the query rectangle, G a stored geometry.
# ---------------------------------------------------------------------------
def _pf_intersects(rec_mbr, window, xp=np):
    return geom.mbr_intersects(rec_mbr, window, xp=xp)


def _pf_rec_mbr_covers_window(rec_mbr, window, xp=np):
    return geom.mbr_contains(rec_mbr, window, xp=xp)


register_relation(Relation(
    name="intersects",
    predicate=geom.rect_intersects_geoms,
    augment=True,   # hits may start before W: Zmin_GM < Zmin_Q (Lemma 2)
    mbr_prefilter=_pf_intersects,
    doc="W and G share at least one point (the paper's Intersects).",
))

register_relation(Relation(
    name="contains",
    predicate=geom.rect_contains_geoms_proper,
    augment=False,  # MBR(G) inside W implies Zmin_GM in [Zmin_Q, Zmax_Q]
    mbr_prefilter=_pf_intersects,
    doc="G lies in W and touches W's interior (GEOS-style proper Contains).",
))

register_relation(Relation(
    name="covers",
    predicate=lambda rect, verts, nverts, kinds, xp=np:
        geom.rect_covers_geoms(rect, verts, nverts, xp=xp),
    augment=False,
    mbr_prefilter=_pf_intersects,
    doc="Every point of G lies in closed W (boundary-inclusive Contains; "
        "the paper's closed-window Contains).",
))

register_relation(Relation(
    name="within",
    predicate=geom.geoms_cover_rect,
    augment=True,   # covering geometries start before W: Zmin_GM <= Zmin_Q
    mbr_prefilter=_pf_rec_mbr_covers_window,
    prefilter_kind="contains",
    doc="W lies entirely inside G (window within geometry; exact for simple "
        "polygons, convex or concave).",
))

register_relation(Relation(
    name="disjoint",
    predicate=geom.rect_disjoint_geoms,
    augment=False,
    mbr_prefilter=_pf_intersects,   # prefilter of the base relation
    device_native=False,
    complement_of="intersects",
    doc="W and G share no point: complement of Intersects over live records.",
))

register_relation(Relation(
    name="touches",
    predicate=geom.rect_touches_geoms,
    augment=True,   # touching geometries overlap W's boundary: Zmin may precede
    mbr_prefilter=_pf_intersects,
    doc="W and G share points but their interiors are disjoint (DE-9IM "
        "Touches: boundary contact only).",
))

register_relation(Relation(
    name="crosses",
    predicate=geom.rect_crosses_geoms,
    augment=True,
    mbr_prefilter=_pf_intersects,
    doc="G's interior passes through W's interior and exits W (DE-9IM "
        "Crosses; polylines only — area/area crosses is undefined and "
        "returns False for polygons).",
))


def _bind_dwithin(dist: float, name: str) -> Relation:
    """Instantiate ``dwithin:<d>``: Euclidean distance(W, G) <= d."""
    if not (math.isfinite(dist) and dist >= 0.0):
        raise ValueError(
            f"dwithin distance must be finite and >= 0, got {dist!r}")

    def pred(rect, verts, nverts, kinds, xp=np):
        return geom.rect_dwithin_geoms(rect, verts, nverts, kinds, dist,
                                       xp=xp)

    def prefilter(rec_mbr, window, xp=np):
        return geom.mbr_intersects(rec_mbr, _pad_window(window, dist, xp=xp),
                                   xp=xp)

    return dataclasses.replace(
        RELATIONS["dwithin"], name=name, predicate=pred,
        mbr_prefilter=prefilter, probe_pad=dist, parametric=False, bind=None,
        doc=f"Euclidean distance between W and G is at most {dist!r} "
            "(distance-buffered Intersects).")


register_relation(Relation(
    name="dwithin",
    predicate=lambda rect, verts, nverts, kinds, xp=np:
        geom.rect_dwithin_geoms(rect, verts, nverts, kinds, 0.0, xp=xp),
    augment=True,   # buffered hits may start before the expanded window
    mbr_prefilter=_pf_intersects,
    parametric=True,
    bind=_bind_dwithin,
    doc="Euclidean distance between W and G is at most d; parametric — "
        "query as 'dwithin:<d>' (the ROADMAP's knn-radius relation).",
))
