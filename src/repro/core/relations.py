"""First-class spatial relations for the GLIN query engine.

The paper's central claim (§VI, §VIII) is that ONE interval-probe mechanism
answers many spatial relationships exactly, provided each relation brings two
things: an *exact predicate* for the refinement step and a *window-augmentation
rule* for the probe key. This module makes that pairing explicit: a
:class:`Relation` bundles

* ``predicate``      — the exact-shape check (array-namespace generic, so the
  same rule runs on the fp64 host path and the fp32 jitted device path);
* ``augment``        — whether the probe key ``Zmin_Q`` must be lowered by the
  piecewise function (Alg 2 / Lemma 2). Relations whose hits can have
  ``Zmin_GM < Zmin_Q`` (anything that admits geometries *overlapping* the
  window) need it; relations whose hits start inside the window do not;
* ``mbr_prefilter``  — a conservative record-MBR test (never drops a true hit)
  used by both the host refinement loop and the batched device kernel;
* ``device_native``  — whether the batched device path evaluates it directly;
* ``complement_of``  — relations answered as the complement of another
  (``disjoint`` = live records minus ``intersects``); these are host-finished.

Every query layer — host ``GLIN.query``, the jitted ``core.device`` batch
path, the sharded ``core.distributed`` step, the baselines' refinement, and
the ``SpatialIndex`` facade — dispatches through this registry, so adding a
relation is one ``register_relation`` call, not five string branches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from . import geometry as geom

__all__ = ["Relation", "RELATIONS", "register_relation", "get_relation",
           "relation_names"]

# predicate(window(4,), verts(N,V,2), nverts(N,), kinds(N,), xp) -> (N,) bool
Predicate = Callable[..., np.ndarray]
# prefilter(rec_mbr(...,4), window(...,4), xp) -> bool mask (broadcasting)
MbrPrefilter = Callable[..., np.ndarray]


@dataclasses.dataclass(frozen=True)
class Relation:
    """A spatial relationship between a rectangular query window and the
    stored geometries, with everything the probe + refine pipeline needs."""

    name: str
    predicate: Predicate
    augment: bool                 # probe key needs piecewise augmentation
    mbr_prefilter: MbrPrefilter
    device_native: bool = True    # batched device path evaluates it directly
    complement_of: Optional[str] = None
    doc: str = ""

    def base_name(self) -> str:
        """Relation whose candidate interval is actually probed."""
        return self.complement_of if self.complement_of else self.name


RELATIONS: Dict[str, Relation] = {}


def register_relation(rel: Relation) -> Relation:
    if rel.complement_of is not None and rel.complement_of not in RELATIONS:
        raise ValueError(f"complement_of {rel.complement_of!r} is unknown")
    RELATIONS[rel.name] = rel
    return rel


def get_relation(name: str) -> Relation:
    try:
        return RELATIONS[name]
    except KeyError:
        raise ValueError(
            f"unknown relation {name!r}; registered: {sorted(RELATIONS)}"
        ) from None


def relation_names(device_native: Optional[bool] = None) -> Tuple[str, ...]:
    names = (n for n, r in RELATIONS.items()
             if device_native is None or r.device_native == device_native)
    return tuple(sorted(names))


# ---------------------------------------------------------------------------
# Built-in relations. Window W is the query rectangle, G a stored geometry.
# ---------------------------------------------------------------------------
def _pf_intersects(rec_mbr, window, xp=np):
    return geom.mbr_intersects(rec_mbr, window, xp=xp)


def _pf_rec_mbr_covers_window(rec_mbr, window, xp=np):
    return geom.mbr_contains(rec_mbr, window, xp=xp)


register_relation(Relation(
    name="intersects",
    predicate=geom.rect_intersects_geoms,
    augment=True,   # hits may start before W: Zmin_GM < Zmin_Q (Lemma 2)
    mbr_prefilter=_pf_intersects,
    doc="W and G share at least one point (the paper's Intersects).",
))

register_relation(Relation(
    name="contains",
    predicate=geom.rect_contains_geoms_proper,
    augment=False,  # MBR(G) inside W implies Zmin_GM in [Zmin_Q, Zmax_Q]
    mbr_prefilter=_pf_intersects,
    doc="G lies in W and touches W's interior (GEOS-style proper Contains).",
))

register_relation(Relation(
    name="covers",
    predicate=lambda rect, verts, nverts, kinds, xp=np:
        geom.rect_covers_geoms(rect, verts, nverts, xp=xp),
    augment=False,
    mbr_prefilter=_pf_intersects,
    doc="Every point of G lies in closed W (boundary-inclusive Contains; "
        "the paper's closed-window Contains).",
))

register_relation(Relation(
    name="within",
    predicate=geom.geoms_cover_rect,
    augment=True,   # covering geometries start before W: Zmin_GM <= Zmin_Q
    mbr_prefilter=_pf_rec_mbr_covers_window,
    doc="W lies entirely inside G (window within geometry).",
))

register_relation(Relation(
    name="disjoint",
    predicate=geom.rect_disjoint_geoms,
    augment=False,
    mbr_prefilter=_pf_intersects,   # prefilter of the base relation
    device_native=False,
    complement_of="intersects",
    doc="W and G share no point: complement of Intersects over live records.",
))
