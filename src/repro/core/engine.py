"""`SpatialIndex` — the one public way to build, mutate, snapshot and query.

The paper's mechanism (probe an interval, refine with a predicate) is the same
whether one window runs on the host or ten thousand run on a TPU; what differed
in this repo was plumbing: the mutable host ``GLIN`` answered one window at a
time while callers hand-stitched ``snapshot_from_host`` + ``batch_query`` for
the device path. This facade owns all of it:

* **relations** are first-class (``core.relations``): ``contains``,
  ``intersects``, ``within``, ``covers``, ``disjoint`` — plus ``knn`` as a
  query *kind* — all through one entry point, ``SpatialIndex.query``;
* **snapshots are epoch-invalidated**: every insert/delete bumps a mutation
  epoch; the flattened device snapshot is materialized lazily and republished
  automatically when stale, so a stale snapshot is never served;
* **writes are LSM-style deltas** (DESIGN.md §2): ALEX-style in-place mutation
  does not map onto immutable device arrays — per-record scatter into a sorted
  device array is O(N). Instead every insert/delete is applied to the host
  ``GLIN`` immediately (host queries are always exact) and recorded in a small
  delta against the last *published* snapshot: inserted record ids in an
  added-set, deleted published records in a tombstone-set. Device queries can
  then be served from the stale snapshot and *patched* — tombstones masked
  out, added records brute-force checked (the delta is tiny, a vectorized
  fp32 mask) — instead of paying a full republish per write. Once the delta
  grows past ``EngineConfig.refresh_threshold`` the snapshot is republished
  (bulk re-flatten, a few ms of vectorized work, amortized O(1)/update);
* **execution is planned, then staged**: ``plan(batch)`` picks a backend
  (host loop for small or stats-collecting batches and knn; jitted device
  ``batch_query`` for large batches against a fresh or republished
  snapshot; ``device+delta`` for a stale snapshot with a small delta;
  ``sharded`` when a mesh is active) and ``core.exec.compile_plan`` turns
  the choice into an :class:`~repro.core.exec.ExecutionPlan` — an ordered
  stage composition (refine -> delta-patch -> complement-finish) with ONE
  shared overflow-ladder/patch/complement implementation across backends
  and per-stage telemetry on every result (``QueryResult.stages``,
  ``stats()["stages"]``, :meth:`SpatialIndex.explain`); the adaptive
  candidate ``cap`` is shared by all device modes, and
  ``count_candidates`` routes through the Pallas refine kernel on TPU;
* **precision**: host execution refines in fp64; device execution refines in
  fp32 (results can differ at exact window boundaries, by design — the probe
  interval is quantized conservatively so hits are never missed, see
  ``core.device``).

Typical use::

    from repro.core import SpatialIndex, QueryBatch, generate, make_query_windows

    index = SpatialIndex.build(generate("cluster", 100_000))
    res = index.query(make_query_windows(index.gs, 1e-3, 256), "intersects")
    ids0 = res[0]                       # hits of window 0, ascending record id
    nn = index.query(QueryBatch.knn([[0.5, 0.5]], k=10))
    rec = index.insert(verts, nverts=8, kind=0)   # bumps the epoch
    res = index.query(windows, "contains")        # snapshot auto-rebuilt
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Dict, Iterator, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from . import exec as qexec
from .datasets import GeometrySet
# batch_query is re-exported for the exec stages (and tests), which resolve
# it through THIS module's namespace so a monkeypatched binding is honored
from .device import batch_query, batch_query_fused  # noqa: F401
from .device import (DeltaTable, GLINSnapshot, HostCapture, _pow2ceil,
                     batch_query_bounds, delta_table_from_host,
                     pods_from_store, snapshot_capture, snapshot_from_capture)
from .index import GLIN, GLINConfig, QueryStats
from .relations import get_relation

__all__ = ["EngineConfig", "QueryBatch", "QueryPlan", "QueryResult",
           "SpatialIndex"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Planner / execution knobs for :class:`SpatialIndex`."""

    device_min_batch: int = 16        # smaller window batches run on host
    stale_rebuild_min_batch: int = 64  # stale + unpatchable: republish only
                                       # for batches this big, else host
    initial_cap: int = 4096           # device candidate capacity per query
    max_cap: int = 1 << 20            # give up (OverflowError) past this
    exact_budget: int = 256           # two-stage refinement budget (0 = off):
                                      # stage 1 masks + compacts, stage 2
                                      # exact-checks at most this many
                                      # candidates per query
    compaction: Optional[str] = None  # stage-1 impl: "pallas" (fused kernel),
                                      # "scan" (jnp reference), "sort"
                                      # (legacy argsort); None = pallas on
                                      # TPU, scan elsewhere
    fusion: Optional[str] = None      # one-kernel probe+compact+refine:
                                      # "pallas" (fused kernel; interpret
                                      # off-TPU), "interpret" (force the
                                      # kernel through interpret mode — CI
                                      # correctness), "reference" (single-jit
                                      # XLA composition, any backend), "off";
                                      # None = auto: pallas on TPU, off
                                      # elsewhere. Custom-prefilter relations,
                                      # budgets outside (0, MAX_COMPACT_
                                      # BUDGET] and stores past the kernel's
                                      # VMEM envelope fall back to the staged
                                      # pipeline automatically
    delta_device_min: int = 64        # added-set size at which device+delta
                                      # patching moves from the host loop to
                                      # the device-resident DeltaTable
    knn_device_min_batch: int = 16    # knn point batches this big run
                                      # device-complete (seeded dwithin
                                      # probes + on-device top-k ranking);
                                      # smaller ones loop on the host
    knn_seed: Optional[str] = None    # initial knn radius selection: "cdf"
                                      # (per-point density seed read off the
                                      # published learned model — leaf count
                                      # over leaf-MBR area) or "global" (one
                                      # whole-store density estimate); None =
                                      # cdf. Either way the doubling ladder
                                      # is the correctness backstop: a bad
                                      # seed costs extra rungs, never hits
    knn_topk: Optional[str] = None    # device top-k impl: "sort" (two-key
                                      # lax.sort reference) or "pallas" (the
                                      # k-round partial-selection kernel —
                                      # wins when k << candidate columns);
                                      # None = auto: pallas on TPU, sort
                                      # elsewhere. Both obey the (distance,
                                      # id) tie-break contract
    pad_quantum: int = 4096           # bucket-pad record/slot array lengths so
                                      # insert-driven growth does not change
                                      # jitted shapes (0 disables padding)
    delta_patch_max: int = 4096       # patch a stale snapshot instead of
                                      # republishing while the delta (added +
                                      # tombstoned records) is at most this
                                      # (0 disables delta patching)
    refresh_threshold: int = 4096     # delta size at which the planner prefers
                                      # a republish over patching (0 means
                                      # republish on every stale query)
    mesh: Optional[Any] = None        # jax Mesh with a "model" axis (query
                                      # sharding) and a "data"/"pod" axis
                                      # (record sharding): activates the
                                      # "sharded" planner backend
    shard_min_records: int = 1 << 16  # below this the single-device path
                                      # beats per-shard dispatch overhead;
                                      # the sharded backend is not chosen
    async_republish: bool = False     # double-buffered snapshots: a stale
                                      # delta past refresh_threshold builds
                                      # the NEXT snapshot on a background
                                      # thread while queries keep serving the
                                      # current snapshot + delta patch; the
                                      # finished build swaps in atomically
    replicas: int = 1                 # independent device placements of the
                                      # published snapshot + geometry payload
                                      # for serving fan-out: every publish
                                      # (sync or async swap) refreshes all of
                                      # them from the same HostCapture;
                                      # query(..., replica=r) serves placement
                                      # r (round-robin over jax.devices(); on
                                      # a single device the copies alias the
                                      # primary buffers, costing nothing but
                                      # enabling concurrent callers)


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """One or many queries of one kind against one relation.

    Build with :meth:`window` / :meth:`knn`; ``backend`` forces a specific
    execution path (benchmarks, tests), otherwise the planner decides.
    """

    kind: str = "window"                    # "window" | "knn"
    windows: Optional[np.ndarray] = None    # (Q, 4) fp64
    relation: str = "intersects"
    points: Optional[np.ndarray] = None     # (Q, 2) fp64, knn only
    k: int = 1
    backend: Optional[str] = None     # force "host"/"device"/"device+delta"/
                                      # "sharded"
    collect_stats: bool = False             # per-window QueryStats (host path)

    @classmethod
    def window(cls, windows, relation: str = "intersects",
               backend: Optional[str] = None,
               collect_stats: bool = False) -> "QueryBatch":
        w = np.atleast_2d(np.asarray(windows, np.float64))
        if w.ndim != 2 or w.shape[1] != 4:
            raise ValueError(f"windows must be (Q, 4); got {w.shape}")
        get_relation(relation)  # fail fast on unknown relations
        return cls(kind="window", windows=w, relation=relation,
                   backend=backend, collect_stats=collect_stats)

    @classmethod
    def knn(cls, points, k: int,
            backend: Optional[str] = None) -> "QueryBatch":
        p = np.atleast_2d(np.asarray(points, np.float64))
        if p.ndim != 2 or p.shape[1] != 2:
            raise ValueError(f"points must be (Q, 2); got {p.shape}")
        return cls(kind="knn", points=p, k=int(k), backend=backend)

    def __len__(self) -> int:
        arr = self.windows if self.kind == "window" else self.points
        return 0 if arr is None else int(arr.shape[0])


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """How a batch will execute (returned by ``plan``, recorded on results)."""

    backend: str                  # "host" | "device" | "device+delta" |
                                  # "sharded"
    kind: str                     # "window" | "knn"
    relation: Optional[str]       # None for knn
    base_relation: Optional[str]  # probed relation (complements differ)
    rebuild_snapshot: bool        # device path will republish the snapshot
    reason: str
    delta_size: int = 0           # added + tombstoned records vs the snapshot
    fused: bool = False           # device refine compiles to the one-dispatch
                                  # FusedDeviceStage (EngineConfig.fusion)


@dataclasses.dataclass
class QueryResult:
    """Per-query hit ids (ascending record id) plus execution metadata."""

    ids: List[np.ndarray]
    plan: QueryPlan
    epoch: int                                  # index epoch that was served
    stats: Optional[List[QueryStats]] = None    # host path, when requested
    distances: Optional[List[np.ndarray]] = None  # knn only
    stages: Optional[List["qexec.StageStats"]] = None  # per-stage telemetry
    # (wall time, survivors, ladder escalations, delta sizes) of the
    # executed ExecutionPlan, in stage order

    def __len__(self) -> int:
        return len(self.ids)

    def __getitem__(self, i: int) -> np.ndarray:
        return self.ids[i]

    def __iter__(self) -> Iterator[np.ndarray]:
        return iter(self.ids)

    @property
    def total_hits(self) -> int:
        return int(sum(r.shape[0] for r in self.ids))


@dataclasses.dataclass
class _InflightPublish:
    """A double-buffered snapshot build running on a background thread.

    ``capture`` is the synchronous host flattening at ``epoch``; the thread
    turns it into the padded snapshot (+ the sharded placement when a mesh is
    active) and sets ``done``. ``tombs_after`` collects records deleted while
    the build runs that the PENDING snapshot contains (``rec < recs``) — they
    become the tombstone set of the swapped-in snapshot."""

    capture: HostCapture
    epoch: int
    recs: int
    done: threading.Event
    tombs_after: Set[int]
    thread: Optional[threading.Thread] = None
    snapshot: Optional[GLINSnapshot] = None
    table_np: Optional[Dict[str, np.ndarray]] = None
    error: Optional[BaseException] = None


class SpatialIndex:
    """Facade over the host ``GLIN`` + lazily-materialized device snapshot.

    All mutations MUST go through :meth:`insert` / :meth:`delete` so the
    mutation epoch tracks the host structure; the device snapshot and device
    geometry payload are invalidated by epoch and rebuilt on demand.

    Thread-safe for concurrent callers (the serving tier drives it from many
    worker threads): writes and the query prologue (planning, snapshot
    install/swap, delta freezing) serialize on one internal lock, while the
    heavy device compute of the ``device``/``device+delta`` backends runs
    OUTSIDE it against frozen immutable state — a ``device`` query is exact
    at the epoch frozen under the lock, and concurrent writers are never
    blocked by device execution. The host, sharded and knn paths hold the
    lock for their whole run (they walk the mutable host tree, or own every
    mesh device anyway). The ``async_republish`` machinery runs the snapshot
    REBUILD on a background thread; all state transitions (start, swap)
    happen under the lock at query boundaries.
    """

    def __init__(self, glin: GLIN, config: Optional[EngineConfig] = None):
        self.glin = glin
        self.config = config or EngineConfig()
        # one reentrant lock guards every mutable facade field AND the host
        # tree (writers mutate leaves in place); device compute runs outside
        self._lock = threading.RLock()
        self._epoch = 0
        self._snapshot: Optional[GLINSnapshot] = None
        self._snapshot_epoch = -1
        self._snapshot_recs = 0         # store length at publish time
        self._publishes = 0             # snapshot (re)publish count
        # delta vs the last published snapshot (LSM-style patch-not-rebuild)
        self._added: Set[int] = set()   # record ids inserted since publish
        self._tombstones: Set[int] = set()  # published records deleted since
        self._dtable: Optional[DeltaTable] = None  # device added-set index
        self._dtable_epoch = -1
        self._payload = None
        self._payload_key: Optional[Tuple[int, int]] = None
        # (real records, store layout generation)
        # adaptive candidate capacity: remembered across queries so the
        # overflow ladder (cap doubling) is walked once, not per call
        self._cap = self.config.initial_cap
        # host capture backing the published snapshot (sharded placement src)
        self._capture: Optional[HostCapture] = None
        # double-buffered republish in flight (async_republish)
        self._inflight: Optional[_InflightPublish] = None
        # sticky floors for the snapshot's STATIC jit fields: search_steps /
        # depth may shrink after a refit, but serving the larger value is
        # still correct (extra bounded-search/traversal trips no-op) and
        # keeps the jit signature stable across republishes
        self._steps_floor = 0
        self._depth_floor = 0
        # sticky floors for the geometry payload's STATIC shapes: the pod
        # pool may SHRINK at a compacting republish and the width ladder
        # may shrink after wide records die — serving the larger padded
        # shape is still correct (pad slots are never gathered) and keeps
        # the jitted query signature stable across republishes
        self._pool_floor = 0
        self._width_floor = 1
        self._shard_pool_floor = 0
        # sharded backend caches: jitted steps per (relation, cap, budget,
        # compaction); device placement (replicated model snapshot + sharded
        # record table) per publish
        self._shard_steps: Dict[Tuple, Any] = {}
        self._shard_placement: Optional[Tuple] = None   # (publish_id, ...)
        self._staged_table: Optional[Dict[str, np.ndarray]] = None
        # replica placements (config.replicas > 1): per replica r >= 1 a
        # device_put copy of the published snapshot + payload, keyed on the
        # (publish, payload) generation it was fanned out from
        self._replica_places: Dict[int, Tuple] = {}
        # per-(backend, stage) telemetry aggregates (stats()["stages"]):
        # calls, wall_ms, queries, survivors, ladder escalations, delta sizes
        self._stage_totals: Dict[str, Dict[str, Dict[str, float]]] = {}

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, gs: GeometrySet, glin_cfg: GLINConfig = GLINConfig(),
              config: Optional[EngineConfig] = None) -> "SpatialIndex":
        return cls(GLIN.build(gs, glin_cfg), config)

    @property
    def gs(self) -> GeometrySet:
        return self.glin.gs

    def __len__(self) -> int:
        return self.glin.num_records

    def stats(self) -> dict:
        with self._lock:
            st = self.glin.stats()
            st["epoch"] = self._epoch
            st["snapshot_epoch"] = self._snapshot_epoch
            st["snapshot_stale"] = self.snapshot_is_stale()
            st["delta_size"] = self.delta_size()
            st["snapshot_publishes"] = self._publishes
            st["republish_inflight"] = self._inflight is not None
            st["replicas"] = max(1, self.config.replicas)
            st["stages"] = {b: {s: dict(v) for s, v in per.items()}
                            for b, per in self._stage_totals.items()}
            return st

    def _record_stages(self, backend: str,
                       stage_stats: List["qexec.StageStats"]) -> None:
        """Fold one execution's per-stage telemetry into the aggregates
        surfaced by ``stats()["stages"]`` (keyed backend -> stage label)."""
        with self._lock:
            per = self._stage_totals.setdefault(backend, {})
            for ss in stage_stats:
                ent = per.setdefault(ss.stage, {
                    "impl": ss.impl, "calls": 0, "skipped": 0,
                    "wall_ms": 0.0, "queries": 0, "survivors": 0,
                    "escalations": 0, "dispatches": 0, "delta_added": 0,
                    "delta_tombstoned": 0, "rungs": 0, "seed_hits": 0,
                    "merge_bytes": 0, "rung_hist": []})
                ent["calls"] += 1
                ent["wall_ms"] += ss.wall_ms
                # the executing impl may differ per call (staged vs fused
                # refine share the "refine" label): report the latest
                ent["impl"] = ss.impl
                if ss.skipped:
                    ent["skipped"] += 1
                    continue
                ent["queries"] += ss.queries
                ent["survivors"] += max(ss.survivors, 0)
                ent["escalations"] += ss.escalations
                ent["dispatches"] += ss.dispatches
                ent["delta_added"] += ss.delta_added
                ent["delta_tombstoned"] += ss.delta_tombstoned
                # knn-rank seeding/merge telemetry (zero for window stages):
                # rung_hist sums element-wise — entry i is the points that
                # settled after i+1 probes, so hist[0]/queries is the seed
                # hit-rate across every call
                ent["rungs"] += ss.rungs
                ent["seed_hits"] += ss.seed_hits
                ent["merge_bytes"] += ss.merge_bytes
                hist = ent["rung_hist"]
                for i, v in enumerate(ss.rung_hist):
                    if i < len(hist):
                        hist[i] += v
                    else:
                        hist.append(v)

    # ------------------------------------------------------------ maintenance
    def insert(self, verts: np.ndarray, nverts: int, kind: int = 0) -> int:
        with self._lock:
            rec = self.glin.insert(verts, nverts, kind)
            self._epoch += 1
            self._added.add(rec)
            return rec

    def delete(self, rec: int) -> bool:
        with self._lock:
            ok = self.glin.delete(rec)
            if ok:
                self._epoch += 1
                if rec in self._added:
                    self._added.remove(rec)
                elif rec < self._snapshot_recs:
                    self._tombstones.add(rec)
                # else: the record was never published nor added since the
                # last publish — it cannot appear in snapshot results,
                # nothing to patch
                if self._inflight is not None and rec < self._inflight.recs:
                    # the PENDING double-buffered snapshot contains this
                    # record (it was live at capture time): remember it so
                    # the swap installs the correct tombstone set
                    self._inflight.tombs_after.add(rec)
            return ok

    def delta_size(self) -> int:
        """Records added plus published records tombstoned since the last
        snapshot publish (the work a ``device+delta`` query must patch)."""
        return len(self._added) + len(self._tombstones)

    # --------------------------------------------------------------- snapshot
    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def device_cap(self) -> int:
        """Current adaptive per-query candidate capacity of the device path."""
        return self._cap

    @property
    def snapshot_epoch(self) -> int:
        return self._snapshot_epoch

    def snapshot_is_stale(self) -> bool:
        return self._snapshot is None or self._snapshot_epoch != self._epoch

    def _padded(self, n: int) -> int:
        return self._bucket(n, self.config.pad_quantum)

    # bucket quanta for the small model tables (pad_quantum > 0): a republish
    # that grew the tree or the piecewise function keeps the SAME jitted-shape
    # signature as long as each table stays inside its bucket, so the first
    # query after an (async) snapshot swap hits the jit cache instead of
    # paying an XLA recompile
    _LEAF_QUANTUM = 256
    _NODE_QUANTUM = 64
    _CODE_QUANTUM = 256
    _PW_QUANTUM = 1024
    _INF_HI = np.int32(1 << 30)   # > any valid 30-bit limb

    @staticmethod
    def _bucket(n: int, q: int) -> int:
        return n if q <= 0 else max(q, -(-n // q) * q)

    def _pad_snapshot(self, snap: GLINSnapshot) -> GLINSnapshot:
        """Bucket-pad every snapshot table (``EngineConfig.pad_quantum``
        disables all of it when 0).

        * slot arrays — padding slots sit past the ``leaf_start`` sentinel,
          so no probe or candidate window ever reaches them;
        * leaf tables — padding leaves carry +inf domain bounds (the ±2
          routing fix-up can never step onto one), empty ``leaf_start`` runs
          and far-away MBRs;
        * node tables / child codes — only reachable through ``child_codes``
          entries of real nodes, so zero padding is inert;
        * piecewise pieces — +inf ``zmax_end`` (sorts after every real
          piece) with +inf suffix-min (an augmentation landing there is a
          no-op by the ``z_less`` take-test).
        """
        if self.config.pad_quantum <= 0:
            return snap
        reps: dict = {}
        # static jit fields: sticky-monotonic with generous floors (16 steps
        # cover a model-error window of 2^16 slots — clipped to the leaf size
        # anyway — at ~9 extra cheap binary-search gathers per probe).
        # Shrinking them would change the jit signature for no win; growing
        # them stays correct (extra bounded-search / traversal trips no-op),
        # and the floor keeps a republish whose refit grew the model error
        # from recompiling the query. Read-only here (this runs on the build
        # thread too); the floors are COMMITTED in _install_snapshot, on the
        # caller's thread only.
        steps = max(self._steps_floor, snap.search_steps, 16)
        depth = max(self._depth_floor, snap.depth, 8)
        if (steps, depth) != (snap.search_steps, snap.depth):
            reps.update(search_steps=steps, depth=depth)
        # slot arrays
        n = snap.keys_hi.shape[0]
        pad = self._padded(n) - n
        if pad:
            big = jnp.asarray(np.full(pad, (1 << 30) - 1, np.int32))
            far = jnp.full((pad, 4), 2e30, jnp.float32)  # hits nothing
            reps.update(
                keys_hi=jnp.concatenate([snap.keys_hi, big]),
                keys_lo=jnp.concatenate([snap.keys_lo, big]),
                recs=jnp.concatenate([snap.recs, jnp.zeros(pad, jnp.int32)]),
                rec_leaf=jnp.concatenate(
                    [snap.rec_leaf,
                     jnp.full(pad, snap.num_leaves - 1, jnp.int32)]),
                slot_lmbr=jnp.concatenate([snap.slot_lmbr, far]),
                slot_rmbr=jnp.concatenate([snap.slot_rmbr, far]),
            )
        # leaf tables ((L,) and (L+1,) shapes share one bucket). The domain
        # sentinel dlo[L] (the last leaf's nominal dhi) is REPLACED together
        # with the pads by a strictly-infinite bound: inserted keys may
        # legitimately exceed the nominal dhi (the host tree stores them in
        # the last leaf), and without padding it was the fix-up's clamp to
        # ``num_leaves - 1`` that kept such probes on the last REAL leaf —
        # the infinite sentinel reproduces exactly that, so the ±2 routing
        # fix-up can never step onto a (empty-windowed) pad leaf.
        L = snap.num_leaves
        lb = self._bucket(L, self._LEAF_QUANTUM)
        if lb > L:
            inf_lo = jnp.full(lb + 1 - L, 1 << 30, jnp.int32)
            reps.update(
                leaf_dlo_hi=jnp.concatenate(
                    [snap.leaf_dlo_hi[:L],
                     jnp.full(lb + 1 - L, self._INF_HI, jnp.int32)]),
                leaf_dlo_lo=jnp.concatenate(
                    [snap.leaf_dlo_lo[:L], inf_lo]),
                leaf_start=jnp.concatenate(
                    [snap.leaf_start,
                     jnp.full(lb - L, snap.leaf_start[-1], jnp.int32)]),
                leaf_mbr=jnp.concatenate(
                    [snap.leaf_mbr, jnp.full((lb - L, 4), 2e30,
                                             jnp.float32)]),
                leaf_k0_hi=jnp.concatenate(
                    [snap.leaf_k0_hi, jnp.zeros(lb - L, jnp.int32)]),
                leaf_k0_lo=jnp.concatenate(
                    [snap.leaf_k0_lo, jnp.zeros(lb - L, jnp.int32)]),
                leaf_slope=jnp.concatenate(
                    [snap.leaf_slope, jnp.zeros(lb - L, jnp.float32)]),
                leaf_icpt=jnp.concatenate(
                    [snap.leaf_icpt, jnp.zeros(lb - L, jnp.float32)]),
            )
        # node tables + child codes (reachable only via real child_codes)
        M = snap.node_scale.shape[0]
        mb = self._bucket(M, self._NODE_QUANTUM)
        if mb > M:
            k = mb - M
            reps.update(
                node_dlo_hi=jnp.concatenate(
                    [snap.node_dlo_hi, jnp.zeros(k, jnp.int32)]),
                node_dlo_lo=jnp.concatenate(
                    [snap.node_dlo_lo, jnp.zeros(k, jnp.int32)]),
                node_scale=jnp.concatenate(
                    [snap.node_scale, jnp.zeros(k, jnp.float32)]),
                node_fanout=jnp.concatenate(
                    [snap.node_fanout, jnp.ones(k, jnp.int32)]),
                node_child_base=jnp.concatenate(
                    [snap.node_child_base, jnp.zeros(k, jnp.int32)]),
            )
        C = snap.child_codes.shape[0]
        cb = self._bucket(C, self._CODE_QUANTUM)
        if cb > C:
            reps["child_codes"] = jnp.concatenate(
                [snap.child_codes, jnp.zeros(cb - C, jnp.int32)])
        # piecewise pieces (only when the function exists at all)
        Pn = snap.pw_zmax_hi.shape[0]
        pb = self._bucket(Pn, self._PW_QUANTUM) if Pn else 0
        if pb > Pn:
            k = pb - Pn
            inf = jnp.full(k, self._INF_HI, jnp.int32)
            zero = jnp.zeros(k, jnp.int32)
            reps.update(
                pw_zmax_hi=jnp.concatenate([snap.pw_zmax_hi, inf]),
                pw_zmax_lo=jnp.concatenate([snap.pw_zmax_lo, zero]),
                pw_sufmin_hi=jnp.concatenate([snap.pw_sufmin_hi, inf]),
                pw_sufmin_lo=jnp.concatenate([snap.pw_sufmin_lo, zero]),
            )
        return dataclasses.replace(snap, **reps) if reps else snap

    def snapshot(self) -> GLINSnapshot:
        """The flattened device snapshot at the CURRENT epoch (rebuilds when
        stale; a stale snapshot is never handed out).

        The slot arrays are bucket-padded (``EngineConfig.pad_quantum``) so an
        insert-only epoch bump usually republishes with UNCHANGED shapes and
        the jitted query does not recompile.
        """
        with self._lock:
            if self.snapshot_is_stale():
                # a finished double-buffered build may already BE the current
                # epoch — swap it in instead of rebuilding synchronously
                self._poll_republish()
            if self.snapshot_is_stale():
                cap = snapshot_capture(self.glin)
                self._install_snapshot(
                    self._pad_snapshot(snapshot_from_capture(cap)), cap,
                    self._epoch, added=set(), tombstones=set())
            return self._snapshot

    def _install_snapshot(self, snap: GLINSnapshot, capture: HostCapture,
                          epoch: int, added: Set[int],
                          tombstones: Set[int]) -> None:
        """Atomically publish ``snap`` as the served snapshot (single caller
        thread; every dependent field moves together)."""
        self._snapshot = snap
        self._snapshot_epoch = epoch
        self._snapshot_recs = capture.num_records
        # the capture is only consumed by the sharded placement; without a
        # mesh, retaining it would pin O(N) dead host copies per publish
        self._capture = capture if self.config.mesh is not None else None
        self._publishes += 1
        self._added = added
        self._tombstones = tombstones
        self._dtable = None
        self._dtable_epoch = -1
        # any sharded table staged by a (now superseded) async build belongs
        # to a different capture — serving it would drop post-capture writes
        self._staged_table = None
        # replica placements describe the previous snapshot: refresh lazily
        # (first query routed to each replica fans the new snapshot out)
        self._replica_places.clear()
        # commit the static-field floors on the caller's thread (see
        # _pad_snapshot — the build thread only reads them)
        self._steps_floor = max(self._steps_floor, snap.search_steps)
        self._depth_floor = max(self._depth_floor, snap.depth)

    # ------------------------------------------------- async double-buffering
    @property
    def serving_generation(self) -> Tuple[int, int]:
        """Identity of what a query at this instant would serve: the mutation
        epoch AND the published-snapshot generation. Result caches must key
        on this (not the epoch alone) so an async snapshot swap — which does
        not bump the epoch — can never serve a hit computed against the
        previous snapshot."""
        with self._lock:
            return (self._epoch, self._publishes)

    def republish_inflight(self) -> bool:
        return self._inflight is not None

    def _maintain_async(self) -> None:
        """Per-query async upkeep: swap in a finished double-buffered build,
        then kick off a new one when the delta has crossed the republish
        point. Runs on the caller's thread at the top of :meth:`query`."""
        self._poll_republish()
        cfg = self.config
        if (cfg.async_republish and self._inflight is None
                and self._snapshot is not None and self.snapshot_is_stale()
                and self.delta_size() >= max(cfg.refresh_threshold, 1)):
            self._start_republish()

    def _start_republish(self) -> None:
        """Capture the host tree NOW (synchronous, cheap) and build the next
        snapshot + sharded placement on a daemon thread. Queries keep serving
        the current snapshot + delta until :meth:`_poll_republish` swaps."""
        capture = snapshot_capture(self.glin)
        inf = _InflightPublish(capture=capture, epoch=self._epoch,
                               recs=capture.num_records,
                               done=threading.Event(), tombs_after=set())
        shards = self._shard_count() if self._sharded_available() else 0

        def build():
            try:
                # serve-first: schedule this thread SCHED_IDLE (it runs only
                # on cycles the query threads leave idle — Linux applies it
                # per native TID), falling back to plain niceness. A rebuild
                # stretching a little is fine; query latency spiking is not.
                # On a single-core host SCHED_IDLE is indefinite starvation
                # (a saturated serving thread leaves no idle cycles and the
                # swap never lands), so niceness — a weighted share, not an
                # absolute yield — is the serve-first policy there.
                tid = threading.get_native_id()
                if (os.cpu_count() or 1) > 1:
                    try:
                        os.sched_setscheduler(tid, os.SCHED_IDLE,
                                              os.sched_param(0))
                    except (AttributeError, OSError):
                        os.setpriority(os.PRIO_PROCESS, tid, 10)
                else:
                    os.setpriority(os.PRIO_PROCESS, tid, 10)
            except (AttributeError, OSError, PermissionError):
                pass
            try:
                snap = snapshot_from_capture(capture)
                inf.snapshot = self._pad_snapshot(snap)
                if shards:
                    from .distributed import shard_arrays_from_capture
                    # the sticky per-shard pool floor is read-only here
                    # (committed under the lock in _sharded_placement)
                    inf.table_np = shard_arrays_from_capture(
                        capture, shards,
                        pool_pad_to=self._shard_pool_floor)
            except BaseException as e:   # surfaced on the caller's thread
                inf.error = e
            finally:
                inf.done.set()

        inf.thread = threading.Thread(target=build, daemon=True,
                                      name="glin-republish")
        self._inflight = inf
        inf.thread.start()

    def _poll_republish(self) -> None:
        """Non-blocking: if the background build finished, swap it in. The
        swap is epoch-tagged — a synchronous publish that overtook the build
        (forced rebuild, ``count_candidates``) simply discards it."""
        inf = self._inflight
        if inf is None or not inf.done.is_set():
            return
        self._inflight = None
        inf.thread.join()
        if inf.epoch <= self._snapshot_epoch:
            return   # a newer (or identical) snapshot is already published —
            # the build (even a failed one) is superseded and irrelevant
        if inf.error is not None:
            raise RuntimeError(
                "async snapshot republish failed") from inf.error
        # Post-capture delta: record ids are append-only, so everything
        # inserted after the capture has id >= capture recs; deletes of
        # pending-snapshot records were collected in tombs_after.
        added = {r for r in self._added if r >= inf.recs}
        self._install_snapshot(inf.snapshot, inf.capture, inf.epoch,
                               added=added,
                               tombstones=set(inf.tombs_after))
        if inf.table_np is not None:
            self._staged_table = inf.table_np

    def _published_snapshot(self) -> GLINSnapshot:
        """The last *published* snapshot, possibly behind the current epoch —
        only the ``device+delta`` path may serve it, and only together with
        the tombstone/added patch that restores exactness. Publishes a fresh
        snapshot when none exists yet (the delta is then empty)."""
        if self._snapshot is None:
            return self.snapshot()
        return self._snapshot

    def _device_payload(self, needed_recs: Optional[int] = None):
        """fp32 device copy of the geometry store as width-bucketed
        :class:`~repro.core.device.VertexPods` plus the record-MBR table,
        bucket-padded like the snapshot (padding records are never gathered:
        snapshot ``recs`` only holds real record ids). Keyed on (records,
        store layout generation) rather than the epoch, and reused as long
        as it covers ``needed_recs`` (the store length the snapshot being
        served references): the pool is append-only between compactions, so
        neither deletes nor inserts past the snapshot force a multi-MB
        re-upload — only a compacting republish (layout generation bump)
        rebuilds the payload, which is exactly when the device pool should
        shrink."""
        gs = self.glin.gs
        need = len(gs) if needed_recs is None else needed_recs
        if (self._payload is None
                or self._payload_key[1] != gs.layout_version
                or self._payload_key[0] < need):
            n = len(gs)
            m = self._padded(n)
            # static pod shapes under sticky floors: the width ladder covers
            # the widest live record, the pool covers every record's pow2
            # bucket slots (quantum headroom absorbs insert-driven growth)
            maxw = max(self._width_floor, _pow2ceil(gs.max_nverts))
            nv = np.maximum(gs.nverts.astype(np.int64), 1)
            slots = int(np.sum(np.left_shift(
                1, np.ceil(np.log2(nv)).astype(np.int64))))
            pool_pad = max(self._pool_floor,
                           self._bucket(max(slots, 1),
                                        self.config.pad_quantum))
            pods = pods_from_store(gs, pad_records_to=m,
                                   pool_pad_to=pool_pad, max_width=maxw)
            mbrs = np.zeros((m, 4), np.float32)
            mbrs[:n] = gs.mbrs
            self._payload = (pods, jnp.asarray(mbrs))
            self._payload_key = (n, gs.layout_version)
            self._pool_floor = max(self._pool_floor, pool_pad)
            self._width_floor = max(self._width_floor, maxw)
        return self._payload

    def _replica_view(self, rep: int, snap: GLINSnapshot, payload):
        """Device placement of ``(snap, payload)`` for replica ``rep``.

        Replica 0 is the primary placement (the facade's own fields). Higher
        replicas are ``device_put`` copies fanned out round-robin over
        ``jax.devices()``, built once per (publish, payload) generation from
        the SAME HostCapture-derived snapshot the primary serves — the
        write/delta stream therefore republishes to every replica at the
        same swap. On a single-device host every replica serves the primary
        placement directly: there is no second device to fan out to, and a
        same-device ``device_put`` would commit the arrays, forking the jit
        cache into a recompile per (relation, batch bucket) for zero
        routing benefit. The serving tier's replica routing stays
        meaningful either way (per-replica inflight/telemetry); only the
        physical placement collapses. Call under ``self._lock``."""
        R = max(1, int(self.config.replicas))
        rep = rep % R if R else 0
        if rep <= 0 or jax.device_count() <= 1:
            return snap, payload
        key = (self._publishes, self._payload_key)
        ent = self._replica_places.get(rep)
        if ent is None or ent[0] != key:
            dev = jax.devices()[rep % jax.device_count()]
            ent = (key, jax.device_put(snap, dev),
                   tuple(jax.device_put(p, dev) for p in payload))
            self._replica_places[rep] = ent
        return ent[1], ent[2]

    def _compaction(self, base_relation: str,
                    budget: Optional[int] = None) -> str:
        """Stage-1 refinement implementation for ``batch_query``: the fused
        Pallas kernel on TPU, the jnp reference elsewhere (interpret-mode
        Pallas is a correctness tool, not a CPU execution path), and the jnp
        reference whenever the relation's MBR prefilter has no static kernel
        shape (``prefilter_kind == "custom"``). ``budget`` is the budget the
        call will actually use (the overflow ladder grows it past the
        configured default)."""
        mode = self.config.compaction
        if mode is None:
            mode = "pallas" if jax.default_backend() == "tpu" else "scan"
        if mode == "pallas":
            from repro.kernels.refine import MAX_COMPACT_BUDGET

            b = self.config.exact_budget if budget is None else budget
            if (get_relation(base_relation).prefilter_kind == "custom"
                    or b > MAX_COMPACT_BUDGET):
                # custom MBR prefilters have no static kernel shape, and
                # budgets past the VMEM bound cannot host the one-hot
                # scatter block — both take the jnp reference
                mode = "scan"
        return mode

    def _fusion_mode(self, base_relation: str, budget: Optional[int] = None,
                     snap: Optional[GLINSnapshot] = None,
                     pods=None) -> Optional[str]:
        """Resolve ``EngineConfig.fusion`` to a ``batch_query_fused`` mode,
        or ``None`` when the fused one-dispatch path cannot (or should not)
        serve the call and the staged pipeline must: fusion off, a
        custom-prefilter relation (no static kernel mask shape), a budget
        outside the two-stage envelope ``(0, MAX_COMPACT_BUDGET]``, or —
        for the kernel modes, when ``snap``/``pods`` are at hand — a store
        whose resident tables outgrow ``FUSED_VMEM_LIMIT``."""
        from repro.kernels.refine import (FUSED_VMEM_LIMIT,
                                          MAX_COMPACT_BUDGET,
                                          fused_vmem_bytes)

        mode = self.config.fusion
        if mode is None:
            mode = ("pallas" if jax.default_backend() == "tpu" else "off")
        if mode == "off":
            return None
        if mode not in ("pallas", "interpret", "reference"):
            raise ValueError(f"unknown fusion mode {mode!r}")
        if get_relation(base_relation).prefilter_kind == "custom":
            return None
        b = self.config.exact_budget if budget is None else budget
        if not 0 < b <= MAX_COMPACT_BUDGET:
            return None
        if (mode in ("pallas", "interpret") and snap is not None
                and pods is not None
                and fused_vmem_bytes(
                    snap.num_slots, snap.num_leaves,
                    snap.node_dlo_hi.shape[0], snap.child_codes.shape[0],
                    snap.pw_zmax_hi.shape[0], pods.num_records,
                    pods.pool.shape[0], b, pods.max_width)
                > FUSED_VMEM_LIMIT):
            return None
        return mode

    # ---------------------------------------------------------------- sharded
    def _sharded_available(self) -> bool:
        """A mesh is configured and shaped for the sharded backend (a loud
        error on a malformed mesh beats silently planning around it)."""
        mesh = self.config.mesh
        if mesh is None:
            return False
        names = tuple(mesh.axis_names)
        if "model" not in names or not any(a in ("data", "pod")
                                           for a in names):
            raise ValueError(
                f"EngineConfig.mesh axes {names} unusable: the sharded "
                "backend needs a 'model' axis (query sharding) and a "
                "'data' and/or 'pod' axis (record sharding)")
        return True

    def _shard_count(self) -> int:
        """Number of record shards (product of the data/pod axis sizes)."""
        from .distributed import _data_axes

        mesh = self.config.mesh
        s = 1
        for a in _data_axes(mesh):
            s *= mesh.shape[a]
        return s

    def _sharded_placement(self):
        """Device placement of the PUBLISHED snapshot for the mesh, built
        once per publish: the record table range-partitioned over the data
        axes (slot order, slot-aligned MBR tables) and a model-only snapshot
        (record-level arrays stripped to 1-element stand-ins — the sharded
        step never touches them) replicated on every device."""
        if self._shard_placement is not None \
                and self._shard_placement[0] == self._publishes:
            return self._shard_placement[1:]
        from .distributed import _data_axes, shard_arrays_from_capture

        mesh = self.config.mesh
        shards = self._shard_count()
        if self._capture is None:
            # the mesh was configured AFTER the last publish (captures are
            # only retained while a mesh is active): re-derive it — from the
            # live tree when the snapshot is fresh (they are identical), via
            # a republish otherwise
            if self.snapshot_is_stale():
                self.snapshot()
            else:
                self._capture = snapshot_capture(self.glin)
        table_np = self._staged_table
        self._staged_table = None
        # a staged table (built by the async swap's background thread) must
        # describe exactly the published capture's slots — anything else is
        # rebuilt here (every publish clears stale stagings, so this is just
        # a belt-and-braces shape check)
        n = self._capture.keys.shape[0]
        if table_np is None or table_np["keys_hi"].shape[0] != n + (-n) % shards:
            table_np = shard_arrays_from_capture(
                self._capture, shards, pool_pad_to=self._shard_pool_floor)
        # sticky floors: a compacting republish may shrink the per-shard
        # pool or retire the widest records; serving the previous padded
        # shapes keeps the sharded jit signature stable
        self._shard_pool_floor = max(self._shard_pool_floor,
                                     table_np["vpool"].shape[0] // shards)
        maxw = max(self._width_floor,
                   _pow2ceil(int(table_np["nverts"].max())))
        self._width_floor = max(self._width_floor, maxw)
        tsh = NamedSharding(mesh, P(_data_axes(mesh)))
        table = {k: jax.device_put(v, tsh) for k, v in table_np.items()}
        tiny_i = jnp.zeros((1,), jnp.int32)
        tiny_f = jnp.zeros((1, 4), jnp.float32)
        model_only = dataclasses.replace(
            self._snapshot, keys_hi=tiny_i, keys_lo=tiny_i, recs=tiny_i,
            rec_leaf=tiny_i, slot_lmbr=tiny_f, slot_rmbr=tiny_f)
        repl = NamedSharding(mesh, P())
        snap_repl = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, repl), model_only)
        # key read AFTER the potential republish above bumped the count —
        # caching under the pre-publish key would force a rebuilt placement
        # (and its multi-MB device_put) on the very next query
        self._shard_placement = (self._publishes, snap_repl, table, shards,
                                 maxw)
        return self._shard_placement[1:]

    def _sharded_step(self, base: str, cap: int, budget: int,
                      compaction: str, max_width: int):
        key = (base, cap, budget, compaction, max_width)
        fn = self._shard_steps.get(key)
        if fn is None:
            from .distributed import build_glin_query_step

            step, in_sh, out_sh = build_glin_query_step(
                self.config.mesh, base, cap=cap, exact_budget=budget,
                compaction=compaction, max_width=max_width)
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            self._shard_steps[key] = fn
        return fn

    def _sharded_knn_step(self, relation: str, k: int, cap: int, budget: int,
                          compaction: str, max_width: int):
        """Jit cache for the sharded knn probe+rank+k-merge step, keyed like
        ``_sharded_step`` plus k; pow2-snapped radii keep the relation-string
        key space (and thus compilations) bounded."""
        key = ("knn", relation, k, cap, budget, compaction, max_width)
        fn = self._shard_steps.get(key)
        if fn is None:
            from .distributed import build_glin_knn_step

            step, in_sh, out_sh = build_glin_knn_step(
                self.config.mesh, relation, k, cap=cap, exact_budget=budget,
                compaction=compaction, max_width=max_width)
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            self._shard_steps[key] = fn
        return fn

    def _check_augmentable(self, relation: str, base) -> None:
        """Fail loudly when a relation needs the piecewise augmentation and
        the index was built without it — the device ``_augment()`` would
        silently no-op on an empty piecewise table and drop true hits."""
        if base.augment and self.glin.pw is None:
            raise ValueError(f"{relation} requires the piecewise function "
                             "(cfg.enable_piecewise=True)")

    # ------------------------------------------------------------------- plan
    def plan(self, batch, relation: Optional[str] = None) -> QueryPlan:
        """Planned execution for ``batch`` (same input forms as ``query``)."""
        if not isinstance(batch, QueryBatch):
            batch = QueryBatch.window(batch, relation or "intersects")
        cfg = self.config
        if batch.kind == "knn":
            q = len(batch)
            seed = cfg.knn_seed or "cdf"
            delta = self.delta_size()
            stale = self.snapshot_is_stale()

            def knn_plan(backend, reason, rebuild=False):
                return QueryPlan(backend, "knn", None, None, rebuild,
                                 reason, delta)

            if batch.backend == "host":
                return knn_plan("host", "forced by caller")
            if batch.backend == "sharded":
                if not self._sharded_available():
                    raise ValueError("backend='sharded' requires "
                                     "EngineConfig.mesh")
                return knn_plan("sharded", "forced by caller", rebuild=stale)
            if batch.backend in ("device", "device+delta"):
                return knn_plan(batch.backend, "forced by caller")
            if batch.backend is not None:
                raise ValueError(f"unknown backend {batch.backend!r}")
            if q < cfg.knn_device_min_batch or self.glin.pw is None:
                why = (f"batch of {q} < knn_device_min_batch="
                       f"{cfg.knn_device_min_batch}"
                       if q < cfg.knn_device_min_batch
                       else "no piecewise function published")
                return knn_plan("host",
                                f"knn executes on the host index ({why})")
            shard_ok = (self._sharded_available()
                        and self.glin.num_records >= cfg.shard_min_records)
            if shard_ok:
                nsh = self._shard_count()
                return knn_plan(
                    "sharded",
                    f"device-complete knn over {nsh} shards: {seed}-seeded "
                    f"radii, shard-local top-{batch.k}, one-collective "
                    f"k-merge ({q} points)", rebuild=stale)
            patchable = (self._snapshot is not None
                         and delta <= cfg.delta_patch_max
                         and delta < cfg.refresh_threshold)
            if stale and patchable:
                return knn_plan(
                    "device+delta",
                    f"device-complete knn with {seed}-seeded radii; "
                    f"snapshot stale, delta of {delta} ranked in-line "
                    f"(tombstones masked, added set distance-merged before "
                    f"the device top-{batch.k})")
            return knn_plan(
                "device",
                f"device-complete knn: {seed}-seeded dwithin ladder + "
                f"device top-{batch.k} ({q} points >= knn_device_min_batch="
                f"{cfg.knn_device_min_batch})")
        rel = get_relation(batch.relation)
        base = get_relation(rel.base_name())
        self._check_augmentable(batch.relation, base)
        stale = self.snapshot_is_stale()
        delta = self.delta_size()
        inflight = self._inflight is not None
        # patch viable: a snapshot has been published, the per-query patch
        # work is bounded (delta_patch_max), and the delta has not yet hit
        # the republish point (refresh_threshold)
        patchable = (self._snapshot is not None
                     and delta <= cfg.delta_patch_max
                     and delta < cfg.refresh_threshold)

        def host(reason):
            return QueryPlan("host", "window", rel.name, base.name, False,
                             reason, delta)

        fused = self._fusion_mode(base.name) is not None
        fnote = "; fused one-kernel refine" if fused else ""

        def device(reason):
            return QueryPlan("device", "window", rel.name, base.name, stale,
                             reason + fnote, delta, fused=fused)

        def patched(reason):
            return QueryPlan("device+delta", "window", rel.name, base.name,
                             self._snapshot is None, reason + fnote, delta,
                             fused=fused)

        def sharded(reason, rebuild=False):
            return QueryPlan("sharded", "window", rel.name, base.name,
                             rebuild, reason, delta)

        if batch.collect_stats and batch.backend in ("device", "device+delta",
                                                     "sharded"):
            raise ValueError("collect_stats is host-only; drop it or force "
                             "backend='host'")
        if batch.backend == "host":
            return host("forced by caller")
        if batch.backend == "device":
            return device("forced by caller")
        if batch.backend == "device+delta":
            return patched("forced by caller")
        if batch.backend == "sharded":
            if not self._sharded_available():
                raise ValueError("backend='sharded' requires "
                                 "EngineConfig.mesh")
            return sharded("forced by caller",
                           rebuild=stale and not (patchable or inflight))
        if batch.backend is not None:
            raise ValueError(f"unknown backend {batch.backend!r}")
        if batch.collect_stats:
            return host("QueryStats instrumentation is host-only")
        if not base.device_native:
            return host(f"relation {base.name!r} is not device-native")
        q = len(batch)
        if q < cfg.device_min_batch:
            return host(f"batch of {q} < device_min_batch={cfg.device_min_batch}")
        shard_ok = (self._sharded_available()
                    and self.glin.num_records >= cfg.shard_min_records)
        nsh = self._shard_count() if shard_ok else 0
        if not stale:
            if shard_ok:
                return sharded(f"sharded over {nsh} shards: batch of {q} "
                               f"windows on {jax.default_backend()} mesh")
            return device(f"batch of {q} windows on {jax.default_backend()}")
        if inflight and self._snapshot is not None:
            # double-buffering: the next snapshot is building on the side;
            # keep serving the published one + delta patch (the patch bound
            # is waived — the delta stays bounded by write rate x build time)
            if shard_ok:
                return sharded(f"sharded over {nsh} shards; async republish "
                               f"in flight, delta of {delta} patched on top")
            return patched(f"async republish in flight; serving published "
                           f"snapshot + delta of {delta}")
        if patchable:
            if shard_ok:
                return sharded(f"sharded over {nsh} shards; snapshot stale, "
                               f"delta of {delta} patched on top")
            return patched(f"snapshot stale; delta of {delta} <= "
                           f"delta_patch_max={cfg.delta_patch_max}: patching "
                           "instead of republishing")
        if q < cfg.stale_rebuild_min_batch:
            return host(f"snapshot stale and batch of {q} < "
                        f"stale_rebuild_min_batch={cfg.stale_rebuild_min_batch}")
        if shard_ok:
            verb = ("publishing" if self._snapshot is None
                    else "republishing")
            return sharded(f"sharded over {nsh} shards; {verb} for "
                           f"batch of {q}", rebuild=True)
        if self._snapshot is None:
            return device(f"no published snapshot yet: publishing for "
                          f"batch of {q}")
        return device(f"snapshot stale; delta of {delta} not patchable "
                      f"(delta_patch_max={cfg.delta_patch_max}, "
                      f"refresh_threshold={cfg.refresh_threshold}): "
                      f"republishing for batch of {q}")

    # ------------------------------------------------------------------ query
    def query(self, batch, relation: Optional[str] = None,
              replica: Optional[int] = None, **kw) -> QueryResult:
        """THE entry point: one or thousands of queries, any relation or knn.

        ``batch`` is a :class:`QueryBatch`, or a bare (4,) / (Q, 4) window
        array (``relation`` then applies, default ``intersects``).
        ``replica`` routes a device-backend batch to placement ``replica %
        EngineConfig.replicas`` (the serving tier's least-loaded dispatcher
        sets it; default: the primary placement).

        Concurrency contract: safe to call from many threads, interleaved
        with :meth:`insert`/:meth:`delete`. A window batch on the
        ``device``/``device+delta`` backends is exact at the epoch frozen in
        its prologue (``result.epoch``) and runs its device compute without
        blocking writers; host/sharded batches serialize with writers and
        are exact at the epoch they hold the lock. A device knn batch
        freezes its snapshot + delta ONCE up front — every radius rung of
        every point serves that same frozen epoch.
        """
        if not isinstance(batch, QueryBatch):
            batch = QueryBatch.window(batch, relation or "intersects", **kw)
        else:
            if relation is not None and relation != batch.relation:
                raise ValueError("pass the relation inside the QueryBatch")
            if kw:
                raise ValueError(f"{sorted(kw)} must be set on the QueryBatch "
                                 "itself")
        with self._lock:
            self._maintain_async()
            plan = self.plan(batch)
        rel = base = None
        if batch.kind == "window":
            rel = get_relation(batch.relation)
            base = get_relation(rel.base_name())
        ctx = qexec.ExecContext(index=self, batch=batch, plan=plan,
                                rel=rel, base=base, replica=replica or 0)
        qexec.compile_plan(plan).execute(ctx)
        self._record_stages(plan.backend, ctx.stage_stats)
        return QueryResult(ids=ctx.ids, plan=plan, epoch=ctx.epoch,
                           stats=ctx.host_stats, distances=ctx.distances,
                           stages=ctx.stage_stats)

    def explain(self, batch, relation: Optional[str] = None) -> str:
        """Pretty-print how ``batch`` WOULD execute (same input forms as
        :meth:`query`, nothing runs): the planner's decision plus the
        compiled stage composition — one line per stage with its
        implementation and the canonical pipeline stages it fuses."""
        if not isinstance(batch, QueryBatch):
            batch = QueryBatch.window(batch, relation or "intersects")
        with self._lock:
            plan = self.plan(batch)
        eplan = qexec.compile_plan(plan)
        head = (f"QueryPlan backend={plan.backend} kind={plan.kind} "
                f"relation={plan.relation} delta={plan.delta_size}"
                + (" rebuild" if plan.rebuild_snapshot else ""))
        lines = [head, f"  reason: {plan.reason}", "  stages:"]
        lines += [f"    {row}" for row in eplan.describe()]
        return "\n".join(lines)

    # ------------------------------------------------------------- estimation
    def count_candidates(self, windows, relation: str = "intersects"
                         ) -> np.ndarray:
        """MBR-level candidate counts per window (selectivity estimation)
        through the tiled refine kernel — Pallas on TPU, its XLA reference
        semantics elsewhere."""
        from repro.kernels import ops

        base = get_relation(relation).base_name()
        base_rel = get_relation(base)
        self._check_augmentable(relation, base_rel)
        snap = self.snapshot()
        wj = jnp.asarray(np.atleast_2d(np.asarray(windows)).astype(np.float32))
        start, end = batch_query_bounds(snap, wj, base)
        bounds = jnp.stack([start, end], axis=1).astype(jnp.int32)
        # MBR-level counting uses the padded probe window so dwithin-style
        # relations count the candidates their refine step will actually see;
        # the slot-aligned record-MBR table lives on the snapshot (no per-call
        # host gather + upload)
        counts = ops.refine_count(base_rel.probe_window(wj, xp=jnp), bounds,
                                  snap.slot_rmbr,
                                  use_pallas=jax.default_backend() == "tpu")
        return np.asarray(counts)

    # ----------------------------------------------------- execution support
    # The execution bodies themselves live in ``core.exec`` as stage
    # compositions (compile_plan); what remains here are the freeze helpers
    # the stages call under ``self._lock`` to capture consistent state.
    def _freeze_live(self, rel) -> Optional[np.ndarray]:
        """Live record ids for complement finishing, frozen under the lock
        (the live mask walks the mutable host leaves)."""
        if not rel.is_complement:
            return None
        return np.nonzero(self.glin._live_mask())[0].astype(np.int64)

    def _delta_table(self) -> DeltaTable:
        """The device-resident added-set side table at the current epoch,
        rebuilt lazily after a write burst (one upload per epoch served, not
        one host round-trip per query batch). Rows are padded to a power-of-
        two bucket so the jitted check compiles per bucket, not per insert."""
        if self._dtable is None or self._dtable_epoch != self._epoch:
            a = len(self._added)
            pad = max(self.config.delta_device_min,
                      1 << max(a - 1, 0).bit_length())
            self._dtable = delta_table_from_host(self.glin, self._added,
                                                 pad_to=pad)
            self._dtable_epoch = self._epoch
        return self._dtable

    def _freeze_delta(self) -> Optional[Tuple]:
        """Copies of the tombstone/added delta plus the geometry slices (or
        the device :class:`DeltaTable`) the patch step needs, frozen under
        ``self._lock`` so the shared delta-patch stage can run outside it while
        writers keep mutating the live sets."""
        if not (self._tombstones or self._added):
            return None
        gs = self.glin.gs
        tombs = (np.fromiter(self._tombstones, np.int64,
                             len(self._tombstones))
                 if self._tombstones else None)
        added = np.asarray(sorted(self._added), np.int64)
        table = av = an = ak = None
        if added.shape[0] >= self.config.delta_device_min:
            table = self._delta_table()
        elif added.shape[0]:
            av = gs.padded(added).astype(np.float32)
            an, ak = gs.nverts[added], gs.kinds[added]
        return (tombs, added, table, av, an, ak)

