"""`SpatialIndex` — the one public way to build, mutate, snapshot and query.

The paper's mechanism (probe an interval, refine with a predicate) is the same
whether one window runs on the host or ten thousand run on a TPU; what differed
in this repo was plumbing: the mutable host ``GLIN`` answered one window at a
time while callers hand-stitched ``snapshot_from_host`` + ``batch_query`` for
the device path. This facade owns all of it:

* **relations** are first-class (``core.relations``): ``contains``,
  ``intersects``, ``within``, ``covers``, ``disjoint`` — plus ``knn`` as a
  query *kind* — all through one entry point, ``SpatialIndex.query``;
* **snapshots are epoch-invalidated**: every insert/delete bumps a mutation
  epoch; the flattened device snapshot is materialized lazily and republished
  automatically when stale, so a stale snapshot is never served;
* **writes are LSM-style deltas** (DESIGN.md §2): ALEX-style in-place mutation
  does not map onto immutable device arrays — per-record scatter into a sorted
  device array is O(N). Instead every insert/delete is applied to the host
  ``GLIN`` immediately (host queries are always exact) and recorded in a small
  delta against the last *published* snapshot: inserted record ids in an
  added-set, deleted published records in a tombstone-set. Device queries can
  then be served from the stale snapshot and *patched* — tombstones masked
  out, added records brute-force checked (the delta is tiny, a vectorized
  fp32 mask) — instead of paying a full republish per write. Once the delta
  grows past ``EngineConfig.refresh_threshold`` the snapshot is republished
  (bulk re-flatten, a few ms of vectorized work, amortized O(1)/update);
* **execution is planned**: ``plan(batch)`` picks the host loop (small or
  stats-collecting batches, knn), the jitted device ``batch_query`` (large
  batches, fresh or republished snapshot), or ``device+delta`` (stale
  snapshot, small delta: snapshot query + delta patch, no republish); the
  candidate ``cap`` doubles on overflow and is shared by all device modes,
  and ``count_candidates`` routes through the Pallas refine kernel on TPU;
* **precision**: host execution refines in fp64; device execution refines in
  fp32 (results can differ at exact window boundaries, by design — the probe
  interval is quantized conservatively so hits are never missed, see
  ``core.device``).

Typical use::

    from repro.core import SpatialIndex, QueryBatch, generate, make_query_windows

    index = SpatialIndex.build(generate("cluster", 100_000))
    res = index.query(make_query_windows(index.gs, 1e-3, 256), "intersects")
    ids0 = res[0]                       # hits of window 0, ascending record id
    nn = index.query(QueryBatch.knn([[0.5, 0.5]], k=10))
    rec = index.insert(verts, nverts=8, kind=0)   # bumps the epoch
    res = index.query(windows, "contains")        # snapshot auto-rebuilt
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import geometry as geom
from .datasets import GeometrySet
from .device import (DeltaTable, GLINSnapshot, batch_check_added, batch_query,
                     batch_query_bounds, delta_table_from_host,
                     snapshot_from_host)
from .index import GLIN, GLINConfig, QueryStats
from .index import initial_knn_radius
from .index import knn as _host_knn
from .relations import get_relation

__all__ = ["EngineConfig", "QueryBatch", "QueryPlan", "QueryResult",
           "SpatialIndex"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Planner / execution knobs for :class:`SpatialIndex`."""

    device_min_batch: int = 16        # smaller window batches run on host
    stale_rebuild_min_batch: int = 64  # stale + unpatchable: republish only
                                       # for batches this big, else host
    initial_cap: int = 4096           # device candidate capacity per query
    max_cap: int = 1 << 20            # give up (OverflowError) past this
    exact_budget: int = 256           # two-stage refinement budget (0 = off):
                                      # stage 1 masks + compacts, stage 2
                                      # exact-checks at most this many
                                      # candidates per query
    compaction: Optional[str] = None  # stage-1 impl: "pallas" (fused kernel),
                                      # "scan" (jnp reference), "sort"
                                      # (legacy argsort); None = pallas on
                                      # TPU, scan elsewhere
    delta_device_min: int = 64        # added-set size at which device+delta
                                      # patching moves from the host loop to
                                      # the device-resident DeltaTable
    knn_device_min_batch: int = 16    # knn point batches this big run as
                                      # batched dwithin probes at doubling
                                      # radii; smaller ones loop on the host
    pad_quantum: int = 4096           # bucket-pad record/slot array lengths so
                                      # insert-driven growth does not change
                                      # jitted shapes (0 disables padding)
    delta_patch_max: int = 4096       # patch a stale snapshot instead of
                                      # republishing while the delta (added +
                                      # tombstoned records) is at most this
                                      # (0 disables delta patching)
    refresh_threshold: int = 4096     # delta size at which the planner prefers
                                      # a republish over patching (0 means
                                      # republish on every stale query)


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """One or many queries of one kind against one relation.

    Build with :meth:`window` / :meth:`knn`; ``backend`` forces a specific
    execution path (benchmarks, tests), otherwise the planner decides.
    """

    kind: str = "window"                    # "window" | "knn"
    windows: Optional[np.ndarray] = None    # (Q, 4) fp64
    relation: str = "intersects"
    points: Optional[np.ndarray] = None     # (Q, 2) fp64, knn only
    k: int = 1
    backend: Optional[str] = None     # force "host"/"device"/"device+delta"
    collect_stats: bool = False             # per-window QueryStats (host path)

    @classmethod
    def window(cls, windows, relation: str = "intersects",
               backend: Optional[str] = None,
               collect_stats: bool = False) -> "QueryBatch":
        w = np.atleast_2d(np.asarray(windows, np.float64))
        if w.ndim != 2 or w.shape[1] != 4:
            raise ValueError(f"windows must be (Q, 4); got {w.shape}")
        get_relation(relation)  # fail fast on unknown relations
        return cls(kind="window", windows=w, relation=relation,
                   backend=backend, collect_stats=collect_stats)

    @classmethod
    def knn(cls, points, k: int) -> "QueryBatch":
        p = np.atleast_2d(np.asarray(points, np.float64))
        if p.ndim != 2 or p.shape[1] != 2:
            raise ValueError(f"points must be (Q, 2); got {p.shape}")
        return cls(kind="knn", points=p, k=int(k))

    def __len__(self) -> int:
        arr = self.windows if self.kind == "window" else self.points
        return 0 if arr is None else int(arr.shape[0])


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """How a batch will execute (returned by ``plan``, recorded on results)."""

    backend: str                  # "host" | "device" | "device+delta"
    kind: str                     # "window" | "knn"
    relation: Optional[str]       # None for knn
    base_relation: Optional[str]  # probed relation (complements differ)
    rebuild_snapshot: bool        # device path will republish the snapshot
    reason: str
    delta_size: int = 0           # added + tombstoned records vs the snapshot


@dataclasses.dataclass
class QueryResult:
    """Per-query hit ids (ascending record id) plus execution metadata."""

    ids: List[np.ndarray]
    plan: QueryPlan
    epoch: int                                  # index epoch that was served
    stats: Optional[List[QueryStats]] = None    # host path, when requested
    distances: Optional[List[np.ndarray]] = None  # knn only

    def __len__(self) -> int:
        return len(self.ids)

    def __getitem__(self, i: int) -> np.ndarray:
        return self.ids[i]

    def __iter__(self) -> Iterator[np.ndarray]:
        return iter(self.ids)

    @property
    def total_hits(self) -> int:
        return int(sum(r.shape[0] for r in self.ids))


class SpatialIndex:
    """Facade over the host ``GLIN`` + lazily-materialized device snapshot.

    All mutations MUST go through :meth:`insert` / :meth:`delete` so the
    mutation epoch tracks the host structure; the device snapshot and device
    geometry payload are invalidated by epoch and rebuilt on demand.
    """

    def __init__(self, glin: GLIN, config: Optional[EngineConfig] = None):
        self.glin = glin
        self.config = config or EngineConfig()
        self._epoch = 0
        self._snapshot: Optional[GLINSnapshot] = None
        self._snapshot_epoch = -1
        self._snapshot_recs = 0         # store length at publish time
        self._publishes = 0             # snapshot (re)publish count
        # delta vs the last published snapshot (LSM-style patch-not-rebuild)
        self._added: Set[int] = set()   # record ids inserted since publish
        self._tombstones: Set[int] = set()  # published records deleted since
        self._dtable: Optional[DeltaTable] = None  # device added-set index
        self._dtable_epoch = -1
        self._payload = None
        self._payload_key: Optional[Tuple[int, int]] = None  # (real rows, V)
        # adaptive candidate capacity: remembered across queries so the
        # overflow ladder (cap doubling) is walked once, not per call
        self._cap = self.config.initial_cap

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, gs: GeometrySet, glin_cfg: GLINConfig = GLINConfig(),
              config: Optional[EngineConfig] = None) -> "SpatialIndex":
        return cls(GLIN.build(gs, glin_cfg), config)

    @property
    def gs(self) -> GeometrySet:
        return self.glin.gs

    def __len__(self) -> int:
        return self.glin.num_records

    def stats(self) -> dict:
        st = self.glin.stats()
        st["epoch"] = self._epoch
        st["snapshot_epoch"] = self._snapshot_epoch
        st["snapshot_stale"] = self.snapshot_is_stale()
        st["delta_size"] = self.delta_size()
        st["snapshot_publishes"] = self._publishes
        return st

    # ------------------------------------------------------------ maintenance
    def insert(self, verts: np.ndarray, nverts: int, kind: int = 0) -> int:
        rec = self.glin.insert(verts, nverts, kind)
        self._epoch += 1
        self._added.add(rec)
        return rec

    def delete(self, rec: int) -> bool:
        ok = self.glin.delete(rec)
        if ok:
            self._epoch += 1
            if rec in self._added:
                self._added.remove(rec)
            elif rec < self._snapshot_recs:
                self._tombstones.add(rec)
            # else: the record was never published nor added since the last
            # publish — it cannot appear in snapshot results, nothing to patch
        return ok

    def delta_size(self) -> int:
        """Records added plus published records tombstoned since the last
        snapshot publish (the work a ``device+delta`` query must patch)."""
        return len(self._added) + len(self._tombstones)

    # --------------------------------------------------------------- snapshot
    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def device_cap(self) -> int:
        """Current adaptive per-query candidate capacity of the device path."""
        return self._cap

    @property
    def snapshot_epoch(self) -> int:
        return self._snapshot_epoch

    def snapshot_is_stale(self) -> bool:
        return self._snapshot is None or self._snapshot_epoch != self._epoch

    def _padded(self, n: int) -> int:
        q = self.config.pad_quantum
        return n if q <= 0 else max(q, -(-n // q) * q)

    def snapshot(self) -> GLINSnapshot:
        """The flattened device snapshot at the CURRENT epoch (rebuilds when
        stale; a stale snapshot is never handed out).

        The slot arrays are bucket-padded (``EngineConfig.pad_quantum``) so an
        insert-only epoch bump usually republishes with UNCHANGED shapes and
        the jitted query does not recompile. Padding slots sit past the
        ``leaf_start`` sentinel, so no probe or candidate window ever reaches
        them; their values are inert.
        """
        if self.snapshot_is_stale():
            snap = snapshot_from_host(self.glin)
            n = snap.keys_hi.shape[0]
            pad = self._padded(n) - n
            if pad:
                big = np.full(pad, (1 << 30) - 1, np.int32)
                far = jnp.full((pad, 4), 2e30, jnp.float32)  # hits nothing
                snap = dataclasses.replace(
                    snap,
                    keys_hi=jnp.concatenate([snap.keys_hi, jnp.asarray(big)]),
                    keys_lo=jnp.concatenate([snap.keys_lo, jnp.asarray(big)]),
                    recs=jnp.concatenate(
                        [snap.recs, jnp.zeros(pad, jnp.int32)]),
                    rec_leaf=jnp.concatenate(
                        [snap.rec_leaf,
                         jnp.full(pad, snap.num_leaves - 1, jnp.int32)]),
                    slot_lmbr=jnp.concatenate([snap.slot_lmbr, far]),
                    slot_rmbr=jnp.concatenate([snap.slot_rmbr, far]),
                )
            self._snapshot = snap
            self._snapshot_epoch = self._epoch
            self._snapshot_recs = len(self.glin.gs)
            self._publishes += 1
            self._added.clear()
            self._tombstones.clear()
            self._dtable = None
            self._dtable_epoch = -1
        return self._snapshot

    def _published_snapshot(self) -> GLINSnapshot:
        """The last *published* snapshot, possibly behind the current epoch —
        only the ``device+delta`` path may serve it, and only together with
        the tombstone/added patch that restores exactness. Publishes a fresh
        snapshot when none exists yet (the delta is then empty)."""
        if self._snapshot is None:
            return self.snapshot()
        return self._snapshot

    def _device_payload(self, needed_recs: Optional[int] = None):
        """fp32 device copies of the geometry store, bucket-padded like the
        snapshot (padding rows are never gathered: snapshot ``recs`` only
        holds real record ids). Keyed on the store's (records, vertex
        capacity) rather than the epoch, and reused as long as it covers
        ``needed_recs`` (the store length the snapshot being served
        references): the store is append-only and deletes never touch it, so
        neither deletes nor inserts past the snapshot may force a multi-MB
        re-upload."""
        gs = self.glin.gs
        width = gs.verts.shape[1]
        need = len(gs) if needed_recs is None else needed_recs
        if (self._payload is None or self._payload_key[1] != width
                or self._payload_key[0] < need):
            n = len(gs)
            m = self._padded(n)
            verts = np.zeros((m, *gs.verts.shape[1:]), np.float32)
            verts[:n] = gs.verts
            nverts = np.ones(m, gs.nverts.dtype)
            nverts[:n] = gs.nverts
            kinds = np.zeros(m, np.int32)
            kinds[:n] = gs.kinds
            mbrs = np.zeros((m, 4), np.float32)
            mbrs[:n] = gs.mbrs
            self._payload = (jnp.asarray(verts), jnp.asarray(nverts),
                             jnp.asarray(kinds), jnp.asarray(mbrs))
            self._payload_key = (n, width)
        return self._payload

    def _compaction(self, base_relation: str) -> str:
        """Stage-1 refinement implementation for ``batch_query``: the fused
        Pallas kernel on TPU, the jnp reference elsewhere (interpret-mode
        Pallas is a correctness tool, not a CPU execution path), and the jnp
        reference whenever the relation's MBR prefilter has no static kernel
        shape (``prefilter_kind == "custom"``)."""
        mode = self.config.compaction
        if mode is None:
            mode = "pallas" if jax.default_backend() == "tpu" else "scan"
        if mode == "pallas":
            from repro.kernels.refine import MAX_COMPACT_BUDGET

            if (get_relation(base_relation).prefilter_kind == "custom"
                    or self.config.exact_budget > MAX_COMPACT_BUDGET):
                # custom MBR prefilters have no static kernel shape, and
                # budgets past the VMEM bound cannot host the one-hot
                # scatter block — both take the jnp reference
                mode = "scan"
        return mode

    def _check_augmentable(self, relation: str, base) -> None:
        """Fail loudly when a relation needs the piecewise augmentation and
        the index was built without it — the device ``_augment()`` would
        silently no-op on an empty piecewise table and drop true hits."""
        if base.augment and self.glin.pw is None:
            raise ValueError(f"{relation} requires the piecewise function "
                             "(cfg.enable_piecewise=True)")

    # ------------------------------------------------------------------- plan
    def plan(self, batch, relation: Optional[str] = None) -> QueryPlan:
        """Planned execution for ``batch`` (same input forms as ``query``)."""
        if not isinstance(batch, QueryBatch):
            batch = QueryBatch.window(batch, relation or "intersects")
        cfg = self.config
        if batch.kind == "knn":
            q = len(batch)
            if q >= cfg.knn_device_min_batch and self.glin.pw is not None:
                return QueryPlan(
                    "device", "knn", None, None, False,
                    f"knn as batched dwithin probes at doubling radii "
                    f"({q} points >= knn_device_min_batch="
                    f"{cfg.knn_device_min_batch})")
            return QueryPlan("host", "knn", None, None, False,
                             "knn executes on the host index")
        rel = get_relation(batch.relation)
        base = get_relation(rel.base_name())
        self._check_augmentable(batch.relation, base)
        stale = self.snapshot_is_stale()
        delta = self.delta_size()
        # patch viable: a snapshot has been published, the per-query patch
        # work is bounded (delta_patch_max), and the delta has not yet hit
        # the republish point (refresh_threshold)
        patchable = (self._snapshot is not None
                     and delta <= cfg.delta_patch_max
                     and delta < cfg.refresh_threshold)

        def host(reason):
            return QueryPlan("host", "window", rel.name, base.name, False,
                             reason, delta)

        def device(reason):
            return QueryPlan("device", "window", rel.name, base.name, stale,
                             reason, delta)

        def patched(reason):
            return QueryPlan("device+delta", "window", rel.name, base.name,
                             self._snapshot is None, reason, delta)

        if batch.collect_stats and batch.backend in ("device", "device+delta"):
            raise ValueError("collect_stats is host-only; drop it or force "
                             "backend='host'")
        if batch.backend == "host":
            return host("forced by caller")
        if batch.backend == "device":
            return device("forced by caller")
        if batch.backend == "device+delta":
            return patched("forced by caller")
        if batch.backend is not None:
            raise ValueError(f"unknown backend {batch.backend!r}")
        if batch.collect_stats:
            return host("QueryStats instrumentation is host-only")
        if not base.device_native:
            return host(f"relation {base.name!r} is not device-native")
        q = len(batch)
        if q < cfg.device_min_batch:
            return host(f"batch of {q} < device_min_batch={cfg.device_min_batch}")
        if not stale:
            return device(f"batch of {q} windows on {jax.default_backend()}")
        if patchable:
            return patched(f"snapshot stale; delta of {delta} <= "
                           f"delta_patch_max={cfg.delta_patch_max}: patching "
                           "instead of republishing")
        if q < cfg.stale_rebuild_min_batch:
            return host(f"snapshot stale and batch of {q} < "
                        f"stale_rebuild_min_batch={cfg.stale_rebuild_min_batch}")
        if self._snapshot is None:
            return device(f"no published snapshot yet: publishing for "
                          f"batch of {q}")
        return device(f"snapshot stale; delta of {delta} not patchable "
                      f"(delta_patch_max={cfg.delta_patch_max}, "
                      f"refresh_threshold={cfg.refresh_threshold}): "
                      f"republishing for batch of {q}")

    # ------------------------------------------------------------------ query
    def query(self, batch, relation: Optional[str] = None, **kw) -> QueryResult:
        """THE entry point: one or thousands of queries, any relation or knn.

        ``batch`` is a :class:`QueryBatch`, or a bare (4,) / (Q, 4) window
        array (``relation`` then applies, default ``intersects``).
        """
        if not isinstance(batch, QueryBatch):
            batch = QueryBatch.window(batch, relation or "intersects", **kw)
        else:
            if relation is not None and relation != batch.relation:
                raise ValueError("pass the relation inside the QueryBatch")
            if kw:
                raise ValueError(f"{sorted(kw)} must be set on the QueryBatch "
                                 "itself")
        plan = self.plan(batch)
        if batch.kind == "knn":
            return self._run_knn(batch, plan)
        if plan.backend in ("device", "device+delta"):
            ids = self._run_device(batch, plan)
            stats = None
        else:
            ids, stats = self._run_host(batch)
        return QueryResult(ids=ids, plan=plan, epoch=self._epoch, stats=stats)

    # ------------------------------------------------------------- estimation
    def count_candidates(self, windows, relation: str = "intersects"
                         ) -> np.ndarray:
        """MBR-level candidate counts per window (selectivity estimation)
        through the tiled refine kernel — Pallas on TPU, its XLA reference
        semantics elsewhere."""
        from repro.kernels import ops

        base = get_relation(relation).base_name()
        base_rel = get_relation(base)
        self._check_augmentable(relation, base_rel)
        snap = self.snapshot()
        wj = jnp.asarray(np.atleast_2d(np.asarray(windows)).astype(np.float32))
        start, end = batch_query_bounds(snap, wj, base)
        bounds = jnp.stack([start, end], axis=1).astype(jnp.int32)
        # MBR-level counting uses the padded probe window so dwithin-style
        # relations count the candidates their refine step will actually see;
        # the slot-aligned record-MBR table lives on the snapshot (no per-call
        # host gather + upload)
        counts = ops.refine_count(base_rel.probe_window(wj, xp=jnp), bounds,
                                  snap.slot_rmbr,
                                  use_pallas=jax.default_backend() == "tpu")
        return np.asarray(counts)

    # -------------------------------------------------------------- execution
    def _run_host(self, batch: QueryBatch):
        stats = ([QueryStats() for _ in range(len(batch))]
                 if batch.collect_stats else None)
        ids = []
        for i, w in enumerate(batch.windows):
            st = stats[i] if stats is not None else None
            ids.append(np.sort(self.glin.query(w, batch.relation, st)))
        return ids, stats

    def _run_device(self, batch: QueryBatch, plan: QueryPlan) -> List[np.ndarray]:
        cfg = self.config
        rel = get_relation(batch.relation)
        patch = plan.backend == "device+delta"
        # device+delta serves the published snapshot and patches the delta on
        # top; plain device republishes first — either way a query answer
        # always reflects the current epoch exactly
        snap = self._published_snapshot() if patch else self.snapshot()
        verts, nv, kd, mb = self._device_payload(self._snapshot_recs)
        wj = jnp.asarray(batch.windows.astype(np.float32))
        cap, budget = self._cap, cfg.exact_budget
        compaction = self._compaction(rel.base_name())
        while True:
            use_budget = budget if 0 < budget < cap else 0
            hits, counts = batch_query(
                snap, wj, verts, nv, kd, mb, relation=rel.base_name(),
                cap=cap, exact_budget=use_budget, compaction=compaction)
            counts = np.asarray(counts)
            if (counts >= 0).all():
                self._cap = cap
                break
            # The overflow signal conflates run-length > cap with MBR
            # survivors > exact_budget. A cheap bounds-only probe tells them
            # apart, so we jump straight to a sufficient cap (keeping the
            # two-stage budget) and only drop to single-stage when the budget
            # itself was exceeded.
            start, end = batch_query_bounds(snap, wj, relation=rel.base_name())
            need = int(np.max(np.asarray(end - start))) if len(batch) else 0
            if need > cap:
                if cap >= cfg.max_cap or need > cfg.max_cap:
                    raise OverflowError(
                        f"candidate run of {need} exceeded max_cap="
                        f"{cfg.max_cap}; raise EngineConfig.max_cap or "
                        f"narrow the windows")
                cap = min(max(cap * 2, 1 << (need - 1).bit_length()),
                          cfg.max_cap)
            else:
                if not use_budget:
                    raise AssertionError(
                        "single-stage overflow with run <= cap")  # unreachable
                budget = 0
        hits = np.asarray(hits)
        ids = [np.sort(row[row >= 0]).astype(np.int64) for row in hits]
        if patch:
            ids = self._patch_delta(batch, ids)
        if rel.complement_of is not None:
            live = np.nonzero(self.glin._live_mask())[0].astype(np.int64)
            ids = [np.setdiff1d(live, r) for r in ids]
        return ids

    def _delta_table(self) -> DeltaTable:
        """The device-resident added-set side table at the current epoch,
        rebuilt lazily after a write burst (one upload per epoch served, not
        one host round-trip per query batch). Rows are padded to a power-of-
        two bucket so the jitted check compiles per bucket, not per insert."""
        if self._dtable is None or self._dtable_epoch != self._epoch:
            a = len(self._added)
            pad = max(self.config.delta_device_min,
                      1 << max(a - 1, 0).bit_length())
            self._dtable = delta_table_from_host(self.glin, self._added,
                                                 pad_to=pad)
            self._dtable_epoch = self._epoch
        return self._dtable

    def _patch_delta(self, batch: QueryBatch, ids: List[np.ndarray]
                     ) -> List[np.ndarray]:
        """Restore exactness of snapshot results at the current epoch: mask
        out tombstoned records and check the added set (fp32, to match the
        device precision contract) against the *base* relation — complement
        finishing happens after, on top of the patched ids.

        Small added sets are brute-force checked in a host loop; past
        ``EngineConfig.delta_device_min`` the check runs on device through
        the Zmin-sorted :class:`DeltaTable` (one vectorized (Q × A) pass,
        no per-batch host round-trip)."""
        if not (self._tombstones or self._added):
            return ids
        gs = self.glin.gs
        base = get_relation(batch.relation).base_name()
        tombs = (np.fromiter(self._tombstones, np.int64,
                             len(self._tombstones))
                 if self._tombstones else None)
        added = np.asarray(sorted(self._added), np.int64)
        added_hits: Optional[List[np.ndarray]] = None
        if added.shape[0] >= self.config.delta_device_min:
            t = self._delta_table()
            snap = self._published_snapshot()
            wj = jnp.asarray(batch.windows.astype(np.float32))
            ok = np.asarray(batch_check_added(
                t, wj, base, snap.grid_x0, snap.grid_y0, snap.grid_cell))
            tbl_ids = np.asarray(t.ids, np.int64)
            added_hits = [np.sort(tbl_ids[row]) for row in ok]
        elif added.shape[0]:
            pred = get_relation(base).predicate
            av = gs.verts[added].astype(np.float32)
            an, ak = gs.nverts[added], gs.kinds[added]
            added_hits = []
            for qi in range(len(ids)):
                w32 = batch.windows[qi].astype(np.float32)
                added_hits.append(added[np.asarray(pred(w32, av, an, ak))])
        out: List[np.ndarray] = []
        for qi, h in enumerate(ids):
            if tombs is not None:
                h = h[~np.isin(h, tombs)]
            if added_hits is not None:
                # added ids all postdate (exceed) every snapshot id, so the
                # concatenation stays ascending
                h = np.concatenate([h, added_hits[qi]])
            out.append(h)
        return out

    def _run_knn(self, batch: QueryBatch, plan: QueryPlan) -> QueryResult:
        if plan.backend == "device":
            return self._run_knn_device(batch, plan)
        ids, dists = [], []
        for p in batch.points:
            i, d = _host_knn(self.glin, p, batch.k)
            ids.append(np.asarray(i, np.int64))
            dists.append(np.asarray(d))
        return QueryResult(ids=ids, plan=plan, epoch=self._epoch,
                           distances=dists)

    def _run_knn_device(self, batch: QueryBatch, plan: QueryPlan
                        ) -> QueryResult:
        """knn through ``dwithin`` (cf. LISA): every point becomes a
        degenerate window probed with ``dwithin:<r>`` at doubling radii —
        ONE batched facade query per radius rung, so the planner takes the
        device path instead of Q sequential host walks. A point is done once
        it has >= k candidates whose k-th exact distance fits inside r (the
        dwithin candidate set is exactly {distance <= r}, so no closer
        geometry can be missing). Radii are snapped to powers of two: each
        rung compiles once and is shared by every knn call."""
        gs = self.glin.gs
        pts = batch.points
        q, k = len(batch), batch.k
        wins = np.concatenate([pts, pts], axis=1)       # degenerate windows
        r = initial_knn_radius(self.glin, k)
        r = float(2.0 ** np.ceil(np.log2(max(r, 1e-9))))
        done = np.zeros(q, bool)
        out_ids: List[Optional[np.ndarray]] = [None] * q
        out_d: List[Optional[np.ndarray]] = [None] * q
        for _ in range(64):
            # only the still-undone points ride the next rung: finished
            # points must not re-probe at (exponentially) wider radii, which
            # would also inflate the shared adaptive candidate cap. The
            # shrinking batch is padded to a power-of-two bucket (repeating
            # the last window) so each (bucket, radius) pair compiles once,
            # not each distinct todo-count
            todo = np.nonzero(~done)[0]
            sub = wins[todo]
            bucket = 1 << max(len(sub) - 1, 0).bit_length()
            if bucket > len(sub):
                sub = np.concatenate(
                    [sub, np.repeat(sub[-1:], bucket - len(sub), axis=0)])
            try:
                res = self.query(
                    QueryBatch.window(sub, f"dwithin:{r:.17g}"))
            except OverflowError:
                # a straggler's radius outgrew max_cap: the host loop has no
                # cap — finish the stragglers there instead of failing the
                # whole batch
                for i in todo:
                    hi, hd = _host_knn(self.glin, pts[int(i)], k)
                    out_ids[int(i)] = np.asarray(hi, np.int64)
                    out_d[int(i)] = np.asarray(hd)
                return QueryResult(ids=out_ids, plan=plan, epoch=self._epoch,
                                   distances=out_d)
            for ti, i in enumerate(todo):
                cand = res[ti]
                if cand.shape[0] < k:
                    continue
                d = np.sqrt(geom.rect_geom_sqdist(
                    wins[i], gs.verts[cand], gs.nverts[cand], gs.kinds[cand]))
                order = np.lexsort((cand, d))
                if d[order[k - 1]] <= r:
                    sel = order[:k]
                    out_ids[int(i)] = cand[sel].astype(np.int64)
                    out_d[int(i)] = d[sel]
                    done[i] = True
            if done.all():
                return QueryResult(ids=out_ids, plan=plan, epoch=self._epoch,
                                   distances=out_d)
            r *= 2.0
        raise RuntimeError("knn did not converge")
