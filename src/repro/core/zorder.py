"""Z-order (Morton) addressing for GLIN (paper §IV).

Two synchronized implementations:

* **Host path** (numpy): 60-bit Z-addresses packed into ``np.int64``. Used by
  the mutable host-side index (build / maintenance) and as the oracle.
* **Device path** (jax.numpy): the TPU has no native 64-bit integer lane, so a
  Z-address is an ``(hi, lo)`` pair of non-negative ``int32`` — 30 interleaved
  bits each (see DESIGN.md §2). Lexicographic (hi, lo) comparison reproduces
  64-bit ordering exactly.

Coordinate quantization follows the paper:
    x = floor((lon - lon0) / cell_size),  y = floor((lat - lat0) / cell_size)
with the default cell size 5e-7 (centimetre-level, §IV) and the WGS84 origin
(-180, -90). Synthetic datasets may use a unit-square domain with a matching
cell size; both are expressed through :class:`ZGrid`.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax.numpy as jnp

# 30 bits per dimension -> 60-bit Z-address.
BITS_PER_DIM = 30
_LO_BITS = 15  # bits 0..14 of each dim interleave into z bits 0..29 ("lo")
_LO_MASK = (1 << _LO_BITS) - 1
LO_LIMB_BITS = 2 * _LO_BITS  # 30
LO_LIMB_SIZE = 1 << LO_LIMB_BITS  # 2**30

__all__ = [
    "ZGrid",
    "WGS84",
    "UNIT",
    "morton_encode_np",
    "morton_decode_np",
    "morton_encode_hilo",
    "split_hilo_np",
    "pack_hilo_np",
    "z_less_hilo",
    "z_leq_hilo",
    "hilo_to_float32",
    "mbr_to_zinterval_np",
    "mbr_to_zinterval_hilo",
]


# ---------------------------------------------------------------------------
# Quantization grid
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ZGrid:
    """Maps continuous coordinates onto the integer Morton grid."""

    x0: float
    y0: float
    cell_size: float

    def quantize_np(self, x: np.ndarray, y: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        # clip as floats BEFORE the int cast: far-out-of-domain coordinates
        # (padded dwithin probe windows) would overflow the cast and wrap to
        # a bogus cell instead of saturating at the domain boundary
        lim = (1 << BITS_PER_DIM) - 1
        qx = np.clip(np.floor((np.asarray(x, np.float64) - self.x0)
                              / self.cell_size), 0, lim).astype(np.int64)
        qy = np.clip(np.floor((np.asarray(y, np.float64) - self.y0)
                              / self.cell_size), 0, lim).astype(np.int64)
        return qx, qy

    # fp32 coordinates carry ~2^-24 relative error: tens of cells at
    # centimetre resolution. Device-side window quantization therefore takes
    # a ``guard`` margin (cells) — negative for lower corners, positive for
    # upper corners — so probe intervals are CONSERVATIVE: they may admit a
    # few extra candidates (pruned by exact refinement) but never lose one.
    FP32_GUARD_CELLS = 64

    def quantize_jnp(self, x: jnp.ndarray, y: jnp.ndarray, guard: int = 0
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        # float32 has 24 bits of mantissa; a 30-bit grid index would lose
        # precision, so quantize in two stages: coarse cell-of-2^15 then fine.
        # The coarse cell is clipped into the domain BEFORE the fine stage
        # (and the fine offset is clipped as a float, before any int cast):
        # out-of-domain coordinates — which padded dwithin probe windows
        # legitimately produce at the domain edge — then clamp to the
        # boundary cell exactly like the host-side quantize_np, instead of
        # wrapping to a bogus fine offset within an out-of-range coarse cell.
        coarse_size = self.cell_size * (1 << _LO_BITS)
        lim = (1 << BITS_PER_DIM) - 1
        lim_hi = (1 << _LO_BITS) - 1
        cx = jnp.clip(jnp.floor((x - self.x0) / coarse_size), 0.0, lim_hi)
        cy = jnp.clip(jnp.floor((y - self.y0) / coarse_size), 0.0, lim_hi)
        fx = jnp.floor((x - (self.x0 + cx * coarse_size)) / self.cell_size)
        fy = jnp.floor((y - (self.y0 + cy * coarse_size)) / self.cell_size)
        qx_hi = cx.astype(jnp.int32)
        qy_hi = cy.astype(jnp.int32)
        qx_lo = jnp.clip(fx, 0.0, lim_hi).astype(jnp.int32)
        qy_lo = jnp.clip(fy, 0.0, lim_hi).astype(jnp.int32)
        qx = (qx_hi << _LO_BITS) | qx_lo
        qy = (qy_hi << _LO_BITS) | qy_lo
        if guard:
            qx = jnp.clip(qx + guard, 0, lim)
            qy = jnp.clip(qy + guard, 0, lim)
        return qx, qy


WGS84 = ZGrid(x0=-180.0, y0=-90.0, cell_size=5e-7)  # paper's default
UNIT = ZGrid(x0=0.0, y0=0.0, cell_size=1.0 / (1 << BITS_PER_DIM))  # unit square


# ---------------------------------------------------------------------------
# Host (numpy / int64) Morton codec
# ---------------------------------------------------------------------------
def _part1by1_np(v: np.ndarray) -> np.ndarray:
    """Spread the low 32 bits of ``v`` over even bit positions (uint64)."""
    v = v.astype(np.uint64)
    v = (v | (v << np.uint64(16))) & np.uint64(0x0000FFFF0000FFFF)
    v = (v | (v << np.uint64(8))) & np.uint64(0x00FF00FF00FF00FF)
    v = (v | (v << np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    v = (v | (v << np.uint64(2))) & np.uint64(0x3333333333333333)
    v = (v | (v << np.uint64(1))) & np.uint64(0x5555555555555555)
    return v


def _compact1by1_np(v: np.ndarray) -> np.ndarray:
    v = v.astype(np.uint64) & np.uint64(0x5555555555555555)
    v = (v | (v >> np.uint64(1))) & np.uint64(0x3333333333333333)
    v = (v | (v >> np.uint64(2))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    v = (v | (v >> np.uint64(4))) & np.uint64(0x00FF00FF00FF00FF)
    v = (v | (v >> np.uint64(8))) & np.uint64(0x0000FFFF0000FFFF)
    v = (v | (v >> np.uint64(16))) & np.uint64(0x00000000FFFFFFFF)
    return v


def morton_encode_np(qx: np.ndarray, qy: np.ndarray) -> np.ndarray:
    """Interleave 30-bit integer coords into a 60-bit Z-address (int64).

    Bit i of x -> bit 2i;  bit i of y -> bit 2i+1 (x least significant,
    matching libmorton / the paper's Figure 2 layout).
    """
    z = _part1by1_np(np.asarray(qx)) | (_part1by1_np(np.asarray(qy)) << np.uint64(1))
    return z.astype(np.int64)


def morton_decode_np(z: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    z = np.asarray(z).astype(np.uint64)
    qx = _compact1by1_np(z)
    qy = _compact1by1_np(z >> np.uint64(1))
    return qx.astype(np.int64), qy.astype(np.int64)


def split_hilo_np(z: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """int64 packed Z-address -> (hi, lo) int32 limbs (30 bits each)."""
    z = np.asarray(z).astype(np.int64)
    hi = (z >> LO_LIMB_BITS).astype(np.int32)
    lo = (z & (LO_LIMB_SIZE - 1)).astype(np.int32)
    return hi, lo


def pack_hilo_np(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    return ((np.asarray(hi).astype(np.int64) << LO_LIMB_BITS)
            | np.asarray(lo).astype(np.int64))


# ---------------------------------------------------------------------------
# Device (jax / int32 hi-lo) Morton codec
# ---------------------------------------------------------------------------
def _part1by1_jnp(v: jnp.ndarray) -> jnp.ndarray:
    """Spread a 15-bit int32 value over even positions of a 30-bit int32."""
    v = v.astype(jnp.uint32)
    v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
    v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & jnp.uint32(0x33333333)
    v = (v | (v << 1)) & jnp.uint32(0x55555555)
    return v


def morton_encode_hilo(qx: jnp.ndarray, qy: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """30-bit int32 coords -> (hi, lo) int32 Z-address limbs.

    The key identity: interleaving bits [0,15) of x/y yields z bits [0,30)
    and interleaving bits [15,30) yields z bits [30,60), so each limb is an
    independent 15x15-bit interleave — no 64-bit arithmetic anywhere.
    """
    qx = qx.astype(jnp.int32)
    qy = qy.astype(jnp.int32)
    x_lo, x_hi = qx & _LO_MASK, qx >> _LO_BITS
    y_lo, y_hi = qy & _LO_MASK, qy >> _LO_BITS
    lo = _part1by1_jnp(x_lo) | (_part1by1_jnp(y_lo) << 1)
    hi = _part1by1_jnp(x_hi) | (_part1by1_jnp(y_hi) << 1)
    return hi.astype(jnp.int32), lo.astype(jnp.int32)


def z_less_hilo(a_hi, a_lo, b_hi, b_lo):
    """a < b on (hi, lo) Z-addresses (all limbs non-negative int32)."""
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def z_leq_hilo(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


def hilo_to_float32(hi, lo, hi0=0, lo0=0):
    """Re-centred fp32 view of a Z-address: (hi-hi0)*2^30 + (lo-lo0).

    TPU has no fp64; re-centring at a node-local origin keeps the learned-CDF
    key well-conditioned in fp32 (DESIGN.md §2).
    """
    dh = (hi - hi0).astype(jnp.float32)
    dl = (lo - lo0).astype(jnp.float32)
    return dh * jnp.float32(LO_LIMB_SIZE) + dl


# ---------------------------------------------------------------------------
# Geometry -> Z-address interval (paper §IV: MBR corners, NOT vertices)
# ---------------------------------------------------------------------------
def mbr_to_zinterval_np(mbrs: np.ndarray, grid: ZGrid) -> Tuple[np.ndarray, np.ndarray]:
    """(N,4) [xmin,ymin,xmax,ymax] -> (zmin, zmax) int64 arrays."""
    mbrs = np.asarray(mbrs, np.float64)
    qx0, qy0 = grid.quantize_np(mbrs[..., 0], mbrs[..., 1])
    qx1, qy1 = grid.quantize_np(mbrs[..., 2], mbrs[..., 3])
    return morton_encode_np(qx0, qy0), morton_encode_np(qx1, qy1)


def mbr_to_zinterval_hilo(mbrs: jnp.ndarray, grid: ZGrid, guard: int = 0):
    """(N,4) float32 MBRs -> ((zmin_hi, zmin_lo), (zmax_hi, zmax_lo)).
    ``guard`` > 0 widens the interval by that many cells per corner (fp32
    conservatism for query windows)."""
    qx0, qy0 = grid.quantize_jnp(mbrs[..., 0], mbrs[..., 1], guard=-guard)
    qx1, qy1 = grid.quantize_jnp(mbrs[..., 2], mbrs[..., 3], guard=guard)
    return morton_encode_hilo(qx0, qy0), morton_encode_hilo(qx1, qy1)
