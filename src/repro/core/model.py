"""GLIN's hierarchical learned CDF model (paper §V-B, ALEX-style).

Host-side structure used for index build + maintenance:

* **Internal nodes** split their key domain into ``fanout`` equal-width cells
  (the paper: "the model prediction in each internal node has perfect accuracy
  thanks to the uniform partitioning"), holding child pointers per cell.
* **Leaf nodes** hold sorted ``(Zmin, record-id)`` arrays with slack capacity
  (the numpy analogue of ALEX gapped arrays: amortized-O(leaf) memmove
  insertion), a local linear regression model ``Zmin -> slot``, the model's
  exact max error (bounding the exponential-search window), and the
  aggregate **MBR** of the leaf's geometries (§V-C).

Routing arithmetic on 60-bit keys uses Python ints (arbitrary precision) for
scalar ops and ``np.searchsorted`` for bulk ops, so no int64 overflow is
possible. The device-resident flattened snapshot lives in ``device.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["GLINModelConfig", "LeafNode", "InternalNode", "build_tree",
           "probe", "leaves_in_order", "tree_stats"]


@dataclasses.dataclass(frozen=True)
class GLINModelConfig:
    fanout: int = 64            # children per internal node (equal-width cells)
    max_leaf: int = 512         # split a partition bigger than this
    err_bound: int = 64         # re-split leaves whose model error exceeds this
    max_depth: int = 12         # force a leaf beyond this depth
    min_split_width: int = 64   # domains narrower than this are never split
    upper_density: float = 0.8  # leaf grows/splits above this fill factor
    lower_density: float = 0.2  # leaf merges below this fill factor


# ---------------------------------------------------------------------------
# Nodes
# ---------------------------------------------------------------------------
class LeafNode:
    __slots__ = ("keys", "recs", "size", "slope", "intercept", "key0",
                 "max_err", "mbr", "next", "dlo", "dhi", "parent", "cell")

    def __init__(self, keys: np.ndarray, recs: np.ndarray, dlo: int, dhi: int):
        n = keys.shape[0]
        cap = max(8, int(n / 0.7) + 1)
        self.keys = np.empty(cap, np.int64)
        self.recs = np.empty(cap, np.int64)
        self.keys[:n] = keys
        self.recs[:n] = recs
        self.size = n
        self.dlo = int(dlo)
        self.dhi = int(dhi)
        self.next: Optional["LeafNode"] = None
        self.parent: Optional["InternalNode"] = None
        self.cell: int = -1
        self.mbr = np.array([np.inf, np.inf, -np.inf, -np.inf], np.float64)
        self.refit()

    # -- learned model ------------------------------------------------------
    def refit(self) -> None:
        n = self.size
        if n == 0:
            self.key0, self.slope, self.intercept, self.max_err = 0, 0.0, 0.0, 0
            return
        k = self.keys[:n]
        self.key0 = int(k[0])
        x = (k - k[0]).astype(np.float64)
        y = np.arange(n, dtype=np.float64)
        vx = float(x @ x) - float(x.sum()) ** 2 / n
        if vx <= 0.0:
            self.slope, self.intercept = 0.0, (n - 1) / 2.0
        else:
            cxy = float(x @ y) - float(x.sum()) * float(y.sum()) / n
            self.slope = cxy / vx
            self.intercept = (float(y.sum()) - self.slope * float(x.sum())) / n
        pred = np.rint(self.slope * x + self.intercept)
        self.max_err = int(np.max(np.abs(pred - y))) if n else 0

    def predict_slot(self, key: int) -> int:
        p = int(round(self.slope * float(key - self.key0) + self.intercept))
        return min(max(p, 0), max(self.size - 1, 0))

    def lower_bound(self, key: int) -> int:
        """Model-predicted position + bounded local search (paper §VI-A)."""
        n = self.size
        if n == 0:
            return 0
        p = self.predict_slot(key)
        lo = max(0, p - self.max_err - 1)
        hi = min(n, p + self.max_err + 2)
        pos = lo + int(np.searchsorted(self.keys[lo:hi], key, side="left"))
        # Window-edge validation: fall back to a full-leaf search when the
        # bounded window did not bracket the answer (possible for absent keys).
        if (pos == lo and lo > 0 and self.keys[lo - 1] >= key) or (
            pos == hi and hi < n and self.keys[hi - 1] < key
        ):
            pos = int(np.searchsorted(self.keys[:n], key, side="left"))
        return pos

    # -- MBR maintenance (§V-C / §VII) --------------------------------------
    def set_mbr_from(self, mbrs: np.ndarray) -> None:
        if mbrs.shape[0] == 0:
            self.mbr = np.array([np.inf, np.inf, -np.inf, -np.inf], np.float64)
        else:
            self.mbr = np.array([mbrs[:, 0].min(), mbrs[:, 1].min(),
                                 mbrs[:, 2].max(), mbrs[:, 3].max()], np.float64)

    def expand_mbr(self, mbr: np.ndarray) -> None:
        self.mbr[0] = min(self.mbr[0], mbr[0])
        self.mbr[1] = min(self.mbr[1], mbr[1])
        self.mbr[2] = max(self.mbr[2], mbr[2])
        self.mbr[3] = max(self.mbr[3], mbr[3])

    # -- mutation -----------------------------------------------------------
    def grow(self) -> None:
        cap = max(16, 2 * self.keys.shape[0])
        for name in ("keys", "recs"):
            new = np.empty(cap, np.int64)
            old = getattr(self, name)
            new[: self.size] = old[: self.size]
            setattr(self, name, new)

    def insert_at(self, pos: int, key: int, rec: int) -> None:
        if self.size >= self.keys.shape[0]:
            self.grow()
        self.keys[pos + 1 : self.size + 1] = self.keys[pos : self.size]
        self.recs[pos + 1 : self.size + 1] = self.recs[pos : self.size]
        self.keys[pos] = key
        self.recs[pos] = rec
        self.size += 1

    def delete_at(self, pos: int) -> None:
        self.keys[pos : self.size - 1] = self.keys[pos + 1 : self.size]
        self.recs[pos : self.size - 1] = self.recs[pos + 1 : self.size]
        self.size -= 1

    def metadata_bytes(self) -> int:
        # model (key0, slope, intercept, max_err) + MBR + domain + pointers
        return 8 * 4 + 32 + 16 + 16


class InternalNode:
    __slots__ = ("dlo", "dhi", "children", "parent", "cell")

    def __init__(self, dlo: int, dhi: int, fanout: int):
        self.dlo = int(dlo)
        self.dhi = int(dhi)
        self.children: List[object] = [None] * fanout
        self.parent: Optional["InternalNode"] = None
        self.cell: int = -1

    @property
    def fanout(self) -> int:
        return len(self.children)

    def route(self, key: int) -> int:
        """Equal-width cell of ``key`` — exact integer arithmetic."""
        f = len(self.children)
        idx = (int(key) - self.dlo) * f // (self.dhi - self.dlo)
        return min(max(idx, 0), f - 1)

    def cell_bounds(self, i: int) -> Tuple[int, int]:
        f = len(self.children)
        w = self.dhi - self.dlo
        return self.dlo + w * i // f, self.dlo + w * (i + 1) // f

    def metadata_bytes(self) -> int:
        return 8 * 2 + 8 * len(self.children)


# ---------------------------------------------------------------------------
# Bulk build (paper §V: top-down equal-width partitioning)
# ---------------------------------------------------------------------------
def build_tree(keys: np.ndarray, recs: np.ndarray, cfg: GLINModelConfig):
    """keys must be sorted int64; recs are record ids aligned with keys."""
    assert keys.dtype == np.int64
    n = keys.shape[0]
    if n == 0:
        root = LeafNode(keys, recs, 0, 1)
        return root, [root]

    dlo = int(keys[0])
    dhi = int(keys[-1]) + 1
    leaves: List[LeafNode] = []

    def rec_build(lo: int, hi: int, s: int, e: int, depth: int):
        count = e - s
        width = hi - lo
        make_leaf = (
            count <= cfg.max_leaf
            or depth >= cfg.max_depth
            or width < cfg.min_split_width
        )
        if not make_leaf:
            node = InternalNode(lo, hi, cfg.fanout)
            bounds = [lo + width * i // cfg.fanout for i in range(cfg.fanout + 1)]
            cuts = np.searchsorted(keys[s:e], np.asarray(bounds[1:-1], np.int64),
                                   side="left") + s
            cuts = [s, *cuts.tolist(), e]
            for i in range(cfg.fanout):
                child = rec_build(bounds[i], bounds[i + 1], cuts[i], cuts[i + 1],
                                  depth + 1)
                child.parent, child.cell = node, i
                node.children[i] = child
            return node
        leaf = LeafNode(keys[s:e], recs[s:e], lo, hi)
        # Optional error-driven re-split: an inaccurate leaf becomes internal.
        if (leaf.max_err > cfg.err_bound and count > cfg.fanout
                and width >= cfg.min_split_width and depth < cfg.max_depth):
            node = InternalNode(lo, hi, cfg.fanout)
            bounds = [lo + width * i // cfg.fanout for i in range(cfg.fanout + 1)]
            cuts = np.searchsorted(keys[s:e], np.asarray(bounds[1:-1], np.int64),
                                   side="left") + s
            cuts = [s, *cuts.tolist(), e]
            for i in range(cfg.fanout):
                child = rec_build(bounds[i], bounds[i + 1], cuts[i], cuts[i + 1],
                                  cfg.max_depth)  # children become leaves
                child.parent, child.cell = node, i
                node.children[i] = child
            return node
        leaves.append(leaf)
        return leaf

    root = rec_build(dlo, dhi, 0, n, 0)

    # The recursion appends leaves in key order except when error-driven
    # re-splits interleave; rebuild the ordered list + next pointers by walk.
    ordered = leaves_in_order(root)
    for a, b in zip(ordered, ordered[1:]):
        a.next = b
    if ordered:
        ordered[-1].next = None
    return root, ordered


def leaves_in_order(root) -> List[LeafNode]:
    out: List[LeafNode] = []

    def walk(node):
        if isinstance(node, LeafNode):
            out.append(node)
        else:
            for c in node.children:
                if c is not None:
                    walk(c)

    walk(root)
    return out


def probe(root, key: int) -> Tuple[LeafNode, int]:
    """model_traversal of Algorithm 1: descend to a leaf, then model-predicted
    lower_bound inside it. Returns (leaf, slot)."""
    node = root
    while isinstance(node, InternalNode):
        node = node.children[node.route(key)]
    return node, node.lower_bound(key)


def tree_stats(root) -> dict:
    n_internal = n_leaf = meta = records = 0
    depth_max = 0
    stack = [(root, 1)]
    while stack:
        node, d = stack.pop()
        depth_max = max(depth_max, d)
        if isinstance(node, LeafNode):
            n_leaf += 1
            meta += node.metadata_bytes()
            records += node.size
        else:
            n_internal += 1
            meta += node.metadata_bytes()
            stack.extend((c, d + 1) for c in node.children if c is not None)
    return {
        "internal_nodes": n_internal,
        "leaf_nodes": n_leaf,
        "nodes": n_internal + n_leaf,
        "index_bytes": meta,
        "records": records,
        "depth": depth_max,
    }
