"""GLIN query augmentation — the piecewise function of paper §VIII.

Each piece summarizes ``piece_limitation`` geometries sorted by Zmax with four
aggregates (Fig 4): ``Zmax_end`` (inclusive upper bound of the piece's Zmax
subdomain), ``Min_Zmin``, ``Sum_Zmin`` and ``Count``.

Augmentation (Alg 2): given ``Zmin_Q``, find the first piece whose
``Zmax_end >= Zmin_Q`` and lower ``Zmin_Q`` to the minimum ``Min_Zmin`` of that
piece and all pieces after it, so that every geometry with
``Zmax_GM >= Zmin_Q`` is covered (Lemma 2 OR-conditions 2 and 3).

Two implementations are provided:

* ``augment_scan``  — the paper's Algorithm 2 verbatim (binary search + linear
  scan over the remaining pieces), kept as the faithful baseline;
* ``augment``       — beyond-paper: a **suffix-min** array turns the scan into
  one O(log P) binary search + one gather. Identical output, asymptotically
  faster; benchmarked against each other in ``bench_pl_tuning``.

Maintenance follows §VIII-C: in-bound insertion updates aggregates in place,
out-of-bound insertion extends the first/last piece or appends a new one,
deletion decrements ``Sum``/``Count`` but never ``Min`` (min is a
non-invertible aggregate), and ``avg_diff`` signals when to rebuild.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["PiecewiseFunction"]


class PiecewiseFunction:
    def __init__(self, piece_limitation: int = 10000):
        self.piece_limitation = int(piece_limitation)
        self.zmax_end = np.empty(0, np.int64)
        self.min_zmin = np.empty(0, np.int64)
        self.sum_zmin = np.empty(0, np.float64)  # 60-bit keys overflow int64 sums
        self.count = np.empty(0, np.int64)
        self.domain_lo = 0  # smallest Zmax in the dataset (Fig 4's "[2, ...]")
        self._suffix_min: Optional[np.ndarray] = None  # lazy cache

    # ------------------------------------------------------------------ build
    @classmethod
    def build(cls, zmin: np.ndarray, zmax: np.ndarray,
              piece_limitation: int = 10000) -> "PiecewiseFunction":
        """Sort by Zmax, group every ``piece_limitation`` records (§VIII-B).
        The Zmax-sorted order is used transiently and then dropped, exactly as
        the paper describes."""
        pw = cls(piece_limitation)
        n = zmin.shape[0]
        if n == 0:
            return pw
        order = np.argsort(zmax, kind="stable")
        zmin_s = zmin[order]
        zmax_s = zmax[order]
        k = pw.piece_limitation
        n_pieces = (n + k - 1) // k
        pad = n_pieces * k - n
        if pad:
            # pad with +inf-like sentinels that do not affect min/sum
            zmin_s = np.concatenate([zmin_s, np.full(pad, np.iinfo(np.int64).max)])
            zmax_s = np.concatenate([zmax_s, np.full(pad, zmax_s[-1])])
        zmin_g = zmin_s.reshape(n_pieces, k)
        zmax_g = zmax_s.reshape(n_pieces, k)
        pw.zmax_end = zmax_g.max(axis=1).astype(np.int64)
        pw.min_zmin = zmin_g.min(axis=1).astype(np.int64)
        real = np.where(zmin_g == np.iinfo(np.int64).max, 0, zmin_g)
        pw.sum_zmin = real.astype(np.float64).sum(axis=1)
        pw.count = np.minimum(
            k, np.maximum(0, n - np.arange(n_pieces) * k)).astype(np.int64)
        pw.domain_lo = int(zmax_s[0])
        pw._suffix_min = None
        return pw

    @property
    def num_pieces(self) -> int:
        return int(self.zmax_end.shape[0])

    def nbytes(self) -> int:
        return (self.zmax_end.nbytes + self.min_zmin.nbytes
                + self.sum_zmin.nbytes + self.count.nbytes)

    # -------------------------------------------------------------- suffix min
    def _suffix(self) -> np.ndarray:
        if self._suffix_min is None or self._suffix_min.shape[0] != self.num_pieces:
            if self.num_pieces == 0:
                self._suffix_min = np.empty(0, np.int64)
            else:
                self._suffix_min = np.minimum.accumulate(
                    self.min_zmin[::-1])[::-1].copy()
        return self._suffix_min

    def suffix_min(self) -> np.ndarray:
        """Suffix-min of ``Min_Zmin`` (read-only view used by the augmentation
        fast path and by the device snapshot flattening)."""
        return self._suffix()

    # ------------------------------------------------------------ augmentation
    def augment_scan(self, zmin_q: int) -> int:
        """Paper Algorithm 2: binary search, then scan pieces to the end."""
        if self.num_pieces == 0:
            return zmin_q
        i = int(np.searchsorted(self.zmax_end, zmin_q, side="left"))
        m = zmin_q
        while i < self.num_pieces:  # the paper's while-loop
            m = min(m, int(self.min_zmin[i]))
            i += 1
        return m

    def augment(self, zmin_q: int) -> int:
        """Suffix-min fast path (identical result to ``augment_scan``)."""
        if self.num_pieces == 0:
            return zmin_q
        i = int(np.searchsorted(self.zmax_end, zmin_q, side="left"))
        if i >= self.num_pieces:
            return zmin_q
        return min(zmin_q, int(self._suffix()[i]))

    def augment_batch(self, zmin_q: np.ndarray) -> np.ndarray:
        """Vectorized suffix-min augmentation for query batches."""
        if self.num_pieces == 0:
            return np.asarray(zmin_q, np.int64)
        zmin_q = np.asarray(zmin_q, np.int64)
        idx = np.searchsorted(self.zmax_end, zmin_q, side="left")
        suf = np.concatenate([self._suffix(), [np.iinfo(np.int64).max]])
        return np.minimum(zmin_q, suf[idx])

    # ------------------------------------------------------------- maintenance
    def insert(self, zmin: int, zmax: int) -> None:
        """§VIII-C in-bound / out-of-bound insertion."""
        n = self.num_pieces
        if n == 0:
            self._append_piece(zmax, zmin)
            self.domain_lo = zmax
            return
        if zmax < self.domain_lo:
            # Out-of-bound, lower side: extend or prepend the first piece.
            if int(self.count[0]) < self.piece_limitation:
                self._absorb(0, zmin)
            else:
                self._prepend_piece(zmax, zmin)
            self.domain_lo = zmax
        elif zmax > int(self.zmax_end[-1]):
            # Out-of-bound, upper side: extend or append the last piece.
            if int(self.count[-1]) < self.piece_limitation:
                self._absorb(n - 1, zmin)
                self.zmax_end[-1] = zmax
            else:
                self._append_piece(zmax, zmin)
        else:
            # In-bound: first piece whose Zmax_end >= zmax absorbs the record.
            i = int(np.searchsorted(self.zmax_end, zmax, side="left"))
            self._absorb(min(i, n - 1), zmin)
        self._suffix_min = None

    def _absorb(self, i: int, zmin: int) -> None:
        self.min_zmin[i] = min(int(self.min_zmin[i]), zmin)
        self.sum_zmin[i] += float(zmin)
        self.count[i] += 1
        self._suffix_min = None

    def _append_piece(self, zmax_end: int, zmin: int) -> None:
        self.zmax_end = np.append(self.zmax_end, np.int64(zmax_end))
        self.min_zmin = np.append(self.min_zmin, np.int64(zmin))
        self.sum_zmin = np.append(self.sum_zmin, float(zmin))
        self.count = np.append(self.count, np.int64(1))
        self._suffix_min = None

    def _prepend_piece(self, zmax_end: int, zmin: int) -> None:
        self.zmax_end = np.concatenate([[np.int64(zmax_end)], self.zmax_end])
        self.min_zmin = np.concatenate([[np.int64(zmin)], self.min_zmin])
        self.sum_zmin = np.concatenate([[float(zmin)], self.sum_zmin])
        self.count = np.concatenate([[np.int64(1)], self.count])
        self._suffix_min = None

    def delete(self, zmin: int, zmax: int) -> None:
        n = self.num_pieces
        if n == 0:
            return
        i = int(np.searchsorted(self.zmax_end, zmax, side="left"))
        i = min(i, n - 1)
        self.sum_zmin[i] -= float(zmin)
        self.count[i] -= 1
        # Min_Zmin is NOT updated: min is a non-invertible aggregate (§VIII-C).
        if self.count[i] <= 0:
            keep = np.ones(n, bool)
            keep[i] = False
            self.zmax_end = self.zmax_end[keep]
            self.min_zmin = self.min_zmin[keep]
            self.sum_zmin = self.sum_zmin[keep]
            self.count = self.count[keep]
        self._suffix_min = None

    # --------------------------------------------------------------- avg_diff
    def avg_diff(self) -> float:
        """Rebuild heuristic (§VIII-C): mean relative gap between Min_Zmin and
        Avg_Zmin across pieces. Larger values mean staler pieces."""
        if self.num_pieces == 0:
            return 0.0
        cnt = np.maximum(self.count, 1).astype(np.float64)
        avg = self.sum_zmin / cnt
        avg = np.where(avg == 0.0, 1.0, avg)
        return float(np.mean(np.abs(self.min_zmin.astype(np.float64) - avg) / avg))
