"""Exact-geometry predicates for GLIN's refinement step (paper §VI-B).

The paper refines candidates with GEOS ``Contains``/``Intersects`` on exact
shapes. We support the shape families produced by our data generators
(rectangles, convex polygons, polylines) with fully vectorized predicates.

All functions are array-namespace generic: pass ``xp=numpy`` (host refinement,
float64) or ``xp=jax.numpy`` (jitted batch refinement, float32). Geometries
are stored as padded vertex rings::

    verts:  (N, V, 2)  padded with the last valid vertex
    nverts: (N,)       number of valid vertices
    kind:   GeomKind   POLYGON (closed, convex) or POLYLINE (open chain)

Query windows are axis-aligned rectangles (the paper's query windows are MBRs
of KNN result sets), given as (4,) [xmin, ymin, xmax, ymax].
"""
from __future__ import annotations

import enum

import numpy as np

__all__ = [
    "GeomKind",
    "mbr_intersects",
    "mbr_contains",
    "mbrs_of_verts",
    "rect_contains_geoms",
    "rect_covers_geoms",
    "rect_contains_geoms_proper",
    "rect_intersects_polygons",
    "rect_intersects_polylines",
    "rect_intersects_geoms",
    "rect_disjoint_geoms",
    "geoms_cover_rect",
]


class GeomKind(enum.IntEnum):
    POLYGON = 0   # closed convex ring
    POLYLINE = 1  # open chain (roads / rivers)


# ---------------------------------------------------------------------------
# MBR algebra
# ---------------------------------------------------------------------------
def mbr_intersects(a, b, xp=np):
    """(...,4) x (...,4) -> bool. Closed-boundary intersection test."""
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def mbr_contains(outer, inner, xp=np):
    """outer fully contains inner (closed boundaries)."""
    return (
        (outer[..., 0] <= inner[..., 0])
        & (outer[..., 1] <= inner[..., 1])
        & (inner[..., 2] <= outer[..., 2])
        & (inner[..., 3] <= outer[..., 3])
    )


def mbrs_of_verts(verts, nverts, xp=np):
    """Padded vertex rings -> (N,4) MBRs (padding repeats a valid vertex)."""
    xmin = xp.min(verts[..., 0], axis=-1)
    ymin = xp.min(verts[..., 1], axis=-1)
    xmax = xp.max(verts[..., 0], axis=-1)
    ymax = xp.max(verts[..., 1], axis=-1)
    return xp.stack([xmin, ymin, xmax, ymax], axis=-1)


def _valid_mask(verts, nverts, xp):
    v = verts.shape[-2]
    idx = xp.arange(v)
    return idx[None, :] < xp.asarray(nverts)[:, None]  # (N, V)


# ---------------------------------------------------------------------------
# Contains (Q is a rectangle): true iff every vertex lies inside Q.
# Correct for any geometry because the rectangle is convex, so containing the
# vertex set contains the convex hull (and hence the polygon/polyline).
# ---------------------------------------------------------------------------
def rect_contains_geoms(rect, verts, nverts, xp=np):
    x, y = verts[..., 0], verts[..., 1]
    inside = (x >= rect[0]) & (x <= rect[2]) & (y >= rect[1]) & (y <= rect[3])
    valid = _valid_mask(verts, nverts, xp)
    return xp.all(inside | ~valid, axis=-1)


# DE-9IM name for the closed-boundary test: a geometry touching the window
# boundary from the inside is *covered*.
rect_covers_geoms = rect_contains_geoms


def _seg_next_idx(verts, nverts, kinds, xp):
    """Successor-vertex index per vertex: closed ring for polygons (wraps to
    0), clamped open chain for polylines. Returns (idx, nxt, valid)."""
    nv = xp.asarray(nverts)[:, None]
    vcount = verts.shape[-2]
    idx = xp.arange(vcount)[None, :]
    is_poly = (xp.asarray(kinds) == int(GeomKind.POLYGON))[:, None]
    nxt_poly = xp.where(idx + 1 >= nv, 0, idx + 1)
    nxt_line = xp.minimum(idx + 1, vcount - 1)
    return idx, xp.where(is_poly, nxt_poly, nxt_line), idx < nv


def rect_contains_geoms_proper(rect, verts, nverts, kinds, xp=np):
    """Proper (GEOS-style) Contains: geometry covered by the closed window AND
    at least one point of it lies in the window's open interior.

    Exact for the supported shape families: for a covered geometry the interior
    witness exists iff some vertex, edge midpoint, or (polygons) the vertex
    mean is strictly inside — a convex geometry lying wholly on the 1-D window
    boundary has none of the three.
    """
    covered = rect_contains_geoms(rect, verts, nverts, xp=xp)
    x, y = verts[..., 0], verts[..., 1]
    _, nxt, valid = _seg_next_idx(verts, nverts, kinds, xp)

    def strict(px, py):
        return (px > rect[0]) & (px < rect[2]) & (py > rect[1]) & (py < rect[3])

    wit = xp.any(strict(x, y) & valid, axis=-1)
    mx = (x + xp.take_along_axis(x, nxt, axis=-1)) * 0.5
    my = (y + xp.take_along_axis(y, nxt, axis=-1)) * 0.5
    wit = wit | xp.any(strict(mx, my) & valid, axis=-1)
    cnt = xp.maximum(xp.asarray(nverts), 1)
    cx_ = xp.sum(xp.where(valid, x, 0.0), axis=-1) / cnt
    cy_ = xp.sum(xp.where(valid, y, 0.0), axis=-1) / cnt
    is_poly = xp.asarray(kinds) == int(GeomKind.POLYGON)
    wit = wit | (strict(cx_, cy_) & is_poly)
    return covered & wit


def geoms_cover_rect(rect, verts, nverts, kinds, xp=np):
    """(4,), (N,V,2), (N,), (N,) -> (N,): geometry covers the whole window
    (the facade's *Within* relation: window within geometry).

    Only convex polygons with positive area can cover a 2-D window, and for a
    convex polygon "all four window corners inside" is exact (same-side test
    over every edge; degenerate zero-area rings are rejected via shoelace).
    Polylines never cover a window and return False.
    """
    x, y = verts[..., 0], verts[..., 1]
    _, nxt, valid = _seg_next_idx(verts, nverts, kinds, xp)
    x2 = xp.take_along_axis(x, nxt, axis=-1)
    y2 = xp.take_along_axis(y, nxt, axis=-1)
    ex = xp.where(valid, x2 - x, 0.0)
    ey = xp.where(valid, y2 - y, 0.0)
    cx = xp.stack([rect[0], rect[2], rect[2], rect[0]])
    cy = xp.stack([rect[1], rect[1], rect[3], rect[3]])
    # cross(edge, corner - vertex) per edge per corner: (N, V, 4)
    rx = cx[None, None, :] - x[:, :, None]
    ry = cy[None, None, :] - y[:, :, None]
    cross = ex[:, :, None] * ry - ey[:, :, None] * rx
    pvalid = valid[:, :, None]
    pos = xp.all(xp.where(pvalid, cross >= 0.0, True), axis=1)
    neg = xp.all(xp.where(pvalid, cross <= 0.0, True), axis=1)
    corners_in = xp.all(pos | neg, axis=-1)
    area2 = xp.abs(xp.sum(xp.where(valid, x * y2 - x2 * y, 0.0), axis=-1))
    is_poly = xp.asarray(kinds) == int(GeomKind.POLYGON)
    return corners_in & is_poly & (area2 > 0.0)


# ---------------------------------------------------------------------------
# Intersects — convex polygons, via Separating Axis Theorem.
# Axes: rectangle normals (x-axis, y-axis) + every polygon edge normal.
# ---------------------------------------------------------------------------
def rect_intersects_polygons(rect, verts, nverts, xp=np):
    """(4,), (N,V,2), (N,) -> (N,) bool. Exact convex-polygon vs rect."""
    valid = _valid_mask(verts, nverts, xp)  # (N, V)
    x, y = verts[..., 0], verts[..., 1]

    big = xp.asarray(1e30, verts.dtype)
    px_min = xp.min(xp.where(valid, x, big), axis=-1)
    py_min = xp.min(xp.where(valid, y, big), axis=-1)
    px_max = xp.max(xp.where(valid, x, -big), axis=-1)
    py_max = xp.max(xp.where(valid, y, -big), axis=-1)

    # Rect axes (== MBR overlap test).
    axis_sep = (
        (px_max < rect[0]) | (rect[2] < px_min)
        | (py_max < rect[1]) | (rect[3] < py_min)
    )

    # Polygon edge normals. Edge i: v[i] -> v[(i+1) mod nv]; padded edges are
    # degenerate (normal 0) and never separate.
    nv = xp.asarray(nverts)[:, None]
    vcount = verts.shape[-2]
    idx = xp.arange(vcount)[None, :]
    nxt = xp.where(idx + 1 >= nv, 0, idx + 1)
    vx_next = xp.take_along_axis(x, nxt, axis=-1)
    vy_next = xp.take_along_axis(y, nxt, axis=-1)
    ex = xp.where(valid, vx_next - x, 0.0)
    ey = xp.where(valid, vy_next - y, 0.0)
    # Outward/inward doesn't matter for SAT: normal = (-ey, ex).
    nx_, ny_ = -ey, ex  # (N, V) one normal per edge

    # Project polygon vertices onto each of its edge normals: (N, V_axes, V_pts)
    proj_poly = nx_[:, :, None] * x[:, None, :] + ny_[:, :, None] * y[:, None, :]
    pvalid = valid[:, None, :]
    pp_min = xp.min(xp.where(pvalid, proj_poly, big), axis=-1)
    pp_max = xp.max(xp.where(pvalid, proj_poly, -big), axis=-1)

    # Project the 4 rect corners onto each edge normal.
    cx = xp.stack([rect[0], rect[2], rect[2], rect[0]])
    cy = xp.stack([rect[1], rect[1], rect[3], rect[3]])
    proj_rect = (nx_[:, :, None] * cx[None, None, :]
                 + ny_[:, :, None] * cy[None, None, :])
    pr_min = xp.min(proj_rect, axis=-1)
    pr_max = xp.max(proj_rect, axis=-1)

    degenerate = (nx_ == 0.0) & (ny_ == 0.0)
    edge_sep = ((pp_max < pr_min) | (pr_max < pp_min)) & ~degenerate & valid
    axis_sep = axis_sep | xp.any(edge_sep, axis=-1)
    return ~axis_sep


# ---------------------------------------------------------------------------
# Intersects — polylines: any segment clips the rectangle (Liang–Barsky) or
# any endpoint lies inside.
# ---------------------------------------------------------------------------
def rect_intersects_polylines(rect, verts, nverts, xp=np):
    x, y = verts[..., 0], verts[..., 1]
    nv = xp.asarray(nverts)[:, None]
    vcount = verts.shape[-2]
    idx = xp.arange(vcount)[None, :]
    seg_valid = (idx + 1) < nv  # (N, V): segment i..i+1 exists

    nxt = xp.minimum(idx + 1, vcount - 1)
    x1 = xp.take_along_axis(x, nxt, axis=-1)
    y1 = xp.take_along_axis(y, nxt, axis=-1)
    dx, dy = x1 - x, y1 - y

    # Liang–Barsky: segment P + t*D, t in [0,1], clipped by 4 half-planes.
    eps = xp.asarray(1e-30, verts.dtype)

    def _clip(t0, t1, p, q):
        # p*t <= q  half-plane; update (t0, t1); parallel handled via sign(q).
        p_safe = xp.where(p == 0, eps, p)
        r = q / p_safe
        t0n = xp.where(p < 0, xp.maximum(t0, r), t0)
        t1n = xp.where(p < 0, t1, xp.where(p > 0, xp.minimum(t1, r), t1))
        t0n = xp.where(p > 0, t0n, t0n)
        reject_parallel = (p == 0) & (q < 0)
        return t0n, t1n, reject_parallel

    t0 = xp.zeros_like(dx)
    t1 = xp.ones_like(dx)
    reject = xp.zeros_like(dx, dtype=bool)
    for p, q in (
        (-dx, x - rect[0]),
        (dx, rect[2] - x),
        (-dy, y - rect[1]),
        (dy, rect[3] - y),
    ):
        t0, t1, rej = _clip(t0, t1, p, q)
        reject = reject | rej
    seg_hit = (t0 <= t1) & ~reject & seg_valid

    valid = _valid_mask(verts, nverts, xp)
    pt_in = (x >= rect[0]) & (x <= rect[2]) & (y >= rect[1]) & (y <= rect[3]) & valid
    return xp.any(seg_hit, axis=-1) | xp.any(pt_in, axis=-1)


def rect_intersects_geoms(rect, verts, nverts, kinds, xp=np):
    """Dispatch on geometry kind. ``kinds``: (N,) int array of GeomKind."""
    poly = rect_intersects_polygons(rect, verts, nverts, xp=xp)
    line = rect_intersects_polylines(rect, verts, nverts, xp=xp)
    return xp.where(xp.asarray(kinds) == int(GeomKind.POLYGON), poly, line)


def rect_disjoint_geoms(rect, verts, nverts, kinds, xp=np):
    """Complement of Intersects (closed boundaries: touching is NOT disjoint)."""
    return ~rect_intersects_geoms(rect, verts, nverts, kinds, xp=xp)
