"""Exact-geometry predicates for GLIN's refinement step (paper §VI-B).

The paper refines candidates with GEOS ``Contains``/``Intersects`` on exact
shapes. We support the shape families produced by our data generators
(rectangles, simple polygons — convex OR concave — and polylines) with fully
vectorized predicates. Point-in-polygon is an even-odd ray cast and
window/boundary interaction is decided per edge segment, so no predicate
assumes convexity anywhere.

All functions are array-namespace generic: pass ``xp=numpy`` (host refinement,
float64) or ``xp=jax.numpy`` (jitted batch refinement, float32). Predicates
take DENSE padded vertex blocks::

    verts:  (N, V, 2)  padded with the last valid vertex
    nverts: (N,)       number of valid vertices
    kind:   GeomKind   POLYGON (closed simple ring) or POLYLINE (open chain)

The store itself keeps geometry in a CSR vertex pool (``datasets.GeometrySet``
/ the device ``VertexPods``); :func:`ragged_padded` is the thin adapter that
materializes the dense per-candidate view from ``(pool, offsets, nverts)`` at
a chosen width, reproducing the pad-with-last convention exactly — so the
predicates (and the fp64-host / fp32-device ``xp=`` split) are unchanged by
the pool layout.

Query windows are axis-aligned rectangles (the paper's query windows are MBRs
of KNN result sets), given as (4,) [xmin, ymin, xmax, ymax].
"""
from __future__ import annotations

import enum

import numpy as np

__all__ = [
    "GeomKind",
    "mbr_intersects",
    "mbr_contains",
    "mbrs_of_verts",
    "points_in_polygons",
    "points_strictly_in_polygons",
    "rect_contains_geoms",
    "rect_covers_geoms",
    "rect_contains_geoms_proper",
    "rect_intersects_polygons",
    "rect_intersects_polylines",
    "rect_intersects_geoms",
    "rect_disjoint_geoms",
    "rect_interior_intersects_geoms",
    "rect_touches_geoms",
    "rect_crosses_geoms",
    "rect_dwithin_geoms",
    "rect_geom_sqdist",
    "geoms_cover_rect",
    "ragged_padded",
]


class GeomKind(enum.IntEnum):
    POLYGON = 0   # closed simple ring (convex or concave)
    POLYLINE = 1  # open chain (roads / rivers)


# ---------------------------------------------------------------------------
# MBR algebra
# ---------------------------------------------------------------------------
def mbr_intersects(a, b, xp=np):
    """(...,4) x (...,4) -> bool. Closed-boundary intersection test."""
    return (
        (a[..., 0] <= b[..., 2])
        & (b[..., 0] <= a[..., 2])
        & (a[..., 1] <= b[..., 3])
        & (b[..., 1] <= a[..., 3])
    )


def mbr_contains(outer, inner, xp=np):
    """outer fully contains inner (closed boundaries)."""
    return (
        (outer[..., 0] <= inner[..., 0])
        & (outer[..., 1] <= inner[..., 1])
        & (inner[..., 2] <= outer[..., 2])
        & (inner[..., 3] <= outer[..., 3])
    )


def mbrs_of_verts(verts, nverts, xp=np):
    """Padded vertex rings -> (N,4) MBRs (padding repeats a valid vertex)."""
    xmin = xp.min(verts[..., 0], axis=-1)
    ymin = xp.min(verts[..., 1], axis=-1)
    xmax = xp.max(verts[..., 0], axis=-1)
    ymax = xp.max(verts[..., 1], axis=-1)
    return xp.stack([xmin, ymin, xmax, ymax], axis=-1)


def ragged_padded(pool, offsets, nverts, width, xp=np):
    """CSR ragged view -> dense ``(..., width, 2)`` padded block.

    ``pool`` is the flat ``(P, 2)`` vertex pool; ``offsets``/``nverts`` are
    same-shaped integer arrays addressing rings inside it. Each ring is
    gathered at ``width`` lanes, repeating its last valid vertex past
    ``nverts`` — bit-identical to the legacy dense pad-with-last layout (the
    fp32 cast commutes with a gather, so device parity is preserved).
    Out-of-pool indices are clamped, so masked/inert records only need
    ``offset`` to point at ANY valid pool row.
    """
    nverts = xp.asarray(nverts)
    lane = xp.minimum(xp.arange(width), nverts[..., None] - 1)
    idx = xp.clip(xp.asarray(offsets)[..., None] + lane, 0, pool.shape[0] - 1)
    return pool[idx]


def _valid_mask(verts, nverts, xp):
    v = verts.shape[-2]
    idx = xp.arange(v)
    return idx[None, :] < xp.asarray(nverts)[:, None]  # (N, V)


# ---------------------------------------------------------------------------
# Contains (Q is a rectangle): true iff every vertex lies inside Q.
# Correct for any geometry because the rectangle is convex, so containing the
# vertex set contains the convex hull (and hence the polygon/polyline).
# ---------------------------------------------------------------------------
def rect_contains_geoms(rect, verts, nverts, xp=np):
    x, y = verts[..., 0], verts[..., 1]
    inside = (x >= rect[0]) & (x <= rect[2]) & (y >= rect[1]) & (y <= rect[3])
    valid = _valid_mask(verts, nverts, xp)
    return xp.all(inside | ~valid, axis=-1)


# DE-9IM name for the closed-boundary test: a geometry touching the window
# boundary from the inside is *covered*.
rect_covers_geoms = rect_contains_geoms


def _seg_next_idx(verts, nverts, kinds, xp):
    """Successor-vertex index per vertex: closed ring for polygons (wraps to
    0), clamped open chain for polylines. Returns (idx, nxt, valid)."""
    nv = xp.asarray(nverts)[:, None]
    vcount = verts.shape[-2]
    idx = xp.arange(vcount)[None, :]
    is_poly = (xp.asarray(kinds) == int(GeomKind.POLYGON))[:, None]
    nxt_poly = xp.where(idx + 1 >= nv, 0, idx + 1)
    nxt_line = xp.minimum(idx + 1, vcount - 1)
    return idx, xp.where(is_poly, nxt_poly, nxt_line), idx < nv


def _ring_edges(verts, nverts, xp):
    """Closed-ring edges of polygon records: (x1, y1, x2, y2, valid), each
    (N, V). Padding rows are invalid; the last valid vertex closes to v0."""
    nv = xp.asarray(nverts)[:, None]
    vcount = verts.shape[-2]
    idx = xp.arange(vcount)[None, :]
    nxt = xp.where(idx + 1 >= nv, 0, idx + 1)
    x, y = verts[..., 0], verts[..., 1]
    x2 = xp.take_along_axis(x, nxt, axis=-1)
    y2 = xp.take_along_axis(y, nxt, axis=-1)
    return x, y, x2, y2, idx < nv


def _clip_segments(rect, x, y, dx, dy, xp):
    """Liang–Barsky clip of segments P + t·D, t ∈ [0, 1], against the CLOSED
    rectangle. Returns ``(t0, t1, reject)``: the clipped parameter interval
    and the parallel-outside rejection mask. A segment meets the closed rect
    iff ``(t0 <= t1) & ~reject``; zero-length segments degenerate to a point
    test (t-span stays [0, 1], rejection decides)."""
    eps = xp.asarray(1e-30, x.dtype)
    t0 = xp.zeros_like(dx)
    t1 = xp.ones_like(dx)
    reject = xp.zeros(dx.shape, dtype=bool)
    for p, q in (
        (-dx, x - rect[0]),
        (dx, rect[2] - x),
        (-dy, y - rect[1]),
        (dy, rect[3] - y),
    ):
        # p*t <= q half-plane; parallel segments handled via sign(q).
        p_safe = xp.where(p == 0, eps, p)
        r = q / p_safe
        t0 = xp.where(p < 0, xp.maximum(t0, r), t0)
        t1 = xp.where(p > 0, xp.minimum(t1, r), t1)
        reject = reject | ((p == 0) & (q < 0))
    return t0, t1, reject


def _strict_inside(rect, px, py):
    return (px > rect[0]) & (px < rect[2]) & (py > rect[1]) & (py < rect[3])


def _segs_hit_and_open(rect, x, y, x2, y2, xp):
    """One Liang–Barsky pass per segment -> ``(hit, open_hit)``: meets the
    CLOSED rect, and meets the rect's OPEN interior. The open test uses the
    clipped span's midpoint — a chord of a convex set not contained in the
    boundary has a strictly interior midpoint, and a boundary-only span (or
    single touch point) does not."""
    t0, t1, rej = _clip_segments(rect, x, y, x2 - x, y2 - y, xp)
    hit = (t0 <= t1) & ~rej
    tm = (t0 + t1) * 0.5
    mx = x + tm * (x2 - x)
    my = y + tm * (y2 - y)
    return hit, hit & _strict_inside(rect, mx, my)


# ---------------------------------------------------------------------------
# Point-in-polygon: even-odd ray cast, exact for simple (possibly concave)
# rings. Boundary membership is decided by an explicit collinearity test, so
# both closed (boundary counts) and strict (interior only) variants are exact.
# ---------------------------------------------------------------------------
def _ray_cast(px, py, verts, nverts, xp):
    """(P,), (P,), (N,V,2), (N,) -> (odd, on_edge) each (N, P) bool."""
    x1, y1, x2, y2, valid = _ring_edges(verts, nverts, xp)
    x1, y1 = x1[:, :, None], y1[:, :, None]          # (N, V, 1)
    x2, y2 = x2[:, :, None], y2[:, :, None]
    pxb, pyb = px[None, None, :], py[None, None, :]  # (1, 1, P)
    validb = valid[:, :, None]

    # Horizontal ray to +x: count edges straddling py whose crossing lies
    # strictly right of px (half-open rule: ties on vertices count once).
    straddle = (y1 > pyb) != (y2 > pyb)
    denom = y2 - y1
    denom_safe = xp.where(denom == 0, xp.asarray(1.0, denom.dtype), denom)
    xint = x1 + (pyb - y1) / denom_safe * (x2 - x1)
    crossing = straddle & (pxb < xint) & validb
    odd = (xp.sum(crossing, axis=1) % 2) == 1        # (N, P)

    # On-boundary: collinear with an edge and inside its bounding box.
    cross = (x2 - x1) * (pyb - y1) - (y2 - y1) * (pxb - x1)
    in_box = (
        (pxb >= xp.minimum(x1, x2)) & (pxb <= xp.maximum(x1, x2))
        & (pyb >= xp.minimum(y1, y2)) & (pyb <= xp.maximum(y1, y2))
    )
    on_edge = xp.any((cross == 0) & in_box & validb, axis=1)
    return odd, on_edge


def points_in_polygons(px, py, verts, nverts, xp=np):
    """Closed point-in-polygon: (P,), (P,), (N,V,2), (N,) -> (N,P) bool.
    True when the point lies in the polygon's interior OR on its boundary.
    Exact for simple rings, convex or concave; degenerate (zero-area) rings
    contain only their boundary points."""
    odd, on_edge = _ray_cast(px, py, verts, nverts, xp)
    return odd | on_edge


def points_strictly_in_polygons(px, py, verts, nverts, xp=np):
    """Open point-in-polygon: true only for interior points (boundary
    excluded). Same shapes/guarantees as :func:`points_in_polygons`."""
    odd, on_edge = _ray_cast(px, py, verts, nverts, xp)
    return odd & ~on_edge


def _rect_corners(rect, xp, center=False):
    cx = [rect[0], rect[2], rect[2], rect[0]]
    cy = [rect[1], rect[1], rect[3], rect[3]]
    if center:
        cx.append((rect[0] + rect[2]) * 0.5)
        cy.append((rect[1] + rect[3]) * 0.5)
    return xp.stack(cx), xp.stack(cy)


def rect_contains_geoms_proper(rect, verts, nverts, kinds, xp=np):
    """Proper (GEOS-style) Contains: geometry covered by the closed window AND
    at least one point of it lies in the window's open interior.

    Exact for the supported shape families (simple polygons — convex or
    concave — and polylines): for a covered geometry the interior witness
    exists iff some vertex, edge midpoint, or (polygons) the vertex mean is
    strictly inside — a geometry lying wholly on the 1-D window boundary has
    none of the three.
    """
    covered = rect_contains_geoms(rect, verts, nverts, xp=xp)
    x, y = verts[..., 0], verts[..., 1]
    _, nxt, valid = _seg_next_idx(verts, nverts, kinds, xp)

    wit = xp.any(_strict_inside(rect, x, y) & valid, axis=-1)
    mx = (x + xp.take_along_axis(x, nxt, axis=-1)) * 0.5
    my = (y + xp.take_along_axis(y, nxt, axis=-1)) * 0.5
    wit = wit | xp.any(_strict_inside(rect, mx, my) & valid, axis=-1)
    cnt = xp.maximum(xp.asarray(nverts), 1)
    cx_ = xp.sum(xp.where(valid, x, 0.0), axis=-1) / cnt
    cy_ = xp.sum(xp.where(valid, y, 0.0), axis=-1) / cnt
    is_poly = xp.asarray(kinds) == int(GeomKind.POLYGON)
    wit = wit | (_strict_inside(rect, cx_, cy_) & is_poly)
    return covered & wit


def geoms_cover_rect(rect, verts, nverts, kinds, xp=np):
    """(4,), (N,V,2), (N,), (N,) -> (N,): geometry covers the whole window
    (the facade's *Within* relation: window within geometry).

    Exact for simple polygons, convex or concave: the window is covered iff
    all four corners AND the centre lie in the closed polygon (even-odd ray
    cast) and no polygon edge passes through the window's open interior (a
    clipped-midpoint test per edge). The centre test closes the measure-zero
    gap where every corner sits exactly on the boundary of a polygon that
    excludes the interior. Polylines never cover a 2-D window and return
    False.
    """
    x1, y1, x2, y2, valid = _ring_edges(verts, nverts, xp)
    _, open_hit = _segs_hit_and_open(rect, x1, y1, x2, y2, xp)
    interior_clip = xp.any(open_hit & valid, axis=-1)

    px, py = _rect_corners(rect, xp, center=True)
    inside = points_in_polygons(px, py, verts, nverts, xp=xp)  # (N, 5)
    is_poly = xp.asarray(kinds) == int(GeomKind.POLYGON)
    return xp.all(inside, axis=-1) & ~interior_clip & is_poly


# ---------------------------------------------------------------------------
# Intersects — simple polygons (convex or concave): the closed window meets
# the polygon iff some boundary edge meets the closed window (Liang–Barsky)
# or the window lies entirely inside the polygon (corner ray cast).
# ---------------------------------------------------------------------------
def rect_intersects_polygons(rect, verts, nverts, xp=np):
    """(4,), (N,V,2), (N,) -> (N,) bool. Exact simple-polygon vs rect."""
    x1, y1, x2, y2, valid = _ring_edges(verts, nverts, xp)
    hit, _ = _segs_hit_and_open(rect, x1, y1, x2, y2, xp)
    edge_hit = xp.any(hit & valid, axis=-1)

    px, py = _rect_corners(rect, xp)
    corner_in = xp.any(points_in_polygons(px, py, verts, nverts, xp=xp),
                       axis=-1)
    return edge_hit | corner_in


# ---------------------------------------------------------------------------
# Intersects — polylines: any segment clips the rectangle (Liang–Barsky) or
# any endpoint lies inside.
# ---------------------------------------------------------------------------
def rect_intersects_polylines(rect, verts, nverts, xp=np):
    x, y = verts[..., 0], verts[..., 1]
    nv = xp.asarray(nverts)[:, None]
    vcount = verts.shape[-2]
    idx = xp.arange(vcount)[None, :]
    seg_valid = (idx + 1) < nv  # (N, V): segment i..i+1 exists

    nxt = xp.minimum(idx + 1, vcount - 1)
    x1 = xp.take_along_axis(x, nxt, axis=-1)
    y1 = xp.take_along_axis(y, nxt, axis=-1)
    t0, t1, reject = _clip_segments(rect, x, y, x1 - x, y1 - y, xp)
    seg_hit = (t0 <= t1) & ~reject & seg_valid

    valid = _valid_mask(verts, nverts, xp)
    pt_in = (x >= rect[0]) & (x <= rect[2]) & (y >= rect[1]) & (y <= rect[3]) & valid
    return xp.any(seg_hit, axis=-1) | xp.any(pt_in, axis=-1)


def rect_intersects_geoms(rect, verts, nverts, kinds, xp=np):
    """Dispatch on geometry kind. ``kinds``: (N,) int array of GeomKind."""
    poly = rect_intersects_polygons(rect, verts, nverts, xp=xp)
    line = rect_intersects_polylines(rect, verts, nverts, xp=xp)
    return xp.where(xp.asarray(kinds) == int(GeomKind.POLYGON), poly, line)


def rect_disjoint_geoms(rect, verts, nverts, kinds, xp=np):
    """Complement of Intersects (closed boundaries: touching is NOT disjoint)."""
    return ~rect_intersects_geoms(rect, verts, nverts, kinds, xp=xp)


# ---------------------------------------------------------------------------
# Interior interaction — the DE-9IM int(W) ∩ int(G) test behind Touches and
# Crosses. A geometry's interior meets the open window iff some edge's
# clipped midpoint is strictly inside (the clipped span of a segment through
# the open interior has a strictly-interior midpoint; spans on the boundary
# do not), or — polygons only — the window centre is strictly inside the
# ring (window fully interior to the polygon, no boundary crossing).
# Degenerate point-like records follow the DE-9IM convention that a point's
# interior is the point itself.
# ---------------------------------------------------------------------------
def rect_interior_intersects_geoms(rect, verts, nverts, kinds, xp=np):
    x, y = verts[..., 0], verts[..., 1]
    _, nxt, valid = _seg_next_idx(verts, nverts, kinds, xp)
    x2 = xp.take_along_axis(x, nxt, axis=-1)
    y2 = xp.take_along_axis(y, nxt, axis=-1)
    _, open_hit = _segs_hit_and_open(rect, x, y, x2, y2, xp)
    seg_int = xp.any(open_hit & valid, axis=-1)

    ccx = xp.stack([(rect[0] + rect[2]) * 0.5])
    ccy = xp.stack([(rect[1] + rect[3]) * 0.5])
    center_in = points_strictly_in_polygons(ccx, ccy, verts, nverts,
                                            xp=xp)[:, 0]
    is_poly = xp.asarray(kinds) == int(GeomKind.POLYGON)
    return seg_int | (center_in & is_poly)


def rect_touches_geoms(rect, verts, nverts, kinds, xp=np):
    """DE-9IM Touches: W and G share at least one point but their interiors
    are disjoint (they meet only along boundaries).

    Single-pass: one Liang–Barsky clip over the kind-aware edge set decides
    both closed contact and open-interior contact (for polygons the
    kind-aware edges ARE the closed ring; for polylines the clamped trailing
    zero-length segment makes every vertex — including a single-vertex
    record — a point test, so no separate endpoint term is needed), and one
    five-point ray cast decides corners-in (closed, window inside polygon)
    plus centre-in (strict, window interior inside polygon).
    """
    x, y = verts[..., 0], verts[..., 1]
    _, nxt, valid = _seg_next_idx(verts, nverts, kinds, xp)
    x2 = xp.take_along_axis(x, nxt, axis=-1)
    y2 = xp.take_along_axis(y, nxt, axis=-1)
    hit, open_hit = _segs_hit_and_open(rect, x, y, x2, y2, xp)
    edge_hit = xp.any(hit & valid, axis=-1)
    edge_open = xp.any(open_hit & valid, axis=-1)

    px, py = _rect_corners(rect, xp, center=True)
    odd, on_edge = _ray_cast(px, py, verts, nverts, xp)
    corner_in = xp.any((odd | on_edge)[:, :4], axis=-1)
    center_strict = odd[:, 4] & ~on_edge[:, 4]

    is_poly = xp.asarray(kinds) == int(GeomKind.POLYGON)
    inter = edge_hit | (corner_in & is_poly)
    interior = edge_open | (center_strict & is_poly)
    return inter & ~interior


def rect_crosses_geoms(rect, verts, nverts, kinds, xp=np):
    """DE-9IM Crosses for mixed dimensions: a polyline crosses the window
    when its interior passes through the window's interior AND part of it
    lies outside the closed window. Area/area crosses is undefined in
    DE-9IM, so polygon records always return False."""
    open_hit = rect_interior_intersects_geoms(rect, verts, nverts, kinds,
                                              xp=xp)
    inside_all = rect_contains_geoms(rect, verts, nverts, xp=xp)
    is_line = xp.asarray(kinds) == int(GeomKind.POLYLINE)
    return is_line & open_hit & ~inside_all


# ---------------------------------------------------------------------------
# DWithin — Euclidean distance between the window and the geometry at most d
# (distance-buffered Intersects; the ROADMAP's knn-radius relation). For a
# disjoint segment/rect pair the minimum distance is attained either at a
# segment endpoint (point-to-rect) or at a rect corner (point-to-segment),
# so the vectorized minimum over both families is exact.
# ---------------------------------------------------------------------------
def rect_geom_sqdist(rect, verts, nverts, kinds, xp=np):
    """(4,), (N,V,2), (N,), (N,) -> (N,) squared min Euclidean distance
    between the closed window and each geometry (0 where they intersect).
    Shared by ``rect_dwithin_geoms`` and the exact-distance knn ranking."""
    inter = rect_intersects_geoms(rect, verts, nverts, kinds, xp=xp)

    x, y = verts[..., 0], verts[..., 1]
    valid = _valid_mask(verts, nverts, xp)
    big = xp.asarray(1e30, verts.dtype)
    zero = xp.asarray(0.0, verts.dtype)

    # vertex -> rect distance (covers closest-point-at-segment-endpoint)
    ddx = xp.maximum(xp.maximum(rect[0] - x, x - rect[2]), zero)
    ddy = xp.maximum(xp.maximum(rect[1] - y, y - rect[3]), zero)
    vd2 = xp.min(xp.where(valid, ddx * ddx + ddy * ddy, big), axis=-1)

    # rect corner -> edge-segment distance (covers closest-point-at-corner)
    _, nxt, _ = _seg_next_idx(verts, nverts, kinds, xp)
    bx = xp.take_along_axis(x, nxt, axis=-1)
    by = xp.take_along_axis(y, nxt, axis=-1)
    ex, ey = bx - x, by - y                              # (N, V)
    cx, cy = _rect_corners(rect, xp)                     # (4,)
    px = cx[None, None, :] - x[:, :, None]               # (N, V, 4)
    py = cy[None, None, :] - y[:, :, None]
    ll = ex * ex + ey * ey
    ll_safe = xp.where(ll == 0, xp.asarray(1.0, ll.dtype), ll)[:, :, None]
    t = (px * ex[:, :, None] + py * ey[:, :, None]) / ll_safe
    t = xp.clip(t, 0.0, 1.0)
    qx = px - t * ex[:, :, None]
    qy = py - t * ey[:, :, None]
    sd2 = qx * qx + qy * qy                              # (N, V, 4)
    sd2 = xp.min(xp.where(valid[:, :, None], sd2, big), axis=(1, 2))

    d2 = xp.minimum(vd2, sd2)
    return xp.where(inter, xp.asarray(0.0, d2.dtype), d2)


def rect_dwithin_geoms(rect, verts, nverts, kinds, dist, xp=np):
    """(4,), (N,V,2), (N,), (N,), float -> (N,) bool: min Euclidean distance
    between the closed window and the geometry is at most ``dist``."""
    d2 = rect_geom_sqdist(rect, verts, nverts, kinds, xp=xp)
    return d2 <= xp.asarray(float(dist) ** 2, d2.dtype)


# ---------------------------------------------------------------------------
# kNN ordering contract
# ---------------------------------------------------------------------------
def rank_knn(ids, dists, k: int):
    """Canonical kNN ordering: ascending ``(distance, record id)``.

    This is THE tie-break contract shared by every backend. The host ladder
    ranks with ``np.lexsort((ids, d))``; the device rank sorts the operand
    pair ``[d, ids]`` with ``jax.lax.sort(num_keys=2)``; the sharded k-merge
    re-sorts the all-gathered per-shard blocks the same way. All three reduce
    to this ordering, so co-located records (equal exact distance) resolve to
    the same ids on every path and oracle parity never flakes on ties.

    Returns ``(ids[:k], dists[:k])`` in that order — shorter than ``k`` when
    fewer candidates exist (the k > live-records contract).
    """
    ids = np.asarray(ids)
    dists = np.asarray(dists)
    order = np.lexsort((ids, dists))[: max(int(k), 0)]
    return ids[order], dists[order]
