"""Distributed GLIN — the paper's index scaled over a TPU pod mesh.

Layout (DESIGN.md §4):

* the **learned model** (flattened node table, leaf models, leaf MBRs,
  piecewise suffix-min) is tiny — KBs to a few MBs (paper Fig 8 / Tab V) — and
  is **replicated** on every device;
* the **record table** (sorted Zmin limbs, record MBRs, packed vertex rings)
  is **range-partitioned by slot** over the ``data`` (and ``pod``) mesh axes;
* **query batches are sharded over the ``model`` axis** — each model-column
  owns Q/16 windows, each data-row owns N/16 records, so a (16,16) pod
  evaluates 256 query×record tiles fully in parallel with zero collectives in
  the probe/refine path (results stay sharded; a count ``psum`` is optional).

``glin_query_step`` is built with ``shard_map`` so the per-device block logic
is explicit, and is what the multi-pod dry-run lowers (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import geometry as geom
from .device import (GLINSnapshot, HostCapture, lower_bound_in_window,
                     model_window, query_keys, snapshot_capture)
from .relations import get_relation
from .zorder import LO_LIMB_SIZE
from repro.utils.compat import shard_map as compat_shard_map

__all__ = ["shard_glin_arrays", "shard_arrays_from_capture",
           "build_glin_query_step", "build_glin_knn_step",
           "glin_input_specs", "GLIN_MODEL_SPEC", "TABLE_KEYS"]

_I32 = jnp.int32
_NEVER = 2e30          # padding MBR coordinate: intersects/contains nothing

# Replicated model pytree spec (everything in GLINSnapshot is replicated; the
# big sorted arrays travel separately, sharded).
GLIN_MODEL_SPEC = P()

# Slot-ordered record-table keys sharded over the data axes. ``lmbrs`` /
# ``mbrs`` are the slot-aligned leaf / record MBR tables the fused
# mask+compact stage streams (the sharded analogue of the snapshot's
# ``slot_lmbr`` / ``slot_rmbr``). Vertices travel as PER-SHARD POOL SLICES:
# ``vpool`` is each shard's local CSR vertex pool (equal length across
# shards), ``voff`` the slot-aligned offsets INTO THAT LOCAL SLICE, and
# ``vbucket`` each slot's pow2 width-bucket index — the exact-refine stage
# gathers only the widest surviving bucket's width, never a global ``V``.
TABLE_KEYS = ("keys_hi", "keys_lo", "recs", "rec_leaf", "lmbrs", "mbrs",
              "vpool", "voff", "vbucket", "nverts", "kinds")

# per-shard pool slices are padded to this slot quantum so append-driven
# growth between publishes rarely changes the sharded jit signature
_POOL_QUANTUM = 1024


def shard_arrays_from_capture(c: HostCapture, num_shards: int,
                              pool_pad_to: int = 0) -> Dict[str, np.ndarray]:
    """Slot-ordered record payloads from a host capture, padded to
    ``num_shards``. Padding slots carry +inf keys, ``recs == -1`` and
    ``_NEVER`` MBRs (they intersect and contain nothing), so neither
    prefilter shape can ever pick one up; their vertex pointers are inert
    ``(voff=0, nverts=1)``.

    Each shard's records' rings are gathered into a LOCAL vertex pool in
    slot order; every local pool is padded (zeros) to one common length —
    ``max(tightest shard, pool_pad_to)`` rounded up to ``_POOL_QUANTUM`` —
    so the concatenated ``vpool`` shards evenly. The caller can pass the
    previous publish's per-shard length as ``pool_pad_to`` to keep the
    sharded jit signature stable across (compacting) republishes."""
    keys, recs = c.keys, c.recs
    n = keys.shape[0]
    pad = (-n) % num_shards
    local_n = (n + pad) // num_shards if num_shards else 0
    rec_leaf = np.repeat(np.arange(c.num_leaves, dtype=np.int32),
                         np.diff(c.starts).astype(np.int64))
    lmbrs32 = c.leaf_mbrs.astype(np.float32)
    nvr = c.gs_nverts[recs].astype(np.int64)
    # local CSR offsets: exclusive cumsum of ring widths within each shard
    cnt = np.zeros(n + pad, np.int64)
    cnt[:n] = nvr
    cnt2 = cnt.reshape(num_shards, local_n)
    loc_off = np.zeros((num_shards, local_n), np.int64)
    if local_n > 1:
        np.cumsum(cnt2[:, :-1], axis=1, out=loc_off[:, 1:])
    tight = int(cnt2.sum(axis=1).max()) if num_shards else 0
    plocal = max(tight, pool_pad_to, 1)
    plocal += (-plocal) % _POOL_QUANTUM
    vpool = np.zeros((num_shards * plocal, 2), np.float32)
    total = int(nvr.sum())
    if total:
        pos = np.arange(total) - np.repeat(
            np.concatenate([[0], np.cumsum(nvr)[:-1]]), nvr)
        src = np.repeat(c.gs_offsets[recs], nvr) + pos
        loc_flat = loc_off.reshape(-1)
        dst_base = (np.arange(n) // local_n) * plocal + loc_flat[:n]
        vpool[np.repeat(dst_base, nvr) + pos] = \
            c.gs_pool[src].astype(np.float32)
    ladder = 1 << np.arange(31, dtype=np.int64)   # bucket b holds nv <= 2^b
    out = {
        "keys_hi": (keys >> 30).astype(np.int32),
        "keys_lo": (keys & (LO_LIMB_SIZE - 1)).astype(np.int32),
        "recs": recs.astype(np.int32),
        "rec_leaf": rec_leaf,
        "lmbrs": (lmbrs32[rec_leaf] if c.num_leaves
                  else np.empty((0, 4), np.float32)),
        "mbrs": c.gs_mbrs[recs].astype(np.float32),
        "vpool": vpool,
        "voff": loc_off.reshape(-1)[:n].astype(np.int32),
        "vbucket": np.searchsorted(ladder, nvr).astype(np.int32),
        "nverts": c.gs_nverts[recs].astype(np.int32),
        "kinds": c.gs_kinds[recs].astype(np.int32),
    }
    if pad:
        never = np.full((pad, 4), _NEVER, np.float32)
        # pad keys must be the MAXIMAL key in BOTH limbs: a real corner
        # record can carry hi == 2^30-1 with lo > 0, and a (hi, 0) pad
        # appended after it would break the shard-local sort order the
        # bounded binary search relies on
        out["keys_hi"] = np.concatenate(
            [out["keys_hi"], np.full(pad, 2**30 - 1, np.int32)])
        out["keys_lo"] = np.concatenate(
            [out["keys_lo"], np.full(pad, LO_LIMB_SIZE - 1, np.int32)])
        out["recs"] = np.concatenate([out["recs"], np.full(pad, -1, np.int32)])
        out["rec_leaf"] = np.concatenate(
            [out["rec_leaf"], np.zeros(pad, np.int32)])
        out["lmbrs"] = np.concatenate([out["lmbrs"], never])
        out["mbrs"] = np.concatenate([out["mbrs"], never])
        out["voff"] = np.concatenate([out["voff"], np.zeros(pad, np.int32)])
        out["vbucket"] = np.concatenate(
            [out["vbucket"], np.zeros(pad, np.int32)])
        out["nverts"] = np.concatenate([out["nverts"], np.ones(pad, np.int32)])
        out["kinds"] = np.concatenate([out["kinds"], np.zeros(pad, np.int32)])
    return out


def shard_glin_arrays(glin, num_shards: int) -> Dict[str, np.ndarray]:
    """``shard_arrays_from_capture`` over a fresh capture of the live index."""
    return shard_arrays_from_capture(snapshot_capture(glin), num_shards)


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def build_glin_query_step(mesh: Mesh, relation: str = "intersects",
                          cap: int = 512, exact_budget: int = 0,
                          compaction: str = "scan", max_width: int = 64):
    """Returns (step_fn, in_shardings, out_shardings) for the mesh.

    step(snapshot, windows, table) -> (hits, counts):
      hits  (Q, n_data_shards, K) int32  — -1 padded global record ids,
            K = ``exact_budget`` when two-stage refinement is on, else ``cap``
      counts(Q, n_data_shards)     int32 — per-shard hit counts

    ``exact_budget > 0`` runs the fused probe -> mask+compact -> exact-refine
    pipeline PER SHARD (the PR-4 device pipeline, sharded): stage 1 evaluates
    the interval + MBR masks over the shard-local slot-aligned MBR tables
    (``table["lmbrs"]`` / ``table["mbrs"]``) and compacts the survivors to
    ``(Q, exact_budget)`` local slots; stage 2 gathers vertices and runs the
    exact predicate only on those survivors. Each shard then contributes a
    ``(Q, exact_budget)`` survivor block plus its survivor count — the
    all-gathered per-shard counts replace the dense ``(Q, cap)`` candidate
    window as the only cross-shard signal, so HBM/ICI traffic scales with
    ``budget``, not ``cap``. Overflow is encoded per shard as a negative
    count carrying the exact LOCAL need — ``-(local run length) - 1`` when
    the shard's slot run outgrew ``cap`` (``compaction == "scan"`` windows
    stage 1 to ``(Q, cap)``; the Pallas kernel scans the full local run and
    has no cap), else ``-(survivors) - 1`` when only ``exact_budget``
    overflowed — so the caller can tell the two apart by comparing the
    magnitude against ``cap`` and size the right ladder in one step (the
    GLOBAL probe run is a useless overestimate here: a shard only ever sees
    its sub-run). ``core.exec.OverflowLadder.on_sharded_overflow`` consumes
    this encoding — the same ladder object that drives the single-device
    path, so escalation policy lives in exactly one place.

    ``compaction`` picks the stage-1 implementation: ``"scan"`` (the jnp
    cumsum+scatter reference — the CPU path) or ``"pallas"`` (the fused
    ``refine_compact`` kernel on TPU). ``exact_budget == 0`` is the legacy
    dense single-stage path (kept as the sharded benchmark baseline).

    ``max_width`` (a power of two) is the static top of the vertex-width
    bucket ladder and MUST cover the widest record in the table
    (``max_width >= pow2ceil(max nverts)``) — the exact stage switches on
    the widest SURVIVING bucket per call and gathers only that width from
    the shard-local ``vpool`` slice.
    """
    rel = get_relation(relation)
    if not rel.device_native:
        raise ValueError(f"relation {relation!r} is not device-native; shard "
                         f"its base relation {rel.base_name()!r} instead")
    if compaction not in ("scan", "pallas"):
        raise ValueError(f"unsupported sharded compaction {compaction!r} "
                         "(use 'scan' or 'pallas')")
    if exact_budget and compaction == "pallas" \
            and rel.prefilter_kind == "custom":
        raise ValueError(
            f"relation {relation!r} has a custom MBR prefilter; the fused "
            "kernel cannot evaluate it — use compaction='scan'")
    if max_width < 1 or (max_width & (max_width - 1)):
        raise ValueError(f"max_width must be a power of two, got {max_width}")
    daxes = _data_axes(mesh)
    kb = exact_budget if 0 < exact_budget < cap else 0
    nbuckets = max_width.bit_length()      # widths 1, 2, ..., max_width

    table_spec = {k: P(daxes) for k in TABLE_KEYS}
    in_specs = (
        GLIN_MODEL_SPEC,           # snapshot: fully replicated (prefix spec)
        P("model"),                # windows sharded over query axis
        table_spec,
    )
    out_specs = (P("model", daxes), P("model", daxes))

    def local_step(snapshot: GLINSnapshot, windows, table):
        # Each device sees its local slice of the record table and a local
        # slice of the query batch; only the SMALL model tables (nodes, leaf
        # models, leaf MBRs, piecewise suffix-min) are replicated — the
        # global sorted key array is never materialized per device.
        shard_id = jax.lax.axis_index(daxes[0])
        if len(daxes) == 2:
            shard_id = (shard_id * jax.lax.axis_size(daxes[1])
                        + jax.lax.axis_index(daxes[1]))
        local_n = table["keys_hi"].shape[0]
        offset = shard_id.astype(_I32) * local_n

        zmin_hi, zmin_lo, ub_hi, ub_lo = query_keys(snapshot, windows, relation)

        def local_lb(q_hi, q_lo):
            # model predicts a global window; the final search runs on the
            # LOCAL key shard (clipping makes out-of-shard answers land on
            # the shard edge, which is exactly the local lower bound).
            lo_g, hi_g = model_window(snapshot, q_hi, q_lo)
            lo_l = jnp.clip(lo_g - offset, 0, local_n)
            hi_l = jnp.clip(hi_g - offset, 0, local_n)
            return lower_bound_in_window(table["keys_hi"], table["keys_lo"],
                                         q_hi, q_lo, lo_l, hi_l,
                                         snapshot.search_steps + 2)

        lstart = local_lb(zmin_hi, zmin_lo)
        lend = local_lb(ub_hi, ub_lo)
        qn = windows.shape[0]
        probe_w = rel.probe_window(windows, xp=jnp)

        def exact_for(w, vv, nn, kk):
            return rel.predicate(w, vv, nn, kk, xp=jnp)

        def exact_switch(sel, slotc):
            """Exact predicate over selected slots, gathering rings from the
            shard-local pool slice at the width of the WIDEST surviving
            bucket only: ``lax.switch`` executes exactly one width branch,
            so a batch of points never pays a 64-wide ring gather."""
            off = table["voff"][slotc]
            nvs = table["nverts"][slotc]
            kds = table["kinds"][slotc]
            b = jnp.max(jnp.where(sel, table["vbucket"][slotc], 0))

            def branch(width):
                def run(off, nvs, kds):
                    lane = jnp.minimum(
                        jnp.arange(width, dtype=_I32), nvs[..., None] - 1)
                    idx = jnp.clip(off[..., None] + lane, 0,
                                   table["vpool"].shape[0] - 1)
                    return jax.vmap(exact_for)(windows, table["vpool"][idx],
                                               nvs, kds)
                return run

            return jax.lax.switch(
                b, [branch(1 << i) for i in range(nbuckets)], off, nvs, kds)

        def exact_refine_compacted(slots):
            """Exact-shape stage over compacted local survivor slots."""
            taken = slots >= 0
            slotc = jnp.maximum(slots, 0)
            rec = jnp.where(taken, table["recs"][slotc], -1)
            exact = exact_switch(taken, slotc)
            fmask = taken & exact & (rec >= 0)
            hits = jnp.where(fmask, rec, -1)
            return hits, fmask.sum(axis=1).astype(_I32)

        if kb:
            if compaction == "pallas":
                from repro.kernels import ops

                bounds = jnp.stack([lstart, lend], axis=1)
                slots, surv = ops.refine_compact(
                    probe_w, bounds, table["lmbrs"], table["mbrs"],
                    budget=kb, prefilter=rel.prefilter_kind)
                overflow = surv > kb
            else:
                pos = lstart[:, None] + jnp.arange(cap, dtype=_I32)[None, :]
                valid = pos < jnp.minimum(lend, lstart + cap)[:, None]
                posc = jnp.minimum(pos, local_n - 1)
                # no leaf-MBR gather: padded slots sit at _NEVER and every
                # record MBR lies inside its leaf's aggregate MBR (grow-only
                # maintenance), so the record prefilter implies the leaf test
                rmbr = table["mbrs"][posc]
                rec_ok = rel.mbr_prefilter(rmbr, windows[:, None, :], xp=jnp)
                mask = valid & rec_ok
                m32 = mask.astype(_I32)
                excl = jnp.cumsum(m32, axis=1) - m32
                col = jnp.where(mask & (excl < kb), excl, kb)
                slots = jnp.full((qn, kb), -1, _I32).at[
                    jnp.arange(qn, dtype=_I32)[:, None], col
                ].set(posc, mode="drop")
                surv = m32.sum(axis=1)
                runlen = lend - lstart
                run_over = runlen > cap
                overflow = run_over | (surv > kb)
                # run overflow reports the local run length (> cap, so the
                # caller can distinguish it from a survivor count <= cap)
                surv = jnp.where(run_over, runlen, surv)
            hits, counts = exact_refine_compacted(slots)
            counts = jnp.where(overflow, -surv - 1, counts)
            return hits[:, None, :], counts[:, None]

        # dense single-stage path (exact_budget == 0): the benchmark baseline
        pos = lstart[:, None] + jnp.arange(cap, dtype=_I32)[None, :]
        valid = pos < jnp.minimum(lend, lstart + cap)[:, None]
        posc = jnp.minimum(pos, local_n - 1)

        wq = windows[:, None, :]
        # leaf pruning uses the padded probe window (dwithin); the record
        # prefilter pads internally and the predicate sees the raw window
        lmbr = table["lmbrs"][posc]
        leaf_ok = geom.mbr_intersects(lmbr, probe_w[:, None, :], xp=jnp)
        rmbr = table["mbrs"][posc]
        rec_ok = rel.mbr_prefilter(rmbr, wq, xp=jnp)
        mask = valid & leaf_ok & rec_ok
        exact = exact_switch(mask, posc)
        mask = mask & exact & (table["recs"][posc] >= 0)
        hits = jnp.where(mask, table["recs"][posc], -1)
        counts = mask.sum(axis=1).astype(_I32)
        runlen = lend - lstart
        # truncation signal carries the local run length (the needed cap)
        counts = jnp.where(runlen > cap, -runlen - 1, counts)
        return hits[:, None, :], counts[:, None]

    step = compat_shard_map(local_step, mesh, in_specs, out_specs)

    in_shardings = (
        NamedSharding(mesh, GLIN_MODEL_SPEC),  # prefix: whole snapshot
        NamedSharding(mesh, P("model")),
        {k: NamedSharding(mesh, s) for k, s in table_spec.items()},
    )
    out_shardings = tuple(NamedSharding(mesh, s) for s in out_specs)
    return step, in_shardings, out_shardings


def build_glin_knn_step(mesh: Mesh, relation: str, k: int, cap: int = 512,
                        exact_budget: int = 0, compaction: str = "scan",
                        max_width: int = 64):
    """Device-complete sharded kNN: shard-local top-k + cross-shard k-merge.

    Returns (step_fn, in_shardings, out_shardings) like
    :func:`build_glin_query_step`; ``relation`` must be a bound
    ``dwithin:<r>`` (the probe radius rides on ``rel.probe_pad``).

    step(snapshot, windows, table) -> (ids, dists, counts):
      ids   (Q, k) int32   — merged global record ids, ascending
                             (distance, id), -1 past the candidate count
      dists (Q, k) float32 — matching exact point-to-geometry distances
      counts(Q, n_data_shards) int32 — per-shard within-radius candidate
                             counts; negative = the shard's overflow signal
                             (same encoding as the window step, consumed by
                             ``OverflowLadder.on_sharded_overflow``)

    Inside the shard_map each shard selects its dwithin candidates exactly
    like the window step (same compaction ladder, same overflow encoding),
    then gathers exact SQUARED distances from its local vertex pool at the
    widest surviving width bucket and partial-sorts its own block to a
    local ``(Q, k)`` top-k by ascending ``(d2, global id)`` — candidate
    sets never leave their shard. The cross-shard merge is ONE collective:
    reshaping the ``(Q, shards, k)`` output across the data axes
    all-gathers every shard's block, and a replicated two-key sort takes
    the global k — ``q * shards * (k*8 + 4)`` bytes on the wire (the
    collective term of ``kernels.refine.sharded_knn_cost``), independent of
    the candidate counts.

    The within-radius counts are compared in squared form — exactly the
    dwithin predicate's test — so the caller's settlement rule (done once
    the summed counts reach k) never over-counts. Snapshot records only: no
    tombstone/delta merge here — the caller republishes a stale snapshot
    first (the sharded k-merge exactness contract)."""
    rel = get_relation(relation)
    if not relation.startswith("dwithin:") or rel.parametric:
        raise ValueError(f"knn step needs a bound dwithin relation, got "
                         f"{relation!r}")
    if compaction not in ("scan", "pallas"):
        raise ValueError(f"unsupported sharded compaction {compaction!r} "
                         "(use 'scan' or 'pallas')")
    if max_width < 1 or (max_width & (max_width - 1)):
        raise ValueError(f"max_width must be a power of two, got {max_width}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    r2 = jnp.float32(float(rel.probe_pad) ** 2)
    daxes = _data_axes(mesh)
    kb = exact_budget if 0 < exact_budget < cap else 0
    nbuckets = max_width.bit_length()
    id_pad = jnp.int32(2**31 - 1)     # sorts after every real record id

    table_spec = {kk: P(daxes) for kk in TABLE_KEYS}
    in_specs = (GLIN_MODEL_SPEC, P("model"), table_spec)
    out_specs = (P("model", daxes), P("model", daxes), P("model", daxes))

    def local_step(snapshot: GLINSnapshot, windows, table):
        # candidate selection: the window step's per-shard slot compaction,
        # verbatim (see build_glin_query_step for the commentary)
        shard_id = jax.lax.axis_index(daxes[0])
        if len(daxes) == 2:
            shard_id = (shard_id * jax.lax.axis_size(daxes[1])
                        + jax.lax.axis_index(daxes[1]))
        local_n = table["keys_hi"].shape[0]
        offset = shard_id.astype(_I32) * local_n

        zmin_hi, zmin_lo, ub_hi, ub_lo = query_keys(snapshot, windows,
                                                    relation)

        def local_lb(q_hi, q_lo):
            lo_g, hi_g = model_window(snapshot, q_hi, q_lo)
            lo_l = jnp.clip(lo_g - offset, 0, local_n)
            hi_l = jnp.clip(hi_g - offset, 0, local_n)
            return lower_bound_in_window(table["keys_hi"], table["keys_lo"],
                                         q_hi, q_lo, lo_l, hi_l,
                                         snapshot.search_steps + 2)

        lstart = local_lb(zmin_hi, zmin_lo)
        lend = local_lb(ub_hi, ub_lo)
        qn = windows.shape[0]
        probe_w = rel.probe_window(windows, xp=jnp)

        if kb:
            if compaction == "pallas":
                from repro.kernels import ops

                bounds = jnp.stack([lstart, lend], axis=1)
                slots, surv = ops.refine_compact(
                    probe_w, bounds, table["lmbrs"], table["mbrs"],
                    budget=kb, prefilter=rel.prefilter_kind)
                overflow = surv > kb
            else:
                pos = lstart[:, None] + jnp.arange(cap, dtype=_I32)[None, :]
                valid = pos < jnp.minimum(lend, lstart + cap)[:, None]
                posc = jnp.minimum(pos, local_n - 1)
                rmbr = table["mbrs"][posc]
                rec_ok = rel.mbr_prefilter(rmbr, windows[:, None, :], xp=jnp)
                mask = valid & rec_ok
                m32 = mask.astype(_I32)
                excl = jnp.cumsum(m32, axis=1) - m32
                col = jnp.where(mask & (excl < kb), excl, kb)
                slots = jnp.full((qn, kb), -1, _I32).at[
                    jnp.arange(qn, dtype=_I32)[:, None], col
                ].set(posc, mode="drop")
                surv = m32.sum(axis=1)
                runlen = lend - lstart
                run_over = runlen > cap
                overflow = run_over | (surv > kb)
                surv = jnp.where(run_over, runlen, surv)
        else:
            # dense single-stage selection: every in-run slot passing the
            # (radius-padded) record-MBR prefilter becomes a candidate
            pos = lstart[:, None] + jnp.arange(cap, dtype=_I32)[None, :]
            valid = pos < jnp.minimum(lend, lstart + cap)[:, None]
            posc = jnp.minimum(pos, local_n - 1)
            rmbr = table["mbrs"][posc]
            rec_ok = rel.mbr_prefilter(rmbr, windows[:, None, :], xp=jnp)
            slots = jnp.where(valid & rec_ok, posc, -1)
            runlen = lend - lstart
            overflow = runlen > cap
            surv = runlen

        # shard-local ranking: exact squared distances over the surviving
        # slots, rings gathered from the LOCAL pool slice at the width of
        # the widest surviving bucket only (the knn analogue of the window
        # step's exact_switch — sqdist instead of the predicate)
        taken = slots >= 0
        slotc = jnp.maximum(slots, 0)
        rec = jnp.where(taken, table["recs"][slotc], -1)
        off = table["voff"][slotc]
        nvs = table["nverts"][slotc]
        kds = table["kinds"][slotc]
        b = jnp.max(jnp.where(taken, table["vbucket"][slotc], 0))

        def branch(width):
            def run(off, nvs, kds):
                lane = jnp.minimum(
                    jnp.arange(width, dtype=_I32), nvs[..., None] - 1)
                idx = jnp.clip(off[..., None] + lane, 0,
                               table["vpool"].shape[0] - 1)
                return jax.vmap(
                    lambda w, vv, nn, kk: geom.rect_geom_sqdist(
                        w, vv, nn, kk, xp=jnp)
                )(windows, table["vpool"][idx], nvs, kds)
            return run

        d2 = jax.lax.switch(
            b, [branch(1 << i) for i in range(nbuckets)], off, nvs, kds)
        ok = taken & (rec >= 0)
        inf = jnp.float32(jnp.inf)
        d2 = jnp.where(ok, d2, inf)
        idv = jnp.where(ok, rec, id_pad)
        within = (d2 <= r2).sum(axis=1).astype(_I32)
        counts = jnp.where(overflow, -surv.astype(_I32) - 1, within)
        if d2.shape[1] < k:               # k > budget: pad the sort columns
            padw = k - d2.shape[1]
            d2 = jnp.concatenate([d2, jnp.full((qn, padw), inf)], axis=1)
            idv = jnp.concatenate(
                [idv, jnp.full((qn, padw), id_pad, _I32)], axis=1)
        d2s, idss = jax.lax.sort([d2, idv], num_keys=2)
        return (d2s[:, None, :k], idss[:, None, :k], counts[:, None])

    local = compat_shard_map(local_step, mesh, in_specs, out_specs)
    nshards = 1
    for a in daxes:
        nshards *= mesh.shape[a]

    def step(snapshot, windows, table):
        d2b, idb, counts = local(snapshot, windows, table)
        q = windows.shape[0]
        # cross-shard k-merge: flattening the shard axis all-gathers the
        # (shards, k) blocks over the data axes (ONE collective) and the
        # replicated two-key sort takes the global k
        d2s, idss = jax.lax.sort(
            [d2b.reshape(q, nshards * k), idb.reshape(q, nshards * k)],
            num_keys=2)
        d2k, idk = d2s[:, :k], idss[:, :k]
        dists = jnp.sqrt(jnp.maximum(d2k, 0.0))
        return jnp.where(jnp.isinf(d2k), -1, idk), dists, counts

    in_shardings = (
        NamedSharding(mesh, GLIN_MODEL_SPEC),
        NamedSharding(mesh, P("model")),
        {kk: NamedSharding(mesh, s) for kk, s in table_spec.items()},
    )
    out_shardings = (NamedSharding(mesh, P("model")),
                     NamedSharding(mesh, P("model")),
                     NamedSharding(mesh, P("model", daxes)))
    return step, in_shardings, out_shardings


def _snapshot_spec_tree():
    """A GLINSnapshot-shaped pytree of placeholder leaves (for spec mapping)."""
    fields = [f.name for f in dataclasses.fields(GLINSnapshot)
              if not f.metadata.get("static")]
    dummy = {name: 0 for name in fields}
    return GLINSnapshot(**dummy, search_steps=1, depth=1, grid_x0=0.0,
                        grid_y0=0.0, grid_cell=1.0)


def glin_input_specs(num_records: int, num_queries: int, mesh: Mesh,
                     num_leaves: int = 1 << 20, num_nodes: int = 1 << 14,
                     num_pieces: int = 1 << 12, max_verts: int = 12,
                     fanout: int = 64, pool_slots: int = 0):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation).

    Sizes default to a 2^30-record production index: the model tables stay
    tiny (replicated), the record table shards over pod×data.
    ``pool_slots`` sizes the sharded CSR vertex pool (total slots across
    shards); it defaults to ``num_records * (max_verts + 1) // 2`` — the
    pooled layout stores the MEAN record width, not N x the max.
    """
    f32 = jnp.float32
    i32 = jnp.int32
    # Record-level arrays are NOT used by the distributed step (they travel
    # sharded in `table`); keep them 1-element so the replicated snapshot is
    # only the model tables (a few MB, matching the paper's index sizes).
    snap = GLINSnapshot(
        keys_hi=jax.ShapeDtypeStruct((1,), i32),
        keys_lo=jax.ShapeDtypeStruct((1,), i32),
        recs=jax.ShapeDtypeStruct((1,), i32),
        rec_leaf=jax.ShapeDtypeStruct((1,), i32),
        slot_lmbr=jax.ShapeDtypeStruct((1, 4), f32),
        slot_rmbr=jax.ShapeDtypeStruct((1, 4), f32),
        leaf_start=jax.ShapeDtypeStruct((num_leaves + 1,), i32),
        leaf_dlo_hi=jax.ShapeDtypeStruct((num_leaves + 1,), i32),
        leaf_dlo_lo=jax.ShapeDtypeStruct((num_leaves + 1,), i32),
        leaf_mbr=jax.ShapeDtypeStruct((num_leaves, 4), f32),
        leaf_k0_hi=jax.ShapeDtypeStruct((num_leaves,), i32),
        leaf_k0_lo=jax.ShapeDtypeStruct((num_leaves,), i32),
        leaf_slope=jax.ShapeDtypeStruct((num_leaves,), f32),
        leaf_icpt=jax.ShapeDtypeStruct((num_leaves,), f32),
        node_dlo_hi=jax.ShapeDtypeStruct((num_nodes,), i32),
        node_dlo_lo=jax.ShapeDtypeStruct((num_nodes,), i32),
        node_scale=jax.ShapeDtypeStruct((num_nodes,), f32),
        node_fanout=jax.ShapeDtypeStruct((num_nodes,), i32),
        node_child_base=jax.ShapeDtypeStruct((num_nodes,), i32),
        child_codes=jax.ShapeDtypeStruct((num_nodes * fanout,), i32),
        pw_zmax_hi=jax.ShapeDtypeStruct((num_pieces,), i32),
        pw_zmax_lo=jax.ShapeDtypeStruct((num_pieces,), i32),
        pw_sufmin_hi=jax.ShapeDtypeStruct((num_pieces,), i32),
        pw_sufmin_lo=jax.ShapeDtypeStruct((num_pieces,), i32),
        search_steps=8, depth=4, grid_x0=-180.0, grid_y0=-90.0,
        grid_cell=5e-7,
    )
    windows = jax.ShapeDtypeStruct((num_queries, 4), f32)
    if not pool_slots:
        pool_slots = num_records * (max_verts + 1) // 2
    table = {
        "keys_hi": jax.ShapeDtypeStruct((num_records,), i32),
        "keys_lo": jax.ShapeDtypeStruct((num_records,), i32),
        "recs": jax.ShapeDtypeStruct((num_records,), i32),
        "rec_leaf": jax.ShapeDtypeStruct((num_records,), i32),
        "lmbrs": jax.ShapeDtypeStruct((num_records, 4), f32),
        "mbrs": jax.ShapeDtypeStruct((num_records, 4), f32),
        "vpool": jax.ShapeDtypeStruct((pool_slots, 2), f32),
        "voff": jax.ShapeDtypeStruct((num_records,), i32),
        "vbucket": jax.ShapeDtypeStruct((num_records,), i32),
        "nverts": jax.ShapeDtypeStruct((num_records,), i32),
        "kinds": jax.ShapeDtypeStruct((num_records,), i32),
    }
    return snap, windows, table
