"""Distributed GLIN — the paper's index scaled over a TPU pod mesh.

Layout (DESIGN.md §4):

* the **learned model** (flattened node table, leaf models, leaf MBRs,
  piecewise suffix-min) is tiny — KBs to a few MBs (paper Fig 8 / Tab V) — and
  is **replicated** on every device;
* the **record table** (sorted Zmin limbs, record MBRs, packed vertex rings)
  is **range-partitioned by slot** over the ``data`` (and ``pod``) mesh axes;
* **query batches are sharded over the ``model`` axis** — each model-column
  owns Q/16 windows, each data-row owns N/16 records, so a (16,16) pod
  evaluates 256 query×record tiles fully in parallel with zero collectives in
  the probe/refine path (results stay sharded; a count ``psum`` is optional).

``glin_query_step`` is built with ``shard_map`` so the per-device block logic
is explicit, and is what the multi-pod dry-run lowers (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import geometry as geom
from .device import (GLINSnapshot, lower_bound_in_window, model_window,
                     query_keys)
from .relations import get_relation
from .zorder import LO_LIMB_SIZE
from repro.utils.compat import shard_map as compat_shard_map

__all__ = ["shard_glin_arrays", "build_glin_query_step", "glin_input_specs",
           "GLIN_MODEL_SPEC"]

_I32 = jnp.int32

# Replicated model pytree spec (everything in GLINSnapshot is replicated; the
# big sorted arrays travel separately, sharded).
GLIN_MODEL_SPEC = P()


def shard_glin_arrays(glin, num_shards: int) -> Dict[str, np.ndarray]:
    """Reorder record payloads into slot order and pad to ``num_shards``.

    Returns host arrays ready to be device_put with a 'data'-sharded layout:
    keys/recs/leaf-ids plus slot-ordered record MBRs and vertex rings.
    """
    keys, recs, starts, _ = glin.all_leaf_arrays()
    n = keys.shape[0]
    pad = (-n) % num_shards
    gs = glin.gs
    rec_leaf = np.repeat(np.arange(len(glin.leaves), dtype=np.int32),
                         np.diff(starts).astype(np.int64))
    out = {
        "keys_hi": (keys >> 30).astype(np.int32),
        "keys_lo": (keys & (LO_LIMB_SIZE - 1)).astype(np.int32),
        "recs": recs.astype(np.int32),
        "rec_leaf": rec_leaf,
        "mbrs": gs.mbrs[recs].astype(np.float32),
        "verts": gs.verts[recs].astype(np.float32),
        "nverts": gs.nverts[recs].astype(np.int32),
        "kinds": gs.kinds[recs].astype(np.int32),
    }
    if pad:
        out["keys_hi"] = np.concatenate(
            [out["keys_hi"], np.full(pad, 2**30 - 1, np.int32)])
        out["keys_lo"] = np.concatenate([out["keys_lo"], np.full(pad, 0, np.int32)])
        out["recs"] = np.concatenate([out["recs"], np.full(pad, -1, np.int32)])
        out["rec_leaf"] = np.concatenate(
            [out["rec_leaf"], np.zeros(pad, np.int32)])
        out["mbrs"] = np.concatenate([out["mbrs"], np.zeros((pad, 4), np.float32)])
        out["verts"] = np.concatenate(
            [out["verts"], np.zeros((pad, *gs.verts.shape[1:]), np.float32)])
        out["nverts"] = np.concatenate([out["nverts"], np.zeros(pad, np.int32)])
        out["kinds"] = np.concatenate([out["kinds"], np.zeros(pad, np.int32)])
    return out


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def build_glin_query_step(mesh: Mesh, relation: str = "intersects",
                          cap: int = 512):
    """Returns (step_fn, in_shardings, out_shardings) for the mesh.

    step(snapshot, windows, table) -> (hits, counts):
      hits  (Q, n_data_shards, cap) int32  — -1 padded global record ids
      counts(Q, n_data_shards)       int32 — per-shard hit counts
    """
    rel = get_relation(relation)
    if not rel.device_native:
        raise ValueError(f"relation {relation!r} is not device-native; shard "
                         f"its base relation {rel.base_name()!r} instead")
    daxes = _data_axes(mesh)

    table_spec = {k: P(daxes) for k in
                  ("keys_hi", "keys_lo", "recs", "rec_leaf", "mbrs", "verts",
                   "nverts", "kinds")}
    in_specs = (
        GLIN_MODEL_SPEC,           # snapshot: fully replicated (prefix spec)
        P("model"),                # windows sharded over query axis
        table_spec,
    )
    out_specs = (P("model", daxes), P("model", daxes))

    def local_step(snapshot: GLINSnapshot, windows, table):
        # Each device sees its local slice of the record table and a local
        # slice of the query batch; only the SMALL model tables (nodes, leaf
        # models, leaf MBRs, piecewise suffix-min) are replicated — the
        # global sorted key array is never materialized per device.
        shard_id = jax.lax.axis_index(daxes[0])
        if len(daxes) == 2:
            shard_id = (shard_id * jax.lax.axis_size(daxes[1])
                        + jax.lax.axis_index(daxes[1]))
        local_n = table["keys_hi"].shape[0]
        offset = shard_id.astype(_I32) * local_n

        zmin_hi, zmin_lo, ub_hi, ub_lo = query_keys(snapshot, windows, relation)

        def local_lb(q_hi, q_lo):
            # model predicts a global window; the final search runs on the
            # LOCAL key shard (clipping makes out-of-shard answers land on
            # the shard edge, which is exactly the local lower bound).
            lo_g, hi_g = model_window(snapshot, q_hi, q_lo)
            lo_l = jnp.clip(lo_g - offset, 0, local_n)
            hi_l = jnp.clip(hi_g - offset, 0, local_n)
            return lower_bound_in_window(table["keys_hi"], table["keys_lo"],
                                         q_hi, q_lo, lo_l, hi_l,
                                         snapshot.search_steps + 2)

        lstart = local_lb(zmin_hi, zmin_lo)
        lend = local_lb(ub_hi, ub_lo)

        pos = lstart[:, None] + jnp.arange(cap, dtype=_I32)[None, :]
        valid = pos < jnp.minimum(lend, lstart + cap)[:, None]
        posc = jnp.minimum(pos, local_n - 1)

        leaf = table["rec_leaf"][posc]
        lmbr = snapshot.leaf_mbr[leaf]
        wq = windows[:, None, :]
        # leaf pruning uses the padded probe window (dwithin); the record
        # prefilter pads internally and the predicate sees the raw window
        leaf_ok = geom.mbr_intersects(
            lmbr, rel.probe_window(windows, xp=jnp)[:, None, :], xp=jnp)
        rmbr = table["mbrs"][posc]
        rec_ok = rel.mbr_prefilter(rmbr, wq, xp=jnp)
        mask = valid & leaf_ok & rec_ok

        qn, _ = pos.shape
        v = table["verts"][posc.reshape(-1)]
        nv = table["nverts"][posc.reshape(-1)]
        kd = table["kinds"][posc.reshape(-1)]

        def exact_for(w, vv, nn, kk):
            return rel.predicate(w, vv, nn, kk, xp=jnp)

        exact = jax.vmap(exact_for)(windows,
                                    v.reshape(qn, cap, *v.shape[1:]),
                                    nv.reshape(qn, cap), kd.reshape(qn, cap))
        mask = mask & exact & (table["recs"][posc] >= 0)
        hits = jnp.where(mask, table["recs"][posc], -1)
        counts = mask.sum(axis=1).astype(_I32)
        overflow = (lend - lstart) > cap
        counts = jnp.where(overflow, -counts - 1, counts)  # signal truncation
        return hits[:, None, :], counts[:, None]

    step = compat_shard_map(local_step, mesh, in_specs, out_specs)

    in_shardings = (
        NamedSharding(mesh, GLIN_MODEL_SPEC),  # prefix: whole snapshot
        NamedSharding(mesh, P("model")),
        {k: NamedSharding(mesh, s) for k, s in table_spec.items()},
    )
    out_shardings = tuple(NamedSharding(mesh, s) for s in out_specs)
    return step, in_shardings, out_shardings


def _snapshot_spec_tree():
    """A GLINSnapshot-shaped pytree of placeholder leaves (for spec mapping)."""
    fields = [f.name for f in dataclasses.fields(GLINSnapshot)
              if not f.metadata.get("static")]
    dummy = {name: 0 for name in fields}
    return GLINSnapshot(**dummy, search_steps=1, depth=1, grid_x0=0.0,
                        grid_y0=0.0, grid_cell=1.0)


def glin_input_specs(num_records: int, num_queries: int, mesh: Mesh,
                     num_leaves: int = 1 << 20, num_nodes: int = 1 << 14,
                     num_pieces: int = 1 << 12, max_verts: int = 12,
                     fanout: int = 64):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation).

    Sizes default to a 2^30-record production index: the model tables stay
    tiny (replicated), the record table shards over pod×data.
    """
    f32 = jnp.float32
    i32 = jnp.int32
    # Record-level arrays are NOT used by the distributed step (they travel
    # sharded in `table`); keep them 1-element so the replicated snapshot is
    # only the model tables (a few MB, matching the paper's index sizes).
    snap = GLINSnapshot(
        keys_hi=jax.ShapeDtypeStruct((1,), i32),
        keys_lo=jax.ShapeDtypeStruct((1,), i32),
        recs=jax.ShapeDtypeStruct((1,), i32),
        rec_leaf=jax.ShapeDtypeStruct((1,), i32),
        slot_lmbr=jax.ShapeDtypeStruct((1, 4), f32),
        slot_rmbr=jax.ShapeDtypeStruct((1, 4), f32),
        leaf_start=jax.ShapeDtypeStruct((num_leaves + 1,), i32),
        leaf_dlo_hi=jax.ShapeDtypeStruct((num_leaves + 1,), i32),
        leaf_dlo_lo=jax.ShapeDtypeStruct((num_leaves + 1,), i32),
        leaf_mbr=jax.ShapeDtypeStruct((num_leaves, 4), f32),
        leaf_k0_hi=jax.ShapeDtypeStruct((num_leaves,), i32),
        leaf_k0_lo=jax.ShapeDtypeStruct((num_leaves,), i32),
        leaf_slope=jax.ShapeDtypeStruct((num_leaves,), f32),
        leaf_icpt=jax.ShapeDtypeStruct((num_leaves,), f32),
        node_dlo_hi=jax.ShapeDtypeStruct((num_nodes,), i32),
        node_dlo_lo=jax.ShapeDtypeStruct((num_nodes,), i32),
        node_scale=jax.ShapeDtypeStruct((num_nodes,), f32),
        node_fanout=jax.ShapeDtypeStruct((num_nodes,), i32),
        node_child_base=jax.ShapeDtypeStruct((num_nodes,), i32),
        child_codes=jax.ShapeDtypeStruct((num_nodes * fanout,), i32),
        pw_zmax_hi=jax.ShapeDtypeStruct((num_pieces,), i32),
        pw_zmax_lo=jax.ShapeDtypeStruct((num_pieces,), i32),
        pw_sufmin_hi=jax.ShapeDtypeStruct((num_pieces,), i32),
        pw_sufmin_lo=jax.ShapeDtypeStruct((num_pieces,), i32),
        search_steps=8, depth=4, grid_x0=-180.0, grid_y0=-90.0,
        grid_cell=5e-7,
    )
    windows = jax.ShapeDtypeStruct((num_queries, 4), f32)
    table = {
        "keys_hi": jax.ShapeDtypeStruct((num_records,), i32),
        "keys_lo": jax.ShapeDtypeStruct((num_records,), i32),
        "recs": jax.ShapeDtypeStruct((num_records,), i32),
        "rec_leaf": jax.ShapeDtypeStruct((num_records,), i32),
        "mbrs": jax.ShapeDtypeStruct((num_records, 4), f32),
        "verts": jax.ShapeDtypeStruct((num_records, max_verts, 2), f32),
        "nverts": jax.ShapeDtypeStruct((num_records,), i32),
        "kinds": jax.ShapeDtypeStruct((num_records,), i32),
    }
    return snap, windows, table
