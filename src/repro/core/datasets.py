"""Synthetic geometry datasets standing in for the paper's Table IV corpora.

Real TIGER / OSM extracts are not available offline; these generators emulate
the distributions the paper evaluates:

* ``uniform``   — SpiderWeb UNIF_S/UNIF_L: polygons uniform over the domain.
* ``diagonal``  — SpiderWeb DIAG_S/DIAG_L: polygons hugging the main diagonal.
* ``cluster``   — OSM-points / PARKS style: Gaussian metro clusters.
* ``roads``     — TIGER ROADS / LINEARWATER style: long, thin, anisotropic
                  polylines.
* ``points``    — OSM_Points: degenerate single-vertex geometries.
* ``concave``   — LAKES/BUILDINGS style simple CONCAVE rings: alternating
                  star polygons and rotated L-shaped rings. Real corpora are
                  dominated by concave geometry; this family exercises the
                  exact (ray-cast / edge-clip) refinement predicates that the
                  convex generators never stress.
* ``rings``     — dense boundary rings with exactly ``max_verts`` vertices
                  (coastline/lake-shore style wide records).
* ``mixed``     — heavy-tailed vertex-count mix: points + short polylines +
                  convex polygons + 64-vertex rings in ONE store. This is the
                  workload where dense ``(N, V, 2)`` padding is pathological
                  (every point pays for the widest ring) and the vertex pool
                  pays off.

Every generator is deterministic in its seed and returns a
:class:`GeometrySet` in CSR vertex-pool layout (see the class docstring).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import numpy as np

from .geometry import GeomKind, mbrs_of_verts
from .zorder import ZGrid, UNIT

__all__ = ["GeometrySet", "generate", "make_query_windows", "DATASETS"]


class GeometrySet:
    """A batch of geometries in CSR vertex-pool layout.

    The source of truth is one flat ``pool`` of ``(total_verts, 2)`` float64
    vertices plus per-record ``(offset, nverts)``: record ``r``'s ring is
    ``pool[offsets[r] : offsets[r] + nverts[r]]``. A point record owns one
    pool row, a 64-vertex ring owns 64 — no record pays for the widest
    geometry in the store, and appending a record moves O(record width)
    bytes (amortized), not O(N·V).

    Invariants:

    * ``pool``/``offsets``/``nverts``/``kinds``/``mbrs`` are live views onto
      internal capacity buffers. Growth REPLACES a buffer (never resizes it
      in place) and appends only ever write past the live length, so a view
      taken at time T stays valid and immutable forever — snapshot captures
      rely on this.
    * ``mark_dead`` tombstones a record; its ring stays readable until
      :meth:`compact` (run at republish) rewrites the pool without it and
      repoints the dead record at ``(offset=0, nverts=1)`` — still finite
      and in-bounds for masked device reads.
    * ``verts`` is a backward-compatible DENSE ``(N, maxV, 2)`` view padded
      with the last valid vertex (the pre-pool layout), materialized lazily
      and cached until the next mutation. Assigning ``gs.verts = dense``
      re-imports the dense data back into the pool (same N / nverts).
    * ``bytes_moved`` counts every byte the store copied (appends, buffer
      doublings, compaction) — the maintenance bench and the O(width)
      insert regression test read it.
    """

    def __init__(self, *, nverts, kinds, mbrs, grid: ZGrid,
                 name: str = "synthetic", verts=None, pool=None,
                 offsets=None):
        self.grid = grid
        self.name = name
        nv = np.asarray(nverts, np.int32)
        n = int(nv.shape[0])
        self._n = n
        self._nv = np.array(nv, np.int32)
        self._kinds = np.array(np.asarray(kinds), np.int8)
        self._mbrs = np.array(np.asarray(mbrs), np.float64)
        self._dead = np.zeros(n, bool)
        self._dirty_dead = False
        self.pool_version = 0
        # bumped only when EXISTING pool contents are rewritten (verts
        # setter re-import, compaction) — appends extend the pool without
        # touching live data, so device payload caches key on this instead
        # of pool_version and survive insert bursts between publishes
        self.layout_version = 0
        self.bytes_moved = 0
        self._dense = None
        self._dense_version = -1
        if pool is not None:
            self._pool = np.asarray(pool, np.float64).reshape(-1, 2)
            self._off = np.asarray(offsets, np.int64).reshape(-1).copy()
            self._pool_len = int(self._pool.shape[0])
        elif verts is not None:
            self._import_dense(np.asarray(verts, np.float64))
        else:
            raise TypeError("GeometrySet needs either pool+offsets or verts")

    # -- construction ------------------------------------------------------
    def _import_dense(self, dense: np.ndarray) -> None:
        """Build the CSR pool from a dense padded ``(N, W, 2)`` block."""
        n = self._n
        nv = self._nv[:n].astype(np.int64)
        off = np.zeros(n, np.int64)
        if n:
            np.cumsum(nv[:-1], out=off[1:])
        total = int(nv.sum())
        pool = np.empty((max(total, 1), 2), np.float64)
        if total:
            rec_of = np.repeat(np.arange(n), nv)
            pos = np.arange(total) - np.repeat(off, nv)
            pool[:total] = dense[rec_of, pos]
        else:
            pool[:] = 0.0
        self._pool = pool
        self._off = off
        self._pool_len = max(total, 1) if n else total
        if n == 0:
            self._pool_len = 0

    @classmethod
    def concat(cls, parts: Iterable["GeometrySet"],
               name: str = "concat") -> "GeometrySet":
        parts = list(parts)
        pool = np.concatenate([p.pool for p in parts])
        offs, base = [], 0
        for p in parts:
            offs.append(p.offsets + base)
            base += p.pool.shape[0]
        return cls(pool=pool, offsets=np.concatenate(offs),
                   nverts=np.concatenate([p.nverts for p in parts]),
                   kinds=np.concatenate([p.kinds for p in parts]),
                   mbrs=np.concatenate([p.mbrs for p in parts]),
                   grid=parts[0].grid, name=name)

    # -- live views --------------------------------------------------------
    def __len__(self) -> int:
        return self._n

    @property
    def pool(self) -> np.ndarray:
        return self._pool[:self._pool_len]

    @property
    def pool_len(self) -> int:
        return self._pool_len

    @property
    def offsets(self) -> np.ndarray:
        return self._off[:self._n]

    @property
    def nverts(self) -> np.ndarray:
        return self._nv[:self._n]

    @property
    def kinds(self) -> np.ndarray:
        return self._kinds[:self._n]

    @property
    def mbrs(self) -> np.ndarray:
        return self._mbrs[:self._n]

    @mbrs.setter
    def mbrs(self, m) -> None:
        m = np.array(np.asarray(m), np.float64)
        if m.shape != (self._n, 4):
            raise ValueError(f"mbrs shape {m.shape} != ({self._n}, 4)")
        self._mbrs = m

    @property
    def max_nverts(self) -> int:
        return int(self._nv[:self._n].max()) if self._n else 1

    # -- dense compatibility view -----------------------------------------
    @property
    def verts(self) -> np.ndarray:
        """Dense ``(N, maxV, 2)`` padded-with-last-vertex view (cached)."""
        if self._dense is None or self._dense_version != self.pool_version:
            self._dense = self.padded()
            self._dense_version = self.pool_version
        return self._dense

    @verts.setter
    def verts(self, dense) -> None:
        dense = np.asarray(dense, np.float64)
        if dense.shape[0] != self._n or (self._n and
                                         dense.shape[1] < self.max_nverts):
            raise ValueError(
                f"dense verts {dense.shape} cannot cover {self._n} records "
                f"of up to {self.max_nverts} vertices")
        self._import_dense(dense)
        self.layout_version += 1
        self._touch()

    def padded(self, idx=None, width: Optional[int] = None) -> np.ndarray:
        """Dense ``(len(idx), W, 2)`` gather of a record subset, padded with
        each record's last valid vertex (the device-layout convention)."""
        if idx is None:
            off, nv = self.offsets, self.nverts
        else:
            idx = np.asarray(idx)
            off, nv = self._off[idx], self._nv[idx]
        if off.shape[0] == 0:
            return np.empty((0, width or 1, 2), np.float64)
        w = int(width) if width else max(int(nv.max()), 1)
        j = np.minimum(np.arange(w)[None, :], nv[:, None].astype(np.int64) - 1)
        return self._pool[off[:, None] + j]

    def ring(self, rec: int) -> np.ndarray:
        """The ``(nverts, 2)`` ring of one record (a pool view)."""
        o = int(self._off[rec])
        return self._pool[o : o + int(self._nv[rec])]

    def take(self, idx) -> "GeometrySet":
        idx = np.asarray(idx).reshape(-1)
        counts = self._nv[idx].astype(np.int64)
        starts = self._off[idx]
        total = int(counts.sum())
        off = np.zeros(idx.shape[0], np.int64)
        if idx.shape[0]:
            np.cumsum(counts[:-1], out=off[1:])
        pool = np.empty((max(total, 1), 2), np.float64)
        if total:
            pos = np.arange(total) - np.repeat(off, counts)
            pool[:total] = self._pool[np.repeat(starts, counts) + pos]
        else:
            pool[:] = 0.0
        return GeometrySet(pool=pool[:max(total, 1)], offsets=off,
                           nverts=self._nv[idx], kinds=self._kinds[idx],
                           mbrs=self._mbrs[idx], grid=self.grid,
                           name=self.name)

    # -- sizes -------------------------------------------------------------
    def nbytes(self) -> int:
        """Live store bytes in the CSR pool layout."""
        return (self.pool.nbytes + self.offsets.nbytes + self.nverts.nbytes
                + self.kinds.nbytes + self.mbrs.nbytes)

    def dense_nbytes(self) -> int:
        """What the pre-pool dense ``(N, maxV, 2)`` layout would cost."""
        return (self._n * self.max_nverts * 16 + self.nverts.nbytes
                + self.kinds.nbytes + self.mbrs.nbytes)

    # -- mutation ----------------------------------------------------------
    def _touch(self) -> None:
        self.pool_version += 1
        self._dense = None

    def reserve(self, num_records: int, num_verts: int) -> None:
        """Pre-grow capacity buffers (does not change live contents)."""
        if num_verts > self._pool.shape[0]:
            self._grow_pool(num_verts)
        if num_records > self._off.shape[0]:
            self._grow_records(num_records)

    def _grow_pool(self, need: int) -> None:
        cap = max(need, 2 * self._pool.shape[0], 64)
        new = np.empty((cap, 2), np.float64)
        new[:self._pool_len] = self._pool[:self._pool_len]
        self.bytes_moved += self._pool_len * 16
        self._pool = new

    def _grow_records(self, need: int) -> None:
        cap = max(need, 2 * self._off.shape[0], 64)
        n = self._n

        def grow(buf, dtype, cols=None):
            shape = (cap,) if cols is None else (cap, cols)
            new = np.zeros(shape, dtype)
            new[:n] = buf[:n]
            self.bytes_moved += buf[:n].nbytes
            return new

        self._off = grow(self._off, np.int64)
        self._nv = grow(self._nv, np.int32)
        self._kinds = grow(self._kinds, np.int8)
        self._mbrs = grow(self._mbrs, np.float64, 4)
        self._dead = grow(self._dead, bool)

    def append(self, verts, nverts: int, kind: int, mbr=None) -> int:
        """Append one record; O(record width) bytes moved, amortized."""
        w = int(nverts)
        ring = np.asarray(verts, np.float64).reshape(-1, 2)[:w]
        if ring.shape[0] != w or w < 1:
            raise ValueError(f"need {nverts} vertices, got {ring.shape[0]}")
        if self._pool_len + w > self._pool.shape[0]:
            self._grow_pool(self._pool_len + w)
        if self._n + 1 > self._off.shape[0]:
            self._grow_records(self._n + 1)
        self._pool[self._pool_len : self._pool_len + w] = ring
        self.bytes_moved += w * 16
        rec = self._n
        self._off[rec] = self._pool_len
        self._nv[rec] = w
        self._kinds[rec] = np.int8(kind)
        if mbr is None:
            mbr = mbrs_of_verts(ring[None], np.asarray([w], np.int32))[0]
        self._mbrs[rec] = np.asarray(mbr, np.float64)
        self._dead[rec] = False
        self.bytes_moved += 8 + 4 + 1 + 32
        self._pool_len += w
        self._n += 1
        self._touch()
        return rec

    def mark_dead(self, rec: int) -> None:
        """Tombstone a record's storage; reclaimed at the next compact()."""
        if not self._dead[rec]:
            self._dead[rec] = True
            self._dirty_dead = True

    @property
    def dead_count(self) -> int:
        return int(self._dead[:self._n].sum())

    def compact(self) -> int:
        """Rewrite the pool without dead records' rings; returns bytes
        reclaimed. Record ids are stable: a dead record keeps its id and is
        repointed at ``(offset=0, nverts=1)`` — finite, in-bounds data for
        masked reads. Replaces (never mutates) the offset/nverts buffers so
        previously captured views stay consistent."""
        if not self._dirty_dead:
            return 0
        n = self._n
        dead = self._dead[:n]
        live_idx = np.nonzero(~dead)[0]
        counts = self._nv[live_idx].astype(np.int64)
        starts = self._off[live_idx]
        total = int(counts.sum())
        pool = np.empty((max(total, 1), 2), np.float64)
        seg = np.zeros(live_idx.shape[0], np.int64)
        if live_idx.shape[0]:
            np.cumsum(counts[:-1], out=seg[1:])
        if total:
            pos = np.arange(total) - np.repeat(seg, counts)
            pool[:total] = self._pool[np.repeat(starts, counts) + pos]
        else:
            pool[:] = 0.0
        self.bytes_moved += total * 16
        reclaimed = (self._pool_len - max(total, 1)) * 16
        off = np.zeros(n, np.int64)
        off[live_idx] = seg
        nv = np.ones(n, np.int32)
        nv[live_idx] = self._nv[live_idx]
        self._pool = pool
        self._pool_len = max(total, 1)
        self._off = off
        self._nv = nv
        self._dirty_dead = False
        self.layout_version += 1
        self._touch()
        return max(reclaimed, 0)


def _convex_polygons(rng: np.random.Generator, centers: np.ndarray, sizes: np.ndarray,
                     max_verts: int) -> Dict[str, np.ndarray]:
    """Random convex polygons: sorted random angles on a jittered radius."""
    n = centers.shape[0]
    nverts = rng.integers(3, max_verts + 1, size=n).astype(np.int32)
    angles = np.sort(rng.uniform(0.0, 2 * np.pi, size=(n, max_verts)), axis=1)
    radii = sizes[:, None] * rng.uniform(0.5, 1.0, size=(n, max_verts))
    vx = centers[:, 0:1] + radii * np.cos(angles)
    vy = centers[:, 1:2] + radii * np.sin(angles)
    verts = np.stack([vx, vy], axis=-1)
    # Pad: repeat the (nv-1)-th vertex beyond nv.
    idx = np.minimum(np.arange(max_verts)[None, :], nverts[:, None] - 1)
    verts = np.take_along_axis(verts, idx[:, :, None], axis=1)
    return {"verts": verts, "nverts": nverts}


def _concave_polygons(rng: np.random.Generator, centers: np.ndarray,
                      sizes: np.ndarray, max_verts: int) -> Dict[str, np.ndarray]:
    """Simple concave rings: star polygons (alternating outer/inner radius —
    star-shaped about the centre, hence simple) interleaved with randomly
    rotated L-shaped rings. Requires ``max_verts >= 6``."""
    if max_verts < 6:
        raise ValueError(f"concave rings need max_verts >= 6, got {max_verts}")
    n = centers.shape[0]

    # Stars: sorted angles, radius alternating between r and frac*r.
    nverts = (2 * rng.integers(3, max_verts // 2 + 1, size=n)).astype(np.int32)
    angles = np.sort(rng.uniform(0.0, 2 * np.pi, size=(n, max_verts)), axis=1)
    frac = rng.uniform(0.25, 0.5, size=(n, 1))
    radii = np.where(np.arange(max_verts)[None, :] % 2 == 0,
                     sizes[:, None], sizes[:, None] * frac)
    vx = centers[:, 0:1] + radii * np.cos(angles)
    vy = centers[:, 1:2] + radii * np.sin(angles)
    verts = np.stack([vx, vy], axis=-1)

    # L-shaped rings on half the records (reflex corner at (t, t)).
    ell = rng.random(n) < 0.5
    t = rng.uniform(0.25, 0.6, size=n)
    unit = np.zeros((n, 6, 2))
    unit[:, 1] = np.stack([np.ones(n), np.zeros(n)], -1)
    unit[:, 2] = np.stack([np.ones(n), t], -1)
    unit[:, 3] = np.stack([t, t], -1)
    unit[:, 4] = np.stack([t, np.ones(n)], -1)
    unit[:, 5] = np.stack([np.zeros(n), np.ones(n)], -1)
    theta = rng.uniform(0.0, 2 * np.pi, size=n)
    c, s = np.cos(theta)[:, None], np.sin(theta)[:, None]
    shifted = (unit - 0.5) * (2.0 * sizes[:, None, None])
    lx = centers[:, 0:1] + shifted[..., 0] * c - shifted[..., 1] * s
    ly = centers[:, 1:2] + shifted[..., 0] * s + shifted[..., 1] * c
    lverts = np.zeros_like(verts)
    lverts[:, :6] = np.stack([lx, ly], axis=-1)
    verts = np.where(ell[:, None, None], lverts, verts)
    nverts = np.where(ell, np.int32(6), nverts).astype(np.int32)

    idx = np.minimum(np.arange(max_verts)[None, :], nverts[:, None] - 1)
    verts = np.take_along_axis(verts, idx[:, :, None], axis=1)
    return {"verts": verts, "nverts": nverts}


def _polylines(rng: np.random.Generator, starts: np.ndarray, steps: np.ndarray,
               max_verts: int, anisotropy: float) -> Dict[str, np.ndarray]:
    """Random-walk polylines with a persistent heading (road-like)."""
    n = starts.shape[0]
    nverts = rng.integers(2, max_verts + 1, size=n).astype(np.int32)
    heading = rng.uniform(0.0, 2 * np.pi, size=(n, 1))
    wiggle = rng.normal(0.0, 0.25, size=(n, max_verts)).cumsum(axis=1)
    theta = heading + wiggle
    dx = np.cos(theta) * steps[:, None] * anisotropy
    dy = np.sin(theta) * steps[:, None]
    vx = starts[:, 0:1] + np.concatenate(
        [np.zeros((n, 1)), dx[:, :-1].cumsum(axis=1)], axis=1)
    vy = starts[:, 1:2] + np.concatenate(
        [np.zeros((n, 1)), dy[:, :-1].cumsum(axis=1)], axis=1)
    verts = np.stack([vx, vy], axis=-1)
    idx = np.minimum(np.arange(max_verts)[None, :], nverts[:, None] - 1)
    verts = np.take_along_axis(verts, idx[:, :, None], axis=1)
    return {"verts": verts, "nverts": nverts}


def generate(name: str, n: int, seed: int = 0, max_verts: int = 12,
             grid: Optional[ZGrid] = None) -> GeometrySet:
    """Build a synthetic dataset. Domain is the unit square."""
    rng = np.random.default_rng(seed)
    grid = grid or UNIT
    kinds = np.full(n, int(GeomKind.POLYGON), np.int8)

    if name == "uniform":
        centers = rng.uniform(0.02, 0.98, size=(n, 2))
        sizes = rng.uniform(1e-5, 4e-4, size=n)
        parts = _convex_polygons(rng, centers, sizes, max_verts)
    elif name == "diagonal":
        t = rng.uniform(0.02, 0.98, size=n)
        off = rng.normal(0.0, 0.01, size=(n, 2))
        centers = np.clip(np.stack([t, t], axis=1) + off, 0.001, 0.999)
        sizes = rng.uniform(1e-5, 4e-4, size=n)
        parts = _convex_polygons(rng, centers, sizes, max_verts)
    elif name == "cluster":
        k = 32
        mus = rng.uniform(0.05, 0.95, size=(k, 2))
        sig = rng.uniform(0.004, 0.03, size=k)
        comp = rng.integers(0, k, size=n)
        centers = np.clip(
            mus[comp] + rng.normal(0, 1, (n, 2)) * sig[comp][:, None],
            0.001, 0.999)
        sizes = rng.uniform(1e-5, 3e-4, size=n)
        parts = _convex_polygons(rng, centers, sizes, max_verts)
    elif name == "roads":
        starts = rng.uniform(0.02, 0.98, size=(n, 2))
        steps = rng.uniform(2e-5, 2e-4, size=n)
        parts = _polylines(rng, starts, steps, max_verts, anisotropy=3.0)
        kinds = np.full(n, int(GeomKind.POLYLINE), np.int8)
    elif name == "concave":
        centers = rng.uniform(0.02, 0.98, size=(n, 2))
        sizes = rng.uniform(5e-5, 5e-4, size=n)
        parts = _concave_polygons(rng, centers, sizes, max_verts)
    elif name == "points":
        centers = rng.uniform(0.0, 1.0, size=(n, 2))
        parts = {"verts": centers[:, None, :],
                 "nverts": np.ones(n, np.int32)}
    elif name == "rings":
        # Dense boundary rings with exactly max_verts vertices each.
        centers = rng.uniform(0.02, 0.98, size=(n, 2))
        sizes = rng.uniform(5e-5, 5e-4, size=n)
        angles = np.sort(rng.uniform(0.0, 2 * np.pi, (n, max_verts)), axis=1)
        radii = sizes[:, None] * rng.uniform(0.7, 1.0, (n, max_verts))
        parts = {"verts": np.stack(
                     [centers[:, 0:1] + radii * np.cos(angles),
                      centers[:, 1:2] + radii * np.sin(angles)], -1),
                 "nverts": np.full(n, max_verts, np.int32)}
    elif name == "mixed":
        # Heavy-tailed vertex counts in one store: ~45% single-vertex
        # points, 25% short polylines, 20% mid-width (concave) polygons, 10%
        # 64-vertex rings. Mean width ~8, max 64 — dense padding makes every
        # point pay 64 slots.
        n_ring = max(n // 10, 1)
        n_poly = max(n // 5, 1)
        n_road = max(n // 4, 1)
        n_pts = max(n - n_ring - n_poly - n_road, 1)
        gs = GeometrySet.concat(
            [generate("points", n_pts, seed=seed + 1, grid=grid),
             generate("roads", n_road, seed=seed + 2, max_verts=8, grid=grid),
             generate("concave", n_poly, seed=seed + 3, max_verts=12,
                      grid=grid),
             generate("rings", n_ring, seed=seed + 4, max_verts=64,
                      grid=grid)],
            name="mixed")
        # shuffle so the families interleave in Zmin order too
        return gs.take(rng.permutation(len(gs)))
    else:
        raise ValueError(f"unknown dataset {name!r}")

    verts = np.clip(parts["verts"], 0.0, 1.0 - 1e-12)
    mbrs = mbrs_of_verts(verts, parts["nverts"])
    return GeometrySet(verts=verts, nverts=parts["nverts"], kinds=kinds,
                       mbrs=mbrs, grid=grid, name=name)


# Named dataset registry mirroring Table IV (cardinalities scaled to CPU).
DATASETS = {
    "UNIF_S": ("uniform", 1),
    "DIAG_S": ("diagonal", 1),
    "CLUSTER": ("cluster", 2),
    "ROADS": ("roads", 3),
    "POINTS": ("points", 4),
    "CONCAVE": ("concave", 5),
    "MIXED": ("mixed", 6),
}


def make_query_windows(gs: GeometrySet, selectivity: float, num_windows: int,
                       seed: int = 0) -> np.ndarray:
    """Selectivity-matched query windows, following the paper's §IX-A recipe:
    pick a random geometry, take the K = selectivity * N nearest geometries
    (by MBR-centre distance), and use the MBR of that result set.
    Returns (num_windows, 4).
    """
    rng = np.random.default_rng(seed + 7)
    n = len(gs)
    k = max(1, int(round(selectivity * n)))
    cx = (gs.mbrs[:, 0] + gs.mbrs[:, 2]) * 0.5
    cy = (gs.mbrs[:, 1] + gs.mbrs[:, 3]) * 0.5
    windows = np.empty((num_windows, 4), np.float64)
    anchors = rng.integers(0, n, size=num_windows)
    for i, a in enumerate(anchors):
        d = np.maximum(np.abs(cx - cx[a]), np.abs(cy - cy[a]))  # Chebyshev
        nearest = np.argpartition(d, k - 1)[:k]
        m = gs.mbrs[nearest]
        windows[i] = (m[:, 0].min(), m[:, 1].min(), m[:, 2].max(), m[:, 3].max())
    return windows
