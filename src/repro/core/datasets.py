"""Synthetic geometry datasets standing in for the paper's Table IV corpora.

Real TIGER / OSM extracts are not available offline; these generators emulate
the distributions the paper evaluates:

* ``uniform``   — SpiderWeb UNIF_S/UNIF_L: polygons uniform over the domain.
* ``diagonal``  — SpiderWeb DIAG_S/DIAG_L: polygons hugging the main diagonal.
* ``cluster``   — OSM-points / PARKS style: Gaussian metro clusters.
* ``roads``     — TIGER ROADS / LINEARWATER style: long, thin, anisotropic
                  polylines.
* ``points``    — OSM_Points: degenerate single-vertex geometries.
* ``concave``   — LAKES/BUILDINGS style simple CONCAVE rings: alternating
                  star polygons and rotated L-shaped rings. Real corpora are
                  dominated by concave geometry; this family exercises the
                  exact (ray-cast / edge-clip) refinement predicates that the
                  convex generators never stress.

Every generator is deterministic in its seed and returns a
:class:`GeometrySet` with padded vertex rings (see core.geometry).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .geometry import GeomKind, mbrs_of_verts
from .zorder import ZGrid, UNIT

__all__ = ["GeometrySet", "generate", "make_query_windows", "DATASETS"]


@dataclasses.dataclass
class GeometrySet:
    """A batch of geometries in struct-of-arrays layout."""

    verts: np.ndarray   # (N, V, 2) float64, padded with last valid vertex
    nverts: np.ndarray  # (N,) int32
    kinds: np.ndarray   # (N,) int8 (GeomKind)
    mbrs: np.ndarray    # (N, 4) float64 [xmin, ymin, xmax, ymax]
    grid: ZGrid
    name: str = "synthetic"

    def __len__(self) -> int:
        return self.verts.shape[0]

    def take(self, idx: np.ndarray) -> "GeometrySet":
        return GeometrySet(
            verts=self.verts[idx],
            nverts=self.nverts[idx],
            kinds=self.kinds[idx],
            mbrs=self.mbrs[idx],
            grid=self.grid,
            name=self.name,
        )

    def nbytes(self) -> int:
        return (self.verts.nbytes + self.nverts.nbytes
                + self.kinds.nbytes + self.mbrs.nbytes)

    def grow_vertex_capacity(self, new_vmax: int) -> None:
        """Widen the padded vertex rings to ``new_vmax`` in place, preserving
        the pad-with-last-valid-vertex convention for every record."""
        old = self.verts
        n, old_vmax = old.shape[0], old.shape[1]
        if new_vmax <= old_vmax:
            return
        grown = np.empty((n, new_vmax, 2), old.dtype)
        grown[:, :old_vmax] = old
        if n:
            last = old[np.arange(n), np.minimum(self.nverts - 1, old_vmax - 1)]
            grown[:, old_vmax:] = last[:, None, :]
        self.verts = grown


def _convex_polygons(rng: np.random.Generator, centers: np.ndarray, sizes: np.ndarray,
                     max_verts: int) -> Dict[str, np.ndarray]:
    """Random convex polygons: sorted random angles on a jittered radius."""
    n = centers.shape[0]
    nverts = rng.integers(3, max_verts + 1, size=n).astype(np.int32)
    angles = np.sort(rng.uniform(0.0, 2 * np.pi, size=(n, max_verts)), axis=1)
    radii = sizes[:, None] * rng.uniform(0.5, 1.0, size=(n, max_verts))
    vx = centers[:, 0:1] + radii * np.cos(angles)
    vy = centers[:, 1:2] + radii * np.sin(angles)
    verts = np.stack([vx, vy], axis=-1)
    # Pad: repeat the (nv-1)-th vertex beyond nv.
    idx = np.minimum(np.arange(max_verts)[None, :], nverts[:, None] - 1)
    verts = np.take_along_axis(verts, idx[:, :, None], axis=1)
    return {"verts": verts, "nverts": nverts}


def _concave_polygons(rng: np.random.Generator, centers: np.ndarray,
                      sizes: np.ndarray, max_verts: int) -> Dict[str, np.ndarray]:
    """Simple concave rings: star polygons (alternating outer/inner radius —
    star-shaped about the centre, hence simple) interleaved with randomly
    rotated L-shaped rings. Requires ``max_verts >= 6``."""
    if max_verts < 6:
        raise ValueError(f"concave rings need max_verts >= 6, got {max_verts}")
    n = centers.shape[0]

    # Stars: sorted angles, radius alternating between r and frac*r.
    nverts = (2 * rng.integers(3, max_verts // 2 + 1, size=n)).astype(np.int32)
    angles = np.sort(rng.uniform(0.0, 2 * np.pi, size=(n, max_verts)), axis=1)
    frac = rng.uniform(0.25, 0.5, size=(n, 1))
    radii = np.where(np.arange(max_verts)[None, :] % 2 == 0,
                     sizes[:, None], sizes[:, None] * frac)
    vx = centers[:, 0:1] + radii * np.cos(angles)
    vy = centers[:, 1:2] + radii * np.sin(angles)
    verts = np.stack([vx, vy], axis=-1)

    # L-shaped rings on half the records (reflex corner at (t, t)).
    ell = rng.random(n) < 0.5
    t = rng.uniform(0.25, 0.6, size=n)
    unit = np.zeros((n, 6, 2))
    unit[:, 1] = np.stack([np.ones(n), np.zeros(n)], -1)
    unit[:, 2] = np.stack([np.ones(n), t], -1)
    unit[:, 3] = np.stack([t, t], -1)
    unit[:, 4] = np.stack([t, np.ones(n)], -1)
    unit[:, 5] = np.stack([np.zeros(n), np.ones(n)], -1)
    theta = rng.uniform(0.0, 2 * np.pi, size=n)
    c, s = np.cos(theta)[:, None], np.sin(theta)[:, None]
    shifted = (unit - 0.5) * (2.0 * sizes[:, None, None])
    lx = centers[:, 0:1] + shifted[..., 0] * c - shifted[..., 1] * s
    ly = centers[:, 1:2] + shifted[..., 0] * s + shifted[..., 1] * c
    lverts = np.zeros_like(verts)
    lverts[:, :6] = np.stack([lx, ly], axis=-1)
    verts = np.where(ell[:, None, None], lverts, verts)
    nverts = np.where(ell, np.int32(6), nverts).astype(np.int32)

    idx = np.minimum(np.arange(max_verts)[None, :], nverts[:, None] - 1)
    verts = np.take_along_axis(verts, idx[:, :, None], axis=1)
    return {"verts": verts, "nverts": nverts}


def _polylines(rng: np.random.Generator, starts: np.ndarray, steps: np.ndarray,
               max_verts: int, anisotropy: float) -> Dict[str, np.ndarray]:
    """Random-walk polylines with a persistent heading (road-like)."""
    n = starts.shape[0]
    nverts = rng.integers(2, max_verts + 1, size=n).astype(np.int32)
    heading = rng.uniform(0.0, 2 * np.pi, size=(n, 1))
    wiggle = rng.normal(0.0, 0.25, size=(n, max_verts)).cumsum(axis=1)
    theta = heading + wiggle
    dx = np.cos(theta) * steps[:, None] * anisotropy
    dy = np.sin(theta) * steps[:, None]
    vx = starts[:, 0:1] + np.concatenate(
        [np.zeros((n, 1)), dx[:, :-1].cumsum(axis=1)], axis=1)
    vy = starts[:, 1:2] + np.concatenate(
        [np.zeros((n, 1)), dy[:, :-1].cumsum(axis=1)], axis=1)
    verts = np.stack([vx, vy], axis=-1)
    idx = np.minimum(np.arange(max_verts)[None, :], nverts[:, None] - 1)
    verts = np.take_along_axis(verts, idx[:, :, None], axis=1)
    return {"verts": verts, "nverts": nverts}


def generate(name: str, n: int, seed: int = 0, max_verts: int = 12,
             grid: Optional[ZGrid] = None) -> GeometrySet:
    """Build a synthetic dataset. Domain is the unit square."""
    rng = np.random.default_rng(seed)
    grid = grid or UNIT
    kinds = np.full(n, int(GeomKind.POLYGON), np.int8)

    if name == "uniform":
        centers = rng.uniform(0.02, 0.98, size=(n, 2))
        sizes = rng.uniform(1e-5, 4e-4, size=n)
        parts = _convex_polygons(rng, centers, sizes, max_verts)
    elif name == "diagonal":
        t = rng.uniform(0.02, 0.98, size=n)
        off = rng.normal(0.0, 0.01, size=(n, 2))
        centers = np.clip(np.stack([t, t], axis=1) + off, 0.001, 0.999)
        sizes = rng.uniform(1e-5, 4e-4, size=n)
        parts = _convex_polygons(rng, centers, sizes, max_verts)
    elif name == "cluster":
        k = 32
        mus = rng.uniform(0.05, 0.95, size=(k, 2))
        sig = rng.uniform(0.004, 0.03, size=k)
        comp = rng.integers(0, k, size=n)
        centers = np.clip(
            mus[comp] + rng.normal(0, 1, (n, 2)) * sig[comp][:, None],
            0.001, 0.999)
        sizes = rng.uniform(1e-5, 3e-4, size=n)
        parts = _convex_polygons(rng, centers, sizes, max_verts)
    elif name == "roads":
        starts = rng.uniform(0.02, 0.98, size=(n, 2))
        steps = rng.uniform(2e-5, 2e-4, size=n)
        parts = _polylines(rng, starts, steps, max_verts, anisotropy=3.0)
        kinds = np.full(n, int(GeomKind.POLYLINE), np.int8)
    elif name == "concave":
        centers = rng.uniform(0.02, 0.98, size=(n, 2))
        sizes = rng.uniform(5e-5, 5e-4, size=n)
        parts = _concave_polygons(rng, centers, sizes, max_verts)
    elif name == "points":
        centers = rng.uniform(0.0, 1.0, size=(n, 2))
        verts = np.repeat(centers[:, None, :], max_verts, axis=1)
        parts = {"verts": verts, "nverts": np.ones(n, np.int32)}
    else:
        raise ValueError(f"unknown dataset {name!r}")

    verts = np.clip(parts["verts"], 0.0, 1.0 - 1e-12)
    mbrs = mbrs_of_verts(verts, parts["nverts"])
    return GeometrySet(verts=verts, nverts=parts["nverts"], kinds=kinds,
                       mbrs=mbrs, grid=grid, name=name)


# Named dataset registry mirroring Table IV (cardinalities scaled to CPU).
DATASETS = {
    "UNIF_S": ("uniform", 1),
    "DIAG_S": ("diagonal", 1),
    "CLUSTER": ("cluster", 2),
    "ROADS": ("roads", 3),
    "POINTS": ("points", 4),
    "CONCAVE": ("concave", 5),
}


def make_query_windows(gs: GeometrySet, selectivity: float, num_windows: int,
                       seed: int = 0) -> np.ndarray:
    """Selectivity-matched query windows, following the paper's §IX-A recipe:
    pick a random geometry, take the K = selectivity * N nearest geometries
    (by MBR-centre distance), and use the MBR of that result set.
    Returns (num_windows, 4).
    """
    rng = np.random.default_rng(seed + 7)
    n = len(gs)
    k = max(1, int(round(selectivity * n)))
    cx = (gs.mbrs[:, 0] + gs.mbrs[:, 2]) * 0.5
    cy = (gs.mbrs[:, 1] + gs.mbrs[:, 3]) * 0.5
    windows = np.empty((num_windows, 4), np.float64)
    anchors = rng.integers(0, n, size=num_windows)
    for i, a in enumerate(anchors):
        d = np.maximum(np.abs(cx - cx[a]), np.abs(cy - cy[a]))  # Chebyshev
        nearest = np.argpartition(d, k - 1)[:k]
        m = gs.mbrs[nearest]
        windows[i] = (m[:, 0].min(), m[:, 1].min(), m[:, 2].max(), m[:, 3].max())
    return windows
