"""Delta-buffer maintenance for device-resident GLIN snapshots.

ALEX-style in-place mutation does not map onto immutable device arrays
(DESIGN.md §2): per-record scatter into a sorted device array is O(N).
Production TPU systems instead maintain the index host-side and refresh the
device copy in bulk. :class:`SnapshotManager` implements that LSM-style
policy:

* inserts/deletes are applied to the **host** GLIN immediately (so host
  queries are always exact) and recorded in a small **delta set**;
* device queries run against the last published snapshot, then are patched
  with the delta: tombstoned records are filtered out, new records are
  brute-force checked (the delta is tiny, this is a vectorized mask);
* once the delta exceeds ``refresh_threshold`` the snapshot is republished
  (bulk re-flatten — a few ms of vectorized work, amortized O(1)/update).

The manager is validated against the host index in tests: device-patched
results equal host results at fp32 precision at every point in the update
stream.
"""
from __future__ import annotations

from typing import List, Optional, Set

import numpy as np
import jax.numpy as jnp

from .device import GLINSnapshot, batch_query, snapshot_from_host
from .index import GLIN
from .relations import get_relation

__all__ = ["SnapshotManager"]


class SnapshotManager:
    def __init__(self, glin: GLIN, refresh_threshold: int = 4096):
        self.glin = glin
        self.refresh_threshold = int(refresh_threshold)
        self.snapshot: GLINSnapshot = snapshot_from_host(glin)
        self._snapshot_recs = int(len(glin.gs))
        self.added: List[int] = []      # record ids inserted since publish
        self.tombstones: Set[int] = set()
        self.refresh_count = 0

    # ------------------------------------------------------------- maintenance
    def insert(self, verts: np.ndarray, nverts: int, kind: int) -> int:
        rec = self.glin.insert(verts, nverts, kind)
        self.added.append(rec)
        self._maybe_refresh()
        return rec

    def delete(self, rec: int) -> bool:
        ok = self.glin.delete(rec)
        if ok:
            if rec in self.added:
                self.added.remove(rec)
            elif rec < self._snapshot_recs:
                self.tombstones.add(rec)
        self._maybe_refresh()
        return ok

    def delta_size(self) -> int:
        return len(self.added) + len(self.tombstones)

    def _maybe_refresh(self) -> None:
        if self.delta_size() >= self.refresh_threshold:
            self.refresh()

    def refresh(self) -> None:
        """Republish the device snapshot from the host index (bulk)."""
        self.snapshot = snapshot_from_host(self.glin)
        self._snapshot_recs = len(self.glin.gs)
        self.added.clear()
        self.tombstones.clear()
        self.refresh_count += 1

    # ------------------------------------------------------------------ query
    def query_device(self, windows: np.ndarray, relation: str = "contains",
                     cap: int = 4096, exact_budget: int = 0) -> List[np.ndarray]:
        """Snapshot query + delta patch. Returns per-window hit id arrays."""
        gs = self.glin.gs
        verts32 = jnp.asarray(gs.verts.astype(np.float32))
        nv = jnp.asarray(gs.nverts)
        kd = jnp.asarray(gs.kinds.astype(np.int32))
        mb = jnp.asarray(gs.mbrs.astype(np.float32))
        win = jnp.asarray(np.asarray(windows, np.float32))
        hits, counts = batch_query(self.snapshot, win, verts32, nv, kd, mb,
                                   relation=relation, cap=cap,
                                   exact_budget=exact_budget)
        hits = np.asarray(hits)
        counts = np.asarray(counts)

        added = np.asarray(sorted(self.added), np.int64)
        out: List[np.ndarray] = []
        for qi in range(win.shape[0]):
            if counts[qi] < 0:
                raise OverflowError(
                    f"candidate run exceeded cap={cap} for window {qi}; "
                    f"re-issue with a larger cap")
            h = hits[qi][hits[qi] >= 0].astype(np.int64)
            if self.tombstones:
                h = h[~np.isin(h, np.fromiter(self.tombstones, np.int64))]
            if added.shape[0]:
                w32 = np.asarray(windows[qi], np.float32)
                av = gs.verts[added].astype(np.float32)
                ok = get_relation(relation).predicate(w32, av, gs.nverts[added],
                                                      gs.kinds[added])
                h = np.concatenate([h, added[ok]])
            out.append(np.sort(h))
        return out
