"""Version compatibility for the jax API surface this repo uses.

The code targets the current jax API (``jax.shard_map``, explicit mesh
``axis_types``); the container image may ship an older jax (0.4.x) where
``shard_map`` still lives in ``jax.experimental`` (with ``check_rep`` instead
of ``check_vma``) and ``jax.make_mesh`` has no ``axis_types``. These two
wrappers pick whichever spelling exists — use them instead of calling the jax
functions directly.
"""
from __future__ import annotations

import jax

__all__ = ["make_auto_mesh", "shard_map"]


def make_auto_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off (the call sites return
    per-shard values on purpose); falls back to ``jax.experimental``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
