"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §7).

Terms (seconds) per (arch × shape × mesh), TPU v5e constants:

    compute_s    = HLO_FLOPs  / (chips · 197e12 bf16 FLOP/s)
    memory_s     = HLO_bytes  / (chips · 819e9 B/s HBM)
    collective_s = coll_bytes / (chips · 50e9 B/s per ICI link)

``cost_analysis`` provides FLOPs/bytes; collective bytes are parsed from the
optimized HLO text by summing the result-shape sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12     # bf16 per chip, TPU v5e
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `  %x = (bf16[8,128]{1,0}, f32[4]{0}) all-reduce(...)` or plain shape
_INSTR_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s*"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")[\.\s(]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(expr: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(expr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective op kind over the HLO module."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        out[m.group("op")] += _shape_bytes(m.group("shape"))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int) -> Dict[str, float]:
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = bytes_accessed / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": total,
        "compute_fraction": compute_s / total if total > 0 else 0.0,
    }


def model_flops(cfg, shape, per_step_tokens: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6·N(_active)·D for train; 2·N·D forward-only."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
