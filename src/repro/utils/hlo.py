"""HLO-text cost analyzer with while-loop trip-count scaling.

XLA's built-in ``compiled.cost_analysis()`` counts each computation ONCE —
a scan-over-layers model reports ~1 layer of FLOPs regardless of depth
(verified empirically; see EXPERIMENTS.md §Method). This analyzer parses the
*optimized, partitioned* HLO text and walks the call graph instead:

* ``dot`` FLOPs    = 2 · elems(result) · prod(contracting dims)   (exact)
* bytes accessed   = Σ (result + operand bytes) over top-level compute ops;
  - fusion internals are excluded (they never touch HBM),
  - a fusion operand that is only ``dynamic-slice``d inside the fusion
    contributes the *slice* bytes (scan-over-layers weight stacks would
    otherwise be charged in full every layer),
  - ``while`` / ``call`` / ``conditional`` / ``tuple`` pass-through operands
    are not traffic;
* collective bytes = result-shape bytes per all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute;
* ``while`` bodies/conditions are weighted by XLA's ``known_trip_count``.

The module is the per-partition SPMD program, so all totals are **per-chip**.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# no HBM traffic of their own (aliases / control / pass-through)
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "while",
             "conditional", "call", "custom-call"}

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"([a-z][\w\-]*)\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')


def _shape_elems_bytes(expr: str) -> Tuple[int, int]:
    elems = byts = 0
    for dtype, dims in _SHAPE.findall(expr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


def _balanced_group(s: str, start: int) -> str:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return s[start + 1:i]
    return s[start + 1:]


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: List[_Instr] = dataclasses.field(default_factory=list)
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collectives: Dict[str, float]
    unknown_trip_whiles: int
    entry: str

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


def _parse(text: str) -> Tuple[Dict[str, _Comp], str]:
    comps: Dict[str, _Comp] = {}
    entry = ""
    cur: Optional[_Comp] = None
    for raw in text.splitlines():
        m = _COMP_START.match(raw)
        if m:
            cur = _Comp(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if raw.startswith("}"):
            cur = None
            continue
        im = _INSTR.match(raw)
        if not im:
            continue
        name, shape_expr, op = im.groups()
        op_start = raw.index(op + "(", im.start(3)) + len(op)
        operands = re.findall(r"%([\w\.\-]+)",
                              _balanced_group(raw, op_start))
        cur.shapes[name] = shape_expr
        cur.instrs.append(_Instr(name, shape_expr, op, operands, raw))
    if not entry and comps:
        entry = next(iter(comps))
    return comps, entry


def _param_effective_bytes(comp: _Comp) -> Dict[int, float]:
    """Per-parameter HBM traffic inside a fused computation: a parameter only
    consumed by dynamic-slice / slice / gather counts its slices, not its
    full shape (weight stacks in scan bodies)."""
    pname_to_idx: Dict[str, int] = {}
    for ins in comp.instrs:
        if ins.op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", ins.raw)
            if pm:
                pname_to_idx[ins.name] = int(pm.group(1))
    eff: Dict[int, float] = {}
    for pname, idx in pname_to_idx.items():
        full = _shape_elems_bytes(comp.shapes[pname])[1]
        sliced = 0.0
        only_sliced = True
        used = False
        for ins in comp.instrs:
            if pname in ins.operands:
                used = True
                if ins.op in ("dynamic-slice", "slice", "gather") and \
                        ins.operands and ins.operands[0] == pname:
                    sliced += _shape_elems_bytes(ins.shape)[1]
                else:
                    only_sliced = False
        eff[idx] = sliced if (used and only_sliced and sliced > 0) else full
    return eff


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse(text)
    param_eff: Dict[str, Dict[int, float]] = {
        name: _param_effective_bytes(c) for name, c in comps.items()}

    raw_stats: Dict[str, Tuple[float, float, Dict[str, float],
                               List[Tuple[str, float, bool]]]] = {}
    unknown_whiles = 0

    for cname, comp in comps.items():
        flops = byts = 0.0
        coll: Dict[str, float] = {}
        children: List[Tuple[str, float, bool]] = []
        for ins in comp.instrs:
            res_b = _shape_elems_bytes(ins.shape)[1]
            if ins.op == "fusion":
                fm = re.search(r"calls=%([\w\.\-]+)", ins.raw)
                child = fm.group(1) if fm else None
                eff = param_eff.get(child, {})
                b = res_b
                for i, o in enumerate(ins.operands):
                    if o in comp.shapes:
                        b += eff.get(i, _shape_elems_bytes(comp.shapes[o])[1])
                byts += b
                if child:
                    children.append((child, 1.0, True))  # flops only
            elif ins.op not in _FREE_OPS:
                b = res_b
                for o in ins.operands:
                    if o in comp.shapes:
                        b += _shape_elems_bytes(comp.shapes[o])[1]
                byts += b

            if ins.op == "dot":
                res_elems = _shape_elems_bytes(ins.shape)[0]
                lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
                contract = 1
                lhs = ins.operands[0] if ins.operands else None
                if lhs in comp.shapes and lc:
                    dm = _SHAPE.search(comp.shapes[lhs])
                    if dm:
                        ldims = [int(d) for d in dm.group(2).split(",") if d]
                        for ci in lc.group(1).split(","):
                            if ci:
                                contract *= ldims[int(ci)]
                flops += 2.0 * res_elems * contract
            elif ins.op in _COLLECTIVES:
                coll[ins.op] = coll.get(ins.op, 0.0) + res_b

            if ins.op == "while":
                tm = _TRIP.search(ins.raw)
                trip = float(tm.group(1)) if tm else 1.0
                if not tm:
                    unknown_whiles += 1
                for attr in ("body", "condition"):
                    am = re.search(attr + r"=%([\w\.\-]+)", ins.raw)
                    if am:
                        children.append((am.group(1), trip, False))
            elif ins.op in ("call", "conditional", "custom-call", "sort",
                            "reduce", "reduce-window", "scatter", "map",
                            "all-reduce", "reduce-scatter"):
                for am in re.finditer(
                        r"(?:to_apply|branch_computations)="
                        r"(\{[^}]*\}|%[\w\.\-]+)", ins.raw):
                    for nm in re.findall(r"%([\w\.\-]+)", am.group(1)):
                        children.append((nm, 1.0, False))
        raw_stats[cname] = (flops, byts, coll, children)

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def total(name: str) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in raw_stats:
            return 0.0, 0.0, {}
        memo[name] = (0.0, 0.0, {})  # cycle guard
        f, b, coll, children = raw_stats[name]
        coll = dict(coll)
        for child, mult, flops_only in children:
            cf, cb, cc = total(child)
            f += mult * cf
            if not flops_only:
                b += mult * cb
                for k, v in cc.items():
                    coll[k] = coll.get(k, 0.0) + mult * v
        memo[name] = (f, b, coll)
        return memo[name]

    f, b, coll = total(entry)
    return HloCost(flops=f, bytes=b, collectives=coll,
                   unknown_trip_whiles=unknown_whiles, entry=entry)
