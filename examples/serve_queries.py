"""End-to-end driver: GLIN spatial-query serving with batched requests.

Builds a 200k-geometry index behind the ``SpatialIndex`` facade and serves
batches of Intersects queries through the ``SpatialQueryServer`` front-end
while interleaved inserts/deletes stream through the same facade — every
mutation is recorded as a delta against the published device snapshot, so
the planner serves the ``device+delta`` backend (snapshot + tombstone mask +
added-set check, exact at the current epoch) instead of republishing per
write, and republishes only once the delta crosses
``EngineConfig.refresh_threshold``.

    PYTHONPATH=src python examples/serve_queries.py [--n 200000] [--batches 20]
"""
import argparse
import time

import numpy as np

from repro.core import EngineConfig, GLINConfig, SpatialIndex, generate, \
    make_query_windows
from repro.serve import SpatialQueryServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--selectivity", type=float, default=1e-4)
    args = ap.parse_args()

    print(f"[serve] building index over {args.n} geometries ...")
    gs = generate("cluster", args.n, seed=0)
    t0 = time.time()
    # augmented Intersects runs are long (EXPERIMENTS.md §Perf): two-stage
    # refinement — full-run MBR masks, exact checks on <=1024 survivors; the
    # facade's adaptive cap climbs from initial_cap to the run length once
    index = SpatialIndex.build(
        gs, GLINConfig(piece_limitation=10_000),
        config=EngineConfig(initial_cap=8192, exact_budget=1024,
                            refresh_threshold=4096, delta_patch_max=4096))
    server = SpatialQueryServer(index)
    print(f"[serve] built in {time.time()-t0:.1f}s; "
          f"index {index.stats()['total_index_bytes']/1024:.0f} KiB")

    base = make_query_windows(gs, args.selectivity, 64, seed=2)
    rng = np.random.default_rng(3)
    lat = []
    total_hits = 0
    refreshes = 0
    for b in range(args.batches):
        # a fresh batch of query windows (jittered around the base set)
        idx = rng.integers(0, len(base), args.batch_size)
        jitter = rng.normal(0, 1e-4, (args.batch_size, 1))
        windows = base[idx] + jitter * [[1, 1, 1, 1]]
        t0 = time.time()
        res = server.query(windows, "intersects")
        dt = time.time() - t0
        lat.append(dt)
        refreshes += int(res.plan.rebuild_snapshot)
        total_hits += res.total_hits
        # interleaved writes (hybrid workload, paper Fig 17)
        for _ in range(32):
            if rng.random() < 0.7:
                c = rng.uniform(0.1, 0.9, 2)
                ang = np.sort(rng.uniform(0, 2 * np.pi, 8))
                verts = np.stack([c[0] + 2e-4 * np.cos(ang),
                                  c[1] + 2e-4 * np.sin(ang)], -1)
                server.insert(verts, 8, 0)
            else:
                live = np.nonzero(index.glin._live_mask())[0]
                server.delete(int(rng.choice(live)))
        if b % 5 == 0:
            print(f"[serve] batch {b}: {dt*1e3:.1f} ms "
                  f"({args.batch_size/dt:.0f} q/s) "
                  f"[{res.plan.backend}, epoch {res.epoch}]")
    lat = np.array(lat[1:])  # drop compile batch
    qps = args.batch_size / lat.mean()
    st = index.stats()
    print(f"[serve] {args.batches} batches, {total_hits} total hits, "
          f"{server.write_ops} writes, {refreshes} snapshot refreshes")
    print(f"[serve] backends {server.backend_counts}; "
          f"{st['snapshot_publishes']} publishes, "
          f"delta {st['delta_size']} at exit")
    print(f"[serve] p50={np.percentile(lat,50)*1e3:.1f}ms "
          f"p95={np.percentile(lat,95)*1e3:.1f}ms throughput={qps:.0f} queries/s")


if __name__ == "__main__":
    main()
