"""End-to-end LM training driver: a ~100M-parameter granite-style model for a
few hundred steps on the synthetic pipeline, with checkpointing.

Defaults are CPU-sized (~20M params, 200 steps, ~15 min); pass ``--full`` for
the 100M-parameter configuration.

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps 200]
"""
import argparse
import sys

from repro.configs.base import ArchConfig


def config_100m() -> ArchConfig:
    return ArchConfig(name="demo-100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
                      d_ff=2048, vocab=8192, dtype="float32")


def config_20m() -> ArchConfig:
    return ArchConfig(name="demo-20m", family="dense", n_layers=6,
                      d_model=384, n_heads=6, n_kv_heads=2, head_dim=64,
                      d_ff=1024, vocab=4096, dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_100m() if args.full else config_20m()
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    # monkey-patch the launcher's arch lookup so the demo config flows
    # through the exact production code path (launch/train.py)
    import repro.launch.train as lt
    lt.get_arch = lambda _: cfg
    rc = lt.main(["--arch", cfg.name, "--steps", str(args.steps),
                  "--batch", str(args.batch), "--seq", str(args.seq),
                  "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
                  "--lr", "1e-3", "--log-every", "20"])
    sys.exit(rc)


if __name__ == "__main__":
    main()
