"""GLIN quickstart: the ONE public API — build, query, maintain.

    PYTHONPATH=src python examples/quickstart.py

Everything goes through the ``SpatialIndex`` facade::

    from repro.core import SpatialIndex, QueryBatch, generate

    index = SpatialIndex.build(generate("cluster", 100_000, seed=0))
    res = index.query(windows, "intersects")     # 1 or 10k windows; host or
    ids0 = res[0]                                # device picked by the planner
    nn = index.query(QueryBatch.knn([[0.5, 0.5]], k=10))
    rec = index.insert(verts, nverts=8, kind=0)  # bumps the mutation epoch
    index.delete(rec)                            # snapshot rebuilt lazily

Relations: contains, intersects, within, covers, disjoint, touches, crosses
and the parametric ``dwithin:<d>`` (``repro.core.relations`` registry; exact
for concave polygons) — plus knn as a query kind.
"""
import numpy as np

from repro.core import (GLINConfig, QueryBatch, SpatialIndex, generate,
                        make_query_windows, relation_names)
from repro.core.relations import RELATIONS

# 1. a synthetic "parks"-like dataset (100k convex polygons, metro clusters)
gs = generate("cluster", 100_000, seed=0)

# 2. build the learned index behind the facade (Zmin-sorted hierarchical model
#    + leaf MBRs + the piecewise augmentation function)
index = SpatialIndex.build(gs, GLINConfig(piece_limitation=10_000))
stats = index.stats()
print(f"index: {stats['nodes']} nodes, {stats['total_index_bytes']/1024:.0f} KiB "
      f"({stats['piecewise_pieces']} pieces), data {gs.nbytes()/2**20:.0f} MiB")

# 3. one entry point, every relation, batched: 5 windows x all relations
#    (parametric families like dwithin are bound by name: "dwithin:<d>")
windows = make_query_windows(gs, 0.001, 5, seed=1)
for relation in relation_names():
    if RELATIONS[relation].parametric:
        relation = f"{relation}:0.001"
    res = index.query(windows, relation, collect_stats=True)
    st = res.stats[0] if res.stats else None
    extra = (f", {st.checked} exact checks, {st.leaves_skipped} leaves "
             f"skipped by MBR pruning" if st else "")
    print(f"{relation:10s}: {res.total_hits} hits over {len(res)} windows "
          f"[{res.plan.backend}]{extra}")

# 4. big batches take the jitted device path automatically
big = np.repeat(windows, 64, axis=0)
res = index.query(big, "intersects")
print(f"batched   : {len(res)} windows -> {res.total_hits} hits "
      f"[{res.plan.backend}: {res.plan.reason}]")

# 5. knn is a query kind, not another API
nn = index.query(QueryBatch.knn([[0.5, 0.5]], k=10))
print(f"knn       : {len(nn.ids[0])} neighbours, "
      f"d_max={nn.distances[0].max():.4f}")

# 6. verify against brute force (the library's own oracle)
assert np.array_equal(index.query(windows[1], "intersects")[0],
                      np.sort(index.glin.query_bruteforce(windows[1],
                                                          "intersects")))

# 7. maintenance: insert a new polygon, delete an old record — the device
#    snapshot is epoch-invalidated and rebuilt lazily, never served stale
ang = np.sort(np.random.default_rng(7).uniform(0, 2 * np.pi, 8))
verts = np.stack([0.5 + 3e-4 * np.cos(ang), 0.5 + 3e-4 * np.sin(ang)], -1)
rec = index.insert(verts, 8, kind=0)
assert index.snapshot_is_stale()
hit = index.query(np.array([0.49, 0.49, 0.51, 0.51]), "intersects")
assert rec in hit[0]
assert index.delete(rec)
print(f"insert/delete ok (epoch {index.epoch}); quickstart done.")
