"""GLIN quickstart: build, query, maintain — the paper's workflow in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import GLIN, GLINConfig, QueryStats, generate, make_query_windows

# 1. a synthetic "parks"-like dataset (100k convex polygons, metro clusters)
gs = generate("cluster", 100_000, seed=0)

# 2. build the learned index (Zmin-sorted hierarchical model + leaf MBRs +
#    the piecewise augmentation function for Intersects queries)
glin = GLIN.build(gs, GLINConfig(piece_limitation=10_000))
stats = glin.stats()
print(f"index: {stats['nodes']} nodes, {stats['total_index_bytes']/1024:.0f} KiB "
      f"({stats['piecewise_pieces']} pieces), data {gs.nbytes()/2**20:.0f} MiB")

# 3. spatial range queries at 0.1% selectivity
windows = make_query_windows(gs, 0.001, 5, seed=1)
for relation in ("contains", "intersects"):
    st = QueryStats()
    hits = glin.query(windows[0], relation, st)
    print(f"{relation:10s}: {len(hits)} hits, {st.checked} exact checks, "
          f"{st.leaves_skipped} leaves skipped by MBR pruning")

# 4. verify against brute force (the library's own oracle)
assert np.array_equal(np.sort(glin.query(windows[1], "intersects")),
                      np.sort(glin.query_bruteforce(windows[1], "intersects")))

# 5. maintenance: insert a new polygon, delete an old record
ang = np.sort(np.random.default_rng(7).uniform(0, 2 * np.pi, 8))
verts = np.stack([0.5 + 3e-4 * np.cos(ang), 0.5 + 3e-4 * np.sin(ang)], -1)
rec = glin.insert(verts, 8, 0)
assert glin.delete(rec)
print("insert/delete ok; quickstart done.")
