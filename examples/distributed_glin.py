"""Distributed GLIN on a simulated 8-device mesh (4 data x 2 model).

Demonstrates the production layout from DESIGN.md §4: replicated learned
model, range-partitioned record table, query batch sharded over the model
axis — the same `glin_query_step` the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/distributed_glin.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import time

import numpy as np
import jax

from repro.core import GLINConfig, SpatialIndex, generate, make_query_windows
from repro.core.distributed import build_glin_query_step, shard_glin_arrays


def main() -> None:
    from repro.utils.compat import make_auto_mesh
    mesh = make_auto_mesh((4, 2), ("data", "model"))
    print(f"[dist] mesh {dict(mesh.shape)} over {mesh.devices.size} devices")

    gs = generate("cluster", 100_000, seed=0)
    index = SpatialIndex.build(gs, GLINConfig(piece_limitation=5_000))
    snap = index.snapshot()                  # current-epoch flattened index
    table_np = shard_glin_arrays(index.glin, 4)

    step, in_sh, out_sh = build_glin_query_step(mesh, "intersects", cap=32768)
    windows = make_query_windows(gs, 1e-4, 64, seed=1).astype(np.float32)

    with mesh:
        table = {k: jax.device_put(v, in_sh[2][k]) for k, v in table_np.items()}
        sd = jax.tree_util.tree_map(lambda x: jax.device_put(x, in_sh[0]), snap)
        w = jax.device_put(windows, in_sh[1])
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        hits, counts = fn(sd, w, table)          # compile
        t0 = time.time()
        for _ in range(5):
            hits, counts = fn(sd, w, table)
        jax.block_until_ready(counts)
        dt = (time.time() - t0) / 5

    counts = np.asarray(counts)
    assert (counts >= 0).all(), "cap overflow"
    per_shard = counts.sum(axis=0)
    print(f"[dist] {windows.shape[0]} queries in {dt*1e3:.1f} ms "
          f"({windows.shape[0]/dt:.0f} q/s)")
    print(f"[dist] hits per record-shard: {per_shard.tolist()} "
          f"(total {counts.sum()})")
    # cross-check one query against the host path of the facade
    q0 = np.sort(np.asarray(hits[0])[np.asarray(hits[0]) >= 0])
    host = index.query(windows[0].astype(np.float64), "intersects",
                       backend="host")
    print(f"[dist] query 0: {len(q0)} hits; host agrees: {len(host[0])} "
          f"(fp64 host may differ at window boundaries by design)")


if __name__ == "__main__":
    main()
