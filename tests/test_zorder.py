"""Z-order codec: roundtrip, limb consistency, the monotonic-ordering theorem
(paper Thm 1) and interval covering (the property Lemmas 1/2 rest on)."""
import numpy as np
import jax.numpy as jnp
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import zorder as z

coord = st.integers(min_value=0, max_value=(1 << 30) - 1)


@given(st.lists(st.tuples(coord, coord), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_roundtrip_np(pts):
    qx = np.array([p[0] for p in pts], np.int64)
    qy = np.array([p[1] for p in pts], np.int64)
    zz = z.morton_encode_np(qx, qy)
    rx, ry = z.morton_decode_np(zz)
    np.testing.assert_array_equal(rx, qx)
    np.testing.assert_array_equal(ry, qy)


@given(st.lists(st.tuples(coord, coord), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_hilo_matches_int64(pts):
    qx = np.array([p[0] for p in pts], np.int64)
    qy = np.array([p[1] for p in pts], np.int64)
    packed = z.morton_encode_np(qx, qy)
    hi_ref, lo_ref = z.split_hilo_np(packed)
    hi, lo = z.morton_encode_hilo(jnp.asarray(qx, jnp.int32),
                                  jnp.asarray(qy, jnp.int32))
    np.testing.assert_array_equal(np.asarray(hi), hi_ref)
    np.testing.assert_array_equal(np.asarray(lo), lo_ref)
    assert (hi_ref >= 0).all() and (lo_ref >= 0).all()  # int32-safe limbs
    np.testing.assert_array_equal(z.pack_hilo_np(hi_ref, lo_ref), packed)


@given(st.tuples(coord, coord), st.tuples(coord, coord))
@settings(max_examples=100, deadline=None)
def test_monotonic_ordering_theorem(p, q):
    """Thm 1: if p dominates q (p <= q componentwise) then z(p) <= z(q)."""
    if p[0] <= q[0] and p[1] <= q[1]:
        zp = z.morton_encode_np(np.int64(p[0]), np.int64(p[1]))
        zq = z.morton_encode_np(np.int64(q[0]), np.int64(q[1]))
        assert zp <= zq


@given(st.tuples(coord, coord), st.tuples(coord, coord),
       st.tuples(coord, coord))
@settings(max_examples=100, deadline=None)
def test_interval_covers_interior(a, b, r):
    """Any grid point inside an MBR has its Z-address inside [Zmin, Zmax]."""
    x0, x1 = sorted((a[0], b[0]))
    y0, y1 = sorted((a[1], b[1]))
    px = x0 + r[0] % (x1 - x0 + 1)
    py = y0 + r[1] % (y1 - y0 + 1)
    zmin = z.morton_encode_np(np.int64(x0), np.int64(y0))
    zmax = z.morton_encode_np(np.int64(x1), np.int64(y1))
    zp = z.morton_encode_np(np.int64(px), np.int64(py))
    assert zmin <= zp <= zmax


def test_quantize_consistency():
    rng = np.random.default_rng(0)
    lon = rng.uniform(-179, 179, 256)
    lat = rng.uniform(-89, 89, 256)
    qx_np, qy_np = z.WGS84.quantize_np(lon, lat)
    qx_j, qy_j = z.WGS84.quantize_jnp(jnp.asarray(lon, jnp.float64),
                                      jnp.asarray(lat, jnp.float64))
    # fp32 inputs carry ~2^-24 relative coordinate error: a few tens of
    # cells at cm resolution. The guard margin must dominate it.
    assert np.max(np.abs(np.asarray(qx_j) - qx_np)) <= z.ZGrid.FP32_GUARD_CELLS
    assert np.max(np.abs(np.asarray(qy_j) - qy_np)) <= z.ZGrid.FP32_GUARD_CELLS
    # guarded quantization is conservative in the guard's direction
    gx_lo, _ = z.WGS84.quantize_jnp(jnp.asarray(lon, jnp.float64),
                                    jnp.asarray(lat, jnp.float64),
                                    guard=-z.ZGrid.FP32_GUARD_CELLS)
    gx_hi, _ = z.WGS84.quantize_jnp(jnp.asarray(lon, jnp.float64),
                                    jnp.asarray(lat, jnp.float64),
                                    guard=z.ZGrid.FP32_GUARD_CELLS)
    assert (np.asarray(gx_lo) <= qx_np).all()
    assert (np.asarray(gx_hi) >= qx_np).all()


def test_mbr_interval():
    mbrs = np.array([[0.1, 0.2, 0.3, 0.4], [0.0, 0.0, 1.0, 1.0]])
    zmin, zmax = z.mbr_to_zinterval_np(mbrs, z.UNIT)
    assert (zmin <= zmax).all()


def test_quantize_jnp_clamps_out_of_domain_like_host():
    """REGRESSION: padded dwithin probe windows can reach past the grid
    domain. The two-stage device quantization used to compute the fine limb
    inside an out-of-range coarse cell, landing ~32k cells below the true
    boundary; it must clamp to the domain edge exactly like quantize_np."""
    lim = (1 << 30) - 1
    grid = z.UNIT
    xs = np.array([-0.5, -1e-6, 0.0, 0.5, 1.0 - 1e-9, 1.0, 1.003, 2.0, 1e20])
    qx_np, qy_np = grid.quantize_np(xs, xs)
    qx_j, qy_j = grid.quantize_jnp(jnp.asarray(xs, jnp.float32),
                                   jnp.asarray(xs, jnp.float32))
    assert int(np.asarray(qx_j)[0]) == 0 and int(qx_np[0]) == 0
    for big in (5, 6, 7, 8):           # every >= domain-max input saturates
        assert int(np.asarray(qx_j)[big]) == lim, xs[big]
        assert int(qx_np[big]) == lim
    # in-domain values still agree with the host quantizer up to fp32 error
    mid = slice(2, 5)
    assert np.max(np.abs(np.asarray(qx_j)[mid] - qx_np[mid])) \
        <= z.ZGrid.FP32_GUARD_CELLS
    assert np.max(np.abs(np.asarray(qy_j)[mid] - qy_np[mid])) \
        <= z.ZGrid.FP32_GUARD_CELLS
