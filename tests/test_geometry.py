"""Exact-predicate correctness: concave regression cases, Monte-Carlo
sampling oracles, and Liang–Barsky clip edge cases in fp32 and fp64."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import geometry as geom
from repro.core.datasets import generate


def _geom(pts, vmax=8, kind=geom.GeomKind.POLYGON):
    """One padded record from a vertex list."""
    pts = np.asarray(pts, np.float64)
    verts = np.zeros((1, vmax, 2))
    verts[0, :len(pts)] = pts
    verts[0, len(pts):] = pts[-1]
    return (verts, np.array([len(pts)], np.int32),
            np.array([int(kind)], np.int8))


# L occupying {x <= 0.3} ∪ {y <= 0.3} of the unit square, reflex at (.3,.3)
_L_RING = [[0, 0], [1, 0], [1, 0.3], [0.3, 0.3], [0.3, 1], [0, 1]]


@pytest.mark.parametrize("xp,dtype", [(np, np.float64), (np, np.float32),
                                      (jnp, np.float32)])
def test_concave_notch_regression_intersects(xp, dtype):
    """REGRESSION (pre-fix failure): the SAT-based intersects reported a
    window tucked into an L-shape's notch as intersecting — no axis of a
    CONCAVE ring separates them, yet they are disjoint. The exact edge-clip +
    ray-cast rebuild must report disjoint on every backend precision."""
    verts, nv, kinds = _geom(_L_RING)
    rect = np.array([0.6, 0.6, 0.9, 0.9])
    v = xp.asarray(verts.astype(dtype))
    r = xp.asarray(rect.astype(dtype))
    assert not bool(geom.rect_intersects_polygons(r, v, nv, xp=xp)[0])
    assert bool(geom.rect_disjoint_geoms(r, v, nv, xp.asarray(kinds),
                                         xp=xp)[0])
    # ... while a window overlapping the L's arm does intersect
    r2 = xp.asarray(np.array([0.2, 0.2, 0.5, 0.5], dtype))
    assert bool(geom.rect_intersects_polygons(r2, v, nv, xp=xp)[0])


@pytest.mark.parametrize("xp,dtype", [(np, np.float64), (np, np.float32),
                                      (jnp, np.float32)])
def test_concave_within_regression(xp, dtype):
    """REGRESSION (pre-fix failure): the same-side corner test used by
    ``within`` never holds for concave rings, so a window genuinely inside
    the L-shape's fat corner was reported not-within."""
    verts, nv, kinds = _geom(_L_RING)
    v = xp.asarray(verts.astype(dtype))
    k = xp.asarray(kinds)
    inside = xp.asarray(np.array([0.05, 0.05, 0.25, 0.25], dtype))
    assert bool(geom.geoms_cover_rect(inside, v, nv, k, xp=xp)[0])
    # window poking out of the notch: all 4 corners inside would be a false
    # positive — the notch edges clip the window interior
    poking = xp.asarray(np.array([0.1, 0.1, 0.5, 0.5], dtype))
    assert not bool(geom.geoms_cover_rect(poking, v, nv, k, xp=xp)[0])


def test_concave_corner_inside_false_positive_within():
    """All four window corners (and centre) inside a concave ring whose
    spike dips into the window: corners-inside alone would claim within."""
    # square with a triangular notch cut from the top edge to the centre
    pac = [[0, 0], [1, 0], [1, 1], [0.6, 1], [0.5, 0.5], [0.4, 1], [0, 1]]
    verts, nv, kinds = _geom(pac)
    w = np.array([0.3, 0.2, 0.7, 0.6])
    corners = np.array([[0.3, 0.2], [0.7, 0.2], [0.7, 0.6], [0.3, 0.6],
                        [0.5, 0.4]])
    inside = geom.points_in_polygons(corners[:, 0], corners[:, 1], verts, nv)
    assert bool(inside.all())                     # the trap
    assert not bool(geom.geoms_cover_rect(w, verts, nv, kinds)[0])
    assert bool(geom.rect_intersects_polygons(w, verts, nv)[0])


def test_point_in_polygon_concave_star():
    star = [[0.5, 0.9], [0.45, 0.55], [0.1, 0.5], [0.45, 0.45], [0.5, 0.1],
            [0.55, 0.45], [0.9, 0.5], [0.55, 0.55]]
    verts, nv, _ = _geom(star)
    px = np.array([0.5, 0.2, 0.5, 0.75, 0.8])
    py = np.array([0.5, 0.2, 0.9, 0.75, 0.8])
    got = geom.points_in_polygons(px, py, verts, nv)[0]
    # centre in, corner-region out, spike tip on boundary, between spikes ~
    assert got.tolist() == [True, False, True, False, False]
    strict = geom.points_strictly_in_polygons(px, py, verts, nv)[0]
    assert strict.tolist() == [True, False, False, False, False]


def _sample_poly_points(verts, nv, rng, n=64):
    """Points inside a convex polygon via rejection-free barycentric mix."""
    v = verts[:nv]
    w = rng.dirichlet(np.ones(nv), size=n)
    return w @ v


def test_contains_matches_vertex_rule():
    gs = generate("uniform", 500, seed=1)
    rect = np.array([0.2, 0.2, 0.8, 0.8])
    got = geom.rect_contains_geoms(rect, gs.verts, gs.nverts)
    for i in range(0, 500, 17):
        nv = gs.nverts[i]
        v = gs.verts[i, :nv]
        expect = bool(((v[:, 0] >= rect[0]) & (v[:, 0] <= rect[2])
                       & (v[:, 1] >= rect[1]) & (v[:, 1] <= rect[3])).all())
        assert bool(got[i]) == expect


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_polygon_intersects_vs_sampling(seed):
    rng = np.random.default_rng(seed)
    gs = generate("uniform", 64, seed=seed % 97)
    c = rng.uniform(0.2, 0.8, 2)
    half = rng.uniform(0.001, 0.2, 2)
    rect = np.array([c[0] - half[0], c[1] - half[1],
                     c[0] + half[0], c[1] + half[1]])
    got = geom.rect_intersects_polygons(rect, gs.verts, gs.nverts)
    for i in range(64):
        nv = gs.nverts[i]
        pts = _sample_poly_points(gs.verts[i], nv, rng, 128)
        pts = np.concatenate([pts, gs.verts[i, :nv]], axis=0)
        any_in = bool(((pts[:, 0] >= rect[0]) & (pts[:, 0] <= rect[2])
                       & (pts[:, 1] >= rect[1]) & (pts[:, 1] <= rect[3])).any())
        if any_in:
            # sampling found an intersection point -> SAT must agree
            assert bool(got[i]), (i, rect)
        if not bool(got[i]):
            # SAT says disjoint -> no sampled point may fall inside
            assert not any_in


def test_polyline_intersects_segment_cases():
    # segment crossing straight through the rectangle, endpoints outside
    verts = np.zeros((1, 4, 2))
    verts[0, 0] = (0.0, 0.5)
    verts[0, 1] = (1.0, 0.5)
    verts[0, 2:] = verts[0, 1]
    nv = np.array([2], np.int32)
    rect = np.array([0.4, 0.4, 0.6, 0.6])
    assert bool(geom.rect_intersects_polylines(rect, verts, nv)[0])
    # parallel segment far away
    verts2 = verts.copy()
    verts2[0, :, 1] = 0.9
    assert not bool(geom.rect_intersects_polylines(rect, verts2, nv)[0])
    # degenerate: both endpoints inside
    verts3 = np.zeros((1, 4, 2))
    verts3[0, :, :] = (0.5, 0.5)
    assert bool(geom.rect_intersects_polylines(rect, verts3, nv)[0])


@pytest.mark.parametrize("xp,dtype", [(np, np.float64), (np, np.float32),
                                      (jnp, np.float32)])
def test_liang_barsky_zero_length_and_axis_parallel(xp, dtype):
    """REGRESSION for the dead ``xp.where(p > 0, t0n, t0n)`` branch: the clip
    must handle zero-length segments (pure point tests) and axis-parallel
    segments (p == 0 half-planes) identically in fp32 and fp64."""
    rect = np.array([0.4, 0.4, 0.6, 0.6], dtype)
    cases = [
        # (a, b, hits_closed_rect)
        ((0.5, 0.5), (0.5, 0.5), True),     # zero-length inside
        ((0.4, 0.4), (0.4, 0.4), True),     # zero-length on the corner
        ((0.3, 0.5), (0.3, 0.5), False),    # zero-length outside
        ((0.3, 0.5), (0.7, 0.5), True),     # horizontal straight through
        ((0.3, 0.4), (0.7, 0.4), True),     # horizontal ALONG the boundary
        ((0.3, 0.39), (0.7, 0.39), False),  # horizontal just outside
        ((0.5, 0.3), (0.5, 0.7), True),     # vertical straight through
        ((0.6, 0.3), (0.6, 0.7), True),     # vertical along the boundary
        ((0.61, 0.3), (0.61, 0.7), False),  # vertical just outside
        ((0.45, 0.45), (0.55, 0.55), True),  # fully inside
        ((0.0, 0.0), (0.39, 0.39), False),   # stops short of the corner
        ((0.0, 0.0), (1.0, 1.0), True),      # diagonal through
    ]
    n = len(cases)
    verts = np.zeros((n, 2, 2), dtype)
    for i, (a, b, _) in enumerate(cases):
        verts[i, 0], verts[i, 1] = a, b
    nv = np.full(n, 2, np.int32)
    got = geom.rect_intersects_polylines(xp.asarray(rect), xp.asarray(verts),
                                         nv, xp=xp)
    want = [hit for _, _, hit in cases]
    assert np.asarray(got).tolist() == want


def test_touches_crosses_dwithin_examples():
    rect = np.array([0.4, 0.4, 0.6, 0.6])
    # polygon sharing exactly one edge with the window
    vp, np_, kp = _geom([[0.6, 0.4], [0.8, 0.4], [0.8, 0.6], [0.6, 0.6]])
    assert bool(geom.rect_touches_geoms(rect, vp, np_, kp)[0])
    # polygon overlapping the window interior: intersects but not touches
    vo, no, ko = _geom([[0.55, 0.45], [0.8, 0.45], [0.8, 0.55], [0.55, 0.55]])
    assert not bool(geom.rect_touches_geoms(rect, vo, no, ko)[0])
    # window fully inside a polygon: interiors overlap, not touches
    vb, nb, kb = _geom([[0.2, 0.2], [0.8, 0.2], [0.8, 0.8], [0.2, 0.8]])
    assert not bool(geom.rect_touches_geoms(rect, vb, nb, kb)[0])
    assert bool(geom.geoms_cover_rect(rect, vb, nb, kb)[0])

    line = geom.GeomKind.POLYLINE
    # polyline crossing straight through: crosses, not touches
    vl, nl, kl = _geom([[0.3, 0.5], [0.7, 0.5]], kind=line)
    assert bool(geom.rect_crosses_geoms(rect, vl, nl, kl)[0])
    assert not bool(geom.rect_touches_geoms(rect, vl, nl, kl)[0])
    # polyline along the window boundary: touches, not crosses
    vt, nt, kt = _geom([[0.3, 0.4], [0.7, 0.4]], kind=line)
    assert bool(geom.rect_touches_geoms(rect, vt, nt, kt)[0])
    assert not bool(geom.rect_crosses_geoms(rect, vt, nt, kt)[0])
    # polyline fully inside: neither (contained, interiors overlap)
    vi, ni, ki = _geom([[0.45, 0.5], [0.55, 0.5]], kind=line)
    assert not bool(geom.rect_crosses_geoms(rect, vi, ni, ki)[0])
    assert not bool(geom.rect_touches_geoms(rect, vi, ni, ki)[0])
    # polygons never cross
    assert not bool(geom.rect_crosses_geoms(rect, vo, no, ko)[0])

    # dwithin: nearest approach of this segment to the rect is exactly
    # the corner gap hypot(0.1, 0.1)
    vd, nd, kd = _geom([[0.7, 0.7], [0.9, 0.7]], kind=line)
    gap = float(np.hypot(0.1, 0.1))
    assert bool(geom.rect_dwithin_geoms(rect, vd, nd, kd, gap + 1e-9)[0])
    assert not bool(geom.rect_dwithin_geoms(rect, vd, nd, kd, gap - 1e-9)[0])
    # intersecting geometry is dwithin at distance 0
    assert bool(geom.rect_dwithin_geoms(rect, vl, nl, kl, 0.0)[0])


def test_mbr_algebra():
    a = np.array([0.0, 0.0, 1.0, 1.0])
    b = np.array([0.5, 0.5, 1.5, 1.5])
    c = np.array([1.1, 1.1, 1.2, 1.2])
    assert bool(geom.mbr_intersects(a, b))
    assert not bool(geom.mbr_intersects(a, c))
    assert bool(geom.mbr_contains(a, np.array([0.2, 0.2, 0.8, 0.8])))
    assert not bool(geom.mbr_contains(a, b))
    # boundary touch counts as intersection (closed boundaries)
    assert bool(geom.mbr_intersects(a, np.array([1.0, 1.0, 2.0, 2.0])))
