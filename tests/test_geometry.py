"""Exact-predicate correctness via Monte-Carlo oracles (SAT vs sampling)."""
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import geometry as geom
from repro.core.datasets import generate


def _sample_poly_points(verts, nv, rng, n=64):
    """Points inside a convex polygon via rejection-free barycentric mix."""
    v = verts[:nv]
    w = rng.dirichlet(np.ones(nv), size=n)
    return w @ v


def test_contains_matches_vertex_rule():
    gs = generate("uniform", 500, seed=1)
    rect = np.array([0.2, 0.2, 0.8, 0.8])
    got = geom.rect_contains_geoms(rect, gs.verts, gs.nverts)
    for i in range(0, 500, 17):
        nv = gs.nverts[i]
        v = gs.verts[i, :nv]
        expect = bool(((v[:, 0] >= rect[0]) & (v[:, 0] <= rect[2])
                       & (v[:, 1] >= rect[1]) & (v[:, 1] <= rect[3])).all())
        assert bool(got[i]) == expect


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_polygon_intersects_vs_sampling(seed):
    rng = np.random.default_rng(seed)
    gs = generate("uniform", 64, seed=seed % 97)
    c = rng.uniform(0.2, 0.8, 2)
    half = rng.uniform(0.001, 0.2, 2)
    rect = np.array([c[0] - half[0], c[1] - half[1],
                     c[0] + half[0], c[1] + half[1]])
    got = geom.rect_intersects_polygons(rect, gs.verts, gs.nverts)
    for i in range(64):
        nv = gs.nverts[i]
        pts = _sample_poly_points(gs.verts[i], nv, rng, 128)
        pts = np.concatenate([pts, gs.verts[i, :nv]], axis=0)
        any_in = bool(((pts[:, 0] >= rect[0]) & (pts[:, 0] <= rect[2])
                       & (pts[:, 1] >= rect[1]) & (pts[:, 1] <= rect[3])).any())
        if any_in:
            # sampling found an intersection point -> SAT must agree
            assert bool(got[i]), (i, rect)
        if not bool(got[i]):
            # SAT says disjoint -> no sampled point may fall inside
            assert not any_in


def test_polyline_intersects_segment_cases():
    # segment crossing straight through the rectangle, endpoints outside
    verts = np.zeros((1, 4, 2))
    verts[0, 0] = (0.0, 0.5)
    verts[0, 1] = (1.0, 0.5)
    verts[0, 2:] = verts[0, 1]
    nv = np.array([2], np.int32)
    rect = np.array([0.4, 0.4, 0.6, 0.6])
    assert bool(geom.rect_intersects_polylines(rect, verts, nv)[0])
    # parallel segment far away
    verts2 = verts.copy()
    verts2[0, :, 1] = 0.9
    assert not bool(geom.rect_intersects_polylines(rect, verts2, nv)[0])
    # degenerate: both endpoints inside
    verts3 = np.zeros((1, 4, 2))
    verts3[0, :, :] = (0.5, 0.5)
    assert bool(geom.rect_intersects_polylines(rect, verts3, nv)[0])


def test_mbr_algebra():
    a = np.array([0.0, 0.0, 1.0, 1.0])
    b = np.array([0.5, 0.5, 1.5, 1.5])
    c = np.array([1.1, 1.1, 1.2, 1.2])
    assert bool(geom.mbr_intersects(a, b))
    assert not bool(geom.mbr_intersects(a, c))
    assert bool(geom.mbr_contains(a, np.array([0.2, 0.2, 0.8, 0.8])))
    assert not bool(geom.mbr_contains(a, b))
    # boundary touch counts as intersection (closed boundaries)
    assert bool(geom.mbr_intersects(a, np.array([1.0, 1.0, 2.0, 2.0])))
