"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is a dev-only dependency (declared in requirements-dev.txt).
When it is installed the real ``given`` / ``settings`` / ``st`` are exported
and the property tests run in full; when it is missing, stand-ins are exported
that turn each ``@given``-decorated test into an individually-skipped test, so
the rest of the module (the example-based tests) still collects and runs.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy construction; only used as a placeholder."""

        def __getattr__(self, name):
            def make(*args, **kwargs):
                return None
            return make

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def deco(fn):
            # NOT functools.wraps: pytest would follow __wrapped__ to the
            # original signature and treat strategy arguments as fixtures.
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
