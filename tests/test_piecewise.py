"""Piecewise augmentation function (paper §VIII): Algorithm-2 equivalence,
the no-false-negative invariant, and maintenance semantics."""
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.piecewise import PiecewiseFunction

interval = st.tuples(st.integers(0, 10**9), st.integers(0, 10**6))


def _mk(data, pl):
    zmin = np.array([a for a, _ in data], np.int64)
    zmax = zmin + np.array([b for _, b in data], np.int64)
    return zmin, zmax, PiecewiseFunction.build(zmin, zmax, pl)


@given(st.lists(interval, min_size=1, max_size=200),
       st.integers(1, 50), st.integers(0, 2 * 10**9))
@settings(max_examples=60, deadline=None)
def test_augment_equals_algorithm2(data, pl, zq):
    _, _, pw = _mk(data, pl)
    assert pw.augment(zq) == pw.augment_scan(zq)
    assert pw.augment_batch(np.array([zq]))[0] == pw.augment_scan(zq)


@given(st.lists(interval, min_size=1, max_size=200),
       st.integers(1, 50), st.integers(0, 2 * 10**9))
@settings(max_examples=60, deadline=None)
def test_no_false_negatives(data, pl, zq):
    """Lemma-2 support: every geometry with Zmax >= Zmin_Q must have its
    Zmin covered by the augmented interval."""
    zmin, zmax, pw = _mk(data, pl)
    aug = pw.augment(zq)
    qualifying = zmin[zmax >= zq]
    if qualifying.size:
        assert aug <= qualifying.min()
    assert aug <= zq  # augmentation never shrinks the window


def test_build_aggregates_match_paper_fig4():
    # the paper's Figure 4 example, piece_limitation = 3
    itv = [(1, 2), (2, 3), (4, 5), (3, 6), (5, 7), (3, 9), (8, 10), (0, 12),
           (9, 12), (12, 14)]
    zmin = np.array([a for a, _ in itv], np.int64)
    zmax = np.array([b for _, b in itv], np.int64)
    pw = PiecewiseFunction.build(zmin, zmax, 3)
    np.testing.assert_array_equal(pw.zmax_end, [5, 9, 12, 14])
    np.testing.assert_array_equal(pw.min_zmin, [1, 3, 0, 12])
    np.testing.assert_array_equal(pw.sum_zmin, [7.0, 11.0, 17.0, 12.0])
    np.testing.assert_array_equal(pw.count, [3, 3, 3, 1])


def test_maintenance_preserves_invariant():
    rng = np.random.default_rng(0)
    zmin = rng.integers(0, 10**6, 500).astype(np.int64)
    zmax = zmin + rng.integers(0, 10**4, 500).astype(np.int64)
    pw = PiecewiseFunction.build(zmin, zmax, 20)
    live = list(zip(zmin.tolist(), zmax.tolist()))
    for step in range(400):
        if rng.random() < 0.6 or not live:
            a = int(rng.integers(0, 2 * 10**6))
            b = a + int(rng.integers(0, 10**4))
            pw.insert(a, b)
            live.append((a, b))
        else:
            i = int(rng.integers(0, len(live)))
            a, b = live.pop(i)
            pw.delete(a, b)
        if step % 37 == 0:
            zq = int(rng.integers(0, 2 * 10**6))
            aug = pw.augment(zq)
            qual = [a for a, b in live if b >= zq]
            if qual:
                assert aug <= min(qual)


def test_out_of_bound_insertions_create_pieces():
    zmin = np.arange(100, 200, dtype=np.int64)
    zmax = zmin + 5
    pw = PiecewiseFunction.build(zmin, zmax, 10)
    n0 = pw.num_pieces
    # out-of-bound upper, pieces full -> new piece
    pw.insert(10**6, 10**6 + 1)
    assert pw.num_pieces == n0 + 1
    # out-of-bound lower, pieces full -> prepended piece
    pw.insert(0, 1)
    assert pw.num_pieces == n0 + 2
    assert int(pw.zmax_end[0]) == 1


def test_deletion_removes_empty_piece_and_avg_diff():
    zmin = np.arange(0, 30, dtype=np.int64)
    zmax = zmin + 1
    pw = PiecewiseFunction.build(zmin, zmax, 10)
    assert pw.avg_diff() >= 0.0
    n0 = pw.num_pieces
    for i in range(10):  # empty the first piece
        pw.delete(int(zmin[i]), int(zmax[i]))
    assert pw.num_pieces == n0 - 1
