"""Interpret-mode parity suite for the ONE-dispatch fused query kernel.

``batch_query_fused`` executes probe + mask/compact + exact refinement as a
single kernel launch. These tests pin its three vehicles — the Pallas
kernel body via interpret mode (the CPU correctness path; same body the TPU
runs), the single-jit "reference" XLA composition, and the staged
``batch_query(compaction="scan")`` baseline — bit-identical on hits AND
counts, including the awkward shapes: odd Q/N (tile padding), empty and
inverted probe runs, zero-survivor and all-survivor rows, the capless
``-(survivors) - 1`` budget-overflow encoding, and widest-bucket vertex
gathers on the heavy-tailed mixed store. The engine-level tests assert the
3 -> 1 dispatch collapse through the ``StageStats.dispatches`` telemetry
(not timings) and the planner's fused-selection / fallback rules."""
import numpy as np
import jax.numpy as jnp
import pytest

from _oracle import mixed_store
from repro.core import relations
from repro.core.datasets import make_query_windows
from repro.core.device import (_device_relation, _fused_operands,
                               _raw_query_keys, batch_query,
                               batch_query_fused, pods_from_store)
from repro.core.engine import EngineConfig, SpatialIndex
from repro.core.index import GLIN, GLINConfig
from repro.kernels import ops
from repro.kernels.refine import (FUSED_VMEM_LIMIT, MAX_COMPACT_BUDGET,
                                  fused_vmem_bytes)

# relations spanning both static prefilter shapes, augmentation on/off,
# probe pads (dwithin) and a host-predicate-heavy case (crosses)
PARITY_RELATIONS = ("intersects", "contains", "within", "dwithin:0.004",
                    "crosses")


@pytest.fixture(scope="module")
def mixed():
    """Heavy-tailed mixed store (points .. 64-vertex rings), odd N=347,
    published unpadded so slot indices match the raw leaf arrays."""
    gs = mixed_store(347, seed=3)
    g = GLIN.build(gs, GLINConfig(piece_limitation=200))
    s = SpatialIndex(g, EngineConfig(pad_quantum=0)).snapshot()
    return gs, g, s, pods_from_store(gs)


def _staged_scan(s, wj, pods, gs, base, budget, cap=1024):
    mb = jnp.asarray(gs.mbrs.astype(np.float32))
    return batch_query(s, wj, pods, mb, relation=base, cap=cap,
                       exact_budget=budget, compaction="scan")


def _ids(hits):
    return [np.sort(r[r >= 0]) for r in np.asarray(hits)]


# ---------------------------------------------------------------------------
# kernel-level parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("relation", PARITY_RELATIONS)
@pytest.mark.parametrize("q", [1, 13])
def test_fused_parity_odd_shapes(mixed, relation, q):
    """interpret == reference == staged scan, bit-for-bit, on odd Q and N."""
    gs, g, s, pods = mixed
    base = relations.get_relation(relation).base_name()
    wins = make_query_windows(gs, 0.004, q, seed=4)
    wj = jnp.asarray(wins.astype(np.float32))
    h_ref, c_ref = batch_query_fused(s, wj, pods, relation=base,
                                     exact_budget=64, mode="reference")
    h_int, c_int = batch_query_fused(s, wj, pods, relation=base,
                                     exact_budget=64, mode="interpret")
    h_scan, c_scan = _staged_scan(s, wj, pods, gs, base, 64)
    np.testing.assert_array_equal(np.asarray(c_int), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(c_int), np.asarray(c_scan))
    np.testing.assert_array_equal(np.asarray(h_int), np.asarray(h_ref))
    for a, b in zip(_ids(h_int), _ids(h_scan)):
        np.testing.assert_array_equal(a, b)


def test_fused_budget_overflow_signalling(mixed):
    """Capless overflow: a negative count is ALWAYS -(MBR survivors) - 1,
    identical across interpret / reference / staged-scan (the staged path's
    cap is settled high enough that only the budget can overflow)."""
    gs, g, s, pods = mixed
    lo = gs.mbrs[:, :2].min(axis=0) - 0.01
    hi = gs.mbrs[:, 2:].max(axis=0) + 0.01
    wins = np.array([
        [lo[0], lo[1], hi[0], hi[1]],          # whole domain: must overflow
        [hi[0] + 1, hi[1] + 1, hi[0] + 2, hi[1] + 2],   # empty region
        list(gs.mbrs[0, :2]) + list(gs.mbrs[0, :2] + 1e-5),  # tiny
    ], np.float32)
    wj = jnp.asarray(wins)
    outs = {
        "interpret": batch_query_fused(s, wj, pods, relation="intersects",
                                       exact_budget=8, mode="interpret"),
        "reference": batch_query_fused(s, wj, pods, relation="intersects",
                                       exact_budget=8, mode="reference"),
        "scan": _staged_scan(s, wj, pods, gs, "intersects", 8, cap=2048),
    }
    counts = {k: np.asarray(c) for k, (h, c) in outs.items()}
    np.testing.assert_array_equal(counts["interpret"], counts["reference"])
    np.testing.assert_array_equal(counts["interpret"], counts["scan"])
    assert counts["interpret"][0] < 0
    assert -(counts["interpret"][0]) - 1 > 8         # encodes the true need
    assert counts["interpret"][1] == 0
    # non-overflowing rows still return exact hits alongside the signal
    for h, c in outs.values():
        assert (np.asarray(h)[1] == -1).all()


def test_fused_zero_and_all_survivor_rows(mixed):
    """A row with no survivors is all -1 / count 0; a row where EVERY live
    record survives fits when budget >= N and matches the brute oracle."""
    gs, g, s, pods = mixed
    lo = gs.mbrs[:, :2].min(axis=0) - 0.01
    hi = gs.mbrs[:, 2:].max(axis=0) + 0.01
    wins = np.array([
        [hi[0] + 1, hi[1] + 1, hi[0] + 2, hi[1] + 2],   # zero survivors
        [lo[0], lo[1], hi[0], hi[1]],                   # all survivors
    ], np.float32)
    hits, counts = batch_query_fused(s, jnp.asarray(wins), pods,
                                     relation="intersects",
                                     exact_budget=512, mode="interpret")
    hits, counts = np.asarray(hits), np.asarray(counts)
    assert counts[0] == 0 and (hits[0] == -1).all()
    bf = g.query_bruteforce(wins[1].astype(np.float64), "intersects")
    assert counts[1] == len(bf) == len(gs.nverts)
    np.testing.assert_array_equal(np.sort(hits[1][hits[1] >= 0]), bf)


def test_fused_empty_and_inverted_probe_runs(mixed):
    """Doctored probe keys through the raw kernel: an inverted run
    (zmin > ub -> start >= end) and an off-the-end empty run both yield
    zero survivors; an untouched row is unaffected by its neighbours."""
    gs, g, s, pods = mixed
    rel = _device_relation("contains")      # augment=False: probes stay raw
    wins = make_query_windows(gs, 0.02, 3, seed=7).astype(np.float32)
    wj = jnp.asarray(wins)
    probe_w = rel.probe_window(wj, xp=jnp)
    qk = np.stack([np.asarray(a) for a in _raw_query_keys(s, wj, rel)], 1)
    qk[0] = qk[0][[2, 3, 0, 1]]                   # swap zmin <-> ub
    qk[1] = [2**30, 0, 2**30, 0]                  # beyond every stored key
    pod_i = jnp.stack([pods.off, pods.nv, pods.kd, pods.bucket], axis=1)
    hits, counts = ops.refine_fused(
        wj, probe_w, jnp.asarray(qk, jnp.int32), *_fused_operands(s),
        pod_i, pods.pool, s.slot_lmbr, s.slot_rmbr, budget=32,
        prefilter=rel.prefilter_kind,
        predicate=lambda w, vv, nn, kk: rel.predicate(w, vv, nn, kk, xp=jnp),
        augment=False, search_steps=s.search_steps, depth=s.depth,
        num_buckets=pods.num_buckets, interpret=True)
    hits, counts = np.asarray(hits), np.asarray(counts)
    assert counts[0] == 0 and (hits[0] == -1).all()
    assert counts[1] == 0 and (hits[1] == -1).all()
    _, c_ref = batch_query_fused(s, wj, pods, relation="contains",
                                 exact_budget=32, mode="reference")
    assert counts[2] == np.asarray(c_ref)[2]


def test_fused_widest_bucket_gather(mixed):
    """The exact stage's pow2 gather ladder must reach the WIDEST surviving
    bucket: a whole-domain query on the heavy-tailed store pulls the
    64-vertex rings through the top bucket, and stays oracle-exact."""
    gs, g, s, pods = mixed
    assert pods.num_buckets >= 2       # heavy tail actually spans buckets
    assert int(np.asarray(pods.bucket).max()) == pods.num_buckets - 1
    lo = gs.mbrs[:, :2].min(axis=0) - 0.01
    hi = gs.mbrs[:, 2:].max(axis=0) + 0.01
    w = np.array([[lo[0], lo[1], hi[0], hi[1]]], np.float32)
    hits, counts = batch_query_fused(s, jnp.asarray(w), pods,
                                     relation="intersects",
                                     exact_budget=512, mode="interpret")
    ids = np.sort(np.asarray(hits)[0][np.asarray(hits)[0] >= 0])
    bf = g.query_bruteforce(w[0].astype(np.float64), "intersects")
    np.testing.assert_array_equal(ids, bf)
    # the survivors really include a top-bucket (widest) record
    assert int(np.asarray(pods.bucket)[ids].max()) == pods.num_buckets - 1


def test_fused_input_validation(mixed):
    gs, g, s, pods = mixed
    wj = jnp.asarray(make_query_windows(gs, 0.004, 2, seed=1)
                     .astype(np.float32))
    with pytest.raises(ValueError, match="mode"):
        batch_query_fused(s, wj, pods, relation="intersects", mode="turbo")
    with pytest.raises(ValueError, match="exact_budget"):
        batch_query_fused(s, wj, pods, relation="intersects",
                          exact_budget=0, mode="reference")


# ---------------------------------------------------------------------------
# engine-level: planner selection, dispatch telemetry, fallbacks
# ---------------------------------------------------------------------------
def _engine(fusion, n=250, **eng):
    gs = mixed_store(n, seed=5)
    cfg = EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1,
                       fusion=fusion, **eng)
    return SpatialIndex.build(gs, GLINConfig(piece_limitation=200),
                              config=cfg)


def test_engine_fused_one_dispatch():
    """The headline: fusion collapses the staged refine's 3 dispatches to 1,
    asserted via telemetry, with identical results."""
    idx = _engine("interpret")
    wins = make_query_windows(idx.gs, 0.004, 17, seed=5)
    res = idx.query(wins, "intersects", backend="device")
    refine = {st.stage: st for st in res.stages}["refine"]
    assert refine.impl == "fused"
    assert refine.dispatches == 1
    assert "probe" in refine.covers and "refine" in refine.covers
    agg = idx.stats()["stages"]["device"]["refine"]
    assert agg["impl"] == "fused" and agg["dispatches"] == 1

    off = _engine("off")
    res_off = off.query(wins, "intersects", backend="device")
    r_off = {st.stage: st for st in res_off.stages}["refine"]
    assert r_off.impl == "device" and r_off.dispatches == 3
    for a, b in zip(res.ids, res_off.ids):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("relation", ["intersects", "within", "disjoint"])
def test_engine_fused_matches_staged(relation):
    """End-to-end ids agree across fusion modes for plain, contains-shaped
    and complement-finished relations."""
    wins = None
    got = {}
    for fusion in ("interpret", "reference", "off"):
        idx = _engine(fusion)
        if wins is None:
            wins = make_query_windows(idx.gs, 0.004, 9, seed=6)
        got[fusion] = idx.query(wins, relation, backend="device").ids
    for fusion in ("interpret", "reference"):
        for a, b in zip(got[fusion], got["off"]):
            np.testing.assert_array_equal(a, b)


def test_engine_fused_overflow_ladder():
    """Budget overflow inside the fused kernel walks the SAME OverflowLadder
    (capless: no disambiguating bounds probe is spent) and ends exact."""
    idx = _engine("interpret", exact_budget=4)
    lo = idx.gs.mbrs[:, :2].min(axis=0) - 0.01
    hi = idx.gs.mbrs[:, 2:].max(axis=0) + 0.01
    w = np.array([[lo[0], lo[1], hi[0], hi[1]]])
    res = idx.query(w, "intersects", backend="device")
    refine = {st.stage: st for st in res.stages}["refine"]
    assert refine.escalations >= 1
    # one dispatch per attempt: escalations+1 attempts, nothing extra
    assert refine.dispatches == refine.escalations + 1
    bf = idx.glin.query_bruteforce(w[0], "intersects")
    np.testing.assert_array_equal(res.ids[0], bf)


def test_engine_explain_shows_fused():
    idx = _engine("interpret")
    wins = make_query_windows(idx.gs, 0.004, 8, seed=2)
    text = idx.explain(wins, "intersects")
    assert "fused one-kernel refine" in text
    assert "impl=fused" in text
    assert idx.plan(wins, "intersects").fused
    off = _engine("off")
    assert not off.plan(wins, "intersects").fused
    assert "fused one-kernel refine" not in off.explain(wins, "intersects")


def test_custom_prefilter_falls_back_to_staged():
    """A relation the kernel cannot prefilter (prefilter_kind="custom") is
    planned staged, and the raw fused entry point refuses it loudly."""
    base = relations.get_relation("intersects")
    custom = relations.Relation(
        name="_test_custom", predicate=base.predicate, augment=base.augment,
        mbr_prefilter=base.mbr_prefilter, prefilter_kind="custom")
    relations.register_relation(custom)
    try:
        idx = _engine("interpret")
        wins = make_query_windows(idx.gs, 0.004, 8, seed=2)
        assert not idx.plan(wins, "_test_custom").fused
        res = idx.query(wins, "_test_custom", backend="device")
        refine = {st.stage: st for st in res.stages}["refine"]
        assert refine.impl == "device"
        base_res = idx.query(wins, "intersects", backend="device")
        for a, b in zip(res.ids, base_res.ids):
            np.testing.assert_array_equal(a, b)
        s = idx.snapshot()
        pods, _ = idx._device_payload(idx._snapshot_recs)
        with pytest.raises(ValueError, match="custom"):
            batch_query_fused(s, jnp.asarray(wins.astype(np.float32)),
                              pods, relation="_test_custom",
                              mode="reference")
    finally:
        relations.RELATIONS.pop("_test_custom", None)
        relations._BOUND.clear()


def test_fusion_mode_resolution():
    """_fusion_mode: the single gate deciding kernel vs staged."""
    idx = _engine("interpret")
    assert idx._fusion_mode("intersects") == "interpret"
    # budget outside (0, MAX_COMPACT_BUDGET] -> staged (dense/oversized)
    assert idx._fusion_mode("intersects", budget=0) is None
    assert idx._fusion_mode("intersects",
                            budget=MAX_COMPACT_BUDGET + 1) is None
    assert idx._fusion_mode("intersects",
                            budget=MAX_COMPACT_BUDGET) == "interpret"
    off = _engine("off")
    assert off._fusion_mode("intersects") is None
    bogus = _engine("warp9")
    with pytest.raises(ValueError, match="fusion"):
        bogus._fusion_mode("intersects")


def test_fusion_vmem_envelope_falls_back():
    """When the resident tables cannot fit the kernel's VMEM envelope the
    planner keeps the plan fused (it cannot know the budget the ladder will
    settle) but the stage falls back to staged execution at run time."""
    idx = _engine("interpret")
    snap = idx.snapshot()
    pods, _ = idx._device_payload(idx._snapshot_recs)
    assert idx._fusion_mode("intersects", budget=64,
                            snap=snap, pods=pods) == "interpret"
    est = fused_vmem_bytes(
        n_slots=snap.num_slots, n_leaves=snap.leaf_start.shape[0],
        n_nodes=max(snap.node_dlo_hi.shape[0], 1),
        n_codes=max(snap.child_codes.shape[0], 1),
        n_pieces=max(snap.pw_zmax_hi.shape[0], 1),
        n_records=snap.recs.shape[0], pool_rows=pods.pool.shape[0],
        budget=64, max_width=1 << (pods.num_buckets - 1))
    assert 0 < est <= FUSED_VMEM_LIMIT
