"""Serving tier: concurrent submit/flush/insert exactness vs the oracle,
weighted-fair admission control and explicit `Rejected` shedding, overlapped
group-flush telemetry atomicity, replica fan-out routing, the pump-mode
serving loop with adaptive micro-batching, and cache safety across async
snapshot generation swaps."""
import threading
import time

import numpy as np
import pytest

from repro.core.datasets import generate, make_query_windows
from repro.core.engine import EngineConfig, SpatialIndex
from repro.core.geometry import mbrs_of_verts
from repro.core.index import GLINConfig
from repro.core.relations import get_relation
from repro.serve import Rejected, ServerConfig, SpatialQueryServer


def _fp32_index(n=3000, pl=200, seed=0, **eng):
    """fp32-representable dataset: the host (fp64) and device (fp32) paths
    agree bit-for-bit, so serving results compare exactly against the host
    oracle regardless of which backend the planner picks."""
    gs = generate("cluster", n, seed=seed)
    gs.verts = gs.verts.astype(np.float32).astype(np.float64)
    gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
    cfg = EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1, **eng)
    return SpatialIndex.build(gs, GLINConfig(piece_limitation=pl), config=cfg)


def _fp32_windows(idx, sel, k, seed):
    w = make_query_windows(idx.gs, sel, k, seed=seed)
    return w.astype(np.float32).astype(np.float64)


def _fp32_polygon(rng, c, r=1e-3, nv=8):
    ang = np.sort(rng.uniform(0, 2 * np.pi, nv))
    v = np.stack([c[0] + r * np.cos(ang), c[1] + r * np.sin(ang)], -1)
    return v.astype(np.float32).astype(np.float64)


def _drain_inflight(idx, w, relation="intersects"):
    """Poll until any in-flight async snapshot build lands (queries drive
    the poll), so no background build thread outlives the test."""
    deadline = time.perf_counter() + 20.0
    while idx._inflight is not None and time.perf_counter() < deadline:
        idx.query(w[None], relation)
        time.sleep(0.01)


# ------------------------------------------------------------- concurrency --
def test_concurrent_submit_flush_insert_exact_vs_oracle():
    """Three flusher threads and one writer thread hammer a single server.

    Inserts are append-only, so EVERY served result must equal the base hit
    set plus a prefix (in insertion order) of the inserted hitters — i.e. be
    exact at the epoch the engine froze for that batch. A torn read (partial
    delta, stale cache entry mixed with fresh patch, dropped sibling group)
    breaks the prefix property."""
    idx = _fp32_index(n=3000, refresh_threshold=24)
    server = SpatialQueryServer(idx, async_republish=True)
    relation = "intersects"
    wins = _fp32_windows(idx, 2e-3, 6, seed=3)
    base = [set(ids.tolist())
            for ids in idx.query(wins, relation, backend="host")]
    pred = get_relation(relation).predicate

    log = []       # (rec id, hit-per-window flags), in insertion order
    errors = []

    def writer():
        rng = np.random.default_rng(11)
        try:
            for j in range(48):
                if j % 2 == 0:   # half the inserts land inside a probe window
                    w = wins[(j // 2) % len(wins)]
                    c = np.array([(w[0] + w[2]) / 2, (w[1] + w[3]) / 2])
                else:
                    c = rng.uniform(0.05, 0.95, 2)
                v = _fp32_polygon(rng, c, r=2e-4)
                v32 = v.astype(np.float32)[None]
                hits = [bool(np.asarray(pred(
                    wins[q].astype(np.float32), v32, np.array([8]),
                    np.array([0])))[0]) for q in range(len(wins))]
                log.append((server.insert(v, 8, 0), hits))
                time.sleep(0.002)
        except BaseException as e:   # noqa: BLE001 — re-raised via `errors`
            errors.append(e)

    ticket_win = {}
    collected = {}
    t_lock = threading.Lock()

    def flusher(tid):
        try:
            for _ in range(8):
                mine = {}
                for q in range(len(wins)):
                    mine[server.submit(wins[q], relation,
                                       tenant=f"t{tid}")] = q
                with t_lock:
                    ticket_win.update(mine)
                out = server.flush()   # may serve other threads' tickets too
                with t_lock:
                    collected.update(out)
                time.sleep(0.001)
        except BaseException as e:   # noqa: BLE001 — re-raised via `errors`
            errors.append(e)

    threads = [threading.Thread(target=flusher, args=(i,)) for i in range(3)]
    wt = threading.Thread(target=writer)
    for t in threads:
        t.start()
    wt.start()
    for t in threads:
        t.join()
    wt.join()
    assert not errors, errors
    collected.update(server.flush())   # drain any straggler tickets

    assert set(collected) == set(ticket_win)   # every ticket resolved
    hitters = [[rec for rec, h in log if h[q]] for q in range(len(wins))]
    for ticket, ids in collected.items():
        q = ticket_win[ticket]
        assert not isinstance(ids, Rejected)
        s = set(ids.tolist())
        assert base[q] <= s
        extra = sorted(s - base[q])     # rec ids are append-only increasing
        assert extra == hitters[q][:len(extra)]

    final = server.query(wins, relation)
    hostr = idx.query(wins, relation, backend="host")
    for q in range(len(wins)):
        np.testing.assert_array_equal(final[q], hostr[q])
    st = server.stats()
    assert st["queue_depth"] == 0
    assert st["write_ops"] == 48
    assert st["shed"] == 0
    _drain_inflight(idx, wins[0], relation)


def test_cache_never_serves_across_generation_swap():
    """Writes bump the epoch and async republishes bump the publish count;
    a cached result from either dead generation must never resurface."""
    idx = _fp32_index(n=2000, refresh_threshold=4)
    server = SpatialQueryServer(idx, async_republish=True)
    rng = np.random.default_rng(13)
    relation = "intersects"
    w = _fp32_windows(idx, 2e-3, 1, seed=12)[0]
    for j in range(8):
        c = np.array([(w[0] + w[2]) / 2 + (j - 4) * 1e-5,
                      (w[1] + w[3]) / 2])
        rec = server.insert(_fp32_polygon(rng, c, r=2e-4), 8, 0)
        t = server.submit(w, relation)
        out = server.flush()[t]
        assert rec in set(out.tolist())   # a stale cache hit would miss it
        np.testing.assert_array_equal(
            out, idx.query(w[None], relation, backend="host")[0])
        t2 = server.submit(w, relation)
        np.testing.assert_array_equal(server.flush()[t2], out)
    # drive flushes until an async republish lands (the publish count moves
    # without an epoch bump), then the same window must still serve exactly
    pubs0 = idx.serving_generation[1]
    deadline = time.perf_counter() + 20.0
    while idx.serving_generation[1] == pubs0:
        assert time.perf_counter() < deadline, "async republish never landed"
        # keep the delta growing so a republish (re-)triggers, then poll
        server.insert(_fp32_polygon(rng, rng.uniform(0.2, 0.8, 2), r=2e-4),
                      8, 0)
        t = server.submit(w, relation)
        server.flush()
        time.sleep(0.01)
    t = server.submit(w, relation)
    np.testing.assert_array_equal(
        server.flush()[t], idx.query(w[None], relation, backend="host")[0])
    assert server.cache_hits > 0   # the cache was actually exercised
    _drain_inflight(idx, w, relation)


# --------------------------------------------------- admission + fairness --
def test_shed_requests_surface_as_rejected():
    idx = _fp32_index(n=1500)
    server = SpatialQueryServer(
        idx, config=ServerConfig(max_queue=8, fair_watermark=1.0))
    w = _fp32_windows(idx, 2e-3, 1, seed=5)[0]
    tickets = [server.submit(w, "intersects") for _ in range(12)]
    assert server.shed_count == 4
    out = server.flush()
    assert set(out) == set(tickets)        # nothing silently dropped
    rejected = [t for t in tickets if isinstance(out[t], Rejected)]
    assert rejected == tickets[8:]
    assert out[rejected[0]].reason.startswith("queue full")
    assert out[rejected[0]].tenant == "default"
    ref = idx.query(w[None], "intersects", backend="host")[0]
    for t in tickets[:8]:
        np.testing.assert_array_equal(out[t], ref)
    st = server.stats()
    assert st["tenants"]["default"] == {
        "admitted": 8, "rejected": 4, "served": 8}
    assert st["shed"] == 4 and st["queue_depth"] == 0


def test_weighted_fair_admission_protects_trickle_tenant():
    """Above the fairness watermark a flooding tenant is capped at its
    weighted share of the queue bound; a trickle tenant keeps being
    admitted into its reserved slice."""
    idx = _fp32_index(n=1500)
    server = SpatialQueryServer(
        idx, config=ServerConfig(max_queue=16, fair_watermark=0.25))
    w = _fp32_windows(idx, 2e-3, 1, seed=6)[0]
    tb0 = server.submit(w, "intersects", tenant="B")   # B is now known
    ta = [server.submit(w, "intersects", tenant="A") for _ in range(30)]
    st = server.stats()["tenants"]
    assert st["A"] == {"admitted": 8, "rejected": 22, "served": 0}
    tb = [server.submit(w, "intersects", tenant="B") for _ in range(5)]
    assert server.stats()["tenants"]["B"]["rejected"] == 0
    out = server.flush()
    assert set(out) == set([tb0] + ta + tb)
    assert not any(isinstance(out[t], Rejected) for t in [tb0] + tb)
    assert sum(isinstance(out[t], Rejected) for t in ta) == 22


# ------------------------------------------------------ flush atomicity -----
def test_overlapped_flush_atomicity_on_group_failure():
    """One failed relation group: EVERY drained ticket (including the
    sibling group's completed work) is restored untouched, no counter moves,
    and a retry serves everything exactly."""
    idx = _fp32_index(n=1500)
    server = SpatialQueryServer(idx)     # overlap_groups on by default
    wins = _fp32_windows(idx, 2e-3, 4, seed=7)
    real_query = idx.query

    def flaky(batch, relation=None, **kw):
        if getattr(batch, "relation", relation) == "contains":
            raise RuntimeError("boom")
        return real_query(batch, relation, **kw)

    idx.query = flaky
    try:
        t1 = [server.submit(w, "intersects") for w in wins]
        t2 = [server.submit(w, "contains") for w in wins]

        def snap():
            return (server.served_queries, server.served_batches,
                    server.cache_hits, server.cache_misses,
                    dict(server.backend_counts), dict(server.batch_hist),
                    list(server.replica_queries),
                    {t: dict(v) for t, v in server._tenant_stats.items()})

        before = snap()
        with pytest.raises(RuntimeError, match="boom"):
            server.flush()
        assert snap() == before                      # telemetry untouched
        assert server.stats()["queue_depth"] == 8    # every ticket restored
    finally:
        idx.query = real_query
    out = server.flush()
    assert set(out) == set(t1 + t2)
    for rel, tickets in (("intersects", t1), ("contains", t2)):
        hostr = idx.query(wins, rel, backend="host")
        for q, t in enumerate(tickets):
            np.testing.assert_array_equal(out[t], hostr[q])
    assert server.served_queries == 8 and server.served_batches == 2


# -------------------------------------------------------------- replicas ----
def test_replica_fanout_exact_and_counted():
    idx = _fp32_index(n=3000)
    server = SpatialQueryServer(idx, config=ServerConfig(replicas=2))
    assert idx.config.replicas == 2    # the server raised the engine knob
    for rnd in range(3):
        wins = _fp32_windows(idx, 2e-3, 4, seed=20 + rnd)
        tickets = [(server.submit(w, rel), rel, q)
                   for rel in ("intersects", "contains")
                   for q, w in enumerate(wins)]
        out = server.flush()
        for rel in ("intersects", "contains"):
            hostr = idx.query(wins, rel, backend="host")
            for t, r, q in tickets:
                if r == rel:
                    np.testing.assert_array_equal(out[t], hostr[q])
    st = server.stats()
    assert st["replicas"] == 2
    assert sum(st["replica_queries"]) == 24
    assert st["replica_inflight"] == [0, 0]
    # least-loaded dispatch: two concurrent picks land on distinct replicas
    with server._lock:
        picks = {server._pick_replica_locked(), server._pick_replica_locked()}
        server._replica_inflight = [0, 0]
    assert picks == {0, 1}
    # the engine's replica routing itself is exact across placements
    wins = _fp32_windows(idx, 2e-3, 4, seed=40)
    r0 = idx.query(wins, "intersects", replica=0)
    r1 = idx.query(wins, "intersects", replica=1)
    hostr = idx.query(wins, "intersects", backend="host")
    for q in range(len(wins)):
        np.testing.assert_array_equal(r0[q], hostr[q])
        np.testing.assert_array_equal(r1[q], hostr[q])


# ------------------------------------------------------------- pump mode ----
def test_serving_loop_resolves_tickets_with_adaptive_batching():
    idx = _fp32_index(n=2000)
    server = SpatialQueryServer(
        idx, config=ServerConfig(min_batch=4, gather_window_s=0.01))
    wins = _fp32_windows(idx, 2e-3, 8, seed=9)
    hostr = idx.query(wins, "intersects", backend="host")
    server.start()
    try:
        tickets = [(server.submit(wins[i % 8], "intersects"), i % 8)
                   for i in range(40)]
        for t, q in tickets:
            val, ts = server.result_at(t, timeout=60.0)
            assert not isinstance(val, Rejected)
            np.testing.assert_array_equal(val, hostr[q])
            assert ts <= time.perf_counter()
    finally:
        server.stop()
    st = server.stats()
    assert st["queue_depth"] == 0
    assert st["served_queries"] == 40
    assert st["batch_size_hist"]            # micro-batches were recorded
    assert st["failed_batches"] == 0
    with pytest.raises(TimeoutError):       # results are consumed exactly once
        server.result(tickets[0][0], timeout=0.0)


def test_pump_mode_sheds_with_rejected_results_under_backpressure():
    """Gate the single worker: the slot semaphore blocks the pump, queue
    depth saturates, and admission control sheds — every shed ticket still
    resolves through result() as an explicit Rejected."""
    idx = _fp32_index(n=1500)
    server = SpatialQueryServer(idx, config=ServerConfig(
        max_queue=4, fair_watermark=1.0, max_workers=1, min_batch=1,
        adaptive_batch=False))
    w = _fp32_windows(idx, 2e-3, 1, seed=10)[0]
    real_query = idx.query
    gate = threading.Event()

    def slow(batch, relation=None, **kw):
        gate.wait(10.0)
        return real_query(batch, relation, **kw)

    idx.query = slow
    tickets = []
    try:
        server.start()
        deadline = time.perf_counter() + 10.0
        while server.shed_count == 0:
            assert time.perf_counter() < deadline, "backpressure never shed"
            tickets.append(server.submit(w, "intersects"))
            time.sleep(0.001)
    finally:
        gate.set()
        idx.query = real_query
        server.stop()
    outs = [server.result(t, timeout=30.0) for t in tickets]
    rejected = [o for o in outs if isinstance(o, Rejected)]
    served = [o for o in outs if not isinstance(o, Rejected)]
    assert rejected and len(rejected) == server.shed_count
    assert "fair share" in rejected[0].reason or "queue full" in \
        rejected[0].reason
    ref = idx.query(w[None], "intersects", backend="host")[0]
    for o in served:
        np.testing.assert_array_equal(o, ref)
    assert server.stats()["queue_depth"] == 0


def test_stop_drains_pending_tickets():
    idx = _fp32_index(n=1500)
    server = SpatialQueryServer(idx, config=ServerConfig(min_batch=64))
    wins = _fp32_windows(idx, 2e-3, 4, seed=14)
    hostr = idx.query(wins, "intersects", backend="host")
    server.start()
    tickets = [server.submit(wins[q], "intersects") for q in range(4)]
    server.stop()    # must serve what is queued, not strand the waiters
    for q, t in enumerate(tickets):
        np.testing.assert_array_equal(server.result(t, timeout=5.0), hostr[q])


# ------------------------------------------------------------- coalescing ---
def test_flush_coalesces_duplicates_into_independent_results():
    """Duplicate (window, relation) submissions in one micro-batch reach the
    engine as ONE row (`coalesced` counts the folded duplicates), yet every
    caller gets its own writable array — mutating one result must not leak
    into a sibling's or into the cache."""
    idx = _fp32_index(n=2000)
    server = SpatialQueryServer(idx)
    w = _fp32_windows(idx, 2e-3, 2, seed=31)
    engine_rows = []
    real_query = idx.query

    def spy(batch, relation=None, **kw):
        engine_rows.append(len(batch))
        return real_query(batch, relation, **kw)

    idx.query = spy
    try:
        dup = [server.submit(w[0], "intersects", tenant=t)
               for t in ("a", "b", "c")]
        other = server.submit(w[1], "intersects", tenant="a")
        out = server.flush()
    finally:
        idx.query = real_query
    assert engine_rows == [2]          # 4 submissions, 2 distinct windows
    assert server.stats()["coalesced"] == 2
    ref = idx.query(w[0][None], "intersects", backend="host")[0]
    results = [out[t] for t in dup]
    for r in results:
        np.testing.assert_array_equal(r, ref)
        assert r.flags.writeable
    assert len({id(r) for r in results}) == 3   # independent arrays
    results[0][:] = -7                          # vandalize one caller's copy
    np.testing.assert_array_equal(results[1], ref)
    np.testing.assert_array_equal(results[2], ref)
    # the cache stored a frozen copy, untouched by the vandalism
    t2 = server.submit(w[0], "intersects")
    np.testing.assert_array_equal(server.flush()[t2], ref)
    assert not isinstance(out[other], Rejected)


def test_pump_mode_coalesces_and_counts():
    idx = _fp32_index(n=1500)
    server = SpatialQueryServer(idx, config=ServerConfig(min_batch=64))
    w = _fp32_windows(idx, 2e-3, 1, seed=33)[0]
    tickets = [server.submit(w, "disjoint") for _ in range(6)]  # pre-queued
    server.start()
    server.stop()    # drain: all six land in one gather -> one engine row
    outs = [server.result(t, timeout=10.0) for t in tickets]
    ref = idx.query(w[None], "disjoint", backend="host")[0]
    for o in outs:
        np.testing.assert_array_equal(o, ref)
    st = server.stats()
    # every submission resolved through the cache or an engine group, and at
    # least one duplicate was folded before reaching the engine (the rest
    # may have been cache hits across batches — either way none ran twice)
    assert st["cache_hits"] + st["cache_misses"] == len(tickets)
    assert st["coalesced"] + st["cache_hits"] >= len(tickets) - 1
    assert st["coalesced"] >= 1
    assert "engine_stages" in st and st["engine_stages"]
