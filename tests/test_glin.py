"""GLIN correctness: query == brute force across datasets, relations,
selectivities; leaf-MBR pruning effectiveness (Table III); maintenance."""
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.datasets import generate, make_query_windows
from repro.core.index import GLIN, GLINConfig, QueryStats
from repro.core.model import GLINModelConfig


def _build(name, n=6000, pl=300, seed=0, **kw):
    gs = generate(name, n, seed=seed)
    return GLIN.build(gs, GLINConfig(piece_limitation=pl, **kw))


@pytest.mark.parametrize("name", ["uniform", "diagonal", "cluster", "roads"])
@pytest.mark.parametrize("relation", ["contains", "intersects"])
def test_query_matches_bruteforce(name, relation):
    g = _build(name)
    for sel in (0.02, 0.002):
        wins = make_query_windows(g.gs, sel, 4, seed=11)
        for w in wins:
            got = np.sort(g.query(w, relation))
            ref = np.sort(g.query_bruteforce(w, relation))
            np.testing.assert_array_equal(got, ref)


def test_points_contains_only():
    g = _build("points")
    wins = make_query_windows(g.gs, 0.01, 4, seed=3)
    for w in wins:
        np.testing.assert_array_equal(np.sort(g.query(w, "contains")),
                                      np.sort(g.query_bruteforce(w, "contains")))


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_random_windows_never_miss(seed):
    g = _build("cluster", n=2000, pl=100, seed=seed % 7)
    rng = np.random.default_rng(seed)
    c = rng.uniform(0, 1, 2)
    half = rng.uniform(1e-4, 0.4, 2)
    w = np.array([c[0] - half[0], c[1] - half[1], c[0] + half[0], c[1] + half[1]])
    for rel in ("contains", "intersects"):
        np.testing.assert_array_equal(np.sort(g.query(w, rel)),
                                      np.sort(g.query_bruteforce(w, rel)))


def test_leaf_mbr_pruning_reduces_checks():
    """§V-C / Table III: leaf MBRs must cut refinement work on clustered data."""
    g = _build("cluster", n=20000, pl=500)
    wins = make_query_windows(g.gs, 0.001, 10, seed=5)
    tot_cand = tot_checked = 0
    for w in wins:
        stx = QueryStats()
        g.query(w, "contains", stx)
        tot_cand += stx.candidates
        tot_checked += stx.checked
    assert tot_checked < tot_cand, "leaf-MBR skip had no effect"


def test_insert_delete_roundtrip():
    g = _build("uniform", n=3000, pl=200)
    rng = np.random.default_rng(4)
    new_ids = []
    for _ in range(300):
        c = rng.uniform(0.05, 0.95, 2)
        ang = np.sort(rng.uniform(0, 2 * np.pi, 12))
        s = rng.uniform(1e-4, 1e-3)
        verts = np.stack([c[0] + s * np.cos(ang), c[1] + s * np.sin(ang)], -1)
        new_ids.append(g.insert(verts, 12, 0))
    dels = rng.choice(3000, 400, replace=False)
    for d in dels:
        assert g.delete(int(d))
    assert not g.delete(int(dels[0]))  # double delete fails
    for w in make_query_windows(g.gs, 0.01, 4, seed=6):
        for rel in ("contains", "intersects"):
            np.testing.assert_array_equal(np.sort(g.query(w, rel)),
                                          np.sort(g.query_bruteforce(w, rel)))


def test_node_split_and_merge_paths():
    cfg = GLINConfig(model=GLINModelConfig(max_leaf=32, fanout=8),
                     piece_limitation=100)
    gs = generate("uniform", 500, seed=9)
    g = GLIN.build(gs, cfg)
    n_leaves0 = len(g.leaves)
    rng = np.random.default_rng(1)
    # hammer one region to force splits
    for _ in range(400):
        c = np.array([0.5, 0.5]) + rng.normal(0, 1e-4, 2)
        ang = np.sort(rng.uniform(0, 2 * np.pi, 6))
        verts = np.stack([c[0] + 1e-5 * np.cos(ang), c[1] + 1e-5 * np.sin(ang)], -1)
        g.insert(verts, 6, 0)
    assert len(g.leaves) > n_leaves0, "no leaf split happened"
    w = np.array([0.0, 0.0, 1.0, 1.0])
    np.testing.assert_array_equal(np.sort(g.query(w, "contains")),
                                  np.sort(g.query_bruteforce(w, "contains")))
    # deletion storm to force merges
    live = np.nonzero(g._live_mask())[0]
    for d in live[: len(live) * 3 // 4]:
        g.delete(int(d))
    np.testing.assert_array_equal(np.sort(g.query(w, "contains")),
                                  np.sort(g.query_bruteforce(w, "contains")))


def test_stats_and_sizes():
    g = _build("cluster", n=10000)
    st_ = g.stats()
    assert st_["records"] == 10000
    assert st_["leaf_nodes"] >= 1 and st_["index_bytes"] > 0
    assert st_["piecewise_bytes"] > 0
    # the learned index must be far smaller than the raw data
    assert st_["total_index_bytes"] < g.gs.nbytes() / 5


def test_contains_subset_of_intersects():
    g = _build("uniform", n=4000)
    for w in make_query_windows(g.gs, 0.01, 4, seed=2):
        c = set(g.query(w, "contains").tolist())
        i = set(g.query(w, "intersects").tolist())
        assert c.issubset(i)


def test_knn_matches_bruteforce():
    """Beyond-paper: KNN through dwithin probes at doubling radii (paper §XI
    future work; exact point-to-geometry distances, ties broken by id)."""
    from repro.core import geometry as geom
    from repro.core.index import knn
    g = _build("cluster", n=4000, pl=200, seed=2)
    gs = g.gs
    rng = np.random.default_rng(3)
    for _ in range(6):
        p = rng.uniform(0.1, 0.9, 2)
        rect = np.array([p[0], p[1], p[0], p[1]])
        dd = np.sqrt(geom.rect_geom_sqdist(rect, gs.verts, gs.nverts,
                                           gs.kinds))
        for k in (1, 5, 20):
            ids, d = knn(g, p, k)
            ref = np.lexsort((np.arange(len(gs)), dd))[:k]
            np.testing.assert_array_equal(np.sort(ids), np.sort(ref))
            np.testing.assert_allclose(d, np.sort(dd)[:k], atol=1e-12)
            assert np.all(np.diff(d) >= -1e-12)


def test_record_mbr_prefilter_is_transparent():
    """Beyond-paper record-level MBR prefilter must not change results and
    must reduce exact checks."""
    gs = generate("roads", 6000, seed=5)
    g0 = GLIN.build(gs, GLINConfig(piece_limitation=300))
    import copy
    g1 = GLIN.build(copy.deepcopy(gs), GLINConfig(piece_limitation=300,
                                                  record_mbr_prefilter=True))
    checked0 = checked1 = 0
    for w in make_query_windows(gs, 0.005, 6, seed=8):
        s0, s1 = QueryStats(), QueryStats()
        r0 = np.sort(g0.query(w, "intersects", s0))
        r1 = np.sort(g1.query(w, "intersects", s1))
        np.testing.assert_array_equal(r0, r1)
        checked0 += s0.checked
        checked1 += s1.checked
    assert checked1 <= checked0
