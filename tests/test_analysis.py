"""The HLO cost analyzer (utils/hlo.py) — the §Roofline measurement tool —
must be exact on analytically-countable programs."""
import jax
import jax.numpy as jnp

from repro.utils.hlo import analyze_hlo
from repro.utils import roofline


def test_scan_trip_counts_are_applied():
    """cost_analysis() counts while bodies once; our analyzer must not."""
    def f(x, w):
        def body(c, _):
            c = jax.nn.relu(c @ w)
            def inner(d, _):
                return d @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=7)
            return c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y.sum()

    spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(jax.grad(f)).lower(spec, spec).compile()
    cost = analyze_hlo(c.as_text())
    # grad wrt x only: fwd 10*(1+7)=80 dots + bwd 80 dC dots = 160
    analytic = 160 * 2 * 256**3
    assert abs(cost.flops / analytic - 1.0) < 1e-6
    # XLA's own counter must show the undercount we correct for
    ca = c.cost_analysis()
    if isinstance(ca, list):  # jax<=0.4.x returns one dict per device
        ca = ca[0] if ca else {}
    xla_flops = ca.get("flops", 0.0)
    assert xla_flops < cost.flops / 10


def test_collectives_and_bytes_positive_on_sharded_program(tmp_path):
    import os
    import subprocess
    import sys
    import pathlib
    ROOT = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.utils.hlo import analyze_hlo
from repro.utils.compat import make_auto_mesh
mesh = make_auto_mesh((4,2), ("data","model"))
def f(x, w):
    h = x @ w
    h = jax.lax.with_sharding_constraint(h, NamedSharding(mesh, P("data","model")))
    return h.sum()
x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
w = jax.ShapeDtypeStruct((128, 256), jnp.float32)
with mesh:
    c = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P("data", None)),
        NamedSharding(mesh, P(None, "model")))).lower(x, w).compile()
cost = analyze_hlo(c.as_text())
# per-chip dot flops = total / 8
assert abs(cost.flops - 2*64*128*256/8) / (2*64*128*256/8) < 1e-6, cost.flops
assert cost.bytes > 0
assert cost.collective_total > 0  # the final sum all-reduces
print("ANALYZER-OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ANALYZER-OK" in r.stdout


def test_roofline_terms_and_dominance():
    t = roofline.roofline_terms(flops=197e12, bytes_accessed=819e9 * 2,
                                coll_bytes=50e9 * 0.5, chips=1)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 2.0) < 1e-9
    assert abs(t["collective_s"] - 0.5) < 1e-9
    assert t["dominant"] == "memory"
    assert abs(t["compute_fraction"] - 0.5) < 1e-9


def test_collective_regex_shapes():
    from repro.utils.hlo import _shape_elems_bytes
    assert _shape_elems_bytes("bf16[8,128]{1,0}") == (1024, 2048)
    assert _shape_elems_bytes("(f32[4]{0}, s8[2,2]{1,0})") == (8, 20)
    assert _shape_elems_bytes("pred[]") == (1, 1)
