"""Multi-device tests (8 fake CPU devices via subprocess): sharded GLIN,
sharded train step with FSDP+TP, gradient compression, elastic checkpoint."""
import os
import pathlib
import subprocess
import sys
import textwrap

ROOT = pathlib.Path(__file__).resolve().parents[1]


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_distributed_glin_query():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((4,2), ("data","model"))
        from repro.core.datasets import generate, make_query_windows
        from repro.core.index import GLIN, GLINConfig
        from repro.core.engine import EngineConfig, SpatialIndex
        from repro.core.distributed import shard_glin_arrays, build_glin_query_step
        from repro.core import geometry as geom

        gs = generate("cluster", 6000, seed=2)
        g = GLIN.build(gs, GLINConfig(piece_limitation=300))
        snap = SpatialIndex(g, EngineConfig(pad_quantum=0)).snapshot()
        table_np = shard_glin_arrays(g, 4)
        step, in_sh, out_sh = build_glin_query_step(mesh, "intersects", cap=4096)
        wins = make_query_windows(gs, 0.003, 8, seed=5).astype(np.float32)
        with mesh:
            table = {k: jax.device_put(v, in_sh[2][k]) for k, v in table_np.items()}
            sd = jax.tree_util.tree_map(lambda x: jax.device_put(x, in_sh[0]), snap)
            w = jax.device_put(wins, in_sh[1])
            hits, counts = jax.jit(step, in_shardings=in_sh,
                                   out_shardings=out_sh)(sd, w, table)
        hits, counts = np.asarray(hits), np.asarray(counts)
        assert (counts >= 0).all()
        verts32 = gs.verts.astype(np.float32)
        for qi in range(len(wins)):
            got = np.sort(hits[qi][hits[qi] >= 0])
            ref = np.nonzero(geom.rect_intersects_geoms(
                wins[qi], verts32, gs.nverts, gs.kinds))[0]
            assert np.array_equal(got, ref), (qi, len(got), len(ref))
        print("DIST-GLIN-OK")
    """)
    assert "DIST-GLIN-OK" in out


def test_distributed_glin_query_registry_relations():
    """The sharded step serves registry relations generically — including the
    concave-exact touches and the padded-probe dwithin — on a CONCAVE store."""
    out = run_py("""
        import numpy as np, jax
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((4,2), ("data","model"))
        from repro.core.datasets import generate, make_query_windows
        from repro.core.index import GLIN, GLINConfig
        from repro.core.engine import EngineConfig, SpatialIndex
        from repro.core.distributed import (shard_glin_arrays,
                                            build_glin_query_step)
        from repro.core.relations import get_relation

        gs = generate("concave", 4000, seed=4)
        g = GLIN.build(gs, GLINConfig(piece_limitation=300))
        snap = SpatialIndex(g, EngineConfig(pad_quantum=0)).snapshot()
        table_np = shard_glin_arrays(g, 4)
        rand_wins = make_query_windows(gs, 0.003, 8, seed=5).astype(np.float32)
        # windows flush against record MBR left edges: guaranteed touch
        # contact (the leftmost vertex lies ON the window's right edge, the
        # rest of the ring strictly right of it)
        m = gs.mbrs[::517][:8].astype(np.float32)
        touch_wins = np.stack([m[:, 0] - np.float32(0.002), m[:, 1],
                               m[:, 0], m[:, 3]], axis=1)
        verts32 = gs.verts.astype(np.float32)
        for relation, wins in (("touches", touch_wins),
                               ("dwithin:0.002", rand_wins)):
            step, in_sh, out_sh = build_glin_query_step(mesh, relation,
                                                        cap=4096)
            with mesh:
                table = {k: jax.device_put(v, in_sh[2][k])
                         for k, v in table_np.items()}
                sd = jax.tree_util.tree_map(
                    lambda x: jax.device_put(x, in_sh[0]), snap)
                w = jax.device_put(wins, in_sh[1])
                hits, counts = jax.jit(step, in_shardings=in_sh,
                                       out_shardings=out_sh)(sd, w, table)
            hits, counts = np.asarray(hits), np.asarray(counts)
            assert (counts >= 0).all()
            pred = get_relation(relation).predicate
            total = 0
            for qi in range(len(wins)):
                got = np.sort(hits[qi][hits[qi] >= 0])
                ref = np.nonzero(pred(wins[qi], verts32, gs.nverts,
                                      gs.kinds))[0]
                assert np.array_equal(got, ref), (relation, qi)
                total += len(ref)
            assert total > 0, relation   # the windows actually hit something
        print("DIST-REL-OK")
    """)
    assert "DIST-REL-OK" in out


def test_distributed_fused_refinement_matches_dense():
    """The fused per-shard probe->compact->exact pipeline (exact_budget > 0,
    both overflow-free and budget-overflow regimes) against the dense
    per-shard baseline and the brute-force oracle on a (4,2) mesh."""
    out = run_py("""
        import numpy as np, jax
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((4,2), ("data","model"))
        from repro.core.datasets import generate, make_query_windows
        from repro.core.index import GLIN, GLINConfig
        from repro.core.engine import EngineConfig, SpatialIndex
        from repro.core.distributed import (shard_glin_arrays,
                                            build_glin_query_step)
        from repro.core import geometry as geom

        gs = generate("cluster", 6000, seed=2)
        g = GLIN.build(gs, GLINConfig(piece_limitation=300))
        snap = SpatialIndex(g, EngineConfig(pad_quantum=0)).snapshot()
        table_np = shard_glin_arrays(g, 4)
        wins = make_query_windows(gs, 0.003, 8, seed=5).astype(np.float32)

        def run_step(cap, budget):
            step, in_sh, out_sh = build_glin_query_step(
                mesh, "intersects", cap=cap, exact_budget=budget)
            with mesh:
                table = {k: jax.device_put(v, in_sh[2][k])
                         for k, v in table_np.items()}
                sd = jax.tree_util.tree_map(
                    lambda x: jax.device_put(x, in_sh[0]), snap)
                w = jax.device_put(wins, in_sh[1])
                hits, counts = jax.jit(step, in_shardings=in_sh,
                                       out_shardings=out_sh)(sd, w, table)
            return np.asarray(hits), np.asarray(counts)

        dense_h, dense_c = run_step(4096, 0)
        fused_h, fused_c = run_step(4096, 128)
        assert (dense_c >= 0).all() and (fused_c >= 0).all()
        assert fused_h.shape[2] == 128 and dense_h.shape[2] == 4096
        verts32 = gs.verts.astype(np.float32)
        for qi in range(len(wins)):
            got_f = np.sort(fused_h[qi][fused_h[qi] >= 0])
            got_d = np.sort(dense_h[qi][dense_h[qi] >= 0])
            ref = np.nonzero(geom.rect_intersects_geoms(
                wins[qi], verts32, gs.nverts, gs.kinds))[0]
            assert np.array_equal(got_f, ref), (qi, "fused")
            assert np.array_equal(got_d, ref), (qi, "dense")
        # per-shard exact counts agree between the two pipelines
        assert np.array_equal(dense_c, fused_c)

        # budget overflow: counts encode -(survivors) - 1 per shard
        tiny_h, tiny_c = run_step(4096, 8)
        over = tiny_c < 0
        assert over.any()
        surv = -tiny_c[over] - 1
        assert (surv > 8).all()
        print("DIST-FUSED-OK")
    """)
    assert "DIST-FUSED-OK" in out


def test_facade_sharded_backend_on_mesh_matches_host():
    """SpatialIndex.query routes to the sharded step when a mesh is active
    (EngineConfig.mesh) and matches forced-host results exactly, including
    through a write burst served as sharded + delta patch."""
    out = run_py("""
        import numpy as np, jax
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((4,2), ("data","model"))
        from repro.core.datasets import generate, make_query_windows
        from repro.core.engine import EngineConfig, SpatialIndex
        from repro.core.geometry import mbrs_of_verts
        from repro.core.index import GLINConfig

        gs = generate("cluster", 6000, seed=2)
        gs.verts = gs.verts.astype(np.float32).astype(np.float64)
        gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
        idx = SpatialIndex.build(gs, GLINConfig(piece_limitation=300),
                                 EngineConfig(mesh=mesh, shard_min_records=1,
                                              device_min_batch=1,
                                              stale_rebuild_min_batch=1))
        wins = make_query_windows(gs, 0.003, 9, seed=5)  # odd Q: model-pad
        wins = wins.astype(np.float32).astype(np.float64)
        res = idx.query(wins, "intersects")
        assert res.plan.backend == "sharded", res.plan
        host = idx.query(wins, "intersects", backend="host")
        for a, b in zip(res, host):
            np.testing.assert_array_equal(a, b)
        # write burst: sharded serving of the stale placement + delta patch
        rng = np.random.default_rng(7)
        for i in range(6):
            ang = np.sort(rng.uniform(0, 2*np.pi, 8))
            c = rng.uniform(0.3, 0.7, 2)
            v = np.stack([c[0]+3e-3*np.cos(ang), c[1]+3e-3*np.sin(ang)], -1)
            idx.insert(v.astype(np.float32).astype(np.float64), 8, 0)
        live = np.nonzero(idx.glin._live_mask())[0]
        idx.delete(int(live[10]))
        assert idx.snapshot_is_stale()
        res = idx.query(wins, "intersects")
        assert res.plan.backend == "sharded" and "patched" in res.plan.reason
        host = idx.query(wins, "intersects", backend="host")
        for a, b in zip(res, host):
            np.testing.assert_array_equal(a, b)
        assert idx.snapshot_is_stale()   # no republish happened
        print("FACADE-SHARDED-OK")
    """)
    assert "FACADE-SHARDED-OK" in out


def test_sharded_train_step_runs_and_matches_single():
    """FSDP+TP train step on a (4,2) mesh == single-device step (loss)."""
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((4,2), ("data","model"))
        from repro.configs.base import get_arch, ShapeConfig
        from repro.sharding import MeshRules
        from repro.train.step import build_train_step, param_shardings
        from repro.models import transformer as tf
        from repro.train.optimizer import adamw_init
        from repro.sharding import constrain, use_rules

        cfg = get_arch("granite_3_2b").reduced()
        shape = ShapeConfig("t", 64, 8, "train")
        rules = MeshRules(mesh=mesh)
        step, in_sh, out_sh, specs = build_train_step(cfg, shape, rules,
                                                      microbatches=2)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab, (8, 64)).astype(np.int32),
                 "labels": rng.integers(0, cfg.vocab, (8, 64)).astype(np.int32)}
        with mesh:
            params_d = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), params, in_sh[0])
            opt_d = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), opt, in_sh[1])
            batch_d = {k: jax.device_put(v, in_sh[2][k]) for k, v in batch.items()}
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            p2, o2, metrics = fn(params_d, opt_d, batch_d)
        sharded_loss = float(metrics["loss"])

        # single-device reference (same params, same batch, same math)
        def ref_step(params, opt, batch):
            from repro.train.optimizer import AdamWConfig, adamw_update
            import jax as j
            def lf(p):
                mb = {k: v.reshape(2, 4, *v.shape[1:]) for k, v in batch.items()}
                tot = 0.0
                for i in range(2):
                    tot = tot + tf.loss_fn(p, cfg, {k: v[i] for k, v in mb.items()},
                                           constrain, remat=True) / 2
                return tot
            return lf(params)
        ref_loss = float(ref_step(params, opt,
                                  {k: jnp.asarray(v) for k, v in batch.items()}))
        assert abs(sharded_loss - ref_loss) < 5e-3, (sharded_loss, ref_loss)
        # params actually updated & outputs correctly sharded
        d0 = jax.tree_util.tree_leaves(params_d)[0]
        d1 = jax.tree_util.tree_leaves(p2)[0]
        assert not np.allclose(np.asarray(d0), np.asarray(d1))
        print("DIST-TRAIN-OK", sharded_loss, ref_loss)
    """)
    assert "DIST-TRAIN-OK" in out


def test_gradient_compression_psum():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.train.compress import apply_error_feedback, compressed_psum_mean
        from repro.utils.compat import make_auto_mesh
        from repro.utils.compat import shard_map as compat_shard_map
        mesh = make_auto_mesh((8,), ("data",))

        def f(gs):
            return compressed_psum_mean(gs, "data")
        gs = np.random.default_rng(0).normal(0, 1, (8, 256)).astype(np.float32)
        out = jax.jit(compat_shard_map(f, mesh, P("data"), P("data")))(gs)
        ref = gs.mean(axis=0)
        err = np.abs(np.asarray(out)[0] - ref).max()
        # int8 quantization error bound: ~ max|g| / 127
        assert err < np.abs(gs).max() / 127 * 2 + 1e-6, err

        # error feedback drives the accumulated bias to zero on a constant g
        def ef(g, e):
            return apply_error_feedback(g, e, "data")
        g = np.tile(np.linspace(-1, 1, 64, dtype=np.float32), (8, 1))
        e = np.zeros_like(g)
        fn = jax.jit(compat_shard_map(ef, mesh, (P("data"), P("data")),
                                      (P("data"), P("data"))))
        tot = np.zeros(64, np.float32)
        for step in range(20):
            avg, e = fn(g, e)
            tot += np.asarray(avg)[0]
        drift = np.abs(tot / 20 - g[0]).max()
        assert drift < 2e-3, drift
        print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in out


def test_elastic_checkpoint_restore():
    """Save on an 8-device mesh, restore on 1 device (and back)."""
    out = run_py("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as ckpt
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((4,2), ("data","model"))
        tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
                "b": np.ones(16, np.float32)}
        sh = {"w": NamedSharding(mesh, P("data", "model")),
              "b": NamedSharding(mesh, P("data"))}
        dev = {k: jax.device_put(v, sh[k]) for k, v in tree.items()}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 7, dev)
            # restore fully replicated (different placement = elastic)
            step, rest = ckpt.restore(d, {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                                          for k, v in tree.items()})
            assert step == 7
            for k in tree:
                assert np.array_equal(np.asarray(rest[k]), tree[k])
            # restore back onto the mesh with shardings
            step, rest2 = ckpt.restore(d, {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                                           for k, v in tree.items()}, shardings=sh)
            assert rest2["w"].sharding == sh["w"]
        print("ELASTIC-OK")
    """)
    assert "ELASTIC-OK" in out


def test_shard_arrays_pad_keys_preserve_sort_order():
    """REGRESSION (review): shard padding keys must be maximal in BOTH limbs
    — a corner record with hi == 2^30-1 and lo > 0 sorts after a (hi, 0)
    pad, which would break the shard-local binary search's sort invariant."""
    import numpy as np

    from repro.core.datasets import generate
    from repro.core.distributed import shard_glin_arrays
    from repro.core.engine import SpatialIndex
    from repro.core.index import GLIN, GLINConfig

    gs = generate("uniform", 1001, seed=3)   # odd count: every shard pads
    g = GLIN.build(gs, GLINConfig(piece_limitation=100))
    idx = SpatialIndex(g)
    rng = np.random.default_rng(5)
    for _ in range(6):   # tiny squares hugging the (1, 1) corner: max limbs
        c = 1.0 - rng.uniform(1e-6, 3e-6, 2)
        v = np.array([[c[0], c[1]], [c[0] + 1e-7, c[1]],
                      [c[0] + 1e-7, c[1] + 1e-7], [c[0], c[1] + 1e-7]])
        idx.insert(np.clip(v, 0, 1 - 1e-12), 4, 0)
    for shards in (2, 4, 8):
        t = shard_glin_arrays(g, shards)
        hi = t["keys_hi"].astype(np.int64)
        lo = t["keys_lo"].astype(np.int64)
        keys = (hi << 30) | lo
        per = keys.reshape(shards, -1)
        assert (np.diff(per, axis=1) >= 0).all(), shards
