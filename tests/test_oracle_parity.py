"""Oracle parity suite: host, device, and device+delta query paths against
the scalar brute-force oracle (tests/_oracle.py) on a MIXED store — convex
polygons, concave star/L rings, polylines and point records interleaved.

Example-based parity always runs; the randomized hypothesis sweep is marked
``property`` (tier-2: ``pytest -q -m property``) and skips itself gracefully
when hypothesis is absent (tests/_hyp.py).
"""
import numpy as np
import pytest
from _hyp import given, settings, st
from _oracle import mixed_store, oracle_query

from repro.core.datasets import make_query_windows
from repro.core.engine import EngineConfig, SpatialIndex
from repro.core.index import GLINConfig

PARITY_RELATIONS = ("intersects", "contains", "covers", "within", "disjoint",
                    "touches", "crosses", "dwithin:0.004")

_N = 400
_CACHE = {}


def _fp32(w):
    return np.asarray(w, np.float32).astype(np.float64)


def _index(key="base"):
    """Module-cached indexes (hypothesis-safe: no function-scoped fixture)."""
    if key in _CACHE:
        return _CACHE[key]
    gs = mixed_store(_N, seed=3)
    # "delta-table" forces the added-set patch through the device-resident
    # Zmin-sorted DeltaTable (delta_device_min=1) instead of the host loop;
    # "sharded"/"sharded-delta" route through the mesh backend (a (1,1) mesh
    # exercises the full shard_map machinery on one CPU device)
    mesh = None
    if key.startswith("sharded"):
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((1, 1), ("data", "model"))
    cfg = EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1,
                       delta_device_min=1 if key == "delta-table" else 64,
                       mesh=mesh, shard_min_records=1,
                       knn_device_min_batch=1)
    idx = SpatialIndex.build(gs, GLINConfig(piece_limitation=500), cfg)
    if key in ("delta", "delta-table", "sharded-delta"):
        idx.snapshot()   # publish, then build a delta on top
        rng = np.random.default_rng(11)
        star = _star(rng, (0.4, 0.4), 0.05)
        idx.insert(star, star.shape[0], 0)
        ell = _fp32([[0.6, 0.6], [0.7, 0.6], [0.7, 0.66], [0.64, 0.66],
                     [0.64, 0.7], [0.6, 0.7]])
        idx.insert(ell, 6, 0)
        line = _fp32([[0.35, 0.45], [0.55, 0.38], [0.6, 0.5]])
        idx.insert(line, 3, 1)
        for rec in (5, 17, 40):
            assert idx.delete(rec)
        assert idx.snapshot_is_stale() and idx.delta_size() == 6
    _CACHE[key] = idx
    return idx


def _star(rng, c, r, spikes=5):
    ang = np.sort(rng.uniform(0, 2 * np.pi, 2 * spikes))
    rad = np.where(np.arange(2 * spikes) % 2 == 0, r, 0.35 * r)
    return _fp32(np.stack([c[0] + rad * np.cos(ang),
                           c[1] + rad * np.sin(ang)], -1))


def _windows(idx, sel, k, seed):
    return _fp32(make_query_windows(idx.gs, sel, k, seed=seed))


def _assert_parity(idx, wins, relation, backend):
    res = idx.query(wins, relation, backend=backend)
    assert res.plan.backend == backend
    gs = idx.gs
    live = idx.glin._live_mask()
    fp32 = backend != "host"
    verts = gs.verts.astype(np.float32) if fp32 else gs.verts
    for qi, w in enumerate(wins):
        want = oracle_query(w.astype(np.float32) if fp32 else w, verts,
                            gs.nverts, gs.kinds, relation, live)
        np.testing.assert_array_equal(res[qi], want, err_msg=(
            f"{backend}/{relation} window {qi} {w}"))


# ------------------------------------------------------------ example-based --
@pytest.mark.parametrize("relation", PARITY_RELATIONS)
def test_host_matches_oracle(relation):
    idx = _index()
    _assert_parity(idx, _windows(idx, 0.02, 6, seed=5), relation, "host")


@pytest.mark.parametrize("relation", PARITY_RELATIONS)
def test_device_matches_fp32_oracle(relation):
    idx = _index()
    _assert_parity(idx, _windows(idx, 0.02, 6, seed=7), relation, "device")


@pytest.mark.parametrize("relation", PARITY_RELATIONS)
def test_device_delta_matches_fp32_oracle(relation):
    idx = _index("delta")
    # windows over the delta region so added/tombstoned records participate
    wins = np.concatenate([
        _windows(idx, 0.02, 4, seed=9),
        _fp32([[0.3, 0.3, 0.5, 0.5], [0.58, 0.58, 0.72, 0.72]]),
    ])
    _assert_parity(idx, wins, relation, "device+delta")
    assert idx.snapshot_is_stale()   # parity did NOT come from a republish


@pytest.mark.parametrize("relation", PARITY_RELATIONS)
def test_sharded_matches_fp32_oracle(relation):
    """The mesh backend (fused per-shard probe->compact->exact pipeline)
    against the oracle for EVERY registry relation, incl. the bound dwithin
    and the complement (host-finished) disjoint."""
    idx = _index("sharded")
    _assert_parity(idx, _windows(idx, 0.02, 6, seed=7), relation, "sharded")


@pytest.mark.parametrize("relation", PARITY_RELATIONS)
def test_sharded_delta_matches_fp32_oracle(relation):
    """Sharded serving of a STALE snapshot: the published placement is
    queried per shard and the tombstone/added delta patch restores exactness
    on top — no republish."""
    idx = _index("sharded-delta")
    wins = np.concatenate([
        _windows(idx, 0.02, 4, seed=9),
        _fp32([[0.3, 0.3, 0.5, 0.5], [0.58, 0.58, 0.72, 0.72]]),
    ])
    _assert_parity(idx, wins, relation, "sharded")
    assert idx.snapshot_is_stale()   # parity did NOT come from a republish


def test_sharded_knn_matches_host():
    """knn over the mesh: shard-local top-k + one-collective k-merge; the
    returned ids must equal the host knn loop exactly (distances to fp32)."""
    from repro.core.engine import QueryBatch
    from repro.core.index import knn as host_knn

    idx = _index("sharded")
    rng = np.random.default_rng(5)
    pts = _fp32(rng.uniform(0.2, 0.8, (8, 2)))
    res = idx.query(QueryBatch.knn(pts, k=4))
    assert res.plan.backend == "sharded" and res.plan.kind == "knn"
    for i, p in enumerate(pts):
        hi, hd = host_knn(idx.glin, p, 4)
        np.testing.assert_array_equal(res.ids[i], np.asarray(hi, np.int64))
        np.testing.assert_allclose(res.distances[i], hd, rtol=1e-4, atol=1e-7)
    # the merge collective was accounted and the stage ran sharded
    rank = res.stages[-1]
    assert rank.stage == "knn-rank" and rank.impl == "sharded"
    assert rank.merge_bytes > 0
    # plain dwithin probes over the same index also take the sharded backend
    probe = idx.plan(_windows(idx, 0.02, 4, seed=1), "dwithin:0.1")
    assert probe.backend == "sharded"


@pytest.mark.parametrize("relation", PARITY_RELATIONS)
def test_device_delta_side_table_matches_fp32_oracle(relation):
    """Same parity, but the added-set patch runs through the device-resident
    DeltaTable (z-interval prune + MBR prefilter + exact predicate on
    device) rather than the per-batch host loop."""
    idx = _index("delta-table")
    wins = np.concatenate([
        _windows(idx, 0.02, 4, seed=9),
        _fp32([[0.3, 0.3, 0.5, 0.5], [0.58, 0.58, 0.72, 0.72]]),
    ])
    _assert_parity(idx, wins, relation, "device+delta")
    assert idx.snapshot_is_stale()
    assert idx._dtable is not None and idx._dtable_epoch == idx.epoch


# ----------------------------------------------------- hypothesis sweep -----
@pytest.mark.property
@given(seed=st.integers(0, 10_000), sel=st.sampled_from([0.002, 0.02, 0.1]),
       relation=st.sampled_from(PARITY_RELATIONS))
@settings(max_examples=60, deadline=None, derandomize=True)
def test_property_host_matches_oracle(seed, sel, relation):
    idx = _index()
    _assert_parity(idx, _windows(idx, sel, 2, seed=seed), relation, "host")


@pytest.mark.property
@given(seed=st.integers(0, 10_000), relation=st.sampled_from(PARITY_RELATIONS))
@settings(max_examples=30, deadline=None, derandomize=True)
def test_property_device_matches_fp32_oracle(seed, relation):
    idx = _index()
    _assert_parity(idx, _windows(idx, 0.02, 2, seed=seed), relation, "device")


@pytest.mark.property
@given(seed=st.integers(0, 10_000), relation=st.sampled_from(PARITY_RELATIONS))
@settings(max_examples=30, deadline=None, derandomize=True)
def test_property_device_delta_matches_fp32_oracle(seed, relation):
    idx = _index("delta")
    _assert_parity(idx, _windows(idx, 0.02, 2, seed=seed), relation,
                   "device+delta")
