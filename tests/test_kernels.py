"""Per-kernel shape/dtype sweeps, interpret-mode Pallas vs pure-jnp refs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------- morton ----
@pytest.mark.parametrize("n", [7, 128, 1000, 4096])
def test_morton_sweep(n):
    qx = jnp.asarray(RNG.integers(0, 2**30, n), jnp.int32)
    qy = jnp.asarray(RNG.integers(0, 2**30, n), jnp.int32)
    hi, lo = ops.morton_encode(qx, qy)
    rhi, rlo = ref.morton_ref(qx, qy)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(rhi))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(rlo))


# ---------------------------------------------------------------- refine ----
@pytest.mark.parametrize("q,n", [(3, 100), (8, 512), (13, 1000), (32, 2048)])
def test_refine_sweep(q, n):
    wins = RNG.uniform(0, 1, (q, 4)).astype(np.float32)
    wins[:, 2:] = wins[:, :2] + RNG.uniform(0.01, 0.3, (q, 2)).astype(np.float32)
    mbrs = RNG.uniform(0, 1, (n, 4)).astype(np.float32)
    mbrs[:, 2:] = mbrs[:, :2] + 0.01
    lo = RNG.integers(0, n // 2, q).astype(np.int32)
    hi = RNG.integers(n // 2, n, q).astype(np.int32)
    bounds = jnp.asarray(np.stack([lo, hi], 1))
    wins_j, mbrs_j = jnp.asarray(wins), jnp.asarray(mbrs)
    m = ops.refine_mask(wins_j, bounds, mbrs_j)
    mr = ref.refine_mask_ref(wins_j, bounds, mbrs_j)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))
    c = ops.refine_count(wins_j, bounds, mbrs_j)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(m).sum(1))


def _compact_case(q, n, seed=0):
    rng = np.random.default_rng(seed)
    wins = rng.uniform(0, 1, (q, 4)).astype(np.float32)
    wins[:, 2:] = wins[:, :2] + rng.uniform(0.01, 0.3, (q, 2)).astype(np.float32)
    rmbrs = rng.uniform(0, 1, (n, 4)).astype(np.float32)
    rmbrs[:, 2:] = rmbrs[:, :2] + 0.01
    lmbrs = rmbrs + np.array([-0.02, -0.02, 0.02, 0.02], np.float32)
    lo = rng.integers(0, max(n // 2, 1), q).astype(np.int32)
    hi = rng.integers(n // 2, n + 1, q).astype(np.int32)
    bounds = jnp.asarray(np.stack([lo, hi], 1))
    return jnp.asarray(wins), bounds, jnp.asarray(lmbrs), jnp.asarray(rmbrs)


# odd shapes: Q and N not multiples of the tile sizes (internal padding)
@pytest.mark.parametrize("q,n", [(1, 37), (5, 256), (13, 1000), (32, 2049)])
@pytest.mark.parametrize("prefilter", ["intersects", "contains"])
def test_refine_compact_sweep(q, n, prefilter):
    wins, bounds, lmbrs, rmbrs = _compact_case(q, n)
    for budget in (8, 64):
        s, c = ops.refine_compact(wins, bounds, lmbrs, rmbrs, budget=budget,
                                  prefilter=prefilter)
        sr, cr = ref.refine_compact_ref(wins, bounds, lmbrs, rmbrs, budget,
                                        prefilter)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))


def test_refine_compact_empty_runs_and_extremes():
    """Empty probe runs, zero-survivor and all-survivor rows."""
    q, n = 9, 700
    wins, bounds, lmbrs, rmbrs = _compact_case(q, n, seed=3)
    b = np.asarray(bounds).copy()
    b[0] = (50, 50)                      # empty run
    b[1] = (60, 40)                      # inverted (empty) run
    wins = np.asarray(wins).copy()
    wins[2] = (2.0, 2.0, 3.0, 3.0)       # intersects nothing: zero survivors
    wins[3] = (-1.0, -1.0, 2.0, 2.0)     # covers everything: all survive
    b[3] = (0, n)
    wins_j, b_j = jnp.asarray(wins), jnp.asarray(b)
    budget = 1024                        # >= n: nothing truncated
    s, c = ops.refine_compact(wins_j, b_j, lmbrs, rmbrs, budget=budget)
    sr, cr = ref.refine_compact_ref(wins_j, b_j, lmbrs, rmbrs, budget)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    c = np.asarray(c)
    s = np.asarray(s)
    assert c[0] == 0 and c[1] == 0 and c[2] == 0
    assert c[3] == n and (s[3] >= 0).sum() == n
    np.testing.assert_array_equal(s[3][:n], np.arange(n))


def test_refine_compact_budget_overflow_signalling():
    """counts carries TOTAL survivors even past the budget: the caller's
    overflow test (counts > budget) must fire, and the kept slots must be
    the first `budget` survivors in slot order."""
    q, n = 4, 300
    wins = np.tile(np.array([[-1, -1, 2, 2]], np.float32), (q, 1))
    rng = np.random.default_rng(5)
    rmbrs = rng.uniform(0, 1, (n, 4)).astype(np.float32)
    rmbrs[:, 2:] = rmbrs[:, :2] + 0.01
    lmbrs = rmbrs
    bounds = jnp.asarray(np.tile([0, n], (q, 1)).astype(np.int32))
    budget = 16
    s, c = ops.refine_compact(jnp.asarray(wins), bounds, jnp.asarray(lmbrs),
                              jnp.asarray(rmbrs), budget=budget)
    s, c = np.asarray(s), np.asarray(c)
    assert (c == n).all() and (c > budget).all()
    for row in s:
        np.testing.assert_array_equal(row, np.arange(budget))


@pytest.mark.parametrize("q,n", [(3, 100), (13, 999), (30, 2047)])
def test_refine_mask_count_internal_padding(q, n):
    """mask/count accept shapes that are NOT tile multiples (the kernels pad
    internally; callers stopped pre-padding)."""
    wins, bounds, _, mbrs = _compact_case(q, n, seed=7)
    m = ops.refine_mask(wins, bounds, mbrs)
    mr = ref.refine_mask_ref(wins, bounds, mbrs)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))
    c = ops.refine_count(wins, bounds, mbrs)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(m).sum(1))


# ------------------------------------------------------------- attention ----
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("s,d,hq,hkv,window,bq",
                         [(256, 64, 4, 2, 0, 128),
                          (256, 32, 4, 1, 64, 128),
                          (128, 64, 2, 2, 0, 64),
                          (512, 64, 8, 4, 128, 128)])
def test_flash_attention_sweep(dtype, tol, s, d, hq, hkv, window, bq):
    b = 2
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, s, d)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), dtype)
    o = ops.flash_attention(q, k, v, window=window, bq=bq, bk=bq)
    r = ref.attention_ref(q, k, v, window=window)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    assert err < tol, err


# ------------------------------------------------------------------- ssd ----
@pytest.mark.parametrize("s,h,p,n,chunk", [(128, 2, 16, 8, 32),
                                           (256, 3, 32, 16, 64),
                                           (256, 1, 64, 32, 128)])
def test_ssd_sweep(s, h, p, n, chunk):
    b = 2
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.1, 1.0, h), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    y = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk)
    r = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), atol=2e-4, rtol=1e-3)


def test_ssd_chunk_invariance():
    """The chunked algorithm must be exact for ANY chunk size."""
    b, s, h, p, n = 1, 192, 2, 8, 4
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.2, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.1, 1.0, h), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    outs = [np.asarray(ops.ssd_scan(x, dt, a, bm, cm, chunk=c))
            for c in (32, 64, 96, 192)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-4, rtol=1e-3)


def test_xla_path_matches_kernel():
    """models/ssm.ssd_chunked (the XLA lowering path) == Pallas kernel."""
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 2, 128, 3, 16, 8
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.1, 1.0, h), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    y_xla, _ = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    y_pl = ops.ssd_scan(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pl),
                               atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("w,d,hq,hkv,window", [(256, 64, 4, 2, 0),
                                               (512, 32, 4, 1, 128),
                                               (256, 64, 2, 2, 64)])
def test_decode_attention_sweep(dtype, tol, w, d, hq, hkv, window):
    """Ring-cache decode kernel vs dense oracle, incl. empty + SWA slots."""
    b = 2
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, d)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, w, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, w, d)), dtype)
    pos = jnp.asarray(RNG.integers(w // 2, w, b), jnp.int32)
    # ring semantics: slot s holds abs position p = s + w*floor((pos-s)/w)
    slots = np.arange(w)[None, :]
    p = np.asarray(pos)[:, None]
    ap = slots + w * ((p - slots) // w)
    ap = np.where(ap <= p, ap, -1)  # future/unwritten slots empty
    ap = jnp.asarray(ap, jnp.int32)
    o = ops.decode_attention(q, k, v, ap, pos, window=window)
    r = ref.decode_attention_ref(q, k, v, ap, pos, window=window)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    assert err < tol, err


def test_decode_attention_matches_model_path():
    """Kernel == models/attention.attention_decode numerics (fp32)."""
    from repro.configs import get_arch
    from repro.models import transformer as tf
    from repro.sharding import constrain
    cfg = get_arch("phi4_mini_3p8b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 48
    toks = rng.integers(0, cfg.vocab, (B, S + 1))
    _, cache = tf.prefill(params, cfg, {"tokens": jnp.asarray(toks[:, :S])},
                          constrain, seq_len_cache=S + 4)
    # run one decode step through the model, then replicate layer-0 attention
    # with the kernel on the PRE-update cache
    from repro.models.attention import _project_qkv
    import repro.models.attention as A
    pl0 = jax.tree_util.tree_map(lambda x: x[0], params["blocks"])
    lc = jax.tree_util.tree_map(lambda x: x[0], cache)["attn"]
    x = params["embed"][toks[:, S]][:, None, :]
    from repro.models.layers import rms_norm
    h = rms_norm(x, pl0["ln1"])
    y_model, _ = A.attention_decode(h, pl0["attn"], cfg, dict(lc), constrain)
    # kernel path: project, write slot, then decode_attention
    q, k_new, v_new = _project_qkv(h, pl0["attn"], cfg, lc["pos"][:, None])
    w = lc["k"].shape[1]
    slot = lc["pos"] % w
    bidx = jnp.arange(B)
    k = lc["k"].at[bidx, slot].set(k_new[:, 0])
    v = lc["v"].at[bidx, slot].set(v_new[:, 0])
    ap = lc["abs_pos"].at[bidx, slot].set(lc["pos"])
    out = ops.decode_attention(
        q[:, 0], jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), ap, lc["pos"],
        window=cfg.window)
    from repro.models.layers import dense
    y_kernel = dense(out.reshape(B, 1, -1), pl0["attn"]["wo"])
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               atol=2e-5, rtol=1e-4)
