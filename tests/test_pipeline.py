"""GPipe pipeline parallelism over the pod axis (subprocess, 4 fake devices)."""
import os
import pathlib
import subprocess
import sys
import textwrap

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(ROOT / "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.sharding.pipeline import gpipe, bubble_fraction
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((4,), ("pod",))
        S, M, D = 4, 8, 32
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(0, 0.3, (S, D, D)), jnp.float32)
        bs = jnp.asarray(rng.normal(0, 0.1, (S, D)), jnp.float32)
        xs = jnp.asarray(rng.normal(0, 1, (M, 16, D)), jnp.float32)

        def stage(params, x):
            w, b = params
            return jnp.tanh(x @ w + b)

        piped = jax.jit(gpipe(stage, mesh, "pod"))
        with mesh:
            ys = piped((ws, bs), xs)

        # sequential reference
        ref = xs
        for i in range(S):
            ref = jnp.tanh(ref @ ws[i] + bs[i])
        err = float(jnp.max(jnp.abs(ys - ref)))
        assert err < 1e-5, err
        assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
        print("GPIPE-OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-3000:]}"
    assert "GPIPE-OK" in r.stdout
