"""Device-complete kNN suite.

Covers the full tentpole contract: oracle parity on a MIXED store across all
four backends (host / device / device+delta / sharded), the k > live-records
exhaustion path, duplicate query points, deterministic ascending
``(distance, id)`` tie-breaking on co-located records, CDF-seed-underestimate
ladder escalation, ``knn_topk`` impl equivalence (pallas == sort), and the
no-host-gather assertion (device and sharded ranking never pull candidate
geometry to the host). The randomized sweep is marked ``property`` (tier-2:
``pytest -q -m property``) and skips gracefully without hypothesis.
"""
import numpy as np
import pytest
from _hyp import given, settings, st
from _oracle import mixed_store

import repro.core.exec as qexec
from repro.core import geometry as geom
from repro.core.datasets import GeometrySet
from repro.core.engine import EngineConfig, QueryBatch, SpatialIndex
from repro.core.index import GLINConfig
from repro.core.index import knn as host_knn

_N = 400
_CACHE = {}


def _fp32(w):
    return np.asarray(w, np.float32).astype(np.float64)


def _cfg(mesh=None):
    return EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1,
                        knn_device_min_batch=1, mesh=mesh,
                        shard_min_records=1)


def _index(key="device"):
    """Module-cached indexes (hypothesis-safe: no function-scoped fixture)."""
    if key in _CACHE:
        return _CACHE[key]
    mesh = None
    if key == "sharded":
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((1, 1), ("data", "model"))
    idx = SpatialIndex.build(mixed_store(_N, seed=3),
                             GLINConfig(piece_limitation=500), _cfg(mesh))
    _CACHE[key] = idx
    return idx


def _oracle_knn(gs, p, k, live=None):
    """Brute-force fp64 kNN over every live record, ranked by the canonical
    ascending (distance, id) contract (geometry.rank_knn)."""
    ids = np.arange(len(gs.nverts), dtype=np.int64)
    if live is not None:
        ids = ids[np.asarray(live)[ids]]
    rect = np.array([p[0], p[1], p[0], p[1]], np.float64)
    d2 = geom.rect_geom_sqdist(rect, gs.padded(ids), gs.nverts[ids],
                               gs.kinds[ids], xp=np)
    return geom.rank_knn(ids, np.sqrt(np.maximum(d2, 0.0)), k)


def _pts(seed, n=16):
    rng = np.random.default_rng(seed)
    return _fp32(rng.uniform(0.15, 0.85, (n, 2)))


def _assert_rows(res, idx, pts, k, fp32=True):
    live = idx.glin._live_mask()
    for i, p in enumerate(pts):
        oi, od = _oracle_knn(idx.gs, p, k, live=live)
        np.testing.assert_array_equal(res.ids[i], oi)
        rtol = 2e-4 if fp32 else 1e-9
        np.testing.assert_allclose(res.distances[i], od, rtol=rtol, atol=1e-7)


@pytest.mark.parametrize("backend", ["host", "device", "sharded"])
def test_knn_matches_bruteforce_oracle(backend):
    idx = _index("sharded" if backend == "sharded" else "device")
    pts = _pts(seed=5)
    res = idx.query(QueryBatch.knn(pts, k=5, backend=backend))
    assert res.plan.backend == backend and res.plan.kind == "knn"
    _assert_rows(res, idx, pts, 5, fp32=backend != "host")
    rank = res.stages[-1]
    assert rank.stage == "knn-rank" and "knn-rank" in rank.covers
    if backend != "host":
        # CDF seeding settles the bulk of points at their first radius: the
        # median ladder depth must be <= 2 rungs (the acceptance bar)
        probes = np.repeat(np.arange(1, rank.rungs + 1),
                           np.asarray(rank.rung_hist, np.int64))
        assert np.median(probes) <= 2
        assert rank.seed_radius > 0.0


def test_device_delta_ranks_unpublished_inserts():
    """An insert after publish is rankable WITHOUT a republish; a tombstoned
    record disappears from the ranking even when it was the nearest."""
    idx = SpatialIndex.build(mixed_store(_N, seed=3),
                             GLINConfig(piece_limitation=500), _cfg())
    idx.snapshot()
    p = np.array([0.4321, 0.5678])
    # delete the current nearest record, then insert a point right at p
    nearest, _ = host_knn(idx.glin, p, 1)
    assert idx.delete(int(nearest[0]))
    new = idx.insert(_fp32([[p[0], p[1]]]), 1, 0)
    assert idx.snapshot_is_stale()
    pts = np.concatenate([[p], _pts(seed=8, n=7)])
    res = idx.query(QueryBatch.knn(pts, k=4))
    assert res.plan.backend == "device+delta"
    assert res.ids[0][0] == new                 # unpublished insert ranked
    for row in res.ids:
        assert int(nearest[0]) not in row       # tombstone masked everywhere
    for i, q in enumerate(pts):                 # full host parity, same epoch
        hi, hd = host_knn(idx.glin, q, 4)
        np.testing.assert_array_equal(res.ids[i], np.asarray(hi, np.int64))
        np.testing.assert_allclose(res.distances[i], hd, rtol=2e-4, atol=1e-7)
    assert idx.snapshot_is_stale()              # parity did NOT republish


@pytest.mark.parametrize("backend", ["host", "device"])
def test_k_exceeds_live_records(backend):
    """k > records: every row returns ALL live records, exhaustion-terminated
    (the within >= n_live rule), still in ascending (distance, id) order."""
    idx = _index("device")
    n_live = int(idx.glin._live_mask().sum())
    pts = _pts(seed=11, n=4)
    res = idx.query(QueryBatch.knn(pts, k=n_live + 50, backend=backend))
    live_ids = set(np.nonzero(idx.glin._live_mask())[0].tolist())
    for i in range(len(pts)):
        assert len(res.ids[i]) == n_live
        assert set(res.ids[i].tolist()) == live_ids
        d, rid = res.distances[i], res.ids[i]
        order = np.lexsort((rid, d))
        np.testing.assert_array_equal(order, np.arange(n_live))


def test_duplicate_query_points_identical_rows():
    idx = _index("device")
    p = _fp32([[0.44, 0.61]])
    pts = np.repeat(p, 6, axis=0)
    res = idx.query(QueryBatch.knn(pts, k=7, backend="device"))
    for i in range(1, 6):
        np.testing.assert_array_equal(res.ids[i], res.ids[0])
        np.testing.assert_array_equal(res.distances[i], res.distances[0])


def test_tied_records_break_by_ascending_id():
    """Co-located records (exactly equal distance) resolve by ascending id on
    every backend — the geometry.rank_knn contract."""
    idx = SpatialIndex.build(mixed_store(160, seed=7),
                             GLINConfig(piece_limitation=500), _cfg())
    site = _fp32([[0.5117, 0.5117]])
    dup = [idx.insert(site, 1, 0) for _ in range(5)]
    idx.snapshot()                              # publish the coincident rows
    pts = np.concatenate([site, site + 0.003])
    want = [_oracle_knn(idx.gs, q, 4, live=idx.glin._live_mask())
            for q in pts]
    for backend in ("host", "device"):
        res = idx.query(QueryBatch.knn(pts, k=4, backend=backend))
        for i, (oi, od) in enumerate(want):
            np.testing.assert_array_equal(res.ids[i], oi)
            # the tied block itself must be id-ascending
            d = res.distances[i]
            for j in range(1, len(d)):
                if d[j] == d[j - 1]:
                    assert res.ids[i][j] > res.ids[i][j - 1]
    # the coincident inserts dominate the at-site row, lowest ids first
    assert want[0][0].tolist() == sorted(dup)[:4]


def test_seed_underestimate_escalates_ladder(monkeypatch):
    """A pathologically small CDF seed is a performance event, not a
    correctness one: the doubling backstop walks extra rungs and the result
    still matches the host loop exactly."""
    idx = _index("device")
    monkeypatch.setattr(
        qexec, "knn_seed_radii",
        lambda snap, w, k: np.full(np.asarray(w).shape[0], 1e-6))
    pts = _pts(seed=13, n=8)
    res = idx.query(QueryBatch.knn(pts, k=4, backend="device"))
    rank = res.stages[-1]
    assert rank.rungs > 1 and rank.seed_hits < len(pts)
    for i, q in enumerate(pts):
        hi, _ = host_knn(idx.glin, q, 4)
        np.testing.assert_array_equal(res.ids[i], np.asarray(hi, np.int64))


def test_knn_topk_pallas_matches_sort():
    import dataclasses
    idx = _index("device")
    pts = _pts(seed=17, n=8)
    base = idx.config
    try:
        idx.config = dataclasses.replace(base, knn_topk="sort")
        a = idx.query(QueryBatch.knn(pts, k=6, backend="device"))
        idx.config = dataclasses.replace(base, knn_topk="pallas")
        b = idx.query(QueryBatch.knn(pts, k=6, backend="device"))
    finally:
        idx.config = base
    assert "topk=sort" in a.stages[-1].note
    assert "topk=pallas" in b.stages[-1].note
    for i in range(len(pts)):
        np.testing.assert_array_equal(a.ids[i], b.ids[i])
        np.testing.assert_array_equal(a.distances[i], b.distances[i])


@pytest.mark.parametrize("backend", ["device", "sharded"])
def test_no_host_candidate_gather(backend):
    """THE device-complete assertion: once warm, ranking never materialises a
    candidate's vertices on the host — GeometrySet.padded (the only dense
    host gather) must not run during the query."""
    idx = _index("sharded" if backend == "sharded" else "device")
    pts = _pts(seed=19, n=8)
    idx.query(QueryBatch.knn(pts, k=5, backend=backend))   # warm + publish
    want = idx.query(QueryBatch.knn(pts, k=5, backend="host"))

    def boom(self, ids):
        raise AssertionError("host candidate gather during device knn")

    orig = GeometrySet.padded
    GeometrySet.padded = boom
    try:
        res = idx.query(QueryBatch.knn(_pts(seed=23, n=8), k=5,
                                       backend=backend))
        res2 = idx.query(QueryBatch.knn(pts, k=5, backend=backend))
    finally:
        GeometrySet.padded = orig
    assert res.stages[-1].stage == "knn-rank"
    for i in range(len(pts)):
        np.testing.assert_array_equal(res2.ids[i], want.ids[i])


def test_server_submit_knn_flush_cache_and_stages():
    """kNN through the serving tier: one flush = one device-complete batch
    per distinct k, duplicate points coalesce, repeats hit the result cache,
    and knn-rank telemetry surfaces in stats()["engine_stages"]."""
    from repro.serve.server import SpatialQueryServer

    idx = _index("device")
    idx.snapshot()
    srv = SpatialQueryServer(idx)
    pts = _pts(seed=29, n=6)
    ref = idx.query(QueryBatch.knn(pts, k=3, backend="device"))
    tickets = [srv.submit_knn(p, 3) for p in pts]
    dup = srv.submit_knn(pts[0], 3)
    out = srv.flush()
    for i, t in enumerate(tickets):
        ids, dists = out[t]
        np.testing.assert_array_equal(ids, ref.ids[i])
        np.testing.assert_allclose(dists, ref.distances[i])
    np.testing.assert_array_equal(out[dup][0], ref.ids[0])
    assert srv.coalesced >= 1
    t2 = srv.submit_knn(pts[1], 3)          # repeat -> result cache
    ids, dists = srv.flush()[t2]
    np.testing.assert_array_equal(ids, ref.ids[1])
    assert srv.cache_hits >= 1
    ent = srv.stats()["engine_stages"]["device"]["knn-rank"]
    assert ent["calls"] >= 1 and ent["rungs"] >= 1 and ent["rung_hist"]
    assert "knn-rank" in idx.explain(QueryBatch.knn(pts, k=3))


@pytest.mark.property
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 3, 8]))
def test_knn_device_matches_host_property(seed, k):
    idx = _index("device")
    rng = np.random.default_rng(seed)
    pts = _fp32(rng.uniform(0.1, 0.9, (6, 2)))
    dev = idx.query(QueryBatch.knn(pts, k=k, backend="device"))
    hst = idx.query(QueryBatch.knn(pts, k=k, backend="host"))
    for i in range(len(pts)):
        np.testing.assert_array_equal(dev.ids[i], hst.ids[i])
        np.testing.assert_allclose(dev.distances[i], hst.distances[i],
                                   rtol=2e-4, atol=1e-7)
