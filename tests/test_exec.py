"""Staged execution pipeline (core.exec): every backend's compiled plan is
oracle-identical on the mixed convex/concave/polyline/point store AND reports
consistent per-stage telemetry (survivor counts, overflow-ladder escalations,
delta sizes); the shared complement-finish stage answers exactly at the
frozen epoch under concurrent writers; explain() renders without executing."""
import os
import pathlib
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.core import exec as qexec
from repro.core.datasets import generate, make_query_windows
from repro.core.engine import EngineConfig, QueryBatch, SpatialIndex
from repro.core.geometry import mbrs_of_verts
from repro.core.index import GLINConfig
from repro.core.relations import get_relation

ROOT = pathlib.Path(__file__).resolve().parents[1]

RELATIONS = ("contains", "intersects", "within", "covers", "disjoint",
             "touches", "crosses", "dwithin:0.003")


def _mixed(n=3000, pl=250, seed=2, **eng):
    """fp32-representable MIXED store (convex/concave polygons, polylines,
    points): host fp64 and device fp32 refinement decide identically, so one
    oracle serves every backend."""
    gs = generate("mixed", n, seed=seed)
    gs.verts = gs.verts.astype(np.float32).astype(np.float64)
    gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
    cfg = EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1, **eng)
    return SpatialIndex.build(gs, GLINConfig(piece_limitation=pl), config=cfg)


def _windows(idx, sel=0.01, k=4, seed=3):
    w = make_query_windows(idx.gs, sel, k, seed=seed)
    return w.astype(np.float32).astype(np.float64)


def _oracle(idx, w, relation, dtype=np.float32):
    rel = get_relation(relation)
    gs = idx.gs
    ok = rel.predicate(np.asarray(w, dtype), gs.verts.astype(dtype),
                       gs.nverts, gs.kinds)
    live = idx.glin._live_mask()
    return np.nonzero(np.asarray(ok) & live)[0].astype(np.int64)


def _check_stage_telemetry(res):
    """Structural invariants every executed window pipeline must satisfy."""
    assert res.stages, "QueryResult.stages missing"
    order = {s: i for i, s in enumerate(qexec.PIPELINE_STAGES)}
    covered = [c for s in res.stages for c in s.covers]
    ranks = [order[c] for c in covered]
    assert ranks == sorted(ranks) and len(set(ranks)) == len(ranks), covered
    assert covered[:3] == ["probe", "compact", "refine"], covered
    producing = [s for s in res.stages if not s.skipped and s.survivors >= 0]
    assert producing, [s.stage for s in res.stages]
    assert producing[-1].survivors == res.total_hits
    for s in res.stages:
        assert s.wall_ms >= 0.0
        if s.skipped:
            assert s.note, f"skipped stage {s.stage} gives no reason"


# ------------------------------------------------------------ stage parity --
@pytest.mark.parametrize("relation", RELATIONS)
@pytest.mark.parametrize("backend", ["host", "device"])
def test_stage_parity_mixed_store(backend, relation):
    idx = _mixed()
    wins = _windows(idx)
    res = idx.query(wins, relation, backend=backend)
    for qi, w in enumerate(wins):
        np.testing.assert_array_equal(res[qi], _oracle(idx, w, relation))
    _check_stage_telemetry(res)
    refine = {s.stage: s for s in res.stages}["refine"]
    assert refine.impl == backend
    assert refine.queries == len(wins)


def test_backends_report_identical_survivor_counts():
    """Same frozen store, same windows: host and device pipelines must agree
    on the ids AND on the telemetry that describes them — the final stage's
    survivor count is a backend-independent fact."""
    idx = _mixed()
    wins = _windows(idx, sel=0.02)
    for relation in ("intersects", "disjoint", "dwithin:0.003"):
        h = idx.query(wins, relation, backend="host")
        d = idx.query(wins, relation, backend="device")
        for a, b in zip(h, d):
            np.testing.assert_array_equal(a, b)
        hs = [s.survivors for s in h.stages if not s.skipped][-1]
        ds = [s.survivors for s in d.stages if not s.skipped][-1]
        assert hs == ds == h.total_hits == d.total_hits


def test_complement_stage_skipped_vs_active():
    """The complement-finish stage is compiled into every window pipeline but
    must no-op (with a stated reason) for plain relations and fire exactly
    once for complements, fixing the per-query hit counts."""
    idx = _mixed()
    wins = _windows(idx)
    plain = idx.query(wins, "intersects", backend="host")
    comp = idx.query(wins, "disjoint", backend="host",
                     collect_stats=True)
    p = {s.stage: s for s in plain.stages}["complement-finish"]
    c = {s.stage: s for s in comp.stages}["complement-finish"]
    assert p.skipped and p.note
    assert not c.skipped and c.impl == "shared"
    assert c.survivors == comp.total_hits
    for st, ids in zip(comp.stats, comp.ids):
        assert st.results == len(ids)


# ------------------------------------------------------- ladder telemetry ---
def test_ladder_escalations_surface_in_stage_stats():
    """A tiny exact_budget forces the shared OverflowLadder to escalate; the
    refine StageStats must report the retries and the settled budget, and
    SpatialIndex.stats() must aggregate them."""
    idx = _mixed(initial_cap=1 << 14, exact_budget=8)
    wins = _windows(idx, sel=0.02)
    res = idx.query(wins, "intersects", backend="device")
    for qi, w in enumerate(wins):
        np.testing.assert_array_equal(res[qi], _oracle(idx, w, "intersects"))
    refine = {s.stage: s for s in res.stages}["refine"]
    assert refine.escalations >= 1
    assert refine.budget == 0 or refine.budget > 8  # grew or went dense
    assert refine.cap >= 1 << 14
    agg = idx.stats()["stages"]["device"]["refine"]
    assert agg["escalations"] >= refine.escalations
    assert agg["calls"] >= 1 and agg["wall_ms"] > 0.0


# ------------------------------------------------------ delta-patch stage ---
def test_delta_patch_stage_stats_and_parity():
    """Writes after a publish route through device+delta: the shared patch
    stage reports the frozen delta's size and the patched ids equal the host
    oracle's."""
    idx = _mixed(refresh_threshold=10_000, delta_patch_max=4096)
    idx.snapshot()
    wins = _windows(idx, sel=0.02)
    rng = np.random.default_rng(9)
    for _ in range(3):
        c = rng.uniform(0.3, 0.7, 2)
        ang = np.sort(rng.uniform(0, 2 * np.pi, 8))
        v = np.stack([c[0] + 1e-3 * np.cos(ang),
                      c[1] + 1e-3 * np.sin(ang)], -1)
        idx.insert(v.astype(np.float32).astype(np.float64), 8, 0)
    assert idx.delete(0)
    res = idx.query(wins, "intersects")   # planner: stale + small delta
    assert res.plan.backend == "device+delta"
    patch = {s.stage: s for s in res.stages}["delta-patch"]
    assert not patch.skipped and patch.impl == "shared"
    assert patch.delta_added == 3 and patch.delta_tombstoned == 1
    host = idx.query(wins, "intersects", backend="host")
    for a, b in zip(res, host):
        np.testing.assert_array_equal(a, b)
    _check_stage_telemetry(res)


# ------------------------------------------------------------- knn stages ---
def test_knn_pipelines_compose_knn_rank():
    idx = _mixed()
    rng = np.random.default_rng(11)
    pts = rng.uniform(0.2, 0.8, (20, 2)).astype(np.float32).astype(np.float64)
    res = idx.query(QueryBatch.knn(pts, k=5))
    assert res.stages and res.stages[-1].stage == "knn-rank"
    assert "knn-rank" in res.stages[-1].covers
    assert res.distances is not None and len(res.distances) == len(pts)
    one = idx.query(QueryBatch.knn(pts[:1], k=5))
    assert one.stages[-1].stage == "knn-rank"
    np.testing.assert_array_equal(one[0], res[0])


# ----------------------------------------------------------------- explain --
def test_explain_renders_without_executing():
    idx = _mixed()
    wins = _windows(idx)
    txt = idx.explain(wins, "disjoint")
    assert "QueryPlan backend=" in txt and "reason:" in txt
    assert "refine" in txt and "complement-finish" in txt
    assert "probe+compact+refine" in txt
    assert idx.stats()["stages"] == {}  # nothing ran


def test_stats_aggregate_per_backend_per_stage():
    idx = _mixed()
    wins = _windows(idx)
    idx.query(wins, "intersects", backend="host")
    idx.query(wins, "intersects", backend="host")
    idx.query(wins, "disjoint", backend="device")
    st = idx.stats()["stages"]
    assert st["host"]["refine"]["calls"] == 2
    assert st["host"]["refine"]["impl"] == "host"
    assert st["device"]["refine"]["calls"] == 1
    assert st["device"]["complement-finish"]["calls"] == 1
    assert st["device"]["complement-finish"]["skipped"] == 0


# --------------------------------------- complement vs concurrent writers ---
def test_complement_finish_exact_at_frozen_epoch_under_writes(monkeypatch):
    """Satellite regression: the device pipeline freezes the live-id set
    under the lock BEFORE its unlocked device compute; records inserted
    while the compute runs must NOT leak into a complement answer (they are
    disjoint from the window, so a non-frozen live set would include them)."""
    import repro.core.engine as eng

    idx = _mixed(n=1500)
    idx.snapshot()
    w = np.array([0.4, 0.4, 0.6, 0.6], np.float32).astype(np.float64)
    base = idx.query(w[None], "intersects", backend="host")[0]
    live0 = np.nonzero(idx.glin._live_mask())[0].astype(np.int64)

    entered, release = threading.Event(), threading.Event()
    real = eng.batch_query

    def slow(*a, **kw):
        entered.set()
        release.wait(10.0)   # hold the freeze->finish window open
        return real(*a, **kw)

    monkeypatch.setattr(eng, "batch_query", slow)
    inserted = []

    def writer():
        entered.wait(10.0)
        rng = np.random.default_rng(13)
        for _ in range(5):   # far from the window -> in its complement
            c = rng.uniform(0.9, 0.95, 2)
            ang = np.sort(rng.uniform(0, 2 * np.pi, 8))
            v = np.stack([c[0] + 5e-4 * np.cos(ang),
                          c[1] + 5e-4 * np.sin(ang)], -1)
            inserted.append(idx.insert(
                v.astype(np.float32).astype(np.float64), 8, 0))
        release.set()

    t = threading.Thread(target=writer)
    t.start()
    try:
        res = idx.query(w[None], "disjoint", backend="device")
    finally:
        release.set()
        t.join(10.0)
    assert len(inserted) == 5, "writer never ran inside the compute window"
    assert not np.isin(inserted, res[0]).any(), \
        "mid-flight inserts leaked into the frozen complement"
    np.testing.assert_array_equal(res[0], np.setdiff1d(live0, base))
    fin = {s.stage: s for s in res.stages}["complement-finish"]
    assert not fin.skipped and fin.survivors == len(res[0])


# --------------------------------------------------------------- sharded ----
def _run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_sharded_pipeline_parity_and_telemetry():
    """The sharded backend routes through the SAME staged pipeline: refine
    impl 'sharded', the shared patch/complement stages downstream, results
    equal to the host pipeline's on the mixed store (8 fake CPU devices)."""
    out = _run_py("""
        import numpy as np
        from repro.utils.compat import make_auto_mesh
        mesh = make_auto_mesh((4, 2), ("data", "model"))
        from repro.core.datasets import generate, make_query_windows
        from repro.core.geometry import mbrs_of_verts
        from repro.core.index import GLIN, GLINConfig
        from repro.core.engine import EngineConfig, SpatialIndex

        gs = generate("mixed", 4000, seed=2)
        gs.verts = gs.verts.astype(np.float32).astype(np.float64)
        gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
        idx = SpatialIndex(
            GLIN.build(gs, GLINConfig(piece_limitation=300)),
            EngineConfig(mesh=mesh, device_min_batch=1,
                         stale_rebuild_min_batch=1, shard_min_records=1))
        wins = make_query_windows(gs, 0.02, 8, seed=5)
        wins = wins.astype(np.float32).astype(np.float64)
        for rel in ("intersects", "disjoint", "dwithin:0.002"):
            s = idx.query(wins, rel, backend="sharded")
            h = idx.query(wins, rel, backend="host")
            for a, b in zip(s, h):
                assert np.array_equal(a, b), rel
            stages = {st.stage: st for st in s.stages}
            assert stages["refine"].impl == "sharded"
            assert stages["refine"].covers == ("probe", "compact", "refine")
            last = [st for st in s.stages if not st.skipped][-1]
            assert last.survivors == s.total_hits
        agg = idx.stats()["stages"]["sharded"]["refine"]
        assert agg["calls"] == 3 and agg["wall_ms"] > 0.0
        print("EXEC-SHARDED-OK")
    """)
    assert "EXEC-SHARDED-OK" in out
