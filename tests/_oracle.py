"""Brute-force reference oracle for every registered relation.

Scalar, O(N·V) pure-NumPy/Python loops over records, vertices and window
corners — deliberately written as straight-line textbook geometry (orientation
tests, per-edge point-in-polygon, per-corner distances) rather than the
vectorized array-namespace code in ``repro.core.geometry``, so the two can
check each other. The parity tests assert that the host, device, and
device+delta query paths all reproduce this oracle on mixed
convex/concave/polyline stores.

``oracle_query(window, gs_arrays, relation)`` mirrors the public relation
semantics, including ``disjoint`` as a complement over live records and the
parametric ``dwithin:<d>`` family.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.geometry import GeomKind

__all__ = ["oracle_record", "oracle_query", "mixed_store"]


# ---------------------------------------------------------------------------
# Scalar primitives
# ---------------------------------------------------------------------------
def _edges(ring, kind):
    """Edge list of one record: closed ring for polygons, open chain for
    polylines (single-vertex records have no edges)."""
    n = len(ring)
    if kind == int(GeomKind.POLYGON):
        return [(ring[i], ring[(i + 1) % n]) for i in range(n)]
    return [(ring[i], ring[i + 1]) for i in range(n - 1)]


def _pt_in_rect(p, rect, strict=False):
    if strict:
        return rect[0] < p[0] < rect[2] and rect[1] < p[1] < rect[3]
    return rect[0] <= p[0] <= rect[2] and rect[1] <= p[1] <= rect[3]


def _orient(a, b, c):
    return (b[0] - a[0]) * (c[1] - a[1]) - (b[1] - a[1]) * (c[0] - a[0])


def _on_segment(a, b, c):
    """c collinear with a-b assumed; is c within the segment's bbox?"""
    return (min(a[0], b[0]) <= c[0] <= max(a[0], b[0])
            and min(a[1], b[1]) <= c[1] <= max(a[1], b[1]))


def _segments_intersect(a, b, c, d):
    """Closed segment intersection via orientation tests."""
    o1, o2 = _orient(a, b, c), _orient(a, b, d)
    o3, o4 = _orient(c, d, a), _orient(c, d, b)
    if o1 == 0 and _on_segment(a, b, c):
        return True
    if o2 == 0 and _on_segment(a, b, d):
        return True
    if o3 == 0 and _on_segment(c, d, a):
        return True
    if o4 == 0 and _on_segment(c, d, b):
        return True
    return ((o1 > 0) != (o2 > 0)) and ((o3 > 0) != (o4 > 0)) \
        and o1 != 0 and o2 != 0 and o3 != 0 and o4 != 0


def _rect_edges(rect):
    c = [(rect[0], rect[1]), (rect[2], rect[1]),
         (rect[2], rect[3]), (rect[0], rect[3])]
    return [(c[i], c[(i + 1) % 4]) for i in range(4)]


def _seg_meets_rect(a, b, rect):
    """Closed segment vs closed rect."""
    if _pt_in_rect(a, rect) or _pt_in_rect(b, rect):
        return True
    return any(_segments_intersect(a, b, c, d) for c, d in _rect_edges(rect))


def _seg_meets_open_rect(a, b, rect):
    """Does the segment meet the rect's OPEN interior? Clip the parameter
    interval against the closed rect and test the midpoint strictly (a chord
    of a convex set that is not contained in the boundary has a strictly
    interior midpoint)."""
    t0, t1 = 0.0, 1.0
    dx, dy = b[0] - a[0], b[1] - a[1]
    for p, q in ((-dx, a[0] - rect[0]), (dx, rect[2] - a[0]),
                 (-dy, a[1] - rect[1]), (dy, rect[3] - a[1])):
        if p == 0:
            if q < 0:
                return False
        else:
            r = q / p
            if p < 0:
                t0 = max(t0, r)
            else:
                t1 = min(t1, r)
    if t0 > t1:
        return False
    t = (t0 + t1) * 0.5
    return _pt_in_rect((a[0] + t * dx, a[1] + t * dy), rect, strict=True)


def _pt_in_ring(p, ring):
    """Even-odd ray cast -> (odd_crossings, on_boundary)."""
    odd = on = False
    px, py = p
    n = len(ring)
    for i in range(n):
        x1, y1 = ring[i]
        x2, y2 = ring[(i + 1) % n]
        if (min(x1, x2) <= px <= max(x1, x2)
                and min(y1, y2) <= py <= max(y1, y2)
                and _orient((x1, y1), (x2, y2), p) == 0):
            on = True
        if (y1 > py) != (y2 > py):
            xint = x1 + (py - y1) * (x2 - x1) / (y2 - y1)
            if px < xint:
                odd = not odd
    return odd, on


def _pt_rect_dist(p, rect):
    dx = max(rect[0] - p[0], p[0] - rect[2], 0.0)
    dy = max(rect[1] - p[1], p[1] - rect[3], 0.0)
    return math.hypot(dx, dy)


def _pt_seg_dist(p, a, b):
    dx, dy = b[0] - a[0], b[1] - a[1]
    ll = dx * dx + dy * dy
    if ll == 0:
        return math.hypot(p[0] - a[0], p[1] - a[1])
    t = ((p[0] - a[0]) * dx + (p[1] - a[1]) * dy) / ll
    t = min(1.0, max(0.0, t))
    return math.hypot(p[0] - (a[0] + t * dx), p[1] - (a[1] + t * dy))


def _corners(rect, center=False):
    pts = [(rect[0], rect[1]), (rect[2], rect[1]),
           (rect[2], rect[3]), (rect[0], rect[3])]
    if center:
        pts.append(((rect[0] + rect[2]) * 0.5, (rect[1] + rect[3]) * 0.5))
    return pts


# ---------------------------------------------------------------------------
# Per-record relation semantics
# ---------------------------------------------------------------------------
def _intersects(rect, ring, kind):
    for a, b in _edges(ring, kind):
        if _seg_meets_rect(a, b, rect):
            return True
    if any(_pt_in_rect(v, rect) for v in ring):   # single-vertex records
        return True
    if kind == int(GeomKind.POLYGON):
        for c in _corners(rect):
            odd, on = _pt_in_ring(c, ring)
            if odd or on:
                return True
    return False


def _covers(rect, ring, kind):
    return all(_pt_in_rect(v, rect) for v in ring)


def _contains(rect, ring, kind):
    if not _covers(rect, ring, kind):
        return False
    if any(_pt_in_rect(v, rect, strict=True) for v in ring):
        return True
    for a, b in _edges(ring, kind):
        m = ((a[0] + b[0]) * 0.5, (a[1] + b[1]) * 0.5)
        if _pt_in_rect(m, rect, strict=True):
            return True
    if kind == int(GeomKind.POLYGON):
        mean = (sum(v[0] for v in ring) / len(ring),
                sum(v[1] for v in ring) / len(ring))
        if _pt_in_rect(mean, rect, strict=True):
            return True
    return False


def _within(rect, ring, kind):
    if kind != int(GeomKind.POLYGON):
        return False
    for c in _corners(rect, center=True):
        odd, on = _pt_in_ring(c, ring)
        if not (odd or on):
            return False
    return not any(_seg_meets_open_rect(a, b, rect)
                   for a, b in _edges(ring, kind))


def _interior_intersects(rect, ring, kind):
    for a, b in _edges(ring, kind):
        if _seg_meets_open_rect(a, b, rect):
            return True
    if len(ring) == 1 and _pt_in_rect(ring[0], rect, strict=True):
        return True   # point-like record: its interior is itself
    if kind == int(GeomKind.POLYGON):
        cc = ((rect[0] + rect[2]) * 0.5, (rect[1] + rect[3]) * 0.5)
        odd, on = _pt_in_ring(cc, ring)
        if odd and not on:
            return True
    return False


def _touches(rect, ring, kind):
    return _intersects(rect, ring, kind) \
        and not _interior_intersects(rect, ring, kind)


def _crosses(rect, ring, kind):
    if kind != int(GeomKind.POLYLINE):
        return False
    return _interior_intersects(rect, ring, kind) \
        and not _covers(rect, ring, kind)


def _dwithin(rect, ring, kind, dist):
    if _intersects(rect, ring, kind):
        return True
    d = min(_pt_rect_dist(v, rect) for v in ring)
    for a, b in _edges(ring, kind):
        for c in _corners(rect):
            d = min(d, _pt_seg_dist(c, a, b))
    return d <= dist


_ORACLES = {
    "intersects": _intersects,
    "covers": _covers,
    "contains": _contains,
    "within": _within,
    "touches": _touches,
    "crosses": _crosses,
}


def oracle_record(relation, rect, ring, kind):
    """One record against one window; ``relation`` may be ``dwithin:<d>``."""
    if relation == "disjoint":
        return not _intersects(rect, ring, kind)
    if relation.startswith("dwithin:"):
        return _dwithin(rect, ring, kind, float(relation.partition(":")[2]))
    return _ORACLES[relation](rect, ring, kind)


def oracle_query(window, verts, nverts, kinds, relation, live=None):
    """Sorted record ids whose geometry satisfies ``relation`` with
    ``window``. All arithmetic is scalar float64 over the given arrays (cast
    them to float32 and back for fp32-contract comparisons)."""
    rect = tuple(float(v) for v in np.asarray(window))
    out = []
    n = len(nverts)
    for rec in range(n):
        if live is not None and not live[rec]:
            continue
        nv = int(nverts[rec])
        ring = [(float(verts[rec, i, 0]), float(verts[rec, i, 1]))
                for i in range(nv)]
        if oracle_record(relation, rect, ring, int(kinds[rec])):
            out.append(rec)
    return np.asarray(out, np.int64)


# ---------------------------------------------------------------------------
# Mixed store builder (convex polygons + concave rings + polylines + points)
# ---------------------------------------------------------------------------
def mixed_store(n, seed=0, fp32_exact=True):
    """The heavy-tailed ``mixed`` dataset family (points + short polylines +
    convex polygons + 64-vertex rings in one CSR pool), with
    fp32-representable coordinates by default so the fp64 host and fp32
    device paths decide the same geometric configurations."""
    from repro.core.datasets import generate
    from repro.core.geometry import mbrs_of_verts

    gs = generate("mixed", n, seed=seed)
    if fp32_exact:
        # round-trip the pool through fp32 via the dense compatibility view
        # (re-imports into the pool) and recompute MBRs to match
        gs.verts = gs.verts.astype(np.float32).astype(np.float64)
        gs.mbrs = mbrs_of_verts(gs.verts, gs.nverts)
    return gs
