"""Fault tolerance: crash/resume through the real launcher, atomic commits,
async checkpointing, deterministic data pipeline."""
import os
import pathlib
import subprocess
import sys
import tempfile

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run_train(args, expect_rc=0):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-m", "repro.launch.train", *args],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == expect_rc, (r.returncode, r.stdout, r.stderr[-3000:])
    return r.stdout


def test_crash_and_resume():
    with tempfile.TemporaryDirectory() as d:
        common = ["--arch", "granite_3_2b", "--reduced", "--steps", "24",
                  "--batch", "4", "--seq", "32", "--ckpt-dir", d,
                  "--ckpt-every", "5", "--log-every", "4"]
        out1 = _run_train([*common, "--simulate-failure-at", "13"],
                          expect_rc=42)
        assert "simulating crash at step 13" in out1
        from repro.ckpt import checkpoint as ckpt
        resumed_from = ckpt.latest_step(d)
        assert resumed_from is not None and 5 <= resumed_from <= 13
        out2 = _run_train([*common, "--resume"])
        assert f"resumed from step {resumed_from}" in out2
        assert "step=23" in out2
        assert ckpt.latest_step(d) == 24


def test_atomic_commit_ignores_partial():
    from repro.ckpt import checkpoint as ckpt
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, {"x": np.arange(4.0)})
        # simulate a torn write: stale tmp dir + LATEST pointing at garbage
        (pathlib.Path(d) / ".tmp_step_000000009").mkdir()
        assert ckpt.latest_step(d) == 3
        step, tree = ckpt.restore(d, {"x": np.zeros(4, np.float64)})
        assert step == 3 and np.array_equal(tree["x"], np.arange(4.0))


def test_async_checkpoint_and_overwrite():
    from repro.ckpt import checkpoint as ckpt
    with tempfile.TemporaryDirectory() as d:
        f1 = ckpt.save_async(d, 1, {"x": np.ones(8)})
        f2 = ckpt.save_async(d, 2, {"x": np.ones(8) * 2})
        f1.result(); f2.result()
        assert ckpt.latest_step(d) == 2
        # same-step overwrite replaces content atomically
        ckpt.save(d, 2, {"x": np.ones(8) * 5})
        _, t = ckpt.restore(d, {"x": np.zeros(8)})
        assert np.all(np.asarray(t["x"]) == 5)


def test_pipeline_determinism_and_sharding():
    from repro.data.pipeline import SyntheticLM
    a = SyntheticLM(vocab=97, seq_len=16, batch=8, seed=3)
    b = SyntheticLM(vocab=97, seq_len=16, batch=8, seed=3)
    for step in (0, 5, 1000):
        ba, bb = a.batch_at(step), b.batch_at(step)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a.batch_at(0)["tokens"][:, 1:],
                                  a.batch_at(0)["labels"][:, :-1])
    # host sharding: different hosts, different data; deterministic per host
    h0 = SyntheticLM(97, 16, 8, seed=3, host_id=0, num_hosts=2)
    h1 = SyntheticLM(97, 16, 8, seed=3, host_id=1, num_hosts=2)
    assert h0.local_batch == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"])


def test_prefetcher_orders_and_closes():
    from repro.data.pipeline import Prefetcher, SyntheticLM
    src = SyntheticLM(vocab=31, seq_len=8, batch=2, seed=0)
    pf = Prefetcher(src, start_step=5, depth=2)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_latest_pointer_is_monotonic():
    """Regression: a slow async save finishing after a newer save must not
    swing LATEST back to an older step (trainer's final sync save used to
    race the in-flight background save under load)."""
    from repro.ckpt import checkpoint as ckpt
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 24, {"x": np.arange(4.0)})
        ckpt.save(d, 20, {"x": np.zeros(4)})  # late out-of-order commit
        assert ckpt.latest_step(d) == 24
        # the older step is still restorable explicitly
        step, tree = ckpt.restore(d, {"x": np.zeros(4)}, step=20)
        assert step == 20 and np.array_equal(tree["x"], np.zeros(4))
        # same-step overwrite still moves the pointer's content
        ckpt.save(d, 24, {"x": np.ones(4)})
        _, tree = ckpt.restore(d, {"x": np.zeros(4)})
        assert np.array_equal(tree["x"], np.ones(4))
