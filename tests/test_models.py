"""Per-arch smoke tests (reduced configs) + decode/train consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as tf
from repro.models import moe as moe_mod
from repro.sharding import constrain

LM_ARCHS = [a for a in ARCH_IDS if a != "glin"]


def _batch(cfg, b, s, rng, with_labels=True):
    out = {}
    if cfg.frontend == "embed_stub":
        out["embeds"] = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)),
                                    jnp.float32)
        if cfg.mrope:
            out["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, None, :], (b, 3, s))
    else:
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
    if with_labels:
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))
    return out


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_step(arch):
    """Reduced config: one forward + one train step, shapes + finiteness."""
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(0)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 64
    batch = _batch(cfg, b, s, rng)
    logits, _ = tf.forward_train(params, cfg, batch, constrain, remat=False)
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(tf.loss_fn)(params, cfg, batch, constrain,
                                                 remat=True)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ["phi4_mini_3p8b", "granite_34b",
                                  "mamba2_2p7b", "hymba_1p5b",
                                  "mixtral_8x22b", "qwen3_moe_235b",
                                  "qwen2_vl_2b", "musicgen_medium"])
def test_decode_matches_full_forward(arch):
    """KV/SSM-cache decode must reproduce the full forward exactly."""
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(1)
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    b, s, extra = 2, 40, 6
    toks = rng.integers(0, cfg.vocab, (b, s + extra))
    embeds = rng.standard_normal((b, s + extra, cfg.d_model)).astype(np.float32)

    def mk(upto):
        if cfg.frontend == "embed_stub":
            out = {"embeds": jnp.asarray(embeds[:, :upto])}
            if cfg.mrope:
                out["positions"] = jnp.broadcast_to(
                    jnp.arange(upto, dtype=jnp.int32)[None, None, :], (b, 3, upto))
            return out
        return {"tokens": jnp.asarray(toks[:, :upto])}

    last, cache = tf.prefill(params, cfg, mk(s), constrain,
                             seq_len_cache=s + extra)
    full, _ = tf.forward_train(params, cfg, mk(s), constrain, remat=False)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=1e-3)
    for t in range(extra):
        if cfg.frontend == "embed_stub":
            db = {"embeds": jnp.asarray(embeds[:, s + t])}
        else:
            db = {"tokens": jnp.asarray(toks[:, s + t])}
        dec, cache = tf.decode_step(params, cfg, db, cache, constrain)
        full, _ = tf.forward_train(params, cfg, mk(s + t + 1), constrain,
                                   remat=False)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                                   atol=5e-4, rtol=1e-2)


def test_remat_matches_no_remat():
    cfg = get_arch("granite_3_2b").reduced()
    rng = np.random.default_rng(2)
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, 2, 32, rng)
    l1 = tf.loss_fn(params, cfg, batch, constrain, remat=False)
    l2 = tf.loss_fn(params, cfg, batch, constrain, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_moe_matches_per_token_oracle():
    """Sort-based dispatch == explicit per-token expert loop (no drops)."""
    cfg = get_arch("mixtral_8x22b").reduced()
    rng = np.random.default_rng(3)
    params = tf.init_params(cfg, jax.random.PRNGKey(3))
    p = jax.tree_util.tree_map(lambda x: x[0], params["blocks"]["moe"])
    b, s, d = 2, 16, cfg.d_model
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    y = moe_mod.moe_ffn(x, p, cfg, constrain, capacity_factor=8.0)

    # oracle
    xf = np.asarray(x).reshape(-1, d)
    logits = xf @ np.asarray(p["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    y_ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[: cfg.top_k]
        gates = probs[t, top] / probs[t, top].sum()
        for e, g in zip(top, gates):
            wg = np.asarray(p["wg"][e], np.float64)
            wu = np.asarray(p["wu"][e], np.float64)
            wd = np.asarray(p["wd"][e], np.float64)
            h = (xf[t] @ wg)
            h = h / (1 + np.exp(-h)) * (xf[t] @ wu)
            y_ref[t] += g * (h @ wd)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, d), y_ref,
                               atol=2e-3, rtol=2e-2)


def test_training_reduces_loss():
    """~60 steps on the structured synthetic stream must reduce loss."""
    from repro.data.pipeline import SyntheticLM
    from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

    cfg = get_arch("granite_3_2b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(4))
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    src = SyntheticLM(cfg.vocab, 64, 8, seed=5)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(tf.loss_fn)(params, cfg, batch,
                                                     constrain, remat=False)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
        return params, opt, loss

    losses = []
    for i in range(60):
        b = src.batch_at(i)
        params, opt, loss = step(params, opt,
                                 {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, losses[::10]


def test_param_counts_match_published():
    expect = {"hymba_1p5b": 1.6e9, "qwen2_vl_2b": 1.5e9,
              "codeqwen1p5_7b": 8.2e9, "phi4_mini_3p8b": 3.8e9,
              "granite_34b": 34e9, "granite_3_2b": 2.5e9,
              "musicgen_medium": 1.4e9, "mixtral_8x22b": 141e9,
              "qwen3_moe_235b": 235e9, "mamba2_2p7b": 2.7e9}
    for arch, n in expect.items():
        got = get_arch(arch).param_count()
        assert abs(got - n) / n < 0.12, (arch, got, n)
