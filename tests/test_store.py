"""CSR vertex-pool store invariants and maintenance-cost contracts.

The ragged store replaces the dense padded ``(N, V, 2)`` block: one flat
``(total_verts, 2)`` float64 pool plus per-record ``(offset, nverts)``.
These tests pin the layout invariants the rest of the stack leans on —
ring round-trips, padded-gather parity with the ``geometry.ragged_padded``
adapter, O(record width) insert cost, compaction semantics (bytes
reclaimed, ids stable, dead repointed in-bounds), the ``layout_version``
cache-key contract, and the jit-signature stability of a republish after
pool compaction (sticky pool/width floors in the engine).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.datasets import GeometrySet, generate, make_query_windows
from repro.core.engine import EngineConfig, SpatialIndex
from repro.core.geometry import ragged_padded
from repro.core.index import GLINConfig

from _oracle import mixed_store


def _mixed(n=400, seed=0):
    return generate("mixed", n, seed=seed)


# ---------------------------------------------------------------------------
# CSR layout invariants
# ---------------------------------------------------------------------------
def test_csr_offsets_partition_the_pool():
    gs = _mixed()
    off, nv = gs.offsets, gs.nverts.astype(np.int64)
    assert gs.pool_len == int(nv.sum())
    assert gs.pool.shape == (gs.pool_len, 2)
    # freshly generated stores are densely packed in record order
    np.testing.assert_array_equal(off[1:], off[:-1] + nv[:-1])
    assert off[0] == 0
    # every ring stays in-bounds
    assert int((off + nv).max()) <= gs.pool_len


def test_ring_roundtrips_through_dense_view():
    gs = _mixed()
    dense = gs.verts                       # dense compatibility view
    assert dense.shape == (len(gs), gs.max_nverts, 2)
    for rec in (0, 7, len(gs) // 2, len(gs) - 1):
        nv = int(gs.nverts[rec])
        np.testing.assert_array_equal(gs.ring(rec), dense[rec, :nv])
        # padding repeats the last valid vertex
        np.testing.assert_array_equal(
            dense[rec, nv:], np.broadcast_to(dense[rec, nv - 1],
                                             (gs.max_nverts - nv, 2)))


def test_padded_subset_matches_ragged_padded_adapter():
    gs = _mixed()
    idx = np.asarray([3, 0, len(gs) - 1, 11, 11])   # repeats allowed
    for width in (None, 64, 128):
        want = gs.padded(idx, width=width)
        got = ragged_padded(gs.pool, gs.offsets[idx], gs.nverts[idx],
                            want.shape[1], xp=np)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_layout_version_tracks_rewrites_not_appends():
    """Device payload caches key on ``layout_version``: appends must NOT bump
    it (they only extend the pool), while compaction and dense re-import
    rewrite live pool contents and must."""
    gs = _mixed(200)
    lv = gs.layout_version
    pv = gs.pool_version
    gs.append(np.zeros((3, 2)) + 0.5, 3, 0)
    assert gs.layout_version == lv and gs.pool_version > pv
    gs.verts = gs.verts.copy()             # dense re-import rewrites the pool
    assert gs.layout_version == lv + 1
    gs.mark_dead(0)
    gs.compact()
    assert gs.layout_version == lv + 2


# ---------------------------------------------------------------------------
# Insert cost: O(record width), independent of store size
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [128, 8192])
def test_insert_moves_o_record_width_bytes(n):
    """REGRESSION (dense-era re-pad): appending one record used to rebuild
    the whole ``(N, V, 2)`` block when the new record was wider than the
    current padding — O(N*V) bytes per insert. Under the pool, an append
    with capacity available moves exactly the record's own bytes
    (w vertices * 16B + 45B of per-record metadata), for ANY store size and
    ANY width, including widths beyond the current maximum."""
    gs = _mixed(n)
    per_record_meta = 8 + 4 + 1 + 32       # offset + nverts + kind + mbr
    wide = gs.max_nverts * 4               # wider than anything in the store
    gs.reserve(len(gs) + 8, gs.pool_len + 8 * wide)
    for w in (1, 5, wide):
        ring = np.linspace(0.2, 0.4, 2 * w).reshape(w, 2)
        before = gs.bytes_moved
        gs.append(ring, w, 0)
        assert gs.bytes_moved - before == w * 16 + per_record_meta


def test_insert_amortized_without_reserve():
    """Without pre-reserving, doubling growth keeps TOTAL bytes moved over a
    burst linear in the payload actually appended (no per-insert re-pad)."""
    gs = _mixed(256)
    base = gs.bytes_moved
    payload = 0
    for i in range(500):
        w = 1 + (i % 9)
        gs.append(np.full((w, 2), 0.5), w, 0)
        payload += w * 16 + 45
    moved = gs.bytes_moved - base
    # doubling amortization: each byte is copied O(1) times on average
    assert moved < 4 * payload + gs.pool_len * 16


# ---------------------------------------------------------------------------
# Compaction
# ---------------------------------------------------------------------------
def test_compact_reclaims_bytes_and_keeps_ids_stable():
    gs = _mixed(300)
    # tombstone the widest decile so compaction visibly shrinks the pool
    victims = np.argsort(gs.nverts, kind="stable")[-30:]
    live = np.setdiff1d(np.arange(len(gs)), victims)
    rings_before = {int(r): gs.ring(int(r)).copy() for r in live[:50]}
    pool_before = gs.pool_len
    for r in victims:
        gs.mark_dead(int(r))
    assert gs.dead_count == len(victims)
    reclaimed = gs.compact()
    assert reclaimed > 0
    assert gs.pool_len < pool_before
    assert len(gs) == 300                  # ids stable: no renumbering
    for r, ring in rings_before.items():   # live rings byte-identical
        np.testing.assert_array_equal(gs.ring(r), ring)
    # dead records are repointed at finite, in-bounds placeholder storage
    np.testing.assert_array_equal(gs.offsets[victims], 0)
    np.testing.assert_array_equal(gs.nverts[victims], 1)
    assert gs.compact() == 0               # idempotent when nothing is dead


# ---------------------------------------------------------------------------
# The mixed (heavy-tailed) family
# ---------------------------------------------------------------------------
def test_mixed_family_is_heavy_tailed_and_pool_pays_off():
    gs = _mixed(2000)
    nv = gs.nverts
    assert int(nv.min()) == 1              # points
    assert int(nv.max()) == 64             # dense rings
    assert float(nv.mean()) < 16           # the tail is thin
    assert len(np.unique(gs.kinds)) >= 2   # polygons AND polylines
    # the headline the storage bench gates on: dense padding makes every
    # point pay for the 64-vertex rings
    assert gs.dense_nbytes() >= 2 * gs.nbytes()


# ---------------------------------------------------------------------------
# Republish after compaction keeps the jit signature (no recompile)
# ---------------------------------------------------------------------------
def test_republish_after_compaction_keeps_jit_signature():
    """Deletes + compacting republish must NOT change the shapes of the
    device payload or snapshot: the engine's sticky pool/width floors keep
    the padded pod pool, width ladder, and snapshot arrays bit-compatible
    with the compiled step, so the second publish re-uses the first
    publish's compiled ``batch_query`` entry."""
    from repro.core.device import batch_query

    gs = mixed_store(600, seed=3)
    idx = SpatialIndex.build(
        gs, GLINConfig(piece_limitation=10_000),
        EngineConfig(device_min_batch=1, stale_rebuild_min_batch=1,
                     initial_cap=8192, exact_budget=256,
                     delta_patch_max=4096, refresh_threshold=1 << 30))
    wins = make_query_windows(gs, 0.01, 6, seed=5)
    wins = wins.astype(np.float32).astype(np.float64)

    widest = np.argsort(gs.nverts, kind="stable")[::-1]
    for r in widest[:20]:
        idx.delete(int(r))
    idx.snapshot()                          # republish #1 compacts the pool
    res1 = idx.query(wins, "intersects", backend="device")
    host1 = idx.query(wins, "intersects", backend="host")
    pods1, mbrs1 = idx._payload
    shapes1 = (pods1.pool.shape, pods1.off.shape, pods1.nv.shape,
               pods1.bucket.shape, pods1.max_width, mbrs1.shape)
    cache1 = batch_query._cache_size()
    assert cache1 >= 1

    pool_after_first = idx.gs.pool_len
    for r in widest[20:60]:                 # second round of deletes
        idx.delete(int(r))
    idx.snapshot()                          # republish #2 compacts again
    assert idx.gs.pool_len < pool_after_first   # the pool really shrank
    res2 = idx.query(wins, "intersects", backend="device")
    host2 = idx.query(wins, "intersects", backend="host")
    pods2, mbrs2 = idx._payload
    shapes2 = (pods2.pool.shape, pods2.off.shape, pods2.nv.shape,
               pods2.bucket.shape, pods2.max_width, mbrs2.shape)
    assert shapes2 == shapes1               # sticky floors held every shape
    assert batch_query._cache_size() == cache1   # hence: no recompile

    # and the served results stay exact across both publishes
    for res, host in ((res1, host1), (res2, host2)):
        for a, b in zip(res, host):
            np.testing.assert_array_equal(a, b)


def test_snapshot_capture_compacts_store():
    gs = mixed_store(300, seed=1)
    idx = SpatialIndex.build(gs, GLINConfig(piece_limitation=10_000),
                             EngineConfig(refresh_threshold=1 << 30))
    for r in range(0, 30):
        idx.delete(r)
    assert idx.gs.dead_count == 30
    pool_before = idx.gs.pool_len
    idx.snapshot()
    # republish ran compaction: tombstoned rings left the pool, but the
    # records kept their ids (repointed, still masked out of results)
    assert idx.gs.pool_len < pool_before
    assert idx.gs.dead_count == 30
    got = idx.query(make_query_windows(gs, 0.05, 4, seed=2), "intersects")
    for hits in got:
        assert not set(hits.tolist()) & set(range(30))
